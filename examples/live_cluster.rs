//! Live cluster: the coordination logic under *real* concurrency.
//!
//! One OS thread per device, mpsc channels for model broadcast and
//! gradient upload, wall-clock epoch deadlines. The simulated §II-A
//! delays are slept out (scaled), so stragglers really do arrive after
//! the deadline and really are dropped by the gather loop — the same
//! Eq. 18/19 assembly as the DES coordinator, driven by actual message
//! arrival instead of a virtual clock. Both coordinators now build their
//! setup phase from the same `Session`, and the live run reports the
//! same `RunResult` the sweep engine renders (`cfl sweep --live`).
//!
//! The channel fleet here is one of two transports: the same session
//! runs over TCP sockets with real OS processes via `cfl serve` /
//! `cfl device` (see docs/ARCHITECTURE.md, "The transport layer").
//!
//! Run: `cargo run --release --example live_cluster`

use cfl::config::ExperimentConfig;
use cfl::coordinator::LiveCoordinator;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::small();
    cfg.nu_comp = 0.3;
    cfg.nu_link = 0.3;
    cfg.target_nmse = 0.0; // fixed epoch budget: we want straggler stats

    // first run: auto-calibrated grace (the ping/echo handshake measures
    // the channel-hop overhead), everything arrives; second run: larger
    // time scale + a pinned tight grace so straggler sleeps genuinely
    // overrun the wall-clock deadline and get dropped
    for &(scale, grace_ms, epochs) in &[(2e-3, None::<u64>, 150usize), (5e-2, Some(2), 120)] {
        match grace_ms {
            None => println!("--- time scale {scale}, auto-calibrated grace ({epochs} epochs) ---"),
            Some(g) => {
                println!("--- time scale {scale}, grace pinned to {g} ms ({epochs} epochs) ---")
            }
        }
        cfg.max_epochs = epochs;
        let mut live = LiveCoordinator::new(&cfg, scale)?;
        live.grace = grace_ms.map(std::time::Duration::from_millis);
        let report = live.train_cfl()?;
        let total = report.on_time_gradients + report.late_gradients;
        println!(
            "wall {:.2}s | gradients: {} on time, {} late ({:.0}% on time) | final NMSE {:.3e}\n",
            report.wall_secs,
            report.on_time_gradients,
            report.late_gradients,
            100.0 * report.on_time_gradients as f64 / total.max(1) as f64,
            report.trace.final_nmse().unwrap_or(f64::NAN)
        );
    }
    println!("note: tighter scaling (second run) stresses the deadline — more");
    println!("stragglers are dropped, yet training still converges because the");
    println!("master's parity gradient stands in for the missing updates.");
    Ok(())
}
