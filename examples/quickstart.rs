//! Quickstart: the 30-second tour of the `cfl` API.
//!
//! Builds a small heterogeneous edge problem, solves the load/redundancy
//! policy (Eqs. 13–16), trains with Coded Federated Learning and with the
//! uncoded baseline, and compares convergence.
//!
//! Run: `cargo run --release --example quickstart`

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;

fn main() -> anyhow::Result<()> {
    // a small problem: 8 devices × 60 points, d = 40, ν = (0.2, 0.2)
    let cfg = ExperimentConfig::small();
    let mut sim = SimCoordinator::new(&cfg)?;

    // the CFL policy: how much parity the master holds (c, δ), each
    // device's per-epoch systematic load, and the epoch deadline t*
    let policy = sim.policy()?;
    println!(
        "policy: c = {} parity rows (δ = {:.2}), deadline t* = {:.2} s",
        policy.parity_rows, policy.delta, policy.epoch_deadline
    );

    // train both ways on the same problem instance
    let coded = sim.train_cfl()?;
    let uncoded = sim.train_uncoded()?;
    let ls = sim.ls_bound()?;

    println!(
        "CFL:     NMSE {:.2e} after {} epochs ({:.1} simulated s, setup {:.1} s)",
        coded.trace.final_nmse().unwrap(),
        coded.epoch_times.len(),
        coded.trace.points.last().unwrap().time_s,
        coded.setup_secs,
    );
    println!(
        "uncoded: NMSE {:.2e} after {} epochs ({:.1} simulated s)",
        uncoded.trace.final_nmse().unwrap(),
        uncoded.epoch_times.len(),
        uncoded.trace.points.last().unwrap().time_s,
    );
    if let (Some(tc), Some(tu)) = (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
    {
        println!("coding gain to NMSE ≤ {:.0e}: {:.2}×", cfg.target_nmse, tu / tc);
    }
    println!("least-squares bound: NMSE {ls:.2e}");
    Ok(())
}
