//! End-to-end driver: the full three-layer system on the paper's workload.
//!
//! Exercises every layer in one run:
//!   L1/L2 — the AOT artifacts (Pallas kernels inside JAX graphs, lowered
//!           to HLO text by `make artifacts`) execute every gradient and
//!           the parity encode via PJRT;
//!   L3    — the rust coordinator solves the Eq. 13–16 policy, simulates
//!           the §II-A wireless edge, runs the deadline-gated epoch loop,
//!           and logs the NMSE curve.
//!
//! Workload: the paper's §IV setup (24 devices, ℓᵢ=300, d=500, SNR 0 dB,
//! ν=(0.2,0.2)) — a 500-parameter regression over 7200 points, trained to
//! NMSE ≤ 3·10⁻⁴, CFL vs uncoded, with the loss curves written to CSV.
//! Falls back to the native backend (with a notice) if artifacts are
//! missing. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::metrics::Table;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::paper();
    cfg.max_epochs = 3_000;

    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.txt").exists() {
        cfg.artifacts_dir = Some(art.to_str().unwrap().to_string());
    } else {
        eprintln!("NOTE: artifacts/ not built — using the native fallback backend.");
        eprintln!("      run `make artifacts` for the full three-layer path.\n");
    }

    let mut sim = SimCoordinator::new(&cfg)?;
    println!(
        "end-to-end: {} devices × {} points, d = {}, backend = {}",
        cfg.n_devices,
        cfg.points_per_device,
        cfg.model_dim,
        sim.backend_name()
    );

    let policy = sim.policy()?;
    println!(
        "policy: δ = {:.3} (c = {}), t* = {:.2} s, E[R] = {:.0}/{}\n",
        policy.delta,
        policy.parity_rows,
        policy.epoch_deadline,
        policy.expected_return,
        cfg.total_points()
    );

    let t0 = std::time::Instant::now();
    let coded = sim.train_cfl()?;
    let uncoded = sim.train_uncoded()?;
    let wall = t0.elapsed().as_secs_f64();
    let ls = sim.ls_bound()?;

    std::fs::create_dir_all("results").ok();
    coded.trace.write_csv("results/end_to_end_cfl.csv")?;
    uncoded.trace.write_csv("results/end_to_end_uncoded.csv")?;

    // log a readable excerpt of the loss curves
    println!("loss curve (decimated):");
    let mut table = Table::new(&[
        "t_cfl (s)", "epoch", "CFL NMSE", "|", "t_unc (s)", "epoch", "uncoded NMSE",
    ]);
    let (ct, ut) = (coded.trace.decimate(12), uncoded.trace.decimate(12));
    for i in 0..ct.points.len().max(ut.points.len()) {
        let c = ct.points.get(i);
        let u = ut.points.get(i);
        table.row(&[
            c.map(|p| format!("{:.0}", p.time_s)).unwrap_or_default(),
            c.map(|p| format!("{}", p.epoch)).unwrap_or_default(),
            c.map(|p| format!("{:.3e}", p.nmse)).unwrap_or_default(),
            "|".into(),
            u.map(|p| format!("{:.0}", p.time_s)).unwrap_or_default(),
            u.map(|p| format!("{}", p.epoch)).unwrap_or_default(),
            u.map(|p| format!("{:.3e}", p.nmse)).unwrap_or_default(),
        ]);
    }
    println!("{}", table.render());

    let tc = coded.time_to(cfg.target_nmse);
    let tu = uncoded.time_to(cfg.target_nmse);
    println!(
        "CFL:     setup {:.0}s + {} epochs × t*={:.1}s → NMSE {:.2e}",
        coded.setup_secs,
        coded.epoch_times.len(),
        coded.epoch_deadline,
        coded.trace.final_nmse().unwrap()
    );
    println!(
        "uncoded: {} epochs (mean {:.1}s) → NMSE {:.2e}",
        uncoded.epoch_times.len(),
        uncoded.epoch_times.iter().sum::<f64>() / uncoded.epoch_times.len().max(1) as f64,
        uncoded.trace.final_nmse().unwrap()
    );
    println!("LS bound: {ls:.2e}");
    match (tc, tu) {
        (Some(tc), Some(tu)) => println!(
            "\nconvergence to NMSE ≤ {:.0e}: CFL {tc:.0}s vs uncoded {tu:.0}s → coding gain {:.2}×",
            cfg.target_nmse,
            tu / tc
        ),
        _ => println!("\n(one of the runs did not reach the target NMSE)"),
    }
    println!("(host wall time {wall:.1}s; traces → results/end_to_end_*.csv)");

    anyhow::ensure!(coded.converged.is_some(), "CFL failed to converge");
    anyhow::ensure!(uncoded.converged.is_some(), "uncoded failed to converge");
    Ok(())
}
