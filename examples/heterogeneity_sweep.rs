//! Heterogeneity sweep: how the coding gain and the optimizer's policy
//! respond as the edge gets more unequal (a fast, small-scale cousin of
//! the Fig. 4 bench, with policy introspection the figure doesn't show).
//!
//! Run: `cargo run --release --example heterogeneity_sweep`

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::metrics::Table;

fn main() -> anyhow::Result<()> {
    println!("heterogeneity sweep (small scale: 8 devices × 60 points, d = 40)\n");
    let mut table = Table::new(&[
        "ν", "δ*", "t* (s)", "punctured devices", "t_CFL (s)", "t_unc (s)", "gain",
    ]);
    for &nu in &[0.0, 0.1, 0.2, 0.3, 0.4] {
        let mut cfg = ExperimentConfig::small();
        cfg.nu_comp = nu;
        cfg.nu_link = nu;
        cfg.max_epochs = 6_000;
        let mut sim = SimCoordinator::new(&cfg)?;
        let policy = sim.policy()?;
        // devices the optimizer fully punctures (all parity, no local work)
        let idle = policy.device_loads.iter().filter(|&&l| l == 0).count();
        let coded = sim.train_cfl()?;
        let uncoded = sim.train_uncoded()?;
        let (tc, tu) = (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse));
        table.row(&[
            format!("{nu:.1}"),
            format!("{:.3}", policy.delta),
            format!("{:.2}", policy.epoch_deadline),
            format!("{idle}/{}", cfg.n_devices),
            tc.map(|t| format!("{t:.0}")).unwrap_or("—".into()),
            tu.map(|t| format!("{t:.0}")).unwrap_or("—".into()),
            match (tc, tu) {
                (Some(tc), Some(tu)) => format!("{:.2}", tu / tc),
                _ => "—".into(),
            },
        ]);
    }
    println!("{}", table.render());
    println!("reading: as ν grows the optimizer punctures more of the slow tail,");
    println!("the deadline t* shrinks relative to the uncoded wait-for-all epoch,");
    println!("and the coding gain rises — the paper's Fig. 4 mechanism.");
    Ok(())
}
