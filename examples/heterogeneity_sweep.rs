//! Heterogeneity sweep: how the coding gain and the optimizer's policy
//! respond as the edge gets more unequal (a fast, small-scale cousin of
//! the Fig. 4 bench, with policy introspection the figure doesn't show).
//!
//! Runs on the `cfl::sweep` engine: the compound `nu` axis sets
//! ν_comp = ν_link per scenario and the grid executes across all cores —
//! results are identical to a serial loop, only faster. A second, zipped
//! grid sweeps a MEC deployment ladder where fleet size and redundancy
//! grow *together* (`zip_axes`): 3 paired scenarios instead of a 3×3
//! cartesian product.
//!
//! Run: `cargo run --release --example heterogeneity_sweep`

use cfl::config::ExperimentConfig;
use cfl::metrics::Table;
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() -> anyhow::Result<()> {
    println!("heterogeneity sweep (small scale: 8 devices × 60 points, d = 40)\n");
    let mut base = ExperimentConfig::small();
    base.max_epochs = 6_000;
    let grid = ScenarioGrid::new(&base).axis_f64("nu", &[0.0, 0.1, 0.2, 0.3, 0.4])?;
    let outcomes = run_grid(&grid, &SweepOptions::default())?;

    let mut table = Table::new(&[
        "ν", "δ*", "t* (s)", "punctured devices", "t_CFL (s)", "t_unc (s)", "gain",
    ]);
    for o in &outcomes {
        let cfg = &o.scenario.cfg;
        // devices the optimizer fully punctures (all parity, no local work)
        let idle = o.policy.device_loads.iter().filter(|&&l| l == 0).count();
        let fmt_t = |t: Option<f64>| t.map(|t| format!("{t:.0}")).unwrap_or_else(|| "—".into());
        table.row(&[
            format!("{:.1}", cfg.nu_comp),
            format!("{:.3}", o.policy.delta),
            format!("{:.2}", o.policy.epoch_deadline),
            format!("{idle}/{}", cfg.n_devices),
            fmt_t(o.coded.time_to(cfg.target_nmse)),
            fmt_t(o.uncoded.as_ref().and_then(|u| u.time_to(cfg.target_nmse))),
            o.gain().map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    println!("{}", table.render());
    println!("reading: as ν grows the optimizer punctures more of the slow tail,");
    println!("the deadline t* shrinks relative to the uncoded wait-for-all epoch,");
    println!("and the coding gain rises — the paper's Fig. 4 mechanism.");

    // paired (zipped) axes: a MEC deployment ladder where the fleet and
    // its redundancy budget scale together — correlated, not crossed
    println!("\nMEC ladder (zipped n_devices+delta: 3 paired scenarios, not 3×3):");
    let mut base = ExperimentConfig::small();
    base.max_epochs = 6_000;
    base.nu_comp = 0.3;
    base.nu_link = 0.3;
    let ladder = ScenarioGrid::new(&base)
        .axis("n_devices", ["6", "8", "12"])?
        .axis("delta", ["0.10", "0.15", "0.20"])?
        .zip_axes(["n_devices", "delta"])?;
    let outcomes = run_grid(&ladder, &SweepOptions::default())?;
    let mut table = Table::new(&["n", "δ", "t* (s)", "t_CFL (s)", "gain"]);
    for o in &outcomes {
        let cfg = &o.scenario.cfg;
        let fmt_t = |t: Option<f64>| t.map(|t| format!("{t:.0}")).unwrap_or_else(|| "—".into());
        table.row(&[
            format!("{}", cfg.n_devices),
            format!("{:.2}", o.coded.delta),
            format!("{:.2}", o.policy.epoch_deadline),
            fmt_t(o.coded.time_to(cfg.target_nmse)),
            o.gain().map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
