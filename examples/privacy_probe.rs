//! Privacy probe: what does the server actually learn from parity data?
//!
//! §III of the paper argues the parity upload (X̃ⁱ = GᵢWᵢXⁱ with Gᵢ, Wᵢ
//! private) "cannot be used to decode the raw data". This example runs
//! the natural reconstruction attack empirically: a server that somehow
//! knew Gᵢ (best case for the attacker — in reality it does not) solves
//! least squares for the raw rows, and a server without Gᵢ correlates
//! parity rows against candidate raw rows. We report reconstruction error
//! vs the parity/raw ratio c/ℓ.
//!
//! Run: `cargo run --release --example privacy_probe`

use cfl::config::GeneratorKind;
use cfl::coding::DeviceCode;
use cfl::data::{split, Dataset};
use cfl::fl::{GradBackend, NativeBackend};
use cfl::linalg::{matmul_at_b, solve_ls, Mat};
use cfl::metrics::Table;
use cfl::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let (l, d) = (100usize, 24usize);
    let ds = Dataset::generate(l, d, 10.0, &mut rng);
    let shards = split(&ds, &[l]);
    let shard = &shards[0];
    let mut backend = NativeBackend;

    println!("privacy probe: ℓ = {l} raw rows, d = {d}; attacker sees c parity rows\n");
    let mut table = Table::new(&[
        "c/ℓ", "NMSE known-G attack", "max |cos| blind attack",
    ]);

    for &ratio in &[0.25, 0.5, 0.9, 1.0, 1.5] {
        let c = (ratio * l as f64) as usize;
        let code = DeviceCode::draw(l, c, l / 2, 0.4, GeneratorKind::Gaussian, &mut rng);
        let (xt, _yt) = backend.encode(&code.generator, &code.weights, &shard.x, &shard.y)?;

        // --- attack 1: attacker KNOWS G (not true in the protocol) -------
        // solve min ‖G·Z − X̃‖ for Z ≈ W·X column by column; underdetermined
        // for c < ℓ. Report NMSE of the best-effort reconstruction vs W·X.
        let mut wx = shard.x.clone();
        wx.scale_rows(&code.weights);
        let recon_err = if c >= l {
            // overdetermined: LS per column
            let mut err_num = 0.0;
            let mut err_den = 0.0;
            for col in 0..d {
                let xt_col = column(&xt, col);
                let wx_col = column(&wx, col);
                if let Ok(z) = solve_ls(&code.generator, &xt_col) {
                    err_num += z.dist_sq(&wx_col);
                }
                err_den += wx_col.norm_sq();
            }
            err_num / err_den
        } else {
            // underdetermined: minimum-norm solution Gᵀ(GGᵀ)⁻¹X̃ leaves the
            // (ℓ−c)-dimensional nullspace unrecovered
            let gt_sol = min_norm_solve(&code.generator, &xt)?;
            gt_sol.dist_sq(&wx) / wx.norm_sq()
        };

        // --- attack 2: blind correlation (the protocol's actual threat) --
        let mut max_cos = 0.0f64;
        for pr in 0..xt.rows() {
            for rr in 0..l {
                let p = xt.row(pr);
                let r = shard.x.row(rr);
                let dot: f64 = p.iter().zip(r).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
                let np = p.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
                let nr = r.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
                max_cos = max_cos.max((dot / (np * nr)).abs());
            }
        }

        table.row(&[
            format!("{ratio:.2}"),
            format!("{recon_err:.3}"),
            format!("{max_cos:.3}"),
        ]);
    }
    println!("{}", table.render());
    println!("reading: even an attacker who impossibly knows Gᵢ recovers nothing");
    println!("until c ≥ ℓ (NMSE ≈ nullspace fraction 1 − c/ℓ, → ~0 only at c ≥ ℓ);");
    println!("the real server, without Gᵢ, sees parity rows with bounded cosine");
    println!("similarity to every raw row. CFL keeps c ≪ ℓ·n by construction, and");
    println!("puncturing randomizes *which* rows even enter the systematic set.");
    Ok(())
}

fn column(m: &Mat, col: usize) -> Mat {
    let mut out = Mat::zeros(m.rows(), 1);
    for r in 0..m.rows() {
        out[(r, 0)] = m[(r, col)];
    }
    out
}

/// Minimum-norm solution Z = Gᵀ(GGᵀ)⁻¹·B of G·Z = B (c < ℓ).
fn min_norm_solve(g: &Mat, b: &Mat) -> anyhow::Result<Mat> {
    let c = g.rows();
    let ggt = cfl::linalg::matmul(g, &g.transpose()); // c×c
    // solve (GGᵀ)·Y = B column-wise in f64
    let mut y = Mat::zeros(c, b.cols());
    for col in 0..b.cols() {
        let mut a: Vec<f64> = ggt.as_slice().iter().map(|&v| v as f64).collect();
        let mut rhs: Vec<f64> = (0..c).map(|r| b[(r, col)] as f64).collect();
        cfl::linalg::cholesky_solve_in_place(&mut a, &mut rhs, c)?;
        for r in 0..c {
            y[(r, col)] = rhs[r] as f32;
        }
    }
    Ok(matmul_at_b(g, &y)) // ℓ×cols
}
