//! Offline stand-in for the `anyhow` crate.
//!
//! The build sandbox has no crates.io access, so this vendored shim
//! implements exactly the subset the workspace uses — [`Error`],
//! [`Result`], the [`Context`] extension trait (on both `Result` and
//! `Option`), and the `anyhow!` / `bail!` / `ensure!` macros — with
//! call-compatible signatures. Swapping this path dependency for the
//! real crate requires no source changes in the workspace.
//!
//! Representation: an error is an ordered chain of messages, outermost
//! context first. Converting from a `std::error::Error` captures its
//! whole `source()` chain; `Display` shows the outermost message (like
//! anyhow), `Debug` shows the full chain (like anyhow's report format).

use std::fmt::{self, Debug, Display};

/// A message-chain error type mirroring `anyhow::Error`.
pub struct Error {
    /// Outermost message first; later entries are wrapped causes.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or_default()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            // `{:#}` prints the whole chain inline, as anyhow does
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension: attach a message while converting to [`Error`].
pub trait Context<T, E> {
    /// Attach `context` to the error, eagerly evaluated.
    fn context<C: Display>(self, context: C) -> Result<T>;

    /// Attach context computed only on the error path.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_debug_shows_chain() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(e.to_string(), "loading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading config") && dbg.contains("missing file"), "{dbg}");
    }

    #[test]
    fn context_works_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(inner(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
