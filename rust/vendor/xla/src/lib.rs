//! Offline stub of the `xla` PJRT bindings.
//!
//! The build sandbox has neither crates.io access nor an XLA
//! installation, so this crate provides the *compile-time* API surface
//! that `cfl::runtime` needs — `PjRtClient`, `PjRtLoadedExecutable`,
//! `PjRtBuffer`, `Literal`, `HloModuleProto`, `XlaComputation` — with
//! every runtime entry point returning an "unavailable" error. The
//! coordinator falls back to the native backend unless an artifacts
//! directory is configured, so nothing in the default test suite ever
//! reaches these paths (the PJRT integration tests skip when
//! `artifacts/manifest.txt` is absent).
//!
//! To enable the real PJRT runtime, replace the `xla` path dependency in
//! the workspace `Cargo.toml` with the actual bindings; `cfl` compiles
//! against the same names and signatures.

use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' error enum.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT is stubbed out in this offline build — swap the \
         `rust/vendor/xla` path dependency for the real `xla` bindings to \
         enable the PJRT backend (the native backend is unaffected)"
    ))
}

/// A PJRT device handle (never instantiated by the stub).
pub struct PjRtDevice;

/// The PJRT client. `cpu()` always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client — unavailable in the stub.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation — unavailable in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a host buffer — unavailable in the stub.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// A parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file — unavailable in the stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto (pure bookkeeping; succeeds even in the stub).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal operands — unavailable in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-resident buffer operands — unavailable in the stub.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to the host — unavailable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice (shape is attached by
    /// [`Literal::reshape`]; the stub holds no data).
    pub fn vec1<T>(_data: &[T]) -> Self {
        Literal
    }

    /// Reshape — unavailable in the stub.
    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// Unpack a 1-tuple — unavailable in the stub.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Unpack a 2-tuple — unavailable in the stub.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    /// Copy out as a host vector — unavailable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("stubbed out"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1, 1]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }
}
