use super::*;
use crate::config::{ExperimentConfig, Ini};
use crate::coordinator::{CoordinatorKind, SimCoordinator};
use crate::rng::mix_seed;

/// Small enough that a full grid (CFL + uncoded per cell) runs in
/// milliseconds; target 0 ⇒ every run goes to the epoch cap, so traces
/// have a fixed, comparable length.
fn tiny() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.n_devices = 4;
    cfg.points_per_device = 16;
    cfg.model_dim = 8;
    cfg.max_epochs = 40;
    cfg.target_nmse = 0.0;
    cfg.seed = 99;
    cfg
}

// ---------------------------------------------------------------------
// grid expansion
// ---------------------------------------------------------------------

#[test]
fn expansion_is_row_major_with_stable_ids() {
    let grid = ScenarioGrid::new(&tiny())
        .axis("nu_comp", ["0", "0.1"])
        .unwrap()
        .axis("nu_link", ["0", "0.1", "0.2"])
        .unwrap();
    assert_eq!(grid.len(), 6);
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios.len(), 6);
    // first axis slowest, second fastest — nested-for order
    let coords: Vec<(f64, f64)> =
        scenarios.iter().map(|s| (s.cfg.nu_comp, s.cfg.nu_link)).collect();
    assert_eq!(
        coords,
        vec![(0.0, 0.0), (0.0, 0.1), (0.0, 0.2), (0.1, 0.0), (0.1, 0.1), (0.1, 0.2)]
    );
    assert_eq!(scenarios[0].id, "s0__nu_comp=0__nu_link=0");
    assert_eq!(scenarios[5].id, "s5__nu_comp=0.1__nu_link=0.2");
    assert_eq!(scenarios[3].index, 3);
    assert_eq!(
        scenarios[3].assignment,
        vec![("nu_comp".to_string(), "0.1".to_string()), ("nu_link".to_string(), "0".to_string())]
    );
    // expansion is a pure function of the grid
    let again = grid.expand().unwrap();
    for (a, b) in scenarios.iter().zip(&again) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.cfg.seed, b.cfg.seed);
    }
}

#[test]
fn singleton_axis_and_axis_free_grid() {
    let grid = ScenarioGrid::new(&tiny()).axis("delta", ["0.15"]).unwrap();
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios.len(), 1);
    assert_eq!(scenarios[0].cfg.delta, Some(0.15));

    // no axes at all → the single base scenario
    let scenarios = ScenarioGrid::new(&tiny()).expand().unwrap();
    assert_eq!(scenarios.len(), 1);
    assert!(scenarios[0].assignment.is_empty());
    assert_eq!(scenarios[0].cfg.seed, tiny().seed);
}

#[test]
fn bad_axes_are_rejected_at_declaration() {
    let empty: [&str; 0] = [];
    assert!(ScenarioGrid::new(&tiny()).axis("nu_comp", empty).is_err());
    assert!(ScenarioGrid::new(&tiny()).axis("not_a_knob", ["1"]).is_err());
    assert!(ScenarioGrid::new(&tiny()).axis("nu_comp", ["zero"]).is_err());
    assert!(ScenarioGrid::new(&tiny())
        .axis("nu_comp", ["0.1"])
        .unwrap()
        .axis("nu_comp", ["0.2"])
        .is_err());
    // out-of-range values pass parsing but fail expansion's validate()
    let grid = ScenarioGrid::new(&tiny()).axis("nu_comp", ["1.5"]).unwrap();
    assert!(grid.expand().is_err());
}

#[test]
fn axis_spec_and_ini_parsing() {
    let grid = ScenarioGrid::new(&tiny()).axis_spec("delta=0.1, 0.2,auto").unwrap();
    assert_eq!(grid.axes()[0].values, vec!["0.1", "0.2", "auto"]);
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios[0].cfg.delta, Some(0.1));
    assert_eq!(scenarios[2].cfg.delta, None);
    assert!(ScenarioGrid::new(&tiny()).axis_spec("no-equals-sign").is_err());

    let ini = Ini::parse(
        "[sweep]\nnu_link = 0, 0.2\ndelta = 0.1, 0.2\nworkers = 3\nderive_seeds = true\n",
    )
    .unwrap();
    let grid = ScenarioGrid::new(&tiny()).with_ini(&ini).unwrap();
    // axes arrive in the section's alphabetical key order; reserved keys
    // (workers, derive_seeds) never become axes
    let keys: Vec<&str> = grid.axes().iter().map(|a| a.key.as_str()).collect();
    assert_eq!(keys, vec!["delta", "nu_link"]);
    assert_eq!(grid.len(), 4);
    // derive_seeds was honored
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios[1].cfg.seed, mix_seed(tiny().seed, 1));
}

#[test]
fn zip_axes_pair_correlated_parameters() {
    let grid = ScenarioGrid::new(&tiny())
        .axis("n_devices", ["4", "6"])
        .unwrap()
        .axis_f64("nu", &[0.0, 0.1])
        .unwrap()
        .axis("points_per_device", ["16", "12"])
        .unwrap()
        .zip_axes(["n_devices", "points_per_device"])
        .unwrap();
    // the zipped pair contributes one dimension: 2 × 2, not 2 × 2 × 2
    assert_eq!(grid.len(), 4);
    let dims = grid.dims();
    assert_eq!(dims.len(), 2);
    assert_eq!(grid.dim_key(&dims[0]), "n_devices+points_per_device");
    assert_eq!(grid.dim_labels(&dims[0]), vec!["4+16", "6+12"]);
    assert_eq!(grid.dim_key(&dims[1]), "nu");

    let scenarios = grid.expand().unwrap();
    // ids keep one key=value segment per axis, in declaration order
    assert_eq!(scenarios[0].id, "s0__n_devices=4__nu=0__points_per_device=16");
    assert_eq!(scenarios[3].id, "s3__n_devices=6__nu=0.1__points_per_device=12");
    // zipped members advance together, never crossed
    for s in &scenarios {
        match s.cfg.n_devices {
            4 => assert_eq!(s.cfg.points_per_device, 16, "{}", s.id),
            6 => assert_eq!(s.cfg.points_per_device, 12, "{}", s.id),
            other => panic!("unexpected n_devices {other}"),
        }
    }
    // ids() agrees with expand()
    let ids = grid.ids();
    for (s, id) in scenarios.iter().zip(&ids) {
        assert_eq!(&s.id, id);
    }
}

#[test]
fn zip_axes_validation_rejects_bad_groups() {
    let two = || {
        ScenarioGrid::new(&tiny())
            .axis_f64("nu", &[0.0, 0.1])
            .unwrap()
            .axis("delta", ["0.1", "0.2"])
            .unwrap()
    };
    assert!(two().zip_axes(["nu", "not_declared"]).is_err());
    assert!(two().zip_axes(["nu"]).is_err(), "a group of one is meaningless");
    assert!(two().zip_axes(["nu", "nu"]).is_err(), "same axis twice");
    assert!(two()
        .zip_axes(["nu", "delta"])
        .unwrap()
        .zip_axes(["delta", "nu"])
        .is_err(), "an axis joins at most one group");
    // unequal value counts cannot pair
    let uneven = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.1, 0.2])
        .unwrap()
        .axis("delta", ["0.1", "0.2"])
        .unwrap();
    let err = uneven.zip_axes(["nu", "delta"]).unwrap_err().to_string();
    assert!(err.contains("equal value counts"), "{err}");
}

#[test]
fn zip_from_ini_and_cli_spec() {
    let ini = Ini::parse(
        "[sweep]\nn_devices = 4, 6\npoints_per_device = 16, 12\nnu_link = 0, 0.2\n\
         zip = n_devices+points_per_device\n",
    )
    .unwrap();
    let grid = ScenarioGrid::new(&tiny()).with_ini(&ini).unwrap();
    assert_eq!(grid.len(), 4, "zip folds the pair into one dimension");
    assert_eq!(grid.zip_keys(), vec![vec!["n_devices", "points_per_device"]]);

    // the CLI spec form accepts + separators
    let grid = ScenarioGrid::new(&tiny())
        .axis("n_devices", ["4", "6"])
        .unwrap()
        .axis("points_per_device", ["16", "12"])
        .unwrap()
        .zip_spec("n_devices+points_per_device")
        .unwrap();
    assert_eq!(grid.len(), 2);
}

#[test]
fn compound_nu_axis_sets_both_knobs() {
    let scenarios =
        ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.3]).unwrap().expand().unwrap();
    assert_eq!(scenarios[1].cfg.nu_comp, 0.3);
    assert_eq!(scenarios[1].cfg.nu_link, 0.3);
}

#[test]
fn seed_policy_shared_derived_and_explicit() {
    // default: common random numbers — every cell shares the base seed
    let shared =
        ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.1]).unwrap().expand().unwrap();
    assert!(shared.iter().all(|s| s.cfg.seed == tiny().seed));

    // derive_seeds: per-index streams, reproducible from (base, index)
    let derived = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.1])
        .unwrap()
        .derive_seeds(true)
        .expand()
        .unwrap();
    assert_ne!(derived[0].cfg.seed, derived[1].cfg.seed);
    assert_eq!(derived[1].cfg.seed, mix_seed(tiny().seed, 1));

    // an explicit seed axis overrides both policies
    let explicit = ScenarioGrid::new(&tiny())
        .axis("seed", ["7", "8"])
        .unwrap()
        .derive_seeds(true)
        .expand()
        .unwrap();
    assert_eq!(explicit[0].cfg.seed, 7);
    assert_eq!(explicit[1].cfg.seed, 8);
}

// ---------------------------------------------------------------------
// runner determinism
// ---------------------------------------------------------------------

#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    let grid = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.3])
        .unwrap()
        .axis("delta", ["0.15", "auto"])
        .unwrap()
        .derive_seeds(true);
    let serial_opts = SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let parallel_opts = SweepOptions { workers: 2, ..serial_opts.clone() };
    let serial = run_grid(&grid, &serial_opts).unwrap();
    let parallel = run_grid(&grid, &parallel_opts).unwrap();

    assert_eq!(serial.len(), 4);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.scenario.id, b.scenario.id);
        assert_eq!(a.coded.trace.points, b.coded.trace.points, "{}", a.scenario.id);
        assert_eq!(a.coded.setup_secs, b.coded.setup_secs);
        assert_eq!(a.coded.epoch_times, b.coded.epoch_times);
        assert_eq!(
            a.uncoded.as_ref().unwrap().trace.points,
            b.uncoded.as_ref().unwrap().trace.points
        );
    }

    // and the written reports agree to the byte
    let dir = std::env::temp_dir().join("cfl_sweep_determinism");
    std::fs::create_dir_all(&dir).unwrap();
    let (p1, p2) = (dir.join("serial.csv"), dir.join("parallel.csv"));
    write_scenario_csv(p1.to_str().unwrap(), &grid, &serial).unwrap();
    write_scenario_csv(p2.to_str().unwrap(), &grid, &parallel).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    let (j1, j2) = (dir.join("serial.json"), dir.join("parallel.json"));
    write_json(j1.to_str().unwrap(), &grid, &serial).unwrap();
    write_json(j2.to_str().unwrap(), &grid, &parallel).unwrap();
    assert_eq!(std::fs::read(&j1).unwrap(), std::fs::read(&j2).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runner_surfaces_scenario_failures() {
    // delta so large the optimizer cannot satisfy it → policy error,
    // reported with the scenario id attached
    let mut cfg = tiny();
    cfg.delta = Some(0.9);
    cfg.c_up_fraction = 0.9;
    let grid = ScenarioGrid::new(&cfg).axis_f64("nu", &[0.0]).unwrap();
    let opts = SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    match run_grid(&grid, &opts) {
        Err(e) => {
            let msg = format!("{e:?}");
            assert!(msg.contains("s0"), "error lost scenario context: {msg}");
        }
        Ok(outcomes) => {
            // if the tiny fleet can actually carry δ=0.9, the run must
            // at least have honored it
            assert!((outcomes[0].coded.delta - 0.9).abs() < 0.05);
        }
    }
}

#[test]
fn skip_uncoded_drops_baseline_and_gain() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.1]).unwrap();
    let opts = SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    assert!(outcomes[0].uncoded.is_none());
    assert!(outcomes[0].gain().is_none());
    assert!(outcomes[0].comm_load().is_none());
}

#[test]
fn live_backend_runs_the_grid() {
    // the same grid machinery drives the threaded coordinator: every
    // scenario still produces a full outcome (gain needs the target to be
    // reached, which a 20-epoch live demo need not guarantee — we assert
    // structure, not timing)
    let mut cfg = tiny();
    cfg.max_epochs = 20;
    let grid = ScenarioGrid::new(&cfg).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts = SweepOptions {
        workers: 1,
        uncoded_baseline: true,
        progress: false,
        backend: CoordinatorKind::Live {
            time_scale: 1e-4,
            transport: crate::transport::TransportKind::Channel,
            placement: None,
        },
    };
    let outcomes = run_grid(&grid, &opts).unwrap();
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert_eq!(o.backend, "live");
        assert_eq!(o.coded.epoch_times.len(), 20);
        assert!(o.coded.wall_secs > 0.0);
        assert!(o.coded.setup_secs > 0.0, "live CFL must account parity setup");
        let uncoded = o.uncoded.as_ref().expect("baseline requested");
        assert_eq!(uncoded.setup_secs, 0.0);
        assert_eq!(uncoded.on_time_gradients, (cfg.n_devices * 20) as u64);
    }
    // the reports render live outcomes through the same pipeline
    let rendered = summary_table(&outcomes).render();
    assert_eq!(rendered.lines().count(), 4, "{rendered}");

    // trace-export parity: live runs export per-scenario traces in the
    // exact format the sim backend writes
    let dir = std::env::temp_dir().join("cfl_sweep_live_traces");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    for o in &outcomes {
        write_outcome_traces(dir.to_str().unwrap(), o).unwrap();
    }
    let trace =
        std::fs::read_to_string(dir.join("s0__nu=0__cfl.csv")).expect("live CFL trace");
    assert!(trace.starts_with("time_s,epoch,nmse"), "{trace}");
    assert!(trace.lines().count() > 20, "live trace missing epochs: {trace}");
    assert!(dir.join("s0__nu=0__uncoded.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_tasks_is_order_preserving_and_surfaces_errors() {
    // the generic pool returns outputs in input order for any worker count
    let items: Vec<usize> = (0..23).collect();
    let serial = run_tasks(items.clone(), 1, |i| Ok(i * i)).unwrap();
    let parallel = run_tasks(items, 4, |i| Ok(i * i)).unwrap();
    assert_eq!(serial, parallel);
    assert_eq!(serial[7], 49);

    // first failure in input order wins, regardless of completion order
    let err = run_tasks((0..8).collect(), 4, |i| {
        anyhow::ensure!(i != 3, "boom at {i}");
        Ok(i)
    })
    .unwrap_err();
    assert!(err.to_string().contains("boom at 3"), "{err}");

    let empty: Vec<usize> = Vec::new();
    assert!(run_tasks(empty, 4, |i| Ok(i)).unwrap().is_empty());
}

#[test]
fn run_tasks_streaming_delivers_the_prefix_in_order() {
    let items: Vec<usize> = (0..17).collect();
    let mut order = Vec::new();
    let out = run_tasks_streaming(items, 4, |i| Ok(i * 2), |pos, v: &usize| {
        order.push((pos, *v));
        Ok(())
    })
    .unwrap();
    assert_eq!(out, (0..17).map(|i| i * 2).collect::<Vec<_>>());
    // the sink saw every output, in input order, regardless of workers
    assert_eq!(order, (0..17).map(|i| (i, i * 2)).collect::<Vec<_>>());

    // a sink error aborts the run
    let err = run_tasks_streaming((0..8).collect(), 4, |i: usize| Ok(i), |pos, _: &usize| {
        anyhow::ensure!(pos != 2, "sink refused #{pos}");
        Ok(())
    })
    .unwrap_err();
    assert!(err.to_string().contains("sink refused #2"), "{err}");
}

#[test]
fn run_tasks_catches_panicking_tasks_as_errors() {
    // a panic in one task must surface as an orderly Err (first failure
    // in input order), not poison the pool or abort the process
    let err = run_tasks((0..8).collect::<Vec<usize>>(), 4, |i| {
        if i == 3 {
            panic!("kaboom at {i}");
        }
        Ok(i)
    })
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("task panicked"), "{msg}");
    assert!(msg.contains("kaboom at 3"), "{msg}");

    // serial path too
    let err = run_tasks(vec![0usize], 1, |_| -> anyhow::Result<usize> { panic!("solo") })
        .unwrap_err();
    assert!(err.to_string().contains("solo"), "{err}");
}

#[test]
fn coordinator_and_outcomes_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<SimCoordinator>();
    assert_send::<ScenarioOutcome>();
    assert_send::<Scenario>();
}

// ---------------------------------------------------------------------
// reports
// ---------------------------------------------------------------------

#[test]
fn gain_matrix_is_row_major_and_two_axis_only() {
    let mut cfg = tiny();
    cfg.max_epochs = 400;
    cfg.target_nmse = 2e-2; // reachable → real gains in most cells
    let grid = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &[0.0, 0.2])
        .unwrap()
        .axis_f64("nu_link", &[0.0, 0.1, 0.2])
        .unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 2, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let table = gain_matrix(&grid, &outcomes).expect("2-axis grid has a matrix");
    let rendered = table.render();
    assert!(rendered.contains("nu_comp \\ nu_link"), "{rendered}");
    // 2 data rows (one per nu_comp value)
    assert_eq!(rendered.lines().count(), 2 + 2, "{rendered}");

    let one_axis = ScenarioGrid::new(&cfg).axis_f64("nu_comp", &[0.0]).unwrap();
    let one_out = run_grid(
        &one_axis,
        &SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() },
    )
    .unwrap();
    assert!(gain_matrix(&one_axis, &one_out).is_none());
}

#[test]
fn scenario_csv_has_axis_columns_and_json_is_well_formed() {
    let grid = ScenarioGrid::new(&tiny()).axis("delta", ["0.15", "auto"]).unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let dir = std::env::temp_dir().join("cfl_sweep_report");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("scenarios.csv");
    write_scenario_csv(csv_path.to_str().unwrap(), &grid, &outcomes).unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert!(header.starts_with("scenario,delta,delta_used,"), "{header}");
    assert!(header.ends_with("gain,comm_load,backend,config"), "{header}");
    assert_eq!(lines.count(), 2);
    // target 0 is unreachable → empty gain cells, never "NaN"
    assert!(!text.contains("NaN"), "{text}");

    let json_path = dir.join("report.json");
    write_json(json_path.to_str().unwrap(), &grid, &outcomes).unwrap();
    let json = std::fs::read_to_string(&json_path).unwrap();
    for needle in ["\"axes\"", "\"scenarios\"", "\"aggregate\"", "\"s0__delta=0.15\""] {
        assert!(json.contains(needle), "missing {needle}: {json}");
    }
    // balanced braces/brackets (cheap well-formedness check, no serde)
    let balance = |open: char, close: char| {
        json.chars().filter(|&c| c == open).count() == json.chars().filter(|&c| c == close).count()
    };
    assert!(balance('{', '}') && balance('[', ']'));
    assert!(!json.contains("NaN") && !json.contains("inf"), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn gain_matrix_renders_resumed_subsets_by_id() {
    let mut cfg = tiny();
    cfg.max_epochs = 400;
    cfg.target_nmse = 2e-2;
    let grid = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &[0.0, 0.2])
        .unwrap()
        .axis_f64("nu_link", &[0.0, 0.1])
        .unwrap();
    let mut outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 2, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    // drop a cell, as a resumed sweep's freshly-run remainder would
    outcomes.remove(1);
    let table = gain_matrix(&grid, &outcomes).expect("subsets still render");
    let rendered = table.render();
    assert_eq!(rendered.lines().count(), 2 + 2, "{rendered}");
    // the missing (0.0, 0.1) cell renders as a hole, not a crash
    assert!(rendered.contains('—'), "{rendered}");
}

#[test]
fn gain_matrix_uses_zip_groups_as_dimensions() {
    let grid = ScenarioGrid::new(&tiny())
        .axis("n_devices", ["4", "6"])
        .unwrap()
        .axis_f64("nu", &[0.0, 0.1])
        .unwrap()
        .axis("points_per_device", ["16", "12"])
        .unwrap()
        .zip_axes(["n_devices", "points_per_device"])
        .unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 2, uncoded_baseline: false, progress: false, ..Default::default() },
    )
    .unwrap();
    // 3 axes but 2 dimensions → the matrix renders, zipped labels joined
    let rendered = gain_matrix(&grid, &outcomes).expect("2-dim grid").render();
    assert!(rendered.contains("n_devices+points_per_device \\ nu"), "{rendered}");
    assert!(rendered.contains("6+12"), "{rendered}");
}

#[test]
fn resume_merges_to_a_byte_identical_csv() {
    let grid = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.2])
        .unwrap()
        .axis("delta", ["0.15", "auto"])
        .unwrap();
    let opts =
        SweepOptions { workers: 2, uncoded_baseline: true, progress: false, ..Default::default() };
    let header = scenario_csv_header(&grid);
    let ids = grid.ids();
    let dir = std::env::temp_dir().join("cfl_sweep_resume");
    std::fs::create_dir_all(&dir).unwrap();

    // uninterrupted run, streamed through the merge writer
    let full_path = dir.join("full.csv");
    let mut merged = MergedScenarioCsv::create(
        full_path.to_str().unwrap(),
        &header,
        &ids,
        &ResumeState::empty(),
    )
    .unwrap();
    run_scenarios_streaming(grid.expand().unwrap(), &opts, |o| merged.push(o)).unwrap();
    merged.finish().unwrap();
    let full = std::fs::read_to_string(&full_path).unwrap();
    assert_eq!(full.lines().count(), 1 + 4);

    // simulate a mid-run kill: header + the first 2 rows survive
    let partial_path = dir.join("partial.csv");
    let kept: Vec<&str> = full.lines().take(3).collect();
    std::fs::write(&partial_path, format!("{}\n", kept.join("\n"))).unwrap();

    let resume = ResumeState::load(partial_path.to_str().unwrap(), &header).unwrap();
    assert_eq!(resume.len(), 2);
    let todo: Vec<Scenario> = grid
        .expand()
        .unwrap()
        .into_iter()
        .filter(|s| !resume.contains(&s.id))
        .collect();
    assert_eq!(todo.len(), 2, "only the unfinished remainder re-runs");

    let resumed_path = dir.join("resumed.csv");
    let mut merged = MergedScenarioCsv::create(
        resumed_path.to_str().unwrap(),
        &header,
        &ids,
        &resume,
    )
    .unwrap();
    run_scenarios_streaming(todo, &opts, |o| merged.push(o)).unwrap();
    merged.finish().unwrap();
    assert_eq!(
        std::fs::read(&full_path).unwrap(),
        std::fs::read(&resumed_path).unwrap(),
        "resumed CSV must be byte-identical to the uninterrupted run"
    );

    // a torn final line (kill landed mid-write) is dropped on load
    let torn_path = dir.join("torn.csv");
    std::fs::write(&torn_path, format!("{}\ns9__nu=torn", kept.join("\n"))).unwrap();
    let torn = ResumeState::load(torn_path.to_str().unwrap(), &header).unwrap();
    assert_eq!(torn.len(), 2, "the 2 full rows survive, the torn line is dropped");
    assert!(!torn.contains("s9__nu=torn"));

    // resuming onto a different grid (different columns) is refused
    let other = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0]).unwrap();
    let err = ResumeState::load(partial_path.to_str().unwrap(), &scenario_csv_header(&other))
        .unwrap_err()
        .to_string();
    assert!(err.contains("header does not match"), "{err}");

    // same columns but a different base config (e.g. another seed) is
    // refused by the per-row config fingerprint
    let mut reseeded = tiny();
    reseeded.seed = 1234;
    let drifted = ScenarioGrid::new(&reseeded)
        .axis_f64("nu", &[0.0, 0.2])
        .unwrap()
        .axis("delta", ["0.15", "auto"])
        .unwrap();
    let err = resume.check_compat(&drifted.expand().unwrap()).unwrap_err().to_string();
    assert!(err.contains("different config"), "{err}");
    // while the original grid passes
    resume.check_compat(&grid.expand().unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_fingerprint_tracks_the_resolved_config() {
    let a = tiny();
    let mut b = tiny();
    assert_eq!(config_fingerprint(&a), config_fingerprint(&b), "pure function");
    b.seed = 1234;
    assert_ne!(config_fingerprint(&a), config_fingerprint(&b), "seed must show");
    let mut c = tiny();
    c.max_epochs += 1;
    assert_ne!(config_fingerprint(&a), config_fingerprint(&c), "epochs must show");
}

#[test]
fn traces_dir_exports_one_file_per_run() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    let dir = std::env::temp_dir().join("cfl_sweep_traces");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    for o in &outcomes {
        write_outcome_traces(dir.to_str().unwrap(), o).unwrap();
    }
    for stem in ["s0__nu=0", "s1__nu=0.2"] {
        let cfl = std::fs::read_to_string(dir.join(format!("{stem}__cfl.csv"))).unwrap();
        assert!(cfl.starts_with("time_s,epoch,nmse"), "{cfl}");
        assert!(cfl.lines().count() > 40, "trace missing epochs: {cfl}");
        let unc = std::fs::read_to_string(dir.join(format!("{stem}__uncoded.csv"))).unwrap();
        assert!(unc.starts_with("time_s,epoch,nmse"), "{unc}");
    }
    std::fs::remove_dir_all(&dir).ok();

    // ids sanitize to safe file stems
    assert_eq!(trace_file_stem("s0__nu=0.1"), "s0__nu=0.1");
    assert_eq!(trace_file_stem("s0__a/b\\c\"d"), "s0__a_b_c_d");
}

#[test]
fn summary_table_renders_one_row_per_scenario() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let rendered = summary_table(&outcomes).render();
    // header + separator + 2 scenarios
    assert_eq!(rendered.lines().count(), 4, "{rendered}");
    assert!(rendered.contains("s0__nu=0"), "{rendered}");
}

// ---------------------------------------------------------------------
// bench baseline pipeline

#[test]
fn bench_report_writes_and_parses_gains() {
    // a grid tiny() can't converge on (target 0) still writes a report —
    // with null gains — and the parser round-trips it
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    let dir = std::env::temp_dir().join("cfl_bench_report");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_ci.json");
    write_bench_json(path.to_str().unwrap(), &outcomes).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    let gains = parse_gains(&json).unwrap();
    assert_eq!(gains.len(), 2);
    assert_eq!(gains[0].0, "s0__nu=0");
    assert!(json.contains("\"wall_s\": "), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_json_escapes_exotic_scenario_ids() {
    // quote/backslash-bearing ids (reachable via zipped-axis values) must
    // round-trip through both report writers as valid JSON
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let mut outcomes = run_grid(&grid, &opts).unwrap();
    outcomes[0].scenario.id = "s0__note=\"q\"\\p".to_string();

    let dir = std::env::temp_dir().join("cfl_bench_escape");
    std::fs::create_dir_all(&dir).unwrap();
    let bench_path = dir.join("bench.json");
    write_bench_json(bench_path.to_str().unwrap(), &outcomes).unwrap();
    let json = std::fs::read_to_string(&bench_path).unwrap();
    assert!(json.contains(r#""id": "s0__note=\"q\"\\p""#), "{json}");
    let gains = parse_gains(&json).unwrap();
    assert_eq!(gains.len(), 1, "escaped id must not derail the scanner: {json}");
    assert_eq!(gains[0].0, r#"s0__note=\"q\"\\p"#);

    // the full sweep report takes the same escaping path
    let report_path = dir.join("report.json");
    write_json(report_path.to_str().unwrap(), &grid, &outcomes).unwrap();
    let full = std::fs::read_to_string(&report_path).unwrap();
    assert!(full.contains(r#""id": "s0__note=\"q\"\\p""#), "{full}");
    assert_eq!(parse_gains(&full).unwrap().len(), 1, "{full}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parse_gains_errors_on_a_record_missing_its_gain() {
    // the first record has no gain: the scan must error rather than
    // silently borrow the *next* record's gain (mis-attributed gate)
    let json = r#"{"scenarios": [
    {"id": "a", "wall_s": 1.0},
    {"id": "b", "gain": 1.5, "wall_s": 1.0}
  ]}"#;
    let err = parse_gains(json).unwrap_err().to_string();
    assert!(err.contains("scenario a"), "{err}");
    assert!(err.contains("no gain"), "{err}");
}

#[test]
fn parse_gains_reads_the_full_sweep_report_format_too() {
    let json = r#"{
  "axes": [
    {"key": "nu", "values": ["0", "0.2"]}
  ],
  "scenarios": [
    {"id": "s0__nu=0", "assignment": {"nu": "0"}, "backend": "sim", "seed": 99, "gain": 2.5, "comm_load": 1.1},
    {"id": "s1__nu=0.2", "assignment": {"nu": "0.2"}, "backend": "sim", "seed": 99, "gain": null, "comm_load": null}
  ],
  "aggregate": {"scenarios": 2, "gains": 1, "best_scenario": "s0__nu=0"}
}"#;
    let gains = parse_gains(json).unwrap();
    assert_eq!(gains.len(), 2);
    assert_eq!(gains[0], ("s0__nu=0".to_string(), Some(2.5)));
    assert_eq!(gains[1], ("s1__nu=0.2".to_string(), None));
}

#[test]
fn gain_regression_check_passes_and_fails_correctly() {
    let baseline = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 1.0},
    {"id": "b", "gain": 1.5, "wall_s": 1.0},
    {"id": "c", "gain": null, "wall_s": 1.0}
  ]}"#;
    // within tolerance: a dipped 10% (< 20%), b improved, c has no baseline
    let ok = r#"{"scenarios": [
    {"id": "a", "gain": 1.8, "wall_s": 9.0},
    {"id": "b", "gain": 1.9, "wall_s": 9.0},
    {"id": "c", "gain": null, "wall_s": 9.0}
  ]}"#;
    let table = check_gain_regression(baseline, ok, 0.2).unwrap();
    assert!(table.contains("a: gain 1.80"), "{table}");

    // a regressed 40%: fails and names the scenario
    let bad = r#"{"scenarios": [
    {"id": "a", "gain": 1.2, "wall_s": 9.0},
    {"id": "b", "gain": 1.9, "wall_s": 9.0}
  ]}"#;
    let err = check_gain_regression(baseline, bad, 0.2).unwrap_err().to_string();
    assert!(err.contains("a: gain 1.20"), "{err}");
    assert!(!err.contains("b: gain"), "b did not regress: {err}");

    // a scenario vanishing from the report is a regression too
    let missing = r#"{"scenarios": [{"id": "a", "gain": 2.0, "wall_s": 9.0}]}"#;
    let err = check_gain_regression(baseline, missing, 0.2).unwrap_err().to_string();
    assert!(err.contains("b: missing"), "{err}");

    // garbage tolerance is rejected
    assert!(check_gain_regression(baseline, ok, 1.5).is_err());
}

#[test]
fn bench_report_carries_wall_clock_and_phase_fields() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    let dir = std::env::temp_dir().join("cfl_bench_wall");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_ci.json");
    write_bench_json(path.to_str().unwrap(), &outcomes).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    for needle in ["\"epochs\": ", "\"epochs_per_sec\": ", "\"phases\": {", "\"local_grad\""] {
        assert!(json.contains(needle), "missing {needle}: {json}");
    }
    // the scanner reads its own output back, throughput included
    let records = parse_bench_records(&json).unwrap();
    assert_eq!(records.len(), 1);
    assert_eq!(records[0].id, "s0__nu=0");
    let eps = records[0].epochs_per_sec.expect("sim runs record a wall clock");
    assert!(eps > 0.0 && eps.is_finite(), "bad epochs_per_sec {eps}");
    // the legacy format (no epochs_per_sec field) still parses, as None
    let legacy = r#"{"scenarios": [{"id": "a", "gain": 2.0, "wall_s": 1.0}]}"#;
    let records = parse_bench_records(legacy).unwrap();
    assert_eq!(
        records,
        vec![BenchRecord { id: "a".into(), gain: Some(2.0), epochs_per_sec: None }]
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wall_clock_gate_catches_throughput_regressions() {
    let baseline = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 1.0, "epochs_per_sec": 100.0},
    {"id": "b", "gain": 1.5, "wall_s": 1.0, "epochs_per_sec": null}
  ]}"#;
    // a's throughput halved-and-then-some: the wall gate fails on a
    // doctored report even though every gain is healthy
    let doctored = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 9.0, "epochs_per_sec": 10.0},
    {"id": "b", "gain": 1.5, "wall_s": 9.0, "epochs_per_sec": 500.0}
  ]}"#;
    let err = check_regression(baseline, doctored, 0.2, Some(0.5)).unwrap_err().to_string();
    assert!(err.contains("a: 10.00 epochs/s below the 50.00 floor"), "{err}");
    assert!(!err.contains("b:"), "b has no baseline throughput to gate: {err}");

    // the gain-only check ignores the same report's throughput
    check_gain_regression(baseline, doctored, 0.2).unwrap();

    // throughput vanishing from the report is a wall regression
    let stripped = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 9.0},
    {"id": "b", "gain": 1.5, "wall_s": 9.0}
  ]}"#;
    let err = check_regression(baseline, stripped, 0.2, Some(0.5)).unwrap_err().to_string();
    assert!(err.contains("a: wall-clock throughput missing"), "{err}");

    // within tolerance passes, and the success output carries the
    // per-scenario delta table
    let fine = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 1.0, "epochs_per_sec": 80.0},
    {"id": "b", "gain": 1.5, "wall_s": 1.0, "epochs_per_sec": 7.0}
  ]}"#;
    let out = check_regression(baseline, fine, 0.2, Some(0.5)).unwrap();
    assert!(out.contains("a: gain 2.00"), "{out}");
    assert!(out.contains("Δgain"), "missing the delta table: {out}");
    assert!(out.contains("-20.0%"), "eps delta 80/100 should render: {out}");

    // garbage wall tolerance is rejected
    assert!(check_regression(baseline, fine, 0.2, Some(1.5)).is_err());
}

#[test]
fn unknown_scenario_in_the_report_fails_the_check() {
    let baseline = r#"{"scenarios": [{"id": "a", "gain": 2.0, "wall_s": 1.0}]}"#;
    let current = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 1.0},
    {"id": "zz", "gain": 9.0, "wall_s": 1.0}
  ]}"#;
    // an id the baseline has never seen is never silently un-gated —
    // with or without the wall gate
    let err = check_gain_regression(baseline, current, 0.2).unwrap_err().to_string();
    assert!(err.contains("zz: not in the baseline"), "{err}");
    let err = check_regression(baseline, current, 0.2, Some(0.5)).unwrap_err().to_string();
    assert!(err.contains("zz: not in the baseline"), "{err}");
}

#[test]
fn trace_decimation_keeps_first_and_last_rows() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    let dir = std::env::temp_dir().join("cfl_trace_decimate");
    std::fs::remove_dir_all(&dir).ok();

    let rows_at = |every: usize| -> Vec<String> {
        std::fs::create_dir_all(&dir).unwrap();
        write_outcome_traces_decimated(dir.to_str().unwrap(), &outcomes[0], every).unwrap();
        let text = std::fs::read_to_string(dir.join("s0__nu=0__cfl.csv")).unwrap();
        assert!(text.starts_with("time_s,epoch,nmse"), "{text}");
        let rows: Vec<String> = text.lines().skip(1).map(String::from).collect();
        std::fs::remove_dir_all(&dir).ok();
        rows
    };

    let full = rows_at(1);
    let n = full.len();
    assert!(n > 40, "tiny() runs to the epoch cap; got {n} rows");

    // N in the middle: every 7th row plus the final one, in order
    let dec = rows_at(7);
    let expect: Vec<String> = full
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 7 == 0 || i + 1 == n)
        .map(|(_, r)| r.clone())
        .collect();
    assert_eq!(dec, expect);
    assert_eq!(dec.last(), full.last(), "the final row must always survive");

    // N beyond the trace length: first and last rows only
    let sparse = rows_at(100_000);
    assert_eq!(sparse.len(), 2);
    assert_eq!(sparse[0], full[0]);
    assert_eq!(sparse[1], *full.last().unwrap());

    // a zero stride is rejected, not a divide-by-zero
    std::fs::create_dir_all(&dir).unwrap();
    let err = write_outcome_traces_decimated(dir.to_str().unwrap(), &outcomes[0], 0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("≥ 1"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// record sidecar: --resume regenerates the JSON and bench reports too
// ---------------------------------------------------------------------

#[test]
fn resume_regenerates_the_json_report_byte_identically() {
    let grid = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.2])
        .unwrap()
        .axis("delta", ["0.15", "auto"])
        .unwrap();
    let opts =
        SweepOptions { workers: 2, uncoded_baseline: true, progress: false, ..Default::default() };
    let header = scenario_csv_header(&grid);
    let ids = grid.ids();
    let dir = std::env::temp_dir().join("cfl_sweep_resume_records");
    std::fs::create_dir_all(&dir).unwrap();

    // uninterrupted run: CSV and record sidecar streamed together
    let full_csv = dir.join("full.csv");
    let full_csv = full_csv.to_str().unwrap();
    let full_sidecar = sidecar_path(full_csv);
    assert!(full_sidecar.ends_with("full.records.jsonl"), "{full_sidecar}");
    let mut merged =
        MergedScenarioCsv::create(full_csv, &header, &ids, &ResumeState::empty()).unwrap();
    let mut recs =
        RecordLog::create(&full_sidecar, &ids, &ResumeState::empty(), &SidecarRecords::empty())
            .unwrap();
    let outcomes = run_scenarios_streaming(grid.expand().unwrap(), &opts, |o| {
        merged.push(o)?;
        recs.push(o)
    })
    .unwrap();
    merged.finish().unwrap();
    let full_records = recs.finish().unwrap().expect("a fresh run has no gaps");
    assert_eq!(full_records.len(), 4);
    let (full_sweep, full_bench): (Vec<_>, Vec<_>) = full_records.into_iter().unzip();

    // the records-based writers reproduce the outcome-based reports
    // byte-for-byte — there is a single render path
    let fresh_json = dir.join("fresh.json");
    write_json(fresh_json.to_str().unwrap(), &grid, &outcomes).unwrap();
    let from_records = dir.join("from_records.json");
    write_json_records(from_records.to_str().unwrap(), &grid, &full_sweep).unwrap();
    assert_eq!(
        std::fs::read(&fresh_json).unwrap(),
        std::fs::read(&from_records).unwrap(),
        "record-based JSON report must match write_json"
    );
    let fresh_bench = dir.join("fresh_bench.json");
    write_bench_json(fresh_bench.to_str().unwrap(), &outcomes).unwrap();
    let bench_from_records = dir.join("bench_from_records.json");
    write_bench_json_records(bench_from_records.to_str().unwrap(), &full_bench).unwrap();
    assert_eq!(
        std::fs::read(&fresh_bench).unwrap(),
        std::fs::read(&bench_from_records).unwrap()
    );

    // simulate a mid-run kill: both artifacts keep the first 2 scenarios
    let full_csv_text = std::fs::read_to_string(full_csv).unwrap();
    let part_csv = dir.join("partial.csv");
    let kept: Vec<&str> = full_csv_text.lines().take(3).collect();
    std::fs::write(&part_csv, format!("{}\n", kept.join("\n"))).unwrap();
    let full_sidecar_text = std::fs::read_to_string(&full_sidecar).unwrap();
    let part_sidecar = sidecar_path(part_csv.to_str().unwrap());
    let kept_recs: Vec<&str> = full_sidecar_text.lines().take(2).collect();
    std::fs::write(&part_sidecar, format!("{}\n", kept_recs.join("\n"))).unwrap();

    let mut resume = ResumeState::load(part_csv.to_str().unwrap(), &header).unwrap();
    let records = SidecarRecords::load(&part_sidecar).unwrap();
    assert_eq!(records.len(), 2);
    resume.retain(|id| records.contains(id));
    assert_eq!(resume.len(), 2, "CSV and sidecar agree on the first 2 scenarios");
    let todo: Vec<Scenario> =
        grid.expand().unwrap().into_iter().filter(|s| !resume.contains(&s.id)).collect();
    assert_eq!(todo.len(), 2);

    // resumed run: CSV and sweep records land byte-identical; the bench
    // records keep the recovered scenarios' original wall times verbatim
    let res_csv = dir.join("resumed.csv");
    let res_csv = res_csv.to_str().unwrap();
    let mut merged = MergedScenarioCsv::create(res_csv, &header, &ids, &resume).unwrap();
    let mut recs = RecordLog::create(&sidecar_path(res_csv), &ids, &resume, &records).unwrap();
    run_scenarios_streaming(todo, &opts, |o| {
        merged.push(o)?;
        recs.push(o)
    })
    .unwrap();
    merged.finish().unwrap();
    let (res_sweep, res_bench): (Vec<_>, Vec<_>) =
        recs.finish().unwrap().expect("full record coverage").into_iter().unzip();
    assert_eq!(std::fs::read_to_string(res_csv).unwrap(), full_csv_text);
    assert_eq!(res_sweep, full_sweep, "sweep records are wall-free and deterministic");
    let resumed_json = dir.join("resumed.json");
    write_json_records(resumed_json.to_str().unwrap(), &grid, &res_sweep).unwrap();
    assert_eq!(
        std::fs::read(&fresh_json).unwrap(),
        std::fs::read(&resumed_json).unwrap(),
        "resumed JSON report must be byte-identical to the uninterrupted run's"
    );
    assert_eq!(&res_bench[..2], &full_bench[..2], "recovered bench records pass verbatim");
    let resumed_bench = dir.join("resumed_bench.json");
    write_bench_json_records(resumed_bench.to_str().unwrap(), &res_bench).unwrap();
    let parsed =
        parse_bench_records(&std::fs::read_to_string(&resumed_bench).unwrap()).unwrap();
    assert_eq!(parsed.len(), 4, "resumed bench report covers the whole grid");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pre_sidecar_resume_reports_incomplete_record_coverage() {
    // a CSV from before the sidecar existed resumes fine, but the record
    // log cannot rebuild full reports: finish() says so with None, and
    // the recovered-but-recordless scenario is skipped in the new sidecar
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let header = scenario_csv_header(&grid);
    let ids = grid.ids();
    let dir = std::env::temp_dir().join("cfl_sweep_sidecar_gap");
    std::fs::create_dir_all(&dir).unwrap();

    let csv = dir.join("sweep.csv");
    let csv = csv.to_str().unwrap();
    let mut merged =
        MergedScenarioCsv::create(csv, &header, &ids, &ResumeState::empty()).unwrap();
    run_scenarios_streaming(grid.expand().unwrap(), &opts, |o| merged.push(o)).unwrap();
    merged.finish().unwrap();
    let text = std::fs::read_to_string(csv).unwrap();
    let part_csv = dir.join("partial.csv");
    let kept: Vec<&str> = text.lines().take(2).collect();
    std::fs::write(&part_csv, format!("{}\n", kept.join("\n"))).unwrap();

    let resume = ResumeState::load(part_csv.to_str().unwrap(), &header).unwrap();
    assert_eq!(resume.len(), 1);
    let todo: Vec<Scenario> =
        grid.expand().unwrap().into_iter().filter(|s| !resume.contains(&s.id)).collect();
    let sidecar = sidecar_path(csv);
    let mut recs =
        RecordLog::create(&sidecar, &ids, &resume, &SidecarRecords::empty()).unwrap();
    run_scenarios_streaming(todo, &opts, |o| recs.push(o)).unwrap();
    assert!(
        recs.finish().unwrap().is_none(),
        "a recovered scenario without records must disable the record reports"
    );
    let lines: Vec<String> =
        std::fs::read_to_string(&sidecar).unwrap().lines().map(String::from).collect();
    assert_eq!(lines.len(), 1, "only the freshly-run scenario has a record");
    assert!(lines[0].starts_with("{\"id\": \"s1__nu=0.2\""), "{}", lines[0]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sidecar_load_round_trips_exotic_ids_and_drops_torn_lines() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let mut outcomes = run_grid(&grid, &opts).unwrap();
    // quote/backslash-bearing ids (reachable via zipped-axis values) must
    // survive the write → load round trip un-double-escaped
    let exotic = "s0__note=\"q\"\\p";
    outcomes[0].scenario.id = exotic.to_string();

    let dir = std::env::temp_dir().join("cfl_sidecar_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let sidecar = dir.join("sweep.records.jsonl");
    let sidecar = sidecar.to_str().unwrap();
    let ids = vec![exotic.to_string()];
    let mut recs =
        RecordLog::create(sidecar, &ids, &ResumeState::empty(), &SidecarRecords::empty())
            .unwrap();
    recs.push(&outcomes[0]).unwrap();
    let (sweep_rec, bench_rec) = recs.finish().unwrap().unwrap().remove(0);

    let loaded = SidecarRecords::load(sidecar).unwrap();
    assert_eq!(loaded.len(), 1);
    assert!(loaded.contains(exotic), "id must load unescaped");

    // a recovered record re-emits verbatim: mark the scenario as
    // completed (via a one-row CSV), recover through a RecordLog with
    // nothing left to run, and compare against the original render
    let text = std::fs::read_to_string(sidecar).unwrap();
    let replay = dir.join("replay.records.jsonl");
    let replay = replay.to_str().unwrap();
    let header = scenario_csv_header(&grid);
    let row = scenario_csv_row(&outcomes[0]);
    let csv_path = dir.join("fake.csv");
    {
        use crate::metrics::CsvWriter;
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut w = CsvWriter::create(csv_path.to_str().unwrap(), &header_refs).unwrap();
        let row_refs: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        w.write_row_str(&row_refs).unwrap();
        w.flush().unwrap();
    }
    let resume = ResumeState::load(csv_path.to_str().unwrap(), &header).unwrap();
    assert!(resume.contains(exotic));
    let recs = RecordLog::create(replay, &ids, &resume, &loaded).unwrap();
    let (replay_sweep, replay_bench) = recs.finish().unwrap().unwrap().remove(0);
    assert_eq!(replay_sweep, sweep_rec, "recovered sweep record must be verbatim");
    assert_eq!(replay_bench, bench_rec, "recovered bench record must be verbatim");
    assert_eq!(std::fs::read_to_string(replay).unwrap(), text);

    // a torn final line (kill landed mid-write) is dropped on load …
    let torn = dir.join("torn.records.jsonl");
    std::fs::write(&torn, format!("{text}{{\"id\": \"half")).unwrap();
    let loaded = SidecarRecords::load(torn.to_str().unwrap()).unwrap();
    assert_eq!(loaded.len(), 1, "the complete line survives, the torn line is dropped");
    // … but a malformed line elsewhere means the artifact is corrupt
    let corrupt = dir.join("corrupt.records.jsonl");
    std::fs::write(&corrupt, format!("not json\n{text}")).unwrap();
    let err = SidecarRecords::load(corrupt.to_str().unwrap()).unwrap_err().to_string();
    assert!(err.contains("corrupt record sidecar"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// scale knobs as sweep axes, and the named preset grids
// ---------------------------------------------------------------------

#[test]
fn scale_knobs_are_sweepable_axes() {
    let grid = ScenarioGrid::new(&tiny())
        .axis("participation", ["all", "count:2"])
        .unwrap()
        .axis("data_mode", ["materialized", "lean"])
        .unwrap()
        .axis("trace_points", ["0", "8"])
        .unwrap()
        .axis("agg_fanin", ["0", "4"])
        .unwrap()
        .axis("ladder_tiers", ["0", "2"])
        .unwrap();
    assert_eq!(grid.len(), 32);
    let scenarios = grid.expand().unwrap();
    let last = scenarios.last().unwrap();
    assert_eq!(last.cfg.participation, crate::config::Participation::Count(2));
    assert_eq!(last.cfg.data_mode, crate::config::DataMode::Lean);
    assert_eq!(last.cfg.trace_points, 8);
    assert_eq!(last.cfg.agg_fanin, 4);
    assert_eq!(last.cfg.ladder_tiers, 2);
    // bad values fail at declaration, like any other axis
    assert!(ScenarioGrid::new(&tiny()).axis("participation", ["sometimes"]).is_err());
    assert!(ScenarioGrid::new(&tiny()).axis("data_mode", ["sparse"]).is_err());
}

#[test]
fn scale_preset_zips_fleet_size_with_delta() {
    let preset = scenario_preset("scale").unwrap();
    assert!(!preset.uncoded_baseline, "lean presets cannot run the uncoded baseline");
    let scenarios = preset.grid.expand().unwrap();
    assert_eq!(scenarios.len(), 4, "zipped ladder, not a 4×4 product");
    let rungs: Vec<(usize, Option<f64>)> =
        scenarios.iter().map(|s| (s.cfg.n_devices, s.cfg.delta)).collect();
    assert_eq!(
        rungs,
        vec![
            (1_000, Some(0.016)),
            (10_000, Some(0.0016)),
            (100_000, Some(0.00016)),
            (1_000_000, Some(0.000016)),
        ]
    );
    for s in &scenarios {
        // constant parity block: c = δ·m = 64 rows on every rung
        let c = s.cfg.delta.unwrap() * s.cfg.total_points() as f64;
        assert!((c - 64.0).abs() < 1e-6, "{}: c = {c}", s.id);
        assert_eq!(s.cfg.data_mode, crate::config::DataMode::Lean);
        assert_eq!(s.cfg.participation, crate::config::Participation::Count(256));
        assert!(s.cfg.trace_points > 0 && s.cfg.agg_fanin > 0 && s.cfg.ladder_tiers > 0);
    }
}

#[test]
fn scale_ci_preset_is_the_single_budget_cell() {
    let preset = scenario_preset("scale-ci").unwrap();
    let scenarios = preset.grid.expand().unwrap();
    assert_eq!(scenarios.len(), 1);
    assert_eq!(scenarios[0].cfg.n_devices, 100_000);
    assert_eq!(scenarios[0].cfg.delta, Some(0.00016));
    let err = scenario_preset("warp").unwrap_err().to_string();
    assert!(err.contains("scale-ci"), "unknown preset must list the names: {err}");
}

#[test]
fn scale_preset_smallest_rung_runs_end_to_end() {
    // run the 1k-device rung for a couple of epochs: every scale knob on
    // at once (lean + sampled + tiered + tree + bounded trace) must
    // produce a well-formed RunResult through the normal sweep machinery
    let preset = scenario_preset("scale").unwrap();
    let mut cfg = preset.grid.expand().unwrap()[0].cfg.clone();
    cfg.max_epochs = 2;
    let run = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    assert_eq!(run.epoch_times.len(), 2);
    assert_eq!(run.trace.points.len(), 3, "short run keeps every trace point");
    assert!(run.setup_secs > 0.0 && run.delta > 0.0);
}
