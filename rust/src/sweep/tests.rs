use super::*;
use crate::config::{ExperimentConfig, Ini};
use crate::coordinator::{CoordinatorKind, SimCoordinator};
use crate::rng::mix_seed;

/// Small enough that a full grid (CFL + uncoded per cell) runs in
/// milliseconds; target 0 ⇒ every run goes to the epoch cap, so traces
/// have a fixed, comparable length.
fn tiny() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.n_devices = 4;
    cfg.points_per_device = 16;
    cfg.model_dim = 8;
    cfg.max_epochs = 40;
    cfg.target_nmse = 0.0;
    cfg.seed = 99;
    cfg
}

// ---------------------------------------------------------------------
// grid expansion
// ---------------------------------------------------------------------

#[test]
fn expansion_is_row_major_with_stable_ids() {
    let grid = ScenarioGrid::new(&tiny())
        .axis("nu_comp", ["0", "0.1"])
        .unwrap()
        .axis("nu_link", ["0", "0.1", "0.2"])
        .unwrap();
    assert_eq!(grid.len(), 6);
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios.len(), 6);
    // first axis slowest, second fastest — nested-for order
    let coords: Vec<(f64, f64)> =
        scenarios.iter().map(|s| (s.cfg.nu_comp, s.cfg.nu_link)).collect();
    assert_eq!(
        coords,
        vec![(0.0, 0.0), (0.0, 0.1), (0.0, 0.2), (0.1, 0.0), (0.1, 0.1), (0.1, 0.2)]
    );
    assert_eq!(scenarios[0].id, "s0__nu_comp=0__nu_link=0");
    assert_eq!(scenarios[5].id, "s5__nu_comp=0.1__nu_link=0.2");
    assert_eq!(scenarios[3].index, 3);
    assert_eq!(
        scenarios[3].assignment,
        vec![("nu_comp".to_string(), "0.1".to_string()), ("nu_link".to_string(), "0".to_string())]
    );
    // expansion is a pure function of the grid
    let again = grid.expand().unwrap();
    for (a, b) in scenarios.iter().zip(&again) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.cfg.seed, b.cfg.seed);
    }
}

#[test]
fn singleton_axis_and_axis_free_grid() {
    let grid = ScenarioGrid::new(&tiny()).axis("delta", ["0.15"]).unwrap();
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios.len(), 1);
    assert_eq!(scenarios[0].cfg.delta, Some(0.15));

    // no axes at all → the single base scenario
    let scenarios = ScenarioGrid::new(&tiny()).expand().unwrap();
    assert_eq!(scenarios.len(), 1);
    assert!(scenarios[0].assignment.is_empty());
    assert_eq!(scenarios[0].cfg.seed, tiny().seed);
}

#[test]
fn bad_axes_are_rejected_at_declaration() {
    let empty: [&str; 0] = [];
    assert!(ScenarioGrid::new(&tiny()).axis("nu_comp", empty).is_err());
    assert!(ScenarioGrid::new(&tiny()).axis("not_a_knob", ["1"]).is_err());
    assert!(ScenarioGrid::new(&tiny()).axis("nu_comp", ["zero"]).is_err());
    assert!(ScenarioGrid::new(&tiny())
        .axis("nu_comp", ["0.1"])
        .unwrap()
        .axis("nu_comp", ["0.2"])
        .is_err());
    // out-of-range values pass parsing but fail expansion's validate()
    let grid = ScenarioGrid::new(&tiny()).axis("nu_comp", ["1.5"]).unwrap();
    assert!(grid.expand().is_err());
}

#[test]
fn axis_spec_and_ini_parsing() {
    let grid = ScenarioGrid::new(&tiny()).axis_spec("delta=0.1, 0.2,auto").unwrap();
    assert_eq!(grid.axes()[0].values, vec!["0.1", "0.2", "auto"]);
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios[0].cfg.delta, Some(0.1));
    assert_eq!(scenarios[2].cfg.delta, None);
    assert!(ScenarioGrid::new(&tiny()).axis_spec("no-equals-sign").is_err());

    let ini = Ini::parse(
        "[sweep]\nnu_link = 0, 0.2\ndelta = 0.1, 0.2\nworkers = 3\nderive_seeds = true\n",
    )
    .unwrap();
    let grid = ScenarioGrid::new(&tiny()).with_ini(&ini).unwrap();
    // axes arrive in the section's alphabetical key order; reserved keys
    // (workers, derive_seeds) never become axes
    let keys: Vec<&str> = grid.axes().iter().map(|a| a.key.as_str()).collect();
    assert_eq!(keys, vec!["delta", "nu_link"]);
    assert_eq!(grid.len(), 4);
    // derive_seeds was honored
    let scenarios = grid.expand().unwrap();
    assert_eq!(scenarios[1].cfg.seed, mix_seed(tiny().seed, 1));
}

#[test]
fn compound_nu_axis_sets_both_knobs() {
    let scenarios =
        ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.3]).unwrap().expand().unwrap();
    assert_eq!(scenarios[1].cfg.nu_comp, 0.3);
    assert_eq!(scenarios[1].cfg.nu_link, 0.3);
}

#[test]
fn seed_policy_shared_derived_and_explicit() {
    // default: common random numbers — every cell shares the base seed
    let shared =
        ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.1]).unwrap().expand().unwrap();
    assert!(shared.iter().all(|s| s.cfg.seed == tiny().seed));

    // derive_seeds: per-index streams, reproducible from (base, index)
    let derived = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.1])
        .unwrap()
        .derive_seeds(true)
        .expand()
        .unwrap();
    assert_ne!(derived[0].cfg.seed, derived[1].cfg.seed);
    assert_eq!(derived[1].cfg.seed, mix_seed(tiny().seed, 1));

    // an explicit seed axis overrides both policies
    let explicit = ScenarioGrid::new(&tiny())
        .axis("seed", ["7", "8"])
        .unwrap()
        .derive_seeds(true)
        .expand()
        .unwrap();
    assert_eq!(explicit[0].cfg.seed, 7);
    assert_eq!(explicit[1].cfg.seed, 8);
}

// ---------------------------------------------------------------------
// runner determinism
// ---------------------------------------------------------------------

#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    let grid = ScenarioGrid::new(&tiny())
        .axis_f64("nu", &[0.0, 0.3])
        .unwrap()
        .axis("delta", ["0.15", "auto"])
        .unwrap()
        .derive_seeds(true);
    let serial_opts = SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let parallel_opts = SweepOptions { workers: 2, ..serial_opts.clone() };
    let serial = run_grid(&grid, &serial_opts).unwrap();
    let parallel = run_grid(&grid, &parallel_opts).unwrap();

    assert_eq!(serial.len(), 4);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.scenario.id, b.scenario.id);
        assert_eq!(a.coded.trace.points, b.coded.trace.points, "{}", a.scenario.id);
        assert_eq!(a.coded.setup_secs, b.coded.setup_secs);
        assert_eq!(a.coded.epoch_times, b.coded.epoch_times);
        assert_eq!(
            a.uncoded.as_ref().unwrap().trace.points,
            b.uncoded.as_ref().unwrap().trace.points
        );
    }

    // and the written reports agree to the byte
    let dir = std::env::temp_dir().join("cfl_sweep_determinism");
    std::fs::create_dir_all(&dir).unwrap();
    let (p1, p2) = (dir.join("serial.csv"), dir.join("parallel.csv"));
    write_scenario_csv(p1.to_str().unwrap(), &grid, &serial).unwrap();
    write_scenario_csv(p2.to_str().unwrap(), &grid, &parallel).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    let (j1, j2) = (dir.join("serial.json"), dir.join("parallel.json"));
    write_json(j1.to_str().unwrap(), &grid, &serial).unwrap();
    write_json(j2.to_str().unwrap(), &grid, &parallel).unwrap();
    assert_eq!(std::fs::read(&j1).unwrap(), std::fs::read(&j2).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runner_surfaces_scenario_failures() {
    // delta so large the optimizer cannot satisfy it → policy error,
    // reported with the scenario id attached
    let mut cfg = tiny();
    cfg.delta = Some(0.9);
    cfg.c_up_fraction = 0.9;
    let grid = ScenarioGrid::new(&cfg).axis_f64("nu", &[0.0]).unwrap();
    let opts = SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    match run_grid(&grid, &opts) {
        Err(e) => {
            let msg = format!("{e:?}");
            assert!(msg.contains("s0"), "error lost scenario context: {msg}");
        }
        Ok(outcomes) => {
            // if the tiny fleet can actually carry δ=0.9, the run must
            // at least have honored it
            assert!((outcomes[0].coded.delta - 0.9).abs() < 0.05);
        }
    }
}

#[test]
fn skip_uncoded_drops_baseline_and_gain() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.1]).unwrap();
    let opts = SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    assert!(outcomes[0].uncoded.is_none());
    assert!(outcomes[0].gain().is_none());
    assert!(outcomes[0].comm_load().is_none());
}

#[test]
fn live_backend_runs_the_grid() {
    // the same grid machinery drives the threaded coordinator: every
    // scenario still produces a full outcome (gain needs the target to be
    // reached, which a 20-epoch live demo need not guarantee — we assert
    // structure, not timing)
    let mut cfg = tiny();
    cfg.max_epochs = 20;
    let grid = ScenarioGrid::new(&cfg).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts = SweepOptions {
        workers: 1,
        uncoded_baseline: true,
        progress: false,
        backend: CoordinatorKind::Live {
            time_scale: 1e-4,
            transport: crate::transport::TransportKind::Channel,
        },
    };
    let outcomes = run_grid(&grid, &opts).unwrap();
    assert_eq!(outcomes.len(), 2);
    for o in &outcomes {
        assert_eq!(o.backend, "live");
        assert_eq!(o.coded.epoch_times.len(), 20);
        assert!(o.coded.wall_secs > 0.0);
        assert!(o.coded.setup_secs > 0.0, "live CFL must account parity setup");
        let uncoded = o.uncoded.as_ref().expect("baseline requested");
        assert_eq!(uncoded.setup_secs, 0.0);
        assert_eq!(uncoded.on_time_gradients, (cfg.n_devices * 20) as u64);
    }
    // the reports render live outcomes through the same pipeline
    let rendered = summary_table(&outcomes).render();
    assert_eq!(rendered.lines().count(), 4, "{rendered}");
}

#[test]
fn run_tasks_is_order_preserving_and_surfaces_errors() {
    // the generic pool returns outputs in input order for any worker count
    let items: Vec<usize> = (0..23).collect();
    let serial = run_tasks(items.clone(), 1, |i| Ok(i * i)).unwrap();
    let parallel = run_tasks(items, 4, |i| Ok(i * i)).unwrap();
    assert_eq!(serial, parallel);
    assert_eq!(serial[7], 49);

    // first failure in input order wins, regardless of completion order
    let err = run_tasks((0..8).collect(), 4, |i| {
        anyhow::ensure!(i != 3, "boom at {i}");
        Ok(i)
    })
    .unwrap_err();
    assert!(err.to_string().contains("boom at 3"), "{err}");

    let empty: Vec<usize> = Vec::new();
    assert!(run_tasks(empty, 4, |i| Ok(i)).unwrap().is_empty());
}

#[test]
fn coordinator_and_outcomes_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<SimCoordinator>();
    assert_send::<ScenarioOutcome>();
    assert_send::<Scenario>();
}

// ---------------------------------------------------------------------
// reports
// ---------------------------------------------------------------------

#[test]
fn gain_matrix_is_row_major_and_two_axis_only() {
    let mut cfg = tiny();
    cfg.max_epochs = 400;
    cfg.target_nmse = 2e-2; // reachable → real gains in most cells
    let grid = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &[0.0, 0.2])
        .unwrap()
        .axis_f64("nu_link", &[0.0, 0.1, 0.2])
        .unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 2, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let table = gain_matrix(&grid, &outcomes).expect("2-axis grid has a matrix");
    let rendered = table.render();
    assert!(rendered.contains("nu_comp \\ nu_link"), "{rendered}");
    // 2 data rows (one per nu_comp value)
    assert_eq!(rendered.lines().count(), 2 + 2, "{rendered}");

    let one_axis = ScenarioGrid::new(&cfg).axis_f64("nu_comp", &[0.0]).unwrap();
    let one_out = run_grid(
        &one_axis,
        &SweepOptions { workers: 1, uncoded_baseline: false, progress: false, ..Default::default() },
    )
    .unwrap();
    assert!(gain_matrix(&one_axis, &one_out).is_none());
}

#[test]
fn scenario_csv_has_axis_columns_and_json_is_well_formed() {
    let grid = ScenarioGrid::new(&tiny()).axis("delta", ["0.15", "auto"]).unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let dir = std::env::temp_dir().join("cfl_sweep_report");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("scenarios.csv");
    write_scenario_csv(csv_path.to_str().unwrap(), &grid, &outcomes).unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert!(header.starts_with("scenario,delta,delta_used,"), "{header}");
    assert!(header.ends_with("gain,comm_load,backend"), "{header}");
    assert_eq!(lines.count(), 2);
    // target 0 is unreachable → empty gain cells, never "NaN"
    assert!(!text.contains("NaN"), "{text}");

    let json_path = dir.join("report.json");
    write_json(json_path.to_str().unwrap(), &grid, &outcomes).unwrap();
    let json = std::fs::read_to_string(&json_path).unwrap();
    for needle in ["\"axes\"", "\"scenarios\"", "\"aggregate\"", "\"s0__delta=0.15\""] {
        assert!(json.contains(needle), "missing {needle}: {json}");
    }
    // balanced braces/brackets (cheap well-formedness check, no serde)
    let balance = |open: char, close: char| {
        json.chars().filter(|&c| c == open).count() == json.chars().filter(|&c| c == close).count()
    };
    assert!(balance('{', '}') && balance('[', ']'));
    assert!(!json.contains("NaN") && !json.contains("inf"), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn summary_table_renders_one_row_per_scenario() {
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let outcomes = run_grid(
        &grid,
        &SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() },
    )
    .unwrap();
    let rendered = summary_table(&outcomes).render();
    // header + separator + 2 scenarios
    assert_eq!(rendered.lines().count(), 4, "{rendered}");
    assert!(rendered.contains("s0__nu=0"), "{rendered}");
}

// ---------------------------------------------------------------------
// bench baseline pipeline

#[test]
fn bench_report_writes_and_parses_gains() {
    // a grid tiny() can't converge on (target 0) still writes a report —
    // with null gains — and the parser round-trips it
    let grid = ScenarioGrid::new(&tiny()).axis_f64("nu", &[0.0, 0.2]).unwrap();
    let opts =
        SweepOptions { workers: 1, uncoded_baseline: true, progress: false, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).unwrap();
    let dir = std::env::temp_dir().join("cfl_bench_report");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_ci.json");
    write_bench_json(path.to_str().unwrap(), &outcomes).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    let gains = parse_gains(&json).unwrap();
    assert_eq!(gains.len(), 2);
    assert_eq!(gains[0].0, "s0__nu=0");
    assert!(json.contains("\"wall_s\": "), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parse_gains_reads_the_full_sweep_report_format_too() {
    let json = r#"{
  "axes": [
    {"key": "nu", "values": ["0", "0.2"]}
  ],
  "scenarios": [
    {"id": "s0__nu=0", "assignment": {"nu": "0"}, "backend": "sim", "seed": 99, "gain": 2.5, "comm_load": 1.1},
    {"id": "s1__nu=0.2", "assignment": {"nu": "0.2"}, "backend": "sim", "seed": 99, "gain": null, "comm_load": null}
  ],
  "aggregate": {"scenarios": 2, "gains": 1, "best_scenario": "s0__nu=0"}
}"#;
    let gains = parse_gains(json).unwrap();
    assert_eq!(gains.len(), 2);
    assert_eq!(gains[0], ("s0__nu=0".to_string(), Some(2.5)));
    assert_eq!(gains[1], ("s1__nu=0.2".to_string(), None));
}

#[test]
fn gain_regression_check_passes_and_fails_correctly() {
    let baseline = r#"{"scenarios": [
    {"id": "a", "gain": 2.0, "wall_s": 1.0},
    {"id": "b", "gain": 1.5, "wall_s": 1.0},
    {"id": "c", "gain": null, "wall_s": 1.0}
  ]}"#;
    // within tolerance: a dipped 10% (< 20%), b improved, c has no baseline
    let ok = r#"{"scenarios": [
    {"id": "a", "gain": 1.8, "wall_s": 9.0},
    {"id": "b", "gain": 1.9, "wall_s": 9.0},
    {"id": "c", "gain": null, "wall_s": 9.0}
  ]}"#;
    let table = check_gain_regression(baseline, ok, 0.2).unwrap();
    assert!(table.contains("a: gain 1.80"), "{table}");

    // a regressed 40%: fails and names the scenario
    let bad = r#"{"scenarios": [
    {"id": "a", "gain": 1.2, "wall_s": 9.0},
    {"id": "b", "gain": 1.9, "wall_s": 9.0}
  ]}"#;
    let err = check_gain_regression(baseline, bad, 0.2).unwrap_err().to_string();
    assert!(err.contains("a: gain 1.20"), "{err}");
    assert!(!err.contains("b: gain"), "b did not regress: {err}");

    // a scenario vanishing from the report is a regression too
    let missing = r#"{"scenarios": [{"id": "a", "gain": 2.0, "wall_s": 9.0}]}"#;
    let err = check_gain_regression(baseline, missing, 0.2).unwrap_err().to_string();
    assert!(err.contains("b: missing"), "{err}");

    // garbage tolerance is rejected
    assert!(check_gain_regression(baseline, ok, 1.5).is_err());
}
