//! Resumable sweeps: recover completed rows from a partial per-scenario
//! CSV and merge them with freshly-run outcomes.
//!
//! A killed multi-hour grid (or one flaky live/TCP scenario) should not
//! cost the scenarios that already finished. The contract:
//!
//! 1. The runner streams rows in scenario order and each row is flushed
//!    as it lands, so a killed sweep's CSV holds every scenario that
//!    completed before the kill (plus, at worst, one torn final line —
//!    dropped on load when the file does not end in a newline).
//! 2. [`ResumeState::load`] reads that CSV back keyed by scenario id;
//!    the sweep re-runs only the ids that are missing. Two guards
//!    refuse incompatible resumes: the header must match the current
//!    grid's columns, and each recovered row's `config` fingerprint
//!    ([`ResumeState::check_compat`]) must match the current scenario's
//!    resolved config — so a changed seed or epoch budget cannot
//!    silently merge with stale rows.
//! 3. [`MergedScenarioCsv`] rewrites the CSV in grid order, interleaving
//!    recovered lines *verbatim* with freshly-rendered rows — on the
//!    deterministic sim backend the result is byte-identical to an
//!    uninterrupted run.

use super::grid::{config_fingerprint, Scenario};
use super::report::scenario_csv_row;
use super::runner::ScenarioOutcome;
use crate::metrics::CsvWriter;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;

/// Render a header/row line exactly as [`CsvWriter`] would.
fn csv_line(fields: &[String]) -> String {
    fields.iter().map(|f| CsvWriter::escape(f)).collect::<Vec<_>>().join(",")
}

/// The leading (scenario-id) field of a CSV line, unquoting if needed.
fn first_field(line: &str) -> String {
    let Some(rest) = line.strip_prefix('"') else {
        return line.split(',').next().unwrap_or("").to_string();
    };
    // quoted id: scan to the closing quote, folding "" back to "
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        if c != '"' {
            out.push(c);
        } else if chars.next() == Some('"') {
            out.push('"');
        } else {
            break;
        }
    }
    out
}

/// Completed scenario rows recovered from a prior (partial) sweep CSV,
/// keyed by scenario id. Lines are kept verbatim so the merged output
/// stays byte-identical.
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    rows: BTreeMap<String, String>,
}

impl ResumeState {
    /// No recovered rows — the fresh-run case.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse a prior per-scenario CSV. `expected_header` (from
    /// [`super::report::scenario_csv_header`] for the *current* grid)
    /// guards against resuming onto a different grid — a changed axis
    /// set changes the columns, and silently mixing them would corrupt
    /// the report. A final line not terminated by `\n` (the kill landed
    /// mid-write) is dropped.
    pub fn load(path: &str, expected_header: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --resume CSV {path}"))?;
        let mut lines: Vec<&str> = text.lines().collect();
        if !text.ends_with('\n') {
            lines.pop(); // torn final line from the kill
        }
        ensure!(!lines.is_empty(), "--resume CSV {path} has no header line");
        let header = lines.remove(0);
        let expected = csv_line(expected_header);
        ensure!(
            header == expected,
            "--resume CSV {path} header does not match this grid\n  found:    {header}\n  \
             expected: {expected}\n(a resumed sweep must use the same axes/config as the \
             original run)"
        );
        let mut rows = BTreeMap::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            rows.insert(first_field(line), line.to_string());
        }
        Ok(Self { rows })
    }

    /// Was this scenario already completed by the prior run?
    pub fn contains(&self, id: &str) -> bool {
        self.rows.contains_key(id)
    }

    /// Refuse to resume when a recovered row was produced under a
    /// different resolved config than the current grid's scenario of
    /// the same id. Axis keys/values are already pinned by the header
    /// and the id itself; this catches what they cannot — a changed
    /// seed, epoch budget, fleet, target, … — via the `config`
    /// fingerprint column every row carries.
    pub fn check_compat(&self, scenarios: &[Scenario]) -> Result<()> {
        for s in scenarios {
            let Some(line) = self.rows.get(&s.id) else { continue };
            // the fingerprint is the final column and never quoted
            let found = line.rsplit(',').next().unwrap_or("");
            let expected = config_fingerprint(&s.cfg);
            ensure!(
                found == expected,
                "--resume CSV row for {} was produced under a different config \
                 (fingerprint {found} != {expected}); resume with the exact \
                 seed/config/flags of the original run",
                s.id
            );
        }
        Ok(())
    }

    /// Number of recovered rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing was recovered.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Writes the per-scenario CSV in grid order, interleaving rows
/// recovered by [`ResumeState`] with freshly-run outcomes as they
/// stream in (via [`super::run_scenarios_streaming`]'s ordered sink).
/// Every pushed row is flushed immediately, so a kill mid-sweep keeps
/// all completed rows on disk for the *next* resume.
pub struct MergedScenarioCsv {
    csv: CsvWriter,
    /// Per grid index: the scenario id, plus its recovered line when the
    /// prior run already completed it.
    plan: Vec<(String, Option<String>)>,
    cursor: usize,
}

impl MergedScenarioCsv {
    /// Create the output CSV (header included) for a grid whose
    /// expansion ids are `ids`, immediately writing any recovered rows
    /// that precede the first scenario left to run.
    pub fn create(
        path: &str,
        header: &[String],
        ids: &[String],
        resume: &ResumeState,
    ) -> Result<Self> {
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let csv = CsvWriter::create(path, &header_refs)?;
        let plan = ids
            .iter()
            .map(|id| (id.clone(), resume.rows.get(id).cloned()))
            .collect();
        let mut merged = Self { csv, plan, cursor: 0 };
        merged.flush_recovered()?;
        Ok(merged)
    }

    fn flush_recovered(&mut self) -> Result<()> {
        while let Some((_, Some(line))) = self.plan.get(self.cursor) {
            self.csv.write_raw_line(line)?;
            self.cursor += 1;
        }
        self.csv.flush()
    }

    /// Append one freshly-run outcome's row. Outcomes must arrive in
    /// grid order over the *remaining* (non-recovered) scenarios — which
    /// is exactly the order the streaming runner delivers.
    pub fn push(&mut self, o: &ScenarioOutcome) -> Result<()> {
        ensure!(
            self.cursor < self.plan.len() && self.plan[self.cursor].0 == o.scenario.id,
            "scenario {} arrived out of grid order (expected {})",
            o.scenario.id,
            self.plan
                .get(self.cursor)
                .map(|(id, _)| id.as_str())
                .unwrap_or("no further scenarios")
        );
        let row = scenario_csv_row(o);
        let row_refs: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        self.csv.write_row_str(&row_refs)?;
        self.cursor += 1;
        self.flush_recovered()
    }

    /// Finish the merge: every grid scenario must have been written
    /// (recovered or fresh).
    pub fn finish(mut self) -> Result<()> {
        ensure!(
            self.cursor == self.plan.len(),
            "sweep ended with {} of {} scenario rows written",
            self.cursor,
            self.plan.len()
        );
        self.csv.flush()
    }
}
