//! Resumable sweeps: recover completed rows from a partial per-scenario
//! CSV and merge them with freshly-run outcomes.
//!
//! A killed multi-hour grid (or one flaky live/TCP scenario) should not
//! cost the scenarios that already finished. The contract:
//!
//! 1. The runner streams rows in scenario order and each row is flushed
//!    as it lands, so a killed sweep's CSV holds every scenario that
//!    completed before the kill (plus, at worst, one torn final line —
//!    dropped on load when the file does not end in a newline).
//! 2. [`ResumeState::load`] reads that CSV back keyed by scenario id;
//!    the sweep re-runs only the ids that are missing. Two guards
//!    refuse incompatible resumes: the header must match the current
//!    grid's columns, and each recovered row's `config` fingerprint
//!    ([`ResumeState::check_compat`]) must match the current scenario's
//!    resolved config — so a changed seed or epoch budget cannot
//!    silently merge with stale rows.
//! 3. [`MergedScenarioCsv`] rewrites the CSV in grid order, interleaving
//!    recovered lines *verbatim* with freshly-rendered rows — on the
//!    deterministic sim backend the result is byte-identical to an
//!    uninterrupted run.

use super::baseline::{bench_json_record, record_end, str_end};
use super::grid::{config_fingerprint, Scenario};
use super::json::escape as json_escape;
use super::report::{scenario_csv_row, scenario_json_record};
use super::runner::ScenarioOutcome;
use crate::metrics::CsvWriter;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;

/// Render a header/row line exactly as [`CsvWriter`] would.
fn csv_line(fields: &[String]) -> String {
    fields.iter().map(|f| CsvWriter::escape(f)).collect::<Vec<_>>().join(",")
}

/// The leading (scenario-id) field of a CSV line, unquoting if needed.
fn first_field(line: &str) -> String {
    let Some(rest) = line.strip_prefix('"') else {
        return line.split(',').next().unwrap_or("").to_string();
    };
    // quoted id: scan to the closing quote, folding "" back to "
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        if c != '"' {
            out.push(c);
        } else if chars.next() == Some('"') {
            out.push('"');
        } else {
            break;
        }
    }
    out
}

/// Completed scenario rows recovered from a prior (partial) sweep CSV,
/// keyed by scenario id. Lines are kept verbatim so the merged output
/// stays byte-identical.
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    rows: BTreeMap<String, String>,
}

impl ResumeState {
    /// No recovered rows — the fresh-run case.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse a prior per-scenario CSV. `expected_header` (from
    /// [`super::report::scenario_csv_header`] for the *current* grid)
    /// guards against resuming onto a different grid — a changed axis
    /// set changes the columns, and silently mixing them would corrupt
    /// the report. A final line not terminated by `\n` (the kill landed
    /// mid-write) is dropped.
    pub fn load(path: &str, expected_header: &[String]) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --resume CSV {path}"))?;
        let mut lines: Vec<&str> = text.lines().collect();
        if !text.ends_with('\n') {
            lines.pop(); // torn final line from the kill
        }
        ensure!(!lines.is_empty(), "--resume CSV {path} has no header line");
        let header = lines.remove(0);
        let expected = csv_line(expected_header);
        ensure!(
            header == expected,
            "--resume CSV {path} header does not match this grid\n  found:    {header}\n  \
             expected: {expected}\n(a resumed sweep must use the same axes/config as the \
             original run)"
        );
        let mut rows = BTreeMap::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            rows.insert(first_field(line), line.to_string());
        }
        Ok(Self { rows })
    }

    /// Was this scenario already completed by the prior run?
    pub fn contains(&self, id: &str) -> bool {
        self.rows.contains_key(id)
    }

    /// Refuse to resume when a recovered row was produced under a
    /// different resolved config than the current grid's scenario of
    /// the same id. Axis keys/values are already pinned by the header
    /// and the id itself; this catches what they cannot — a changed
    /// seed, epoch budget, fleet, target, … — via the `config`
    /// fingerprint column every row carries.
    pub fn check_compat(&self, scenarios: &[Scenario]) -> Result<()> {
        for s in scenarios {
            let Some(line) = self.rows.get(&s.id) else { continue };
            // the fingerprint is the final column and never quoted
            let found = line.rsplit(',').next().unwrap_or("");
            let expected = config_fingerprint(&s.cfg);
            ensure!(
                found == expected,
                "--resume CSV row for {} was produced under a different config \
                 (fingerprint {found} != {expected}); resume with the exact \
                 seed/config/flags of the original run",
                s.id
            );
        }
        Ok(())
    }

    /// Number of recovered rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing was recovered.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Drop recovered rows whose id fails `keep` — used to narrow CSV
    /// recovery to scenarios the record sidecar also holds, so the three
    /// artifacts (CSV, JSON, bench) stay mutually consistent: a scenario
    /// whose row survived a kill but whose record did not is simply
    /// re-run.
    pub fn retain(&mut self, mut keep: impl FnMut(&str) -> bool) {
        self.rows.retain(|id, _| keep(id));
    }
}

/// Path of the record sidecar a sweep streams next to its per-scenario
/// CSV (`<csv stem>.records.jsonl`): one line per completed scenario
/// carrying the pre-rendered JSON-report and bench-report records, which
/// is what lets `--resume` regenerate *all three* artifacts, not just
/// the CSV.
pub fn sidecar_path(csv_path: &str) -> String {
    format!("{}.records.jsonl", csv_path.strip_suffix(".csv").unwrap_or(csv_path))
}

/// Reverse of the report writers' JSON string escaping (see
/// `sweep::json::escape`): `\" \\ \n \r \t \uXXXX`.
fn json_unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('/') => out.push('/'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                ensure!(hex.len() == 4, "truncated \\u escape");
                let code = u32::from_str_radix(&hex, 16)
                    .with_context(|| format!("bad \\u escape {hex}"))?;
                out.push(char::from_u32(code).context("bad \\u codepoint")?);
            }
            other => bail!("unsupported JSON escape \\{other:?}"),
        }
    }
    Ok(out)
}

/// Split one `{…}` object off the front of `s`, returning it (braces
/// included) and the rest.
fn take_object(s: &str) -> Result<(&str, &str)> {
    ensure!(s.starts_with('{'), "expected an object, found: {s}");
    let end = record_end(&s[1..]);
    ensure!(end < s.len() - 1, "unterminated object: {s}");
    Ok((&s[..end + 2], &s[end + 2..]))
}

/// One sidecar line: `{"id": "<escaped>", "sweep": {…}, "bench": {…}}`.
fn parse_record_line(line: &str) -> Result<(String, String, String)> {
    let rest = line.strip_prefix("{\"id\": \"").context("sidecar line has no leading id")?;
    let end = str_end(rest).context("unterminated sidecar id")?;
    let id = json_unescape(&rest[..end])?;
    let tail =
        rest[end + 1..].strip_prefix(", \"sweep\": ").context("sidecar line has no sweep record")?;
    let (sweep, tail) = take_object(tail)?;
    let tail = tail.strip_prefix(", \"bench\": ").context("sidecar line has no bench record")?;
    let (bench, tail) = take_object(tail)?;
    ensure!(tail == "}", "trailing bytes after the sidecar records: {tail}");
    Ok((id, sweep.to_string(), bench.to_string()))
}

/// Pre-rendered report records recovered from a prior sweep's sidecar,
/// keyed by scenario id. Records are kept verbatim, so a resumed
/// sweep's JSON report is byte-identical to an uninterrupted run's and
/// the bench report keeps the recovered scenarios' original wall times.
#[derive(Clone, Debug, Default)]
pub struct SidecarRecords {
    rows: BTreeMap<String, (String, String)>,
}

impl SidecarRecords {
    /// No recovered records — the fresh-run (or sidecar-less) case.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse a prior sweep's sidecar. As with the CSV, a final line not
    /// terminated by `\n` is the kill landing mid-write and is dropped;
    /// a malformed line anywhere *else* means the artifact is corrupt
    /// and is an error.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading resume record sidecar {path}"))?;
        let complete = match text.strip_suffix('\n') {
            Some(t) => t,
            None => match text.rfind('\n') {
                Some(i) => &text[..i], // torn final line from the kill
                None => "",
            },
        };
        let mut rows = BTreeMap::new();
        for line in complete.lines() {
            if line.is_empty() {
                continue;
            }
            let (id, sweep, bench) = parse_record_line(line)
                .with_context(|| format!("corrupt record sidecar {path}"))?;
            rows.insert(id, (sweep, bench));
        }
        Ok(Self { rows })
    }

    /// Were this scenario's records already persisted by the prior run?
    pub fn contains(&self, id: &str) -> bool {
        self.rows.contains_key(id)
    }

    /// Number of recovered record pairs.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing was recovered.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

enum RecordSlot {
    /// Recovered from the prior run's sidecar: re-emitted verbatim.
    Recovered(String, String),
    /// Awaiting this run's freshly-pushed outcome.
    Fresh,
    /// CSV row recovered but no sidecar record (a pre-sidecar CSV):
    /// the scenario is not re-run, so full reports cannot be rebuilt.
    Gap,
}

/// Streams the record sidecar in grid order as scenarios finish —
/// the report-record counterpart of [`MergedScenarioCsv`], flushed per
/// line so a kill keeps every completed scenario's records on disk.
/// [`RecordLog::finish`] hands back the full in-order record set when
/// coverage is complete, which is what the report writers consume.
pub struct RecordLog {
    out: std::io::BufWriter<std::fs::File>,
    plan: Vec<(String, RecordSlot)>,
    cursor: usize,
    collected: Vec<(String, String)>,
    gaps: usize,
}

impl RecordLog {
    /// Create the sidecar at `path` for a grid expanding to `ids`.
    /// `resume` decides which scenarios are *not* re-run this sweep;
    /// `records` holds their recovered record pairs (a resumed id
    /// missing from `records` — a pre-sidecar CSV — becomes a gap: its
    /// line is skipped and [`RecordLog::finish`] reports incomplete
    /// coverage).
    pub fn create(
        path: &str,
        ids: &[String],
        resume: &ResumeState,
        records: &SidecarRecords,
    ) -> Result<Self> {
        let plan = ids
            .iter()
            .map(|id| {
                let slot = if resume.contains(id) {
                    match records.rows.get(id) {
                        Some((s, b)) => RecordSlot::Recovered(s.clone(), b.clone()),
                        None => RecordSlot::Gap,
                    }
                } else {
                    RecordSlot::Fresh
                };
                (id.clone(), slot)
            })
            .collect();
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating record sidecar {path}"))?;
        let mut log = Self {
            out: std::io::BufWriter::new(file),
            plan,
            cursor: 0,
            collected: Vec::new(),
            gaps: 0,
        };
        log.advance()?;
        Ok(log)
    }

    fn write_line(&mut self, id: &str, sweep: &str, bench: &str) -> Result<()> {
        writeln!(
            self.out,
            "{{\"id\": \"{}\", \"sweep\": {sweep}, \"bench\": {bench}}}",
            json_escape(id)
        )
        .context("writing record sidecar line")?;
        self.out.flush().context("flushing record sidecar")
    }

    fn advance(&mut self) -> Result<()> {
        while self.cursor < self.plan.len() {
            match &self.plan[self.cursor] {
                (id, RecordSlot::Recovered(sweep, bench)) => {
                    let (id, sweep, bench) = (id.clone(), sweep.clone(), bench.clone());
                    self.write_line(&id, &sweep, &bench)?;
                    self.collected.push((sweep, bench));
                }
                (_, RecordSlot::Gap) => self.gaps += 1,
                (_, RecordSlot::Fresh) => break,
            }
            self.cursor += 1;
        }
        Ok(())
    }

    /// Append one freshly-run outcome's records. As with
    /// [`MergedScenarioCsv::push`], outcomes must arrive in grid order
    /// over the scenarios left to run.
    pub fn push(&mut self, o: &ScenarioOutcome) -> Result<()> {
        match self.plan.get(self.cursor) {
            Some((id, RecordSlot::Fresh)) if *id == o.scenario.id => {}
            other => bail!(
                "scenario {} arrived out of grid order (expected {})",
                o.scenario.id,
                other.map(|(id, _)| id.as_str()).unwrap_or("no further scenarios")
            ),
        }
        let sweep = scenario_json_record(o);
        let bench = bench_json_record(o);
        self.write_line(&o.scenario.id, &sweep, &bench)?;
        self.collected.push((sweep, bench));
        self.cursor += 1;
        self.advance()
    }

    /// Finish the log: every grid scenario must have been visited. When
    /// coverage is complete, returns the full record set in grid order —
    /// `(sweep record, bench record)` per scenario — for the report
    /// writers; `None` when pre-sidecar gaps left recovered scenarios
    /// without records (the reports then fall back to fresh outcomes
    /// only).
    pub fn finish(mut self) -> Result<Option<Vec<(String, String)>>> {
        ensure!(
            self.cursor == self.plan.len(),
            "sweep ended with {} of {} scenario records written",
            self.cursor,
            self.plan.len()
        );
        self.out.flush().context("flushing record sidecar")?;
        Ok((self.gaps == 0).then_some(self.collected))
    }
}

/// Writes the per-scenario CSV in grid order, interleaving rows
/// recovered by [`ResumeState`] with freshly-run outcomes as they
/// stream in (via [`super::run_scenarios_streaming`]'s ordered sink).
/// Every pushed row is flushed immediately, so a kill mid-sweep keeps
/// all completed rows on disk for the *next* resume.
pub struct MergedScenarioCsv {
    csv: CsvWriter,
    /// Per grid index: the scenario id, plus its recovered line when the
    /// prior run already completed it.
    plan: Vec<(String, Option<String>)>,
    cursor: usize,
}

impl MergedScenarioCsv {
    /// Create the output CSV (header included) for a grid whose
    /// expansion ids are `ids`, immediately writing any recovered rows
    /// that precede the first scenario left to run.
    pub fn create(
        path: &str,
        header: &[String],
        ids: &[String],
        resume: &ResumeState,
    ) -> Result<Self> {
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let csv = CsvWriter::create(path, &header_refs)?;
        let plan = ids
            .iter()
            .map(|id| (id.clone(), resume.rows.get(id).cloned()))
            .collect();
        let mut merged = Self { csv, plan, cursor: 0 };
        merged.flush_recovered()?;
        Ok(merged)
    }

    fn flush_recovered(&mut self) -> Result<()> {
        while let Some((_, Some(line))) = self.plan.get(self.cursor) {
            self.csv.write_raw_line(line)?;
            self.cursor += 1;
        }
        self.csv.flush()
    }

    /// Append one freshly-run outcome's row. Outcomes must arrive in
    /// grid order over the *remaining* (non-recovered) scenarios — which
    /// is exactly the order the streaming runner delivers.
    pub fn push(&mut self, o: &ScenarioOutcome) -> Result<()> {
        ensure!(
            self.cursor < self.plan.len() && self.plan[self.cursor].0 == o.scenario.id,
            "scenario {} arrived out of grid order (expected {})",
            o.scenario.id,
            self.plan
                .get(self.cursor)
                .map(|(id, _)| id.as_str())
                .unwrap_or("no further scenarios")
        );
        let row = scenario_csv_row(o);
        let row_refs: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        self.csv.write_row_str(&row_refs)?;
        self.cursor += 1;
        self.flush_recovered()
    }

    /// Finish the merge: every grid scenario must have been written
    /// (recovered or fresh).
    pub fn finish(mut self) -> Result<()> {
        ensure!(
            self.cursor == self.plan.len(),
            "sweep ended with {} of {} scenario rows written",
            self.cursor,
            self.plan.len()
        );
        self.csv.flush()
    }
}
