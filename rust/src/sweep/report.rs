//! Sweep reporting: per-scenario CSV, aggregate coding-gain matrices,
//! per-scenario trace export, and a hand-rolled JSON report (no serde
//! offline) — all built on [`crate::metrics::Table`] /
//! [`crate::metrics::CsvWriter`] and free of wall-clock values, so
//! report bytes are identical for any worker count.

use super::grid::{config_fingerprint, ScenarioGrid};
use super::json::{escape as json_escape, num as json_num, opt as json_opt};
use super::runner::ScenarioOutcome;
use crate::metrics::{CsvWriter, Table};
use crate::stats::Summary;
use anyhow::{Context, Result};

fn fmt_opt(v: Option<f64>) -> String {
    v.map(|v| v.to_string()).unwrap_or_default()
}

/// Per-scenario CSV header: `scenario`, one column per axis (zipped or
/// not), then the headline metric columns.
pub fn scenario_csv_header(grid: &ScenarioGrid) -> Vec<String> {
    let mut header: Vec<String> = vec!["scenario".into()];
    header.extend(grid.axes().iter().map(|a| a.key.clone()));
    // "delta_used": the δ the run actually used (an axis may be named
    // "delta", which gets its own assignment column). "config" is the
    // resolved-config fingerprint --resume validates against.
    for col in [
        "delta_used", "epoch_deadline_s", "setup_s", "epochs", "final_nmse", "t_cfl_s",
        "t_uncoded_s", "gain", "comm_load", "backend", "config",
    ] {
        header.push(col.into());
    }
    header
}

/// One scenario's CSV row, field-aligned with [`scenario_csv_header`].
pub fn scenario_csv_row(o: &ScenarioOutcome) -> Vec<String> {
    let target = o.scenario.cfg.target_nmse;
    let mut row: Vec<String> = vec![o.scenario.id.clone()];
    row.extend(o.scenario.assignment.iter().map(|(_, v)| v.clone()));
    row.push(o.coded.delta.to_string());
    row.push(o.coded.epoch_deadline.to_string());
    row.push(o.coded.setup_secs.to_string());
    row.push(o.coded.epoch_times.len().to_string());
    row.push(fmt_opt(o.coded.trace.final_nmse()));
    row.push(fmt_opt(o.coded.time_to(target)));
    row.push(fmt_opt(o.uncoded.as_ref().and_then(|u| u.time_to(target))));
    row.push(fmt_opt(o.gain()));
    row.push(fmt_opt(o.comm_load()));
    row.push(o.backend.to_string());
    row.push(config_fingerprint(&o.scenario.cfg));
    row
}

/// Write one CSV row per scenario: id, the axis assignment columns, and
/// the headline metrics (times/gains at the scenario's target NMSE).
pub fn write_scenario_csv(
    path: &str,
    grid: &ScenarioGrid,
    outcomes: &[ScenarioOutcome],
) -> Result<()> {
    let header = scenario_csv_header(grid);
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::create(path, &header_refs)?;
    for o in outcomes {
        let row = scenario_csv_row(o);
        let row_refs: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        csv.write_row_str(&row_refs)?;
    }
    csv.flush()
}

/// Sanitize a scenario id into a trace-file stem: the characters ids are
/// built from pass through, anything filesystem-hostile becomes `_`.
pub fn trace_file_stem(id: &str) -> String {
    id.chars()
        .map(|c| if c.is_ascii_alphanumeric() || "._=+-".contains(c) { c } else { '_' })
        .collect()
}

/// Write one per-epoch NMSE/time trace CSV per run under `dir`:
/// `<id>__cfl.csv` and (when the baseline ran) `<id>__uncoded.csv` —
/// identical format for the sim and live backends, since both report
/// through [`RunResult`]'s simulated-seconds trace.
///
/// [`RunResult`]: crate::coordinator::RunResult
pub fn write_outcome_traces(dir: &str, o: &ScenarioOutcome) -> Result<()> {
    write_outcome_traces_decimated(dir, o, 1)
}

/// [`write_outcome_traces`] keeping only every `every`-th epoch row
/// (plus the first and last — see
/// [`crate::coordinator::RunResult::write_trace_csv_decimated`]), the
/// `cfl sweep --traces-dir … --trace-decimate N` export for long sweeps
/// whose full traces would dwarf the report.
pub fn write_outcome_traces_decimated(dir: &str, o: &ScenarioOutcome, every: usize) -> Result<()> {
    let stem = trace_file_stem(&o.scenario.id);
    let ctx = |what: &str| format!("scenario {}: writing {what} trace", o.scenario.id);
    o.coded
        .write_trace_csv_decimated(&format!("{dir}/{stem}__cfl.csv"), every)
        .with_context(|| ctx("CFL"))?;
    if let Some(u) = &o.uncoded {
        u.write_trace_csv_decimated(&format!("{dir}/{stem}__uncoded.csv"), every)
            .with_context(|| ctx("uncoded"))?;
    }
    Ok(())
}

/// Human summary: one row per scenario.
pub fn summary_table(outcomes: &[ScenarioOutcome]) -> Table {
    let mut table = Table::new(&[
        "scenario", "δ", "t* (s)", "setup (s)", "epochs", "final NMSE", "t_CFL (s)",
        "t_unc (s)", "gain",
    ]);
    for o in outcomes {
        let target = o.scenario.cfg.target_nmse;
        let fmt_t =
            |t: Option<f64>| t.map(|t| format!("{t:.1}")).unwrap_or_else(|| "—".into());
        table.row(&[
            o.scenario.id.clone(),
            format!("{:.3}", o.coded.delta),
            if o.coded.epoch_deadline.is_finite() {
                format!("{:.3}", o.coded.epoch_deadline)
            } else {
                "inf".into()
            },
            format!("{:.1}", o.coded.setup_secs),
            format!("{}", o.coded.epoch_times.len()),
            o.coded
                .trace
                .final_nmse()
                .map(|n| format!("{n:.3e}"))
                .unwrap_or_else(|| "—".into()),
            fmt_t(o.coded.time_to(target)),
            fmt_t(o.uncoded.as_ref().and_then(|u| u.time_to(target))),
            o.gain().map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    table
}

/// For exactly-2-dimension grids (two axes, or two zip groups, or one of
/// each): the coding-gain matrix with the first dimension as rows and
/// the second as columns (the Fig. 4 presentation). Cells are looked up
/// by scenario id, so a subset of outcomes — a resumed sweep's freshly
/// run remainder, say — renders with `—` in the missing cells instead of
/// refusing to render at all.
pub fn gain_matrix(grid: &ScenarioGrid, outcomes: &[ScenarioOutcome]) -> Option<Table> {
    let dims = grid.dims();
    if dims.len() != 2 || outcomes.is_empty() {
        return None;
    }
    let by_id: std::collections::HashMap<&str, &ScenarioOutcome> =
        outcomes.iter().map(|o| (o.scenario.id.as_str(), o)).collect();
    let ids = grid.ids();
    let (row_dim, col_dim) = (&dims[0], &dims[1]);
    let mut header = vec![format!("{} \\ {}", grid.dim_key(row_dim), grid.dim_key(col_dim))];
    header.extend(grid.dim_labels(col_dim));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for (r, row_label) in grid.dim_labels(row_dim).into_iter().enumerate() {
        let mut cells = vec![row_label];
        for c in 0..col_dim.len {
            // row-major expansion: dimension 0 slowest, dimension 1 fastest
            let id = ids[r * col_dim.len + c].as_str();
            let gain = by_id.get(id).and_then(|o| o.gain());
            cells.push(gain.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()));
        }
        table.row(&cells);
    }
    Some(table)
}

/// Aggregate gain statistics across the grid (count, mean, min, max, and
/// the best scenario id). `None` when no scenario produced a gain.
pub fn gain_stats(outcomes: &[ScenarioOutcome]) -> Option<(Summary, String)> {
    let mut summary = Summary::new();
    let mut best: Option<(f64, &str)> = None;
    for o in outcomes {
        if let Some(g) = o.gain() {
            summary.push(g);
            if best.map(|(bg, _)| g > bg).unwrap_or(true) {
                best = Some((g, o.scenario.id.as_str()));
            }
        }
    }
    best.map(|(_, id)| (summary, id.to_string()))
}

/// Render one scenario's report record — the single-line `{…}` object
/// [`write_json`] emits per scenario. Free of wall-clock values, so the
/// bytes are a pure function of the scenario config; the resume path
/// persists these as scenarios finish and later re-assembles the full
/// report from recovered + fresh records ([`write_json_records`]).
pub fn scenario_json_record(o: &ScenarioOutcome) -> String {
    let target = o.scenario.cfg.target_nmse;
    let mut s = format!("{{\"id\": \"{}\", ", json_escape(&o.scenario.id));
    s.push_str("\"assignment\": {");
    for (j, (k, v)) in o.scenario.assignment.iter().enumerate() {
        if j > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
    }
    s.push_str("}, ");
    s.push_str(&format!("\"backend\": \"{}\", ", json_escape(o.backend)));
    s.push_str(&format!("\"seed\": {}, ", o.scenario.cfg.seed));
    s.push_str(&format!("\"delta\": {}, ", json_num(o.coded.delta)));
    s.push_str(&format!("\"epoch_deadline_s\": {}, ", json_num(o.coded.epoch_deadline)));
    s.push_str(&format!("\"setup_s\": {}, ", json_num(o.coded.setup_secs)));
    s.push_str(&format!("\"epochs\": {}, ", o.coded.epoch_times.len()));
    s.push_str(&format!("\"final_nmse\": {}, ", json_opt(o.coded.trace.final_nmse())));
    s.push_str(&format!("\"t_cfl_s\": {}, ", json_opt(o.coded.time_to(target))));
    s.push_str(&format!(
        "\"t_uncoded_s\": {}, ",
        json_opt(o.uncoded.as_ref().and_then(|u| u.time_to(target)))
    ));
    s.push_str(&format!("\"gain\": {}, ", json_opt(o.gain())));
    s.push_str(&format!("\"comm_load\": {}}}", json_opt(o.comm_load())));
    s
}

/// Write the machine-readable report: axes, zip groups, per-scenario
/// metrics, and the gain aggregate.
pub fn write_json(path: &str, grid: &ScenarioGrid, outcomes: &[ScenarioOutcome]) -> Result<()> {
    let records: Vec<String> = outcomes.iter().map(scenario_json_record).collect();
    write_json_records(path, grid, &records)
}

/// [`write_json`] from pre-rendered scenario records — the resume path.
/// The envelope (axes, zips, aggregate) is recomputed from the grid and
/// the records' `gain` fields, and `f64` text round-trips exactly
/// (shortest-representation `Display`), so a resumed sim sweep's report
/// is byte-identical to an uninterrupted run's.
pub fn write_json_records(path: &str, grid: &ScenarioGrid, records: &[String]) -> Result<()> {
    use super::baseline::{field_raw, parse_opt_f64, record_id};

    let mut s = String::from("{\n  \"axes\": [");
    for (i, axis) in grid.axes().iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\n    {{\"key\": \"{}\", \"values\": [", json_escape(&axis.key)));
        for (j, v) in axis.values.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", json_escape(v)));
        }
        s.push_str("]}");
    }
    s.push_str("\n  ],\n  \"zips\": [");
    for (i, group) in grid.zip_keys().iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push('[');
        for (j, key) in group.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", json_escape(key)));
        }
        s.push(']');
    }
    s.push_str("],\n  \"scenarios\": [");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    ");
        s.push_str(r);
    }
    s.push_str("\n  ],\n  \"aggregate\": ");
    // the gain aggregate, mirroring gain_stats() over parsed records:
    // first strict maximum wins, ids stay in their escaped form
    let mut summary = Summary::new();
    let mut best: Option<(f64, String)> = None;
    for r in records {
        let id = record_id(r)?;
        let graw = field_raw(r, "gain")
            .with_context(|| format!("scenario {id}: record has no gain field"))?;
        if let Some(g) = parse_opt_f64(&id, "gain", graw)? {
            summary.push(g);
            if best.as_ref().map(|(bg, _)| g > *bg).unwrap_or(true) {
                best = Some((g, id));
            }
        }
    }
    match best {
        Some((_, best_id)) => s.push_str(&format!(
            "{{\"scenarios\": {}, \"gains\": {}, \"gain_mean\": {}, \"gain_min\": {}, \
             \"gain_max\": {}, \"best_scenario\": \"{best_id}\"}}",
            records.len(),
            summary.count(),
            json_num(summary.mean()),
            json_num(summary.min()),
            json_num(summary.max()),
        )),
        None => s.push_str(&format!(
            "{{\"scenarios\": {}, \"gains\": 0}}",
            records.len()
        )),
    }
    s.push_str("\n}\n");

    let path_ref = std::path::Path::new(path);
    if let Some(dir) = path_ref.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
        }
    }
    std::fs::write(path_ref, s).with_context(|| format!("writing {path}"))
}
