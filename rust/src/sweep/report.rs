//! Sweep reporting: per-scenario CSV, aggregate coding-gain matrices,
//! and a hand-rolled JSON report (no serde offline) — all built on
//! [`crate::metrics::Table`] / [`crate::metrics::CsvWriter`] and free of
//! wall-clock values, so report bytes are identical for any worker count.

use super::grid::ScenarioGrid;
use super::runner::ScenarioOutcome;
use crate::metrics::{CsvWriter, Table};
use crate::stats::Summary;
use anyhow::{Context, Result};

fn fmt_opt(v: Option<f64>) -> String {
    v.map(|v| v.to_string()).unwrap_or_default()
}

/// Write one CSV row per scenario: id, the axis assignment columns, and
/// the headline metrics (times/gains at the scenario's target NMSE).
pub fn write_scenario_csv(
    path: &str,
    grid: &ScenarioGrid,
    outcomes: &[ScenarioOutcome],
) -> Result<()> {
    let mut header: Vec<String> = vec!["scenario".into()];
    header.extend(grid.axes().iter().map(|a| a.key.clone()));
    // "delta_used": the δ the run actually used (an axis may be named
    // "delta", which gets its own assignment column)
    for col in [
        "delta_used", "epoch_deadline_s", "setup_s", "epochs", "final_nmse", "t_cfl_s",
        "t_uncoded_s", "gain", "comm_load", "backend",
    ] {
        header.push(col.into());
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::create(path, &header_refs)?;
    for o in outcomes {
        let target = o.scenario.cfg.target_nmse;
        let mut row: Vec<String> = vec![o.scenario.id.clone()];
        row.extend(o.scenario.assignment.iter().map(|(_, v)| v.clone()));
        row.push(o.coded.delta.to_string());
        row.push(o.coded.epoch_deadline.to_string());
        row.push(o.coded.setup_secs.to_string());
        row.push(o.coded.epoch_times.len().to_string());
        row.push(fmt_opt(o.coded.trace.final_nmse()));
        row.push(fmt_opt(o.coded.time_to(target)));
        row.push(fmt_opt(o.uncoded.as_ref().and_then(|u| u.time_to(target))));
        row.push(fmt_opt(o.gain()));
        row.push(fmt_opt(o.comm_load()));
        row.push(o.backend.to_string());
        let row_refs: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
        csv.write_row_str(&row_refs)?;
    }
    csv.flush()
}

/// Human summary: one row per scenario.
pub fn summary_table(outcomes: &[ScenarioOutcome]) -> Table {
    let mut table = Table::new(&[
        "scenario", "δ", "t* (s)", "setup (s)", "epochs", "final NMSE", "t_CFL (s)",
        "t_unc (s)", "gain",
    ]);
    for o in outcomes {
        let target = o.scenario.cfg.target_nmse;
        let fmt_t =
            |t: Option<f64>| t.map(|t| format!("{t:.1}")).unwrap_or_else(|| "—".into());
        table.row(&[
            o.scenario.id.clone(),
            format!("{:.3}", o.coded.delta),
            if o.coded.epoch_deadline.is_finite() {
                format!("{:.3}", o.coded.epoch_deadline)
            } else {
                "inf".into()
            },
            format!("{:.1}", o.coded.setup_secs),
            format!("{}", o.coded.epoch_times.len()),
            o.coded
                .trace
                .final_nmse()
                .map(|n| format!("{n:.3e}"))
                .unwrap_or_else(|| "—".into()),
            fmt_t(o.coded.time_to(target)),
            fmt_t(o.uncoded.as_ref().and_then(|u| u.time_to(target))),
            o.gain().map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()),
        ]);
    }
    table
}

/// For exactly-2-axis grids: the coding-gain matrix with the first axis
/// as rows and the second as columns (the Fig. 4 presentation).
pub fn gain_matrix(grid: &ScenarioGrid, outcomes: &[ScenarioOutcome]) -> Option<Table> {
    let axes = grid.axes();
    if axes.len() != 2 || outcomes.len() != grid.len() {
        return None;
    }
    let (row_axis, col_axis) = (&axes[0], &axes[1]);
    let mut header = vec![format!("{} \\ {}", row_axis.key, col_axis.key)];
    header.extend(col_axis.values.iter().cloned());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for (r, row_value) in row_axis.values.iter().enumerate() {
        let mut cells = vec![row_value.clone()];
        for c in 0..col_axis.values.len() {
            // row-major expansion: axis 0 slowest, axis 1 fastest
            let o = &outcomes[r * col_axis.values.len() + c];
            cells.push(o.gain().map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()));
        }
        table.row(&cells);
    }
    Some(table)
}

/// Aggregate gain statistics across the grid (count, mean, min, max, and
/// the best scenario id). `None` when no scenario produced a gain.
pub fn gain_stats(outcomes: &[ScenarioOutcome]) -> Option<(Summary, String)> {
    let mut summary = Summary::new();
    let mut best: Option<(f64, &str)> = None;
    for o in outcomes {
        if let Some(g) = o.gain() {
            summary.push(g);
            if best.map(|(bg, _)| g > bg).unwrap_or(true) {
                best = Some((g, o.scenario.id.as_str()));
            }
        }
    }
    best.map(|(_, id)| (summary, id.to_string()))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON numbers cannot be NaN/∞ — map non-finite to null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        v.to_string()
    } else {
        "null".into()
    }
}

fn json_opt(v: Option<f64>) -> String {
    v.map(json_num).unwrap_or_else(|| "null".into())
}

/// Write the machine-readable report: axes, per-scenario metrics, and
/// the gain aggregate.
pub fn write_json(path: &str, grid: &ScenarioGrid, outcomes: &[ScenarioOutcome]) -> Result<()> {
    let mut s = String::from("{\n  \"axes\": [");
    for (i, axis) in grid.axes().iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\n    {{\"key\": \"{}\", \"values\": [", json_escape(&axis.key)));
        for (j, v) in axis.values.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\"", json_escape(v)));
        }
        s.push_str("]}");
    }
    s.push_str("\n  ],\n  \"scenarios\": [");
    for (i, o) in outcomes.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let target = o.scenario.cfg.target_nmse;
        s.push_str(&format!("\n    {{\"id\": \"{}\", ", json_escape(&o.scenario.id)));
        s.push_str("\"assignment\": {");
        for (j, (k, v)) in o.scenario.assignment.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        s.push_str("}, ");
        s.push_str(&format!("\"backend\": \"{}\", ", json_escape(o.backend)));
        s.push_str(&format!("\"seed\": {}, ", o.scenario.cfg.seed));
        s.push_str(&format!("\"delta\": {}, ", json_num(o.coded.delta)));
        s.push_str(&format!("\"epoch_deadline_s\": {}, ", json_num(o.coded.epoch_deadline)));
        s.push_str(&format!("\"setup_s\": {}, ", json_num(o.coded.setup_secs)));
        s.push_str(&format!("\"epochs\": {}, ", o.coded.epoch_times.len()));
        s.push_str(&format!("\"final_nmse\": {}, ", json_opt(o.coded.trace.final_nmse())));
        s.push_str(&format!("\"t_cfl_s\": {}, ", json_opt(o.coded.time_to(target))));
        s.push_str(&format!(
            "\"t_uncoded_s\": {}, ",
            json_opt(o.uncoded.as_ref().and_then(|u| u.time_to(target)))
        ));
        s.push_str(&format!("\"gain\": {}, ", json_opt(o.gain())));
        s.push_str(&format!("\"comm_load\": {}}}", json_opt(o.comm_load())));
    }
    s.push_str("\n  ],\n  \"aggregate\": ");
    match gain_stats(outcomes) {
        Some((summary, best_id)) => s.push_str(&format!(
            "{{\"scenarios\": {}, \"gains\": {}, \"gain_mean\": {}, \"gain_min\": {}, \
             \"gain_max\": {}, \"best_scenario\": \"{}\"}}",
            outcomes.len(),
            summary.count(),
            json_num(summary.mean()),
            json_num(summary.min()),
            json_num(summary.max()),
            json_escape(&best_id)
        )),
        None => s.push_str(&format!(
            "{{\"scenarios\": {}, \"gains\": 0}}",
            outcomes.len()
        )),
    }
    s.push_str("\n}\n");

    let path_ref = std::path::Path::new(path);
    if let Some(dir) = path_ref.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
        }
    }
    std::fs::write(path_ref, s).with_context(|| format!("writing {path}"))
}
