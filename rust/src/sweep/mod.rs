//! Scenario-grid sweeps: declarative multi-axis experiments, executed in
//! parallel, reported reproducibly.
//!
//! The paper's headline results are all *sweeps* — over heterogeneity ν
//! (Fig. 4), redundancy δ and load (Fig. 5), device counts, SNR — and
//! follow-up work (Prakash et al. 2020, Sun et al. 2022) evaluates even
//! richer multi-axis grids. This module replaces the bespoke serial
//! `for`-loops the benches and examples used to carry with one engine:
//!
//! * [`grid`] — [`ScenarioGrid`]: axes over [`ExperimentConfig`] fields
//!   (`nu_comp`, `nu_link`, `delta`, `n_devices`, `snr_db`, `seed`, …),
//!   cartesian expansion with stable scenario IDs, **zipped axis
//!   groups** ([`ScenarioGrid::zip_axes`] / `--zip a+b`) that sweep
//!   correlated parameters together instead of multiplying them, and
//!   parsing from INI `[sweep]` sections and `--axis key=v1,v2,…` CLI
//!   specs. The scale knobs (`participation`, `data_mode`,
//!   `trace_points`, `agg_fanin`, `ladder_tiers`) are sweepable like
//!   any other field.
//! * [`presets`] — named grids behind `cfl sweep --scenario <name>`:
//!   the million-device scaling ladder (`scale`) and its CI budget cell
//!   (`scale-ci`); see `docs/SCALING.md`.
//! * [`runner`] — a `std::thread` worker pool over a channel work queue.
//!   Each worker instantiates its own [`Coordinator`] — the DES backend
//!   by default, or the threaded live cluster via
//!   [`SweepOptions::backend`] / `cfl sweep --live`. Under the (default)
//!   sim backend every scenario's result is a pure function of its
//!   config, so parallel output is **byte-identical** to a serial run.
//!   [`run_scenarios_streaming`] additionally delivers outcomes to a
//!   sink in grid order as the completed prefix grows, which is what
//!   lets reports hit disk incrementally. The pool itself is exposed as
//!   [`run_tasks`] / [`run_tasks_streaming`] for non-coordinator
//!   workloads (the Fig. 1 bench's load scan runs through it); a
//!   panicking task surfaces as an orderly `Err`, not a pool teardown.
//! * [`report`] — per-scenario CSV, coding-gain matrices (id-keyed, so
//!   subset/resumed sweeps still render), per-scenario NMSE trace export
//!   (`--traces-dir`, identical for sim and live runs), and a JSON
//!   report, built on [`crate::metrics`]; a `backend` column keeps mixed
//!   sim/live CSVs attributable.
//! * [`resume`] — `cfl sweep --resume <csv>`: recover completed rows
//!   from a partial per-scenario CSV, re-run only the remainder, and
//!   merge to a CSV byte-identical (sim backend) to an uninterrupted
//!   run. A `.records.jsonl` sidecar streams each finished scenario's
//!   report + bench records alongside the CSV, so resumed JSON and
//!   bench reports cover recovered scenarios too (the JSON report
//!   byte-identically).
//! * [`baseline`] — the CI bench-smoke pipeline: a compact per-scenario
//!   gain/wall-time report (`cfl sweep --bench-out`) and the regression
//!   check against a committed baseline (`cfl bench-check`).
//!
//! [`Coordinator`]: crate::coordinator::Coordinator
//!
//! ```no_run
//! use cfl::config::ExperimentConfig;
//! use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};
//!
//! let grid = ScenarioGrid::new(&ExperimentConfig::small())
//!     .axis_f64("nu_comp", &[0.0, 0.1, 0.2]).unwrap()
//!     .axis_f64("nu_link", &[0.0, 0.1, 0.2]).unwrap();
//! let outcomes = run_grid(&grid, &SweepOptions::default()).unwrap();
//! for o in &outcomes {
//!     println!("{}: gain {:?}", o.scenario.id, o.gain());
//! }
//! ```
//!
//! From the CLI: `cfl sweep --config experiment.ini` with
//!
//! ```ini
//! [sweep]
//! nu_comp = 0, 0.1, 0.2
//! nu_link = 0, 0.1, 0.2
//! workers = 8
//! ```
//!
//! [`ExperimentConfig`]: crate::config::ExperimentConfig

pub mod baseline;
pub mod grid;
pub(crate) mod json;
pub mod presets;
pub mod report;
pub mod resume;
pub mod runner;

pub use baseline::{
    bench_json_record, check_gain_regression, check_regression, parse_bench_records, parse_gains,
    write_bench_json, write_bench_json_records, BenchRecord,
};
pub use grid::{config_fingerprint, Axis, Dim, Scenario, ScenarioGrid, SWEEPABLE_KEYS};
pub use presets::{scenario_preset, Preset, PRESET_NAMES};
pub use report::{
    gain_matrix, gain_stats, scenario_csv_header, scenario_csv_row, scenario_json_record,
    summary_table, trace_file_stem, write_json, write_json_records, write_outcome_traces,
    write_outcome_traces_decimated, write_scenario_csv,
};
pub use resume::{sidecar_path, MergedScenarioCsv, RecordLog, ResumeState, SidecarRecords};
pub use runner::{
    run_grid, run_scenarios, run_scenarios_streaming, run_tasks, run_tasks_streaming,
    ScenarioOutcome, SweepOptions,
};

#[cfg(test)]
mod tests;
