//! Minimal JSON string/number formatting shared by the report writers
//! (`report::write_json`, `baseline::write_bench_json`) — no serde
//! offline, so escaping lives in exactly one place. Scenario ids and
//! axis values are interpolated into JSON verbatim otherwise, and a
//! quote or backslash in either (reachable via zipped-axis values) must
//! not produce an invalid document.

/// Escape a string for embedding between JSON double quotes.
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON numbers cannot be NaN/∞ — map non-finite to null.
pub(crate) fn num(v: f64) -> String {
    if v.is_finite() {
        v.to_string()
    } else {
        "null".into()
    }
}

/// `Some(v)` → number (or null when non-finite), `None` → null.
pub(crate) fn opt(v: Option<f64>) -> String {
    v.map(num).unwrap_or_else(|| "null".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_controls() {
        assert_eq!(escape(r#"s0__note="q"\"#), r#"s0__note=\"q\"\\"#);
        assert_eq!(escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn numbers_map_nonfinite_to_null() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
        assert_eq!(opt(None), "null");
        assert_eq!(opt(Some(2.0)), "2");
    }
}
