//! Multi-threaded scenario execution.
//!
//! A channel-fed worker pool (`std::thread::scope`, no external deps):
//! scenarios queue through a shared receiver, each worker builds its own
//! [`Coordinator`] from [`SweepOptions::backend`] — gradient backends are
//! `Send` by construction, see [`crate::fl::GradBackend`] — trains CFL
//! (plus the uncoded baseline by default), and reports back over a result
//! channel. With the default [`CoordinatorKind::Sim`] backend every
//! scenario's outcome is a pure function of its config, and results are
//! re-ordered by scenario index before returning, so a parallel sweep is
//! **byte-identical** to `workers = 1` — worker count only changes
//! wall-clock time. (The live backend schedules on the wall clock, so its
//! outcomes are inherently non-reproducible; its reports still render
//! through the same pipeline.) Progress notes go to stderr; stdout stays
//! deterministic for report piping.
//!
//! The pool itself is exposed as [`run_tasks`] — a deterministic parallel
//! map the figure benches reuse for non-coordinator workloads (e.g. the
//! Fig. 1 expected-return scan).

use super::grid::{Scenario, ScenarioGrid};
use crate::coordinator::{Coordinator, CoordinatorKind, RunResult};
use crate::lb::LoadPolicy;
use anyhow::{bail, Context, Result};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

/// Runner knobs.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Worker threads (clamped to the scenario count; 1 = run inline).
    /// The live backend always runs scenarios serially regardless of this
    /// setting — concurrent live scenarios would oversubscribe the host
    /// and distort its wall-clock deadlines.
    pub workers: usize,
    /// Also train the uncoded baseline per scenario (needed for the
    /// coding-gain and comm-load report columns).
    pub uncoded_baseline: bool,
    /// Raise the stderr log level so per-scenario `scenario_done` Info
    /// events render as progress lines (`cfl sweep --progress` wiring —
    /// the runner itself always emits the events; this knob only matters
    /// to the caller installing the sinks).
    pub progress: bool,
    /// Which coordinator executes each scenario (`cfl sweep --live`
    /// selects [`CoordinatorKind::Live`]).
    pub backend: CoordinatorKind,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            uncoded_baseline: true,
            progress: false,
            backend: CoordinatorKind::Sim,
        }
    }
}

/// Everything one scenario produced.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    /// The Eq. 13–16 policy the scenario ran under.
    pub policy: LoadPolicy,
    /// Backend tag ("sim" / "live") — rendered in the reports so mixed
    /// CSVs stay attributable.
    pub backend: &'static str,
    pub coded: RunResult,
    pub uncoded: Option<RunResult>,
}

impl ScenarioOutcome {
    /// Coding gain `t_uncoded / t_cfl` at the scenario's target NMSE
    /// (the Fig. 4/5 metric); `None` unless both runs reached it.
    pub fn gain(&self) -> Option<f64> {
        let target = self.scenario.cfg.target_nmse;
        let tc = self.coded.time_to(target)?;
        let tu = self.uncoded.as_ref()?.time_to(target)?;
        Some(tu / tc)
    }

    /// Communication load relative to uncoded FL (the Fig. 5 bottom
    /// metric): (parity bits + per-epoch bits × epochs-to-target) /
    /// (uncoded per-epoch bits × uncoded epochs-to-target).
    pub fn comm_load(&self) -> Option<f64> {
        let uncoded = self.uncoded.as_ref()?;
        let (ec, _) = self.coded.converged?;
        let (eu, _) = uncoded.converged?;
        let coded_bits = self.coded.parity_upload_bits + self.coded.per_epoch_bits * ec as f64;
        let uncoded_bits = uncoded.per_epoch_bits * eu as f64;
        (uncoded_bits > 0.0).then_some(coded_bits / uncoded_bits)
    }
}

/// Expand a grid and run every scenario (see [`run_scenarios`]).
pub fn run_grid(grid: &ScenarioGrid, opts: &SweepOptions) -> Result<Vec<ScenarioOutcome>> {
    run_scenarios(grid.expand()?, opts)
}

/// Run scenarios across `opts.workers` threads, returning outcomes in
/// input order regardless of completion order (the list need not be a
/// full `0..n`-indexed expansion — any subset works, which is how
/// `--resume` runs the remainder of a grid).
pub fn run_scenarios(
    scenarios: Vec<Scenario>,
    opts: &SweepOptions,
) -> Result<Vec<ScenarioOutcome>> {
    run_scenarios_streaming(scenarios, opts, |_| Ok(()))
}

/// [`run_scenarios`] with an ordered sink: `sink` is invoked once per
/// outcome *in scenario input order* as the completed prefix grows, so a
/// caller can append CSV rows / trace files incrementally and a killed
/// sweep keeps everything that had streamed out — the substrate of
/// `cfl sweep --resume` and `--traces-dir`. A sink error aborts the
/// sweep after the in-flight scenarios finish.
pub fn run_scenarios_streaming(
    scenarios: Vec<Scenario>,
    opts: &SweepOptions,
    mut sink: impl FnMut(&ScenarioOutcome) -> Result<()>,
) -> Result<Vec<ScenarioOutcome>> {
    // a live scenario spawns n_devices compute threads racing wall-clock
    // deadlines; running several scenarios at once oversubscribes the host
    // and drops gradients as artificial stragglers, so the live backend
    // always executes one scenario at a time (see SweepOptions::workers)
    let workers = match opts.backend {
        CoordinatorKind::Live { .. } => 1,
        CoordinatorKind::Sim => opts.workers,
    };
    run_tasks_streaming(scenarios, workers, |scenario| run_one(scenario, opts), |_, o| sink(o))
}

/// The sweep engine's parallel executor, generically: map `f` over
/// `items` on a `workers`-thread pool, returning outputs in input order
/// regardless of completion order. `workers = 1` runs inline; the first
/// failure (in input order) is surfaced as the error, and a panicking
/// task is caught and surfaced the same way rather than tearing down the
/// pool. Any deterministic `f` therefore yields output byte-identical to
/// a serial loop — the benches run their non-coordinator scans (e.g.
/// Fig. 1's load axis) through this.
pub fn run_tasks<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Result<Vec<O>>
where
    I: Send,
    O: Send,
    F: Fn(I) -> Result<O> + Sync,
{
    run_tasks_streaming(items, workers, f, |_, _| Ok(()))
}

/// Render a caught panic payload for the task-failure error message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// [`run_tasks`] with an ordered sink: `sink(position, &output)` runs on
/// the caller's thread once per item, in input order, as soon as every
/// earlier item has completed (streaming prefix delivery). Errors — from
/// a task, a caught task panic, or the sink itself — abort the run: the
/// queue is drained so idle workers exit, in-flight tasks finish, and
/// the first failure in input order is returned.
pub fn run_tasks_streaming<I, O, F, S>(
    items: Vec<I>,
    workers: usize,
    f: F,
    mut sink: S,
) -> Result<Vec<O>>
where
    I: Send,
    O: Send,
    F: Fn(I) -> Result<O> + Sync,
    S: FnMut(usize, &O) -> Result<()>,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);

    // a panic in `f` is converted into an ordinary task error so one bad
    // scenario surfaces as an orderly Err instead of unwinding through
    // the pool (where it would abort the whole process on scope join)
    let run = |item: I| -> Result<O> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item))) {
            Ok(result) => result,
            Err(payload) => bail!("task panicked: {}", panic_message(payload.as_ref())),
        }
    };

    if workers == 1 {
        let reg = crate::obs::registry();
        let busy = reg.counter("sweep.worker0.busy_us");
        let tasks = reg.counter("sweep.worker0.tasks");
        let mut out = Vec::with_capacity(n);
        for (position, item) in items.into_iter().enumerate() {
            let t = Instant::now();
            let output = run(item);
            busy.add(t.elapsed().as_micros() as u64);
            tasks.incr();
            let output = output?;
            sink(position, &output)?;
            out.push(output);
        }
        return Ok(out);
    }

    // work queue: a Mutex-shared receiver hands each worker the next
    // item; a result channel carries the output back keyed by queue
    // position, so output order always mirrors input order
    let (work_tx, work_rx) = mpsc::channel::<(usize, I)>();
    let work_rx = Mutex::new(work_rx);
    let (result_tx, result_rx) = mpsc::channel::<(usize, Result<O>)>();
    for job in items.into_iter().enumerate() {
        // the receiver is alive until the scope below ends, so this only
        // fails if something truly exotic tore the channel down early
        if work_tx.send(job).is_err() {
            bail!("work queue receiver dropped before the pool started");
        }
    }
    drop(work_tx);

    // a poisoned work-queue lock means some worker died mid-pop; the
    // queue state itself is still sound (Receiver::recv is atomic), so
    // every lock treats poison as "keep going" and the missing result
    // surfaces as an orderly task error below
    let pop = |q: &Mutex<mpsc::Receiver<(usize, I)>>| {
        q.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).recv()
    };

    let mut slots: Vec<Option<Result<O>>> = (0..n).map(|_| None).collect();
    let mut first_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        for w in 0..workers {
            let result_tx = result_tx.clone();
            let work_rx = &work_rx;
            let run = &run;
            scope.spawn(move || {
                // per-worker utilization counters: busy_us / (pool wall
                // time × workers) is the sweep's utilization ratio
                let reg = crate::obs::registry();
                let busy = reg.counter(&format!("sweep.worker{w}.busy_us"));
                let tasks = reg.counter(&format!("sweep.worker{w}.tasks"));
                loop {
                    // take the next item, releasing the lock before running
                    let Ok((position, item)) = pop(work_rx) else { break };
                    let t = Instant::now();
                    let output = run(item);
                    busy.add(t.elapsed().as_micros() as u64);
                    tasks.incr();
                    if result_tx.send((position, output)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(result_tx);
        let mut next = 0usize;
        'collect: for (position, output) in result_rx.iter() {
            slots[position] = Some(output);
            // deliver the completed prefix in input order; stop at the
            // first failure — which, because we walk positions in order,
            // is the first failure in input order
            while next < n {
                // take the slot to bind its value by move (no panicking
                // re-match); Ok values go back in for the final collection
                match slots[next].take() {
                    None => break,
                    Some(Ok(output)) => {
                        let delivered = sink(next, &output);
                        slots[next] = Some(Ok(output));
                        if let Err(e) = delivered {
                            first_err = Some(e);
                            break 'collect;
                        }
                        next += 1;
                    }
                    Some(Err(e)) => {
                        first_err = Some(e);
                        break 'collect;
                    }
                }
            }
        }
        if first_err.is_some() {
            // orderly shutdown: drain the queue so workers stop after
            // their in-flight item instead of running the whole backlog
            let q = work_rx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            while q.try_recv().is_ok() {}
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }

    // no error surfaced in order: every slot must hold an Ok
    let mut out = Vec::with_capacity(n);
    for (position, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(output)) => out.push(output),
            Some(Err(e)) => return Err(e),
            None => bail!("task #{position} produced no result (worker died)"),
        }
    }
    Ok(out)
}

/// Run a single scenario to completion on the current thread.
fn run_one(scenario: Scenario, opts: &SweepOptions) -> Result<ScenarioOutcome> {
    // every event/span the runs below emit lands in this scenario's
    // scope, which is what routes them to per-scenario JSONL files
    // under `--events-out DIR`
    let _scope = crate::obs::scope(&scenario.id);
    let ctx = |what: &str| format!("scenario {}: {what}", scenario.id);
    let mut coord: Box<dyn Coordinator> =
        opts.backend.build(&scenario.cfg).with_context(|| ctx("building"))?;
    let policy = coord.policy().with_context(|| ctx("solving the load policy"))?;
    let coded = coord.train_cfl().with_context(|| ctx("training CFL"))?;
    let uncoded = if opts.uncoded_baseline {
        Some(coord.train_uncoded().with_context(|| ctx("training uncoded"))?)
    } else {
        None
    };
    let outcome =
        ScenarioOutcome { scenario, policy, backend: coord.kind(), coded, uncoded };
    let target = outcome.scenario.cfg.target_nmse;
    crate::obs_event!(
        Info,
        "scenario_done",
        backend = outcome.backend,
        delta = outcome.coded.delta,
        epochs = outcome.coded.epoch_times.len(),
        t_cfl_s = outcome.coded.time_to(target).unwrap_or(f64::NAN),
        gain = outcome.gain().unwrap_or(f64::NAN),
    );
    Ok(outcome)
}
