//! Multi-threaded scenario execution.
//!
//! A channel-fed worker pool (`std::thread::scope`, no external deps):
//! scenarios queue through a shared receiver, each worker builds its own
//! [`SimCoordinator`] — backends are `Send` by construction, see
//! [`crate::fl::GradBackend`] — trains CFL (plus the uncoded baseline by
//! default), and reports back over a result channel. Every scenario's
//! outcome is a pure function of its config, and results are re-ordered
//! by scenario index before returning, so a parallel sweep is
//! **byte-identical** to `workers = 1` — worker count only changes
//! wall-clock time. Progress notes go to stderr; stdout stays
//! deterministic for report piping.

use super::grid::{Scenario, ScenarioGrid};
use crate::coordinator::{RunResult, SimCoordinator};
use crate::lb::LoadPolicy;
use anyhow::{bail, Context, Result};
use std::sync::mpsc;
use std::sync::Mutex;

/// Runner knobs.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Worker threads (clamped to the scenario count; 1 = run inline).
    pub workers: usize,
    /// Also train the uncoded baseline per scenario (needed for the
    /// coding-gain and comm-load report columns).
    pub uncoded_baseline: bool,
    /// Emit a stderr line as each scenario completes.
    pub progress: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            uncoded_baseline: true,
            progress: false,
        }
    }
}

/// Everything one scenario produced.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    /// The Eq. 13–16 policy the scenario ran under.
    pub policy: LoadPolicy,
    pub coded: RunResult,
    pub uncoded: Option<RunResult>,
}

impl ScenarioOutcome {
    /// Coding gain `t_uncoded / t_cfl` at the scenario's target NMSE
    /// (the Fig. 4/5 metric); `None` unless both runs reached it.
    pub fn gain(&self) -> Option<f64> {
        let target = self.scenario.cfg.target_nmse;
        let tc = self.coded.time_to(target)?;
        let tu = self.uncoded.as_ref()?.time_to(target)?;
        Some(tu / tc)
    }

    /// Communication load relative to uncoded FL (the Fig. 5 bottom
    /// metric): (parity bits + per-epoch bits × epochs-to-target) /
    /// (uncoded per-epoch bits × uncoded epochs-to-target).
    pub fn comm_load(&self) -> Option<f64> {
        let uncoded = self.uncoded.as_ref()?;
        let (ec, _) = self.coded.converged?;
        let (eu, _) = uncoded.converged?;
        let coded_bits = self.coded.parity_upload_bits + self.coded.per_epoch_bits * ec as f64;
        let uncoded_bits = uncoded.per_epoch_bits * eu as f64;
        (uncoded_bits > 0.0).then_some(coded_bits / uncoded_bits)
    }
}

/// Expand a grid and run every scenario (see [`run_scenarios`]).
pub fn run_grid(grid: &ScenarioGrid, opts: &SweepOptions) -> Result<Vec<ScenarioOutcome>> {
    run_scenarios(grid.expand()?, opts)
}

/// Run scenarios across `opts.workers` threads, returning outcomes in
/// input order regardless of completion order (the list need not be a
/// full `0..n`-indexed expansion — any subset works).
pub fn run_scenarios(
    scenarios: Vec<Scenario>,
    opts: &SweepOptions,
) -> Result<Vec<ScenarioOutcome>> {
    let n = scenarios.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = opts.workers.clamp(1, n);

    if workers == 1 {
        let mut out = Vec::with_capacity(n);
        for scenario in scenarios {
            out.push(run_one(scenario, opts)?);
        }
        return Ok(out);
    }

    // work queue: a Mutex-shared receiver hands each worker the next
    // scenario; a result channel carries the outcome back keyed by queue
    // position (not Scenario::index — callers may pass any subset, e.g. a
    // resumed sweep), so output order always mirrors input order
    let (work_tx, work_rx) = mpsc::channel::<(usize, Scenario)>();
    let work_rx = Mutex::new(work_rx);
    let (result_tx, result_rx) = mpsc::channel::<(usize, Result<ScenarioOutcome>)>();
    for job in scenarios.into_iter().enumerate() {
        work_tx.send(job).expect("queue send on fresh channel");
    }
    drop(work_tx);

    let mut slots: Vec<Option<Result<ScenarioOutcome>>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let result_tx = result_tx.clone();
            let work_rx = &work_rx;
            scope.spawn(move || loop {
                // take the next scenario, releasing the lock before running
                let job = { work_rx.lock().expect("work queue lock").recv() };
                let Ok((position, scenario)) = job else { break };
                let outcome = run_one(scenario, opts);
                if result_tx.send((position, outcome)).is_err() {
                    break;
                }
            });
        }
        drop(result_tx);
        for (position, outcome) in result_rx.iter() {
            slots[position] = Some(outcome);
        }
    });

    // surface the first failure in input order (deterministic), else
    // unwrap everything in order
    let mut out = Vec::with_capacity(n);
    for (position, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(outcome)) => out.push(outcome),
            Some(Err(e)) => return Err(e),
            None => bail!("scenario #{position} produced no result (worker died)"),
        }
    }
    Ok(out)
}

/// Run a single scenario to completion on the current thread.
fn run_one(scenario: Scenario, opts: &SweepOptions) -> Result<ScenarioOutcome> {
    let ctx = |what: &str| format!("scenario {}: {what}", scenario.id);
    let mut sim = SimCoordinator::new(&scenario.cfg).with_context(|| ctx("building"))?;
    let policy = sim.policy().with_context(|| ctx("solving the load policy"))?;
    let coded = sim.train_cfl().with_context(|| ctx("training CFL"))?;
    let uncoded = if opts.uncoded_baseline {
        Some(sim.train_uncoded().with_context(|| ctx("training uncoded"))?)
    } else {
        None
    };
    let outcome = ScenarioOutcome { scenario, policy, coded, uncoded };
    if opts.progress {
        let target = outcome.scenario.cfg.target_nmse;
        eprintln!(
            "  [{}] δ={:.3} t_cfl={} gain={}",
            outcome.scenario.id,
            outcome.coded.delta,
            outcome
                .coded
                .time_to(target)
                .map(|t| format!("{t:.1}s"))
                .unwrap_or_else(|| "—".into()),
            outcome.gain().map(|g| format!("{g:.2}×")).unwrap_or_else(|| "—".into()),
        );
    }
    Ok(outcome)
}
