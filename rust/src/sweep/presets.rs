//! Named sweep presets — curated grids behind `cfl sweep --scenario`.
//!
//! A preset bundles a base config and its axes so the headline
//! experiments are one flag, not a paragraph of `--axis` specs. The
//! first residents are the million-device scaling ladder from
//! `docs/SCALING.md`:
//!
//! * `scale` — n_devices ∈ {1k, 10k, 100k, 1M} with δ zipped so the
//!   parity block stays a constant c = 64 rows while the fleet grows
//!   (δ = c/m and m = 4·n, so δ shrinks 10× per rung). Lean data,
//!   `participation = count:256`, a 24-tier device ladder, fan-in-32
//!   aggregation and 64-point traces: per-epoch cost tracks the
//!   *sampled* set, not the fleet, which is what lets the 1M cell
//!   finish on a laptop.
//! * `scale-ci` — the single 100k-device cell of the same ladder; the
//!   wall-clock + peak-RSS budget gate `scripts/scale_smoke.sh` runs in
//!   CI.
//!
//! Presets run CFL only (`uncoded_baseline = false`): the uncoded
//! baseline needs the full dataset resident, which is exactly what lean
//! mode exists to avoid. `--axis`/`--zip` still extend a preset grid,
//! and an explicit `seed` axis works as usual.

use super::grid::ScenarioGrid;
use crate::config::{DataMode, ExperimentConfig, Participation};
use anyhow::{bail, Result};

/// Names [`scenario_preset`] accepts, in documentation order.
pub const PRESET_NAMES: &[&str] = &["scale", "scale-ci"];

/// A named, ready-to-run sweep grid.
#[derive(Clone, Debug)]
pub struct Preset {
    pub name: &'static str,
    /// One-line description, printed in the sweep header.
    pub about: &'static str,
    pub grid: ScenarioGrid,
    /// Whether the preset can run the uncoded baseline (lean-mode
    /// presets cannot — the baseline needs the resident dataset).
    pub uncoded_baseline: bool,
}

/// The shared base of the scaling ladder: a tiny per-device problem
/// (4 points, d = 16) so the interesting dimension is fleet size, with
/// every millions-scale knob on — lean descriptors, sampled
/// participation, tiered ladder, bounded traces, tree aggregation.
fn scale_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    cfg.points_per_device = 4;
    cfg.model_dim = 16;
    cfg.snr_db = 10.0;
    cfg.max_epochs = 30;
    cfg.target_nmse = 0.0; // epoch-capped: every cell runs exactly 30 epochs
    cfg.nu_comp = 0.2;
    cfg.nu_link = 0.2;
    cfg.ladder_tiers = 24; // tile the paper's 24-device ladder across the fleet
    cfg.data_mode = DataMode::Lean;
    cfg.participation = Participation::Count(256);
    cfg.agg_fanin = 32;
    cfg.trace_points = 64;
    cfg
}

/// Resolve a preset by name. Unknown names list the valid ones.
pub fn scenario_preset(name: &str) -> Result<Preset> {
    match name {
        "scale" => Ok(Preset {
            name: "scale",
            about: "million-device scaling ladder: n ∈ {1k, 10k, 100k, 1M}, c = 64 parity rows",
            grid: ScenarioGrid::new(&scale_base())
                .axis("n_devices", ["1000", "10000", "100000", "1000000"])?
                .axis("delta", ["0.016", "0.0016", "0.00016", "0.000016"])?
                .zip_axes(["n_devices", "delta"])?,
            uncoded_baseline: false,
        }),
        "scale-ci" => Ok(Preset {
            name: "scale-ci",
            about: "the ladder's 100k-device cell alone (the CI budget gate)",
            grid: ScenarioGrid::new(&scale_base())
                .axis("n_devices", ["100000"])?
                .axis("delta", ["0.00016"])?,
            uncoded_baseline: false,
        }),
        other => bail!(
            "unknown sweep scenario '{other}' (available: {})",
            PRESET_NAMES.join(", ")
        ),
    }
}
