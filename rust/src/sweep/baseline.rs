//! Bench-smoke baselines: a tiny gain report and its regression check.
//!
//! CI's `bench-smoke` job runs a small fixed sweep, writes the compact
//! per-scenario report below (`BENCH_ci.json` — coding gain, wall time,
//! wall-clock throughput, and the per-phase timing digests), and compares
//! it against the committed `bench/baseline.json` with `cfl bench-check`,
//! failing the build when a scenario's gain drops more than the tolerance
//! (default 20%).
//!
//! There is deliberately no JSON parser dependency (the build is
//! offline): [`parse_bench_records`] is a scanner for the two reports
//! *this repo writes* — it keys on the `"id"`/`"gain"`/`"epochs_per_sec"`
//! fields that both the bench report and [`super::report::write_json`]'s
//! scenario records emit, so a full sweep report works as a baseline too.
//! It is not a general JSON reader and does not try to be.
//!
//! The coding gain is a simulated-time ratio — stable per seed — and is
//! always gated. Wall-clock throughput (`epochs_per_sec`) is host-noisy,
//! so its gate is opt-in with its own, looser tolerance
//! ([`check_regression`] with `wall_tolerance = Some(..)`; `cfl
//! bench-check --wall-tolerance`), and only fires for baseline scenarios
//! that record a throughput — a `null` baseline keeps the scenario
//! gain-gated only.

use super::json::{escape as json_escape, num as json_num, opt as json_opt};
use super::runner::ScenarioOutcome;
use crate::metrics::Table;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Render one scenario's bench record — the single-line `{…}` object
/// [`write_bench_json`] emits per scenario. Factored out so the resume
/// path can persist records as scenarios finish and later interleave
/// recovered records verbatim ([`write_bench_json_records`]).
pub fn bench_json_record(o: &ScenarioOutcome) -> String {
    let gain = o
        .gain()
        .filter(|g| g.is_finite())
        .map(|g| g.to_string())
        .unwrap_or_else(|| "null".into());
    let mut wall = o.coded.wall_secs;
    if let Some(u) = &o.uncoded {
        wall += u.wall_secs;
    }
    let epochs = o.coded.epoch_times.len();
    let eps = (o.coded.wall_secs > 0.0)
        .then(|| epochs as f64 / o.coded.wall_secs)
        .filter(|p| p.is_finite());
    let mut s = format!(
        "{{\"id\": \"{}\", \"backend\": \"{}\", \"gain\": {gain}, \
         \"wall_s\": {:.3}, \"epochs\": {epochs}, \"epochs_per_sec\": {}",
        json_escape(&o.scenario.id),
        json_escape(o.backend),
        wall,
        json_opt(eps),
    );
    s.push_str(", \"phases\": {");
    for (j, p) in o.coded.phases.iter().enumerate() {
        if j > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!(
            "\"{}\": {{\"count\": {}, \"total_ms\": {}, \"p50_ms\": {}, \"p95_ms\": {}}}",
            p.phase,
            p.count,
            json_num(p.total_s * 1e3),
            json_num(p.p50_s * 1e3),
            json_num(p.p95_s * 1e3),
        ));
    }
    s.push_str("}}");
    s
}

/// Write the compact bench report: one record per scenario with the
/// coding gain (`null` when a run missed its target), the host wall time
/// the scenario took (coded + uncoded runs), the coded run's wall-clock
/// throughput, and its per-phase timing digests.
pub fn write_bench_json(path: &str, outcomes: &[ScenarioOutcome]) -> Result<()> {
    let records: Vec<String> = outcomes.iter().map(bench_json_record).collect();
    write_bench_json_records(path, &records)
}

/// [`write_bench_json`] from pre-rendered records — the resume path,
/// where recovered records (with their original host wall times) are
/// interleaved verbatim with freshly-run scenarios' records.
pub fn write_bench_json_records(path: &str, records: &[String]) -> Result<()> {
    let mut s = String::from("{\n  \"scenarios\": [");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    ");
        s.push_str(r);
    }
    s.push_str("\n  ]\n}\n");
    let path_ref = std::path::Path::new(path);
    if let Some(dir) = path_ref.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
        }
    }
    std::fs::write(path_ref, s).with_context(|| format!("writing {path}"))
}

/// Index of the first unescaped `"` in `s` (the end of a JSON string
/// whose opening quote has already been consumed).
pub(crate) fn str_end(s: &str) -> Option<usize> {
    let mut escaped = false;
    for (i, b) in s.bytes().enumerate() {
        if escaped {
            escaped = false;
        } else if b == b'\\' {
            escaped = true;
        } else if b == b'"' {
            return Some(i);
        }
    }
    None
}

/// Length of the record whose interior `tail` starts in (depth 1, i.e.
/// just inside the record's `{`): bytes up to — excluding — the record's
/// own closing `}`. String-aware, so braces inside escaped ids or axis
/// values don't fool the scan; nested objects (the sweep report's
/// `"assignment": {…}`, the bench report's `"phases": {…}`) are skipped
/// whole.
pub(crate) fn record_end(tail: &str) -> usize {
    let mut depth = 1usize;
    let mut in_str = false;
    let mut escaped = false;
    for (i, b) in tail.bytes().enumerate() {
        if in_str {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_str = false;
            }
        } else {
            match b {
                b'"' => in_str = true,
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
    }
    tail.len()
}

/// Raw (untrimmed-of-JSON, trimmed-of-whitespace) text of a scalar field
/// inside one record's interior, or `None` when the record has no such
/// field. Top-level scan only — `key` must not name a key that also
/// appears inside a record's nested objects.
pub(crate) fn field_raw<'a>(record: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\": ");
    let at = record.find(&needle)?;
    let tail = &record[at + needle.len()..];
    let end = tail.find(&[',', '\n', '}'][..]).unwrap_or(tail.len());
    Some(tail[..end].trim())
}

/// Id of a scenario record (its JSON-escaped form, emitted verbatim
/// when re-interpolated — already-escaped text must not be re-escaped).
/// Every record this repo writes starts `{"id": "…`.
pub(crate) fn record_id(record: &str) -> Result<String> {
    let rest = record
        .strip_prefix("{\"id\": \"")
        .with_context(|| format!("scenario record does not start with an id: {record}"))?;
    let end = str_end(rest).context("unterminated scenario id")?;
    Ok(rest[..end].to_string())
}

/// Parse a scalar field's raw text: `null` → `None`, a number → `Some`.
pub(crate) fn parse_opt_f64(id: &str, key: &str, raw: &str) -> Result<Option<f64>> {
    if raw == "null" {
        return Ok(None);
    }
    raw.parse::<f64>()
        .map(Some)
        .map_err(|e| anyhow::anyhow!("scenario {id}: bad {key} '{raw}': {e}"))
}

/// One scenario's gated metrics, scanned out of a bench (or full sweep)
/// report. `None` means the metric was `null` — or, for
/// `epochs_per_sec`, absent entirely (reports predating the field).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Scenario id in its JSON-escaped form (all this repo's reports
    /// pass through [`write_bench_json`]'s escaper, so baseline and
    /// current reports compare consistently).
    pub id: String,
    /// Coding gain; `None` when the run never reached its target.
    pub gain: Option<f64>,
    /// Coded-run wall-clock throughput; `None` when unrecorded.
    pub epochs_per_sec: Option<f64>,
}

/// Scan a bench (or full sweep) report for per-scenario records. The
/// field lookups are bounded to each record — a record with no gain
/// field is an error, never a silent borrow of the *next* record's gain;
/// a record with no `epochs_per_sec` field (older reports, the full
/// sweep report) parses with `epochs_per_sec: None`.
pub fn parse_bench_records(json: &str) -> Result<Vec<BenchRecord>> {
    let mut out = Vec::new();
    let mut rest = json;
    while let Some(at) = rest.find("\"id\": \"") {
        let after = &rest[at + 7..];
        let id_end = str_end(after).context("unterminated scenario id")?;
        let id = &after[..id_end];
        let tail = &after[id_end + 1..];
        let record = &tail[..record_end(tail)];
        let graw = field_raw(record, "gain")
            .with_context(|| format!("scenario {id}: record has no gain field"))?;
        let gain = parse_opt_f64(id, "gain", graw)?;
        let epochs_per_sec = match field_raw(record, "epochs_per_sec") {
            Some(raw) => parse_opt_f64(id, "epochs_per_sec", raw)?,
            None => None,
        };
        out.push(BenchRecord { id: id.to_string(), gain, epochs_per_sec });
        rest = &tail[record.len()..];
    }
    Ok(out)
}

/// Scan a report for `(scenario id, gain)` pairs — the gain-only view of
/// [`parse_bench_records`].
pub fn parse_gains(json: &str) -> Result<Vec<(String, Option<f64>)>> {
    Ok(parse_bench_records(json)?.into_iter().map(|r| (r.id, r.gain)).collect())
}

fn fmt_gain(v: Option<f64>) -> String {
    v.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into())
}

fn fmt_delta(base: Option<f64>, now: Option<f64>) -> String {
    match (base, now) {
        (Some(b), Some(n)) if b != 0.0 => format!("{:+.1}%", (n / b - 1.0) * 100.0),
        _ => "—".into(),
    }
}

/// Compare a current report against a baseline. Gate one: every baseline
/// scenario with a recorded gain must appear in the current report with
/// a gain of at least `baseline × (1 − tolerance)`. Gate two (only when
/// `wall_tolerance` is `Some`): every baseline scenario with a recorded
/// `epochs_per_sec` must report a throughput of at least `baseline ×
/// (1 − wall_tolerance)`. A current scenario the baseline has never seen
/// is an error in both modes — a silently un-gated scenario is how
/// regressions hide — fixed by re-running with `--update-baseline`.
/// Returns the per-scenario comparison (legacy gain lines plus a delta
/// table) on success; fails listing every regression.
pub fn check_regression(
    baseline: &str,
    current: &str,
    tolerance: f64,
    wall_tolerance: Option<f64>,
) -> Result<String> {
    ensure!(
        (0.0..1.0).contains(&tolerance),
        "tolerance must be a fraction in [0, 1), got {tolerance}"
    );
    if let Some(wt) = wall_tolerance {
        ensure!(
            (0.0..1.0).contains(&wt),
            "wall tolerance must be a fraction in [0, 1), got {wt}"
        );
    }
    let base = parse_bench_records(baseline)?;
    ensure!(!base.is_empty(), "the baseline report contains no scenarios");
    let current = parse_bench_records(current)?;

    let mut regressions = Vec::new();
    let known: BTreeSet<&str> = base.iter().map(|r| r.id.as_str()).collect();
    for rec in &current {
        if !known.contains(rec.id.as_str()) {
            regressions.push(format!(
                "{}: not in the baseline (stale bench/baseline.json? re-run with \
                 --update-baseline to admit it)",
                rec.id
            ));
        }
    }
    let by_id: BTreeMap<&str, &BenchRecord> = current.iter().map(|r| (r.id.as_str(), r)).collect();

    let mut ok_lines = Vec::new();
    let mut table = Table::new(&[
        "scenario", "gain (base)", "gain (now)", "Δgain", "eps (base)", "eps (now)", "Δeps",
    ]);
    for brec in &base {
        let id = &brec.id;
        let cur = by_id.get(id.as_str()).copied();
        match (brec.gain, cur.map(|c| c.gain)) {
            (None, _) => ok_lines.push(format!("{id}: no baseline gain recorded — skipped")),
            (Some(_), None) => regressions.push(format!("{id}: missing from the current report")),
            (Some(bg), Some(None)) => regressions.push(format!(
                "{id}: target never reached (baseline gain {bg:.2}×)"
            )),
            (Some(bg), Some(Some(g))) => {
                let floor = bg * (1.0 - tolerance);
                if g < floor {
                    regressions.push(format!(
                        "{id}: gain {g:.2}× below the {floor:.2}× floor (baseline {bg:.2}×)"
                    ));
                } else {
                    ok_lines.push(format!(
                        "{id}: gain {g:.2}× (baseline {bg:.2}×, floor {floor:.2}×)"
                    ));
                }
            }
        }
        // the wall gate never double-reports a scenario the gain gate
        // already flagged as missing — hence the `if let Some(cur)`
        if let (Some(wt), Some(beps), Some(cur)) = (wall_tolerance, brec.epochs_per_sec, cur) {
            let floor = beps * (1.0 - wt);
            match cur.epochs_per_sec {
                None => regressions.push(format!(
                    "{id}: wall-clock throughput missing from the report \
                     (baseline {beps:.2} epochs/s)"
                )),
                Some(eps) if eps < floor => regressions.push(format!(
                    "{id}: {eps:.2} epochs/s below the {floor:.2} floor (baseline {beps:.2})"
                )),
                Some(_) => {}
            }
        }
        table.row(&[
            id.clone(),
            fmt_gain(brec.gain),
            fmt_gain(cur.and_then(|c| c.gain)),
            fmt_delta(brec.gain, cur.and_then(|c| c.gain)),
            fmt_gain(brec.epochs_per_sec),
            fmt_gain(cur.and_then(|c| c.epochs_per_sec)),
            fmt_delta(brec.epochs_per_sec, cur.and_then(|c| c.epochs_per_sec)),
        ]);
    }
    if regressions.is_empty() {
        Ok(format!("{}\n\n{}", ok_lines.join("\n"), table.render()))
    } else {
        match wall_tolerance {
            Some(wt) => bail!(
                "bench regression (gain tolerance {:.0}%, wall tolerance {:.0}%):\n{}",
                tolerance * 100.0,
                wt * 100.0,
                regressions.join("\n")
            ),
            None => bail!(
                "coding-gain regression (tolerance {:.0}%):\n{}",
                tolerance * 100.0,
                regressions.join("\n")
            ),
        }
    }
}

/// [`check_regression`] with the wall-clock gate off — the historical
/// gain-only check CI ran before throughput was recorded.
pub fn check_gain_regression(baseline: &str, current: &str, tolerance: f64) -> Result<String> {
    check_regression(baseline, current, tolerance, None)
}
