//! Bench-smoke baselines: a tiny gain report and its regression check.
//!
//! CI's `bench-smoke` job runs a small fixed sweep, writes the compact
//! per-scenario report below (`BENCH_ci.json` — coding gain + wall time),
//! and compares its gains against the committed `bench/baseline.json`
//! with `cfl bench-check`, failing the build when a scenario's gain drops
//! more than the tolerance (default 20%).
//!
//! There is deliberately no JSON parser dependency (the build is
//! offline): [`parse_gains`] is a scanner for the two reports *this repo
//! writes* — it keys on the `"id"`/`"gain"` fields that both the bench
//! report and [`super::report::write_json`]'s scenario records emit, so a
//! full sweep report works as a baseline too. It is not a general JSON
//! reader and does not try to be.
//!
//! Wall times are recorded for eyeballing host drift but never gated on:
//! CI runners are too noisy for a hard wall-clock threshold, while the
//! coding gain is a simulated-time ratio — stable per seed.

use super::json::escape as json_escape;
use super::runner::ScenarioOutcome;
use anyhow::{bail, ensure, Context, Result};

/// Write the compact bench report: one record per scenario with the
/// coding gain (`null` when a run missed its target) and the host wall
/// time the scenario took (coded + uncoded runs).
pub fn write_bench_json(path: &str, outcomes: &[ScenarioOutcome]) -> Result<()> {
    let mut s = String::from("{\n  \"scenarios\": [");
    for (i, o) in outcomes.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let gain = o
            .gain()
            .filter(|g| g.is_finite())
            .map(|g| g.to_string())
            .unwrap_or_else(|| "null".into());
        let mut wall = o.coded.wall_secs;
        if let Some(u) = &o.uncoded {
            wall += u.wall_secs;
        }
        s.push_str(&format!(
            "\n    {{\"id\": \"{}\", \"backend\": \"{}\", \"gain\": {gain}, \
             \"wall_s\": {:.3}}}",
            json_escape(&o.scenario.id),
            json_escape(o.backend),
            wall
        ));
    }
    s.push_str("\n  ]\n}\n");
    let path_ref = std::path::Path::new(path);
    if let Some(dir) = path_ref.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
        }
    }
    std::fs::write(path_ref, s).with_context(|| format!("writing {path}"))
}

/// Index of the first unescaped `"` in `s` (the end of a JSON string
/// whose opening quote has already been consumed).
fn str_end(s: &str) -> Option<usize> {
    let mut escaped = false;
    for (i, b) in s.bytes().enumerate() {
        if escaped {
            escaped = false;
        } else if b == b'\\' {
            escaped = true;
        } else if b == b'"' {
            return Some(i);
        }
    }
    None
}

/// Length of the record whose interior `tail` starts in (depth 1, i.e.
/// just inside the record's `{`): bytes up to — excluding — the record's
/// own closing `}`. String-aware, so braces inside escaped ids or axis
/// values don't fool the scan; nested objects (the sweep report's
/// `"assignment": {…}`) are skipped whole.
fn record_end(tail: &str) -> usize {
    let mut depth = 1usize;
    let mut in_str = false;
    let mut escaped = false;
    for (i, b) in tail.bytes().enumerate() {
        if in_str {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_str = false;
            }
        } else {
            match b {
                b'"' => in_str = true,
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return i;
                    }
                }
                _ => {}
            }
        }
    }
    tail.len()
}

/// Scan a bench (or full sweep) report for `(scenario id, gain)` pairs.
/// `gain: null` (target never reached) is preserved as `None`; ids are
/// returned in their JSON-escaped form (all this repo's reports pass
/// through [`write_bench_json`]'s escaper, so baseline and current
/// reports compare consistently). The gain lookup is bounded to each
/// record — a record with no gain field is an error, never a silent
/// borrow of the *next* record's gain.
pub fn parse_gains(json: &str) -> Result<Vec<(String, Option<f64>)>> {
    let mut out = Vec::new();
    let mut rest = json;
    while let Some(at) = rest.find("\"id\": \"") {
        let after = &rest[at + 7..];
        let id_end = str_end(after).context("unterminated scenario id")?;
        let id = &after[..id_end];
        let tail = &after[id_end + 1..];
        let record = &tail[..record_end(tail)];
        let g = record
            .find("\"gain\": ")
            .with_context(|| format!("scenario {id}: record has no gain field"))?;
        let gtail = &record[g + 8..];
        let g_end = gtail.find(&[',', '\n'][..]).unwrap_or(gtail.len());
        let raw = gtail[..g_end].trim();
        let gain = if raw == "null" {
            None
        } else {
            Some(
                raw.parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("scenario {id}: bad gain '{raw}': {e}"))?,
            )
        };
        out.push((id.to_string(), gain));
        rest = &tail[record.len()..];
    }
    Ok(out)
}

/// Compare a current report against a baseline: every baseline scenario
/// with a recorded gain must appear in the current report with a gain of
/// at least `baseline × (1 − tolerance)`. Returns the per-scenario
/// comparison table on success; fails listing every regression.
pub fn check_gain_regression(baseline: &str, current: &str, tolerance: f64) -> Result<String> {
    ensure!(
        (0.0..1.0).contains(&tolerance),
        "tolerance must be a fraction in [0, 1), got {tolerance}"
    );
    let base = parse_gains(baseline)?;
    ensure!(!base.is_empty(), "the baseline report contains no scenarios");
    let current: std::collections::BTreeMap<String, Option<f64>> =
        parse_gains(current)?.into_iter().collect();

    let mut ok_lines = Vec::new();
    let mut regressions = Vec::new();
    for (id, bg) in &base {
        let Some(bg) = bg else {
            ok_lines.push(format!("{id}: no baseline gain recorded — skipped"));
            continue;
        };
        let floor = bg * (1.0 - tolerance);
        match current.get(id) {
            None => regressions.push(format!("{id}: missing from the current report")),
            Some(None) => regressions.push(format!(
                "{id}: target never reached (baseline gain {bg:.2}×)"
            )),
            Some(Some(g)) if *g < floor => regressions.push(format!(
                "{id}: gain {g:.2}× below the {floor:.2}× floor (baseline {bg:.2}×)"
            )),
            Some(Some(g)) => ok_lines
                .push(format!("{id}: gain {g:.2}× (baseline {bg:.2}×, floor {floor:.2}×)")),
        }
    }
    if regressions.is_empty() {
        Ok(ok_lines.join("\n"))
    } else {
        bail!(
            "coding-gain regression (tolerance {:.0}%):\n{}",
            tolerance * 100.0,
            regressions.join("\n")
        );
    }
}
