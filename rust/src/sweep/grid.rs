//! Declarative scenario grids over [`ExperimentConfig`] fields.
//!
//! A grid is a base config plus ordered axes; expansion is the cartesian
//! product in declaration order with the *last* axis fastest (row-major),
//! so an `(A, B)` grid lays scenarios out as `A₀B₀, A₀B₁, …` — the same
//! order a nested `for` loop would produce. Scenario IDs are stable
//! functions of the grid alone (zero-padded index + axis assignment),
//! never of evaluation order or worker count.
//!
//! **Zipped axes** ([`ScenarioGrid::zip_axes`]) pair correlated
//! parameters — e.g. `n_devices` with `delta`, or a ladder of per-device
//! MEC profiles — so they advance together instead of exploding the
//! cartesian product. A zip group contributes a single expansion
//! *dimension* ([`Dim`]) whose length is the axes' shared value count;
//! unzipped axes each form their own dimension. IDs keep the exact
//! `s<index>__key=value__…` shape (one `key=value` segment per axis, in
//! declaration order), so reports and resume files are agnostic to
//! whether a grid zips.
//!
//! Seeding: by default every scenario shares the base seed (common random
//! numbers — paired comparisons across cells, as the paper's figures
//! use). With [`ScenarioGrid::derive_seeds`] each scenario instead gets
//! `rng::mix_seed(base_seed, index)`, and an explicit `seed` axis always
//! wins over both.

use crate::config::{ExperimentConfig, Ini};
use crate::rng::mix_seed;
use anyhow::{anyhow, bail, ensure, Context, Result};

/// Keys an axis may sweep (`nu` fans out to both ν knobs).
pub const SWEEPABLE_KEYS: &[&str] = &[
    "nu",
    "nu_comp",
    "nu_link",
    "delta",
    "n_devices",
    "points_per_device",
    "model_dim",
    "snr_db",
    "seed",
    "erasure_prob",
    "client_fraction",
    "target_nmse",
    "max_epochs",
    "learning_rate",
    "base_throughput_kbps",
    "base_mac_rate_kmacs",
    "master_speedup",
    "header_overhead",
    "mem_overhead_factor",
    "c_up_fraction",
    "epsilon",
    "sharding",
    "generator",
    "setup_cost",
    "participation",
    "data_mode",
    "trace_points",
    "agg_fanin",
    "ladder_tiers",
];

/// `[sweep]` keys that configure the run rather than defining an axis.
const RESERVED_KEYS: &[&str] = &["workers", "derive_seeds", "zip"];

/// One swept parameter: a config key plus its value list (kept as the
/// raw strings so IDs, reports and re-parsing stay exact).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Axis {
    pub key: String,
    pub values: Vec<String>,
}

/// One fully-resolved cell of the grid.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Row-major position in the expansion (axis 0 slowest).
    pub index: usize,
    /// Stable identifier: `s<index>__key=value__…`.
    pub id: String,
    /// `(key, value)` pairs in axis declaration order.
    pub assignment: Vec<(String, String)>,
    /// The base config with the assignment (and seed policy) applied.
    pub cfg: ExperimentConfig,
}

/// One expansion dimension: a single axis, or a zipped group of axes
/// advancing together. Reports use dims (not raw axes) to lay out
/// matrices, so a zipped 2-dim grid still renders as rows × columns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dim {
    /// Indices into [`ScenarioGrid::axes`], ascending declaration order.
    pub axes: Vec<usize>,
    /// The dimension's length (the axes' shared value count).
    pub len: usize,
}

/// A base config plus ordered sweep axes.
///
/// ```
/// use cfl::config::ExperimentConfig;
/// use cfl::sweep::ScenarioGrid;
///
/// let grid = ScenarioGrid::new(&ExperimentConfig::small())
///     .axis_f64("nu", &[0.0, 0.2]).unwrap()
///     .axis("delta", ["0.1", "auto"]).unwrap();
/// assert_eq!(grid.len(), 4);
///
/// let scenarios = grid.expand().unwrap();
/// // row-major: the last axis varies fastest, IDs are stable
/// assert_eq!(scenarios[0].id, "s0__nu=0__delta=0.1");
/// assert_eq!(scenarios[3].cfg.nu_comp, 0.2);
/// assert_eq!(scenarios[3].cfg.delta, None); // "auto" → optimizer's δ
/// ```
#[derive(Clone, Debug)]
pub struct ScenarioGrid {
    base: ExperimentConfig,
    axes: Vec<Axis>,
    derive_seeds: bool,
    /// Groups of axis indices that sweep together (see [`Self::zip_axes`]).
    zips: Vec<Vec<usize>>,
}

impl ScenarioGrid {
    /// Start a grid from a base configuration.
    pub fn new(base: &ExperimentConfig) -> Self {
        Self { base: base.clone(), axes: Vec::new(), derive_seeds: false, zips: Vec::new() }
    }

    /// Declared axes, in declaration order.
    pub fn axes(&self) -> &[Axis] {
        &self.axes
    }

    /// The base configuration the axes perturb.
    pub fn base(&self) -> &ExperimentConfig {
        &self.base
    }

    /// Number of scenarios the grid expands to (1 for an axis-free grid):
    /// the product of the dimension lengths, where a zipped group counts
    /// once rather than per axis.
    pub fn len(&self) -> usize {
        self.dims().iter().map(|d| d.len).product()
    }

    /// True when expansion would yield no scenarios (never, today:
    /// empty-valued axes are rejected at declaration time).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Derive a distinct per-scenario seed (`mix_seed(base.seed, index)`)
    /// instead of sharing the base seed across cells.
    pub fn derive_seeds(mut self, yes: bool) -> Self {
        self.derive_seeds = yes;
        self
    }

    /// Declare an axis. Every value is type-checked against the key now,
    /// so a bad grid fails before any scenario runs.
    pub fn axis<S: AsRef<str>>(
        mut self,
        key: &str,
        values: impl IntoIterator<Item = S>,
    ) -> Result<Self> {
        let key = key.trim();
        let values: Vec<String> =
            values.into_iter().map(|v| v.as_ref().trim().to_string()).collect();
        ensure!(!values.is_empty(), "sweep axis '{key}' has no values");
        ensure!(!self.axes.iter().any(|a| a.key == key), "duplicate sweep axis '{key}'");
        let mut probe = self.base.clone();
        for v in &values {
            apply_key(&mut probe, key, v)?;
        }
        self.axes.push(Axis { key: key.to_string(), values });
        Ok(self)
    }

    /// Declare an axis of numeric values (formatting via `f64`'s
    /// round-trip `Display`, so `0.1` stays `0.1`).
    pub fn axis_f64(self, key: &str, values: &[f64]) -> Result<Self> {
        self.axis(key, values.iter().map(|v| v.to_string()))
    }

    /// Declare an axis from a `key=v1,v2,...` spec (the CLI `--axis` form).
    pub fn axis_spec(self, spec: &str) -> Result<Self> {
        let Some((key, values)) = spec.split_once('=') else {
            bail!("axis spec '{spec}' must be key=v1,v2,...");
        };
        let values: Vec<&str> =
            values.split(',').map(str::trim).filter(|v| !v.is_empty()).collect();
        self.axis(key, values)
    }

    /// Pair already-declared axes so they sweep *together*: the group
    /// contributes one expansion dimension (value `j` of every member is
    /// applied at coordinate `j`) instead of a cartesian factor per axis.
    /// The axes must exist, have equal value counts, and belong to at
    /// most one group. IDs and report columns are unaffected — every
    /// axis still gets its own `key=value` segment and CSV column.
    ///
    /// ```
    /// use cfl::config::ExperimentConfig;
    /// use cfl::sweep::ScenarioGrid;
    ///
    /// let grid = ScenarioGrid::new(&ExperimentConfig::small())
    ///     .axis("n_devices", ["4", "8"]).unwrap()
    ///     .axis("delta", ["0.1", "0.2"]).unwrap()
    ///     .axis_f64("nu", &[0.0, 0.3]).unwrap()
    ///     .zip_axes(["n_devices", "delta"]).unwrap();
    /// // (n_devices, delta) paired × nu — not 2×2×2
    /// assert_eq!(grid.len(), 4);
    /// let s = grid.expand().unwrap();
    /// assert_eq!(s[0].id, "s0__n_devices=4__delta=0.1__nu=0");
    /// assert_eq!(s[2].cfg.n_devices, 8);
    /// assert_eq!(s[2].cfg.delta, Some(0.2));
    /// ```
    pub fn zip_axes<S: AsRef<str>>(
        mut self,
        keys: impl IntoIterator<Item = S>,
    ) -> Result<Self> {
        let keys: Vec<String> =
            keys.into_iter().map(|k| k.as_ref().trim().to_string()).collect();
        ensure!(keys.len() >= 2, "a zip group needs at least two axes, got {keys:?}");
        let mut group = Vec::with_capacity(keys.len());
        for key in &keys {
            let Some(ai) = self.axes.iter().position(|a| &a.key == key) else {
                bail!("zip references undeclared axis '{key}' (declare it with axis()/--axis first)");
            };
            ensure!(!group.contains(&ai), "axis '{key}' listed twice in one zip group");
            ensure!(
                !self.zips.iter().any(|g| g.contains(&ai)),
                "axis '{key}' is already in a zip group"
            );
            group.push(ai);
        }
        let first = &self.axes[group[0]];
        for &ai in &group[1..] {
            let axis = &self.axes[ai];
            ensure!(
                axis.values.len() == first.values.len(),
                "zipped axes must have equal value counts: '{}' has {}, '{}' has {}",
                first.key,
                first.values.len(),
                axis.key,
                axis.values.len()
            );
        }
        // the group's dimension sits at its first-declared axis' position
        group.sort_unstable();
        self.zips.push(group);
        Ok(self)
    }

    /// Pair axes from a `key1+key2[+…]` spec (the CLI `--zip` form;
    /// commas work as separators too, for INI `zip =` entries).
    pub fn zip_spec(self, spec: &str) -> Result<Self> {
        let keys: Vec<&str> = spec
            .split(&['+', ','][..])
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        self.zip_axes(keys)
    }

    /// Declared zip groups as axis-key lists (declaration order).
    pub fn zip_keys(&self) -> Vec<Vec<&str>> {
        self.zips
            .iter()
            .map(|g| g.iter().map(|&ai| self.axes[ai].key.as_str()).collect())
            .collect()
    }

    /// The expansion dimensions, in order (first dimension slowest). A
    /// zip group appears once, at its first-declared axis' position.
    pub fn dims(&self) -> Vec<Dim> {
        let mut dims = Vec::new();
        let mut grouped = vec![false; self.axes.len()];
        for ai in 0..self.axes.len() {
            if grouped[ai] {
                continue;
            }
            let group: Vec<usize> = self
                .zips
                .iter()
                .find(|g| g.contains(&ai))
                .cloned()
                .unwrap_or_else(|| vec![ai]);
            for &i in &group {
                grouped[i] = true;
            }
            let len = self.axes[group[0]].values.len();
            dims.push(Dim { axes: group, len });
        }
        dims
    }

    /// A dimension's header label: its axis keys joined with `+`.
    pub fn dim_key(&self, dim: &Dim) -> String {
        dim.axes.iter().map(|&ai| self.axes[ai].key.as_str()).collect::<Vec<_>>().join("+")
    }

    /// A dimension's per-coordinate labels: the member axes' values at
    /// each coordinate, joined with `+`.
    pub fn dim_labels(&self, dim: &Dim) -> Vec<String> {
        (0..dim.len)
            .map(|j| {
                dim.axes
                    .iter()
                    .map(|&ai| self.axes[ai].values[j].as_str())
                    .collect::<Vec<_>>()
                    .join("+")
            })
            .collect()
    }

    /// Per-axis value index for one scenario position (row-major over
    /// `dims`, last dimension fastest).
    fn axis_coords(&self, dims: &[Dim], index: usize) -> Vec<usize> {
        let mut dim_coord = vec![0usize; dims.len()];
        let mut rem = index;
        for (di, dim) in dims.iter().enumerate().rev() {
            dim_coord[di] = rem % dim.len;
            rem /= dim.len;
        }
        let mut coords = vec![0usize; self.axes.len()];
        for (dim, &c) in dims.iter().zip(&dim_coord) {
            for &ai in &dim.axes {
                coords[ai] = c;
            }
        }
        coords
    }

    /// Every scenario id the grid expands to, in expansion order —
    /// cheaper than [`Self::expand`] (no configs are built), infallible,
    /// and the anchor the resume/report code keys on.
    pub fn ids(&self) -> Vec<String> {
        let dims = self.dims();
        let total = self.len();
        let width = total.to_string().len();
        (0..total)
            .map(|index| {
                let coords = self.axis_coords(&dims, index);
                let mut id = format!("s{index:0width$}");
                for (axis, &ci) in self.axes.iter().zip(&coords) {
                    id.push_str(&format!("__{}={}", axis.key, axis.values[ci]));
                }
                id
            })
            .collect()
    }

    /// Add every axis declared in an INI `[sweep]` section
    /// (`key = v1, v2, ...` per axis, expanded in the section's
    /// alphabetical key order). Reserved keys: `workers` (runner
    /// parallelism, read by the CLI), `derive_seeds`, and `zip`
    /// (`zip = key1+key2, key3+key4` pairs section axes; applied after
    /// all axes are declared).
    pub fn with_ini(mut self, ini: &Ini) -> Result<Self> {
        let mut zip_specs = Vec::new();
        for key in ini.keys("sweep") {
            if key == "derive_seeds" {
                self.derive_seeds = ini.get_or("sweep", "derive_seeds", self.derive_seeds)?;
            } else if key == "zip" {
                zip_specs = ini.get_list("sweep", "zip").unwrap_or_default();
            } else if RESERVED_KEYS.contains(&key) {
                continue;
            } else {
                let values = ini.get_list("sweep", key).unwrap_or_default();
                self = self.axis(key, values)?;
            }
        }
        for spec in zip_specs {
            self = self.zip_spec(&spec)?;
        }
        Ok(self)
    }

    /// Expand to the full scenario list (row-major over the dimensions,
    /// last dimension fastest). An axis-free grid yields the single base
    /// scenario.
    pub fn expand(&self) -> Result<Vec<Scenario>> {
        let dims = self.dims();
        let ids = self.ids();
        let explicit_seed_axis = self.axes.iter().any(|a| a.key == "seed");
        let mut scenarios = Vec::with_capacity(ids.len());
        for (index, id) in ids.into_iter().enumerate() {
            let coords = self.axis_coords(&dims, index);
            let mut cfg = self.base.clone();
            let mut assignment = Vec::with_capacity(self.axes.len());
            for (axis, &ci) in self.axes.iter().zip(&coords) {
                let value = &axis.values[ci];
                apply_key(&mut cfg, &axis.key, value)?;
                assignment.push((axis.key.clone(), value.clone()));
            }
            if self.derive_seeds && !explicit_seed_axis {
                cfg.seed = mix_seed(self.base.seed, index as u64);
            }
            cfg.validate().with_context(|| format!("scenario {id}"))?;
            scenarios.push(Scenario { index, id, assignment, cfg });
        }
        Ok(scenarios)
    }
}

/// Short fingerprint (FNV-1a 64 over the `Debug` rendering) of a
/// scenario's fully-resolved config. Written as the per-scenario CSV's
/// `config` column so `--resume` can refuse a CSV produced under a
/// different seed/epochs/fleet/… — drift the axis columns alone cannot
/// reveal. A pure function of the config, so resumed reports stay
/// byte-identical.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> String {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in format!("{cfg:?}").bytes() {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100_0000_01b3);
    }
    format!("{hash:016x}")
}

fn parse_value<T: std::str::FromStr>(key: &str, raw: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    raw.parse().map_err(|e| anyhow!("sweep axis {key} = '{raw}': {e}"))
}

/// Apply one swept value to a config (the single source of truth for
/// which [`ExperimentConfig`] fields are sweepable).
fn apply_key(cfg: &mut ExperimentConfig, key: &str, raw: &str) -> Result<()> {
    match key {
        "nu" => {
            let v: f64 = parse_value(key, raw)?;
            cfg.nu_comp = v;
            cfg.nu_link = v;
        }
        "nu_comp" => cfg.nu_comp = parse_value(key, raw)?,
        "nu_link" => cfg.nu_link = parse_value(key, raw)?,
        "delta" => {
            cfg.delta =
                if raw.eq_ignore_ascii_case("auto") { None } else { Some(parse_value(key, raw)?) };
        }
        "n_devices" => cfg.n_devices = parse_value(key, raw)?,
        "points_per_device" => cfg.points_per_device = parse_value(key, raw)?,
        "model_dim" => cfg.model_dim = parse_value(key, raw)?,
        "snr_db" => cfg.snr_db = parse_value(key, raw)?,
        "seed" => cfg.seed = parse_value(key, raw)?,
        "erasure_prob" => cfg.erasure_prob = parse_value(key, raw)?,
        "client_fraction" => cfg.client_fraction = parse_value(key, raw)?,
        "target_nmse" => cfg.target_nmse = parse_value(key, raw)?,
        "max_epochs" => cfg.max_epochs = parse_value(key, raw)?,
        "learning_rate" => cfg.learning_rate = parse_value(key, raw)?,
        "base_throughput_kbps" => cfg.base_throughput_kbps = parse_value(key, raw)?,
        "base_mac_rate_kmacs" => cfg.base_mac_rate_kmacs = parse_value(key, raw)?,
        "master_speedup" => cfg.master_speedup = parse_value(key, raw)?,
        "header_overhead" => cfg.header_overhead = parse_value(key, raw)?,
        "mem_overhead_factor" => cfg.mem_overhead_factor = parse_value(key, raw)?,
        "c_up_fraction" => cfg.c_up_fraction = parse_value(key, raw)?,
        "epsilon" => cfg.epsilon = parse_value(key, raw)?,
        "sharding" => cfg.sharding = parse_value(key, raw)?,
        "generator" => cfg.generator = parse_value(key, raw)?,
        "setup_cost" => cfg.setup_cost = parse_value(key, raw)?,
        "participation" => cfg.participation = parse_value(key, raw)?,
        "data_mode" => cfg.data_mode = parse_value(key, raw)?,
        "trace_points" => cfg.trace_points = parse_value(key, raw)?,
        "agg_fanin" => cfg.agg_fanin = parse_value(key, raw)?,
        "ladder_tiers" => cfg.ladder_tiers = parse_value(key, raw)?,
        other => bail!(
            "unknown sweep axis '{other}' (sweepable keys: {})",
            SWEEPABLE_KEYS.join(", ")
        ),
    }
    Ok(())
}
