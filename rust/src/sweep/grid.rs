//! Declarative scenario grids over [`ExperimentConfig`] fields.
//!
//! A grid is a base config plus ordered axes; expansion is the cartesian
//! product in declaration order with the *last* axis fastest (row-major),
//! so an `(A, B)` grid lays scenarios out as `A₀B₀, A₀B₁, …` — the same
//! order a nested `for` loop would produce. Scenario IDs are stable
//! functions of the grid alone (zero-padded index + axis assignment),
//! never of evaluation order or worker count.
//!
//! Seeding: by default every scenario shares the base seed (common random
//! numbers — paired comparisons across cells, as the paper's figures
//! use). With [`ScenarioGrid::derive_seeds`] each scenario instead gets
//! `rng::mix_seed(base_seed, index)`, and an explicit `seed` axis always
//! wins over both.

use crate::config::{ExperimentConfig, Ini};
use crate::rng::mix_seed;
use anyhow::{anyhow, bail, ensure, Context, Result};

/// Keys an axis may sweep (`nu` fans out to both ν knobs).
pub const SWEEPABLE_KEYS: &[&str] = &[
    "nu",
    "nu_comp",
    "nu_link",
    "delta",
    "n_devices",
    "points_per_device",
    "model_dim",
    "snr_db",
    "seed",
    "erasure_prob",
    "client_fraction",
    "target_nmse",
    "max_epochs",
    "learning_rate",
    "base_throughput_kbps",
    "base_mac_rate_kmacs",
    "master_speedup",
    "header_overhead",
    "mem_overhead_factor",
    "c_up_fraction",
    "epsilon",
    "sharding",
    "generator",
    "setup_cost",
];

/// `[sweep]` keys that configure the run rather than defining an axis.
const RESERVED_KEYS: &[&str] = &["workers", "derive_seeds"];

/// One swept parameter: a config key plus its value list (kept as the
/// raw strings so IDs, reports and re-parsing stay exact).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Axis {
    pub key: String,
    pub values: Vec<String>,
}

/// One fully-resolved cell of the grid.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Row-major position in the expansion (axis 0 slowest).
    pub index: usize,
    /// Stable identifier: `s<index>__key=value__…`.
    pub id: String,
    /// `(key, value)` pairs in axis declaration order.
    pub assignment: Vec<(String, String)>,
    /// The base config with the assignment (and seed policy) applied.
    pub cfg: ExperimentConfig,
}

/// A base config plus ordered sweep axes.
///
/// ```
/// use cfl::config::ExperimentConfig;
/// use cfl::sweep::ScenarioGrid;
///
/// let grid = ScenarioGrid::new(&ExperimentConfig::small())
///     .axis_f64("nu", &[0.0, 0.2]).unwrap()
///     .axis("delta", ["0.1", "auto"]).unwrap();
/// assert_eq!(grid.len(), 4);
///
/// let scenarios = grid.expand().unwrap();
/// // row-major: the last axis varies fastest, IDs are stable
/// assert_eq!(scenarios[0].id, "s0__nu=0__delta=0.1");
/// assert_eq!(scenarios[3].cfg.nu_comp, 0.2);
/// assert_eq!(scenarios[3].cfg.delta, None); // "auto" → optimizer's δ
/// ```
#[derive(Clone, Debug)]
pub struct ScenarioGrid {
    base: ExperimentConfig,
    axes: Vec<Axis>,
    derive_seeds: bool,
}

impl ScenarioGrid {
    /// Start a grid from a base configuration.
    pub fn new(base: &ExperimentConfig) -> Self {
        Self { base: base.clone(), axes: Vec::new(), derive_seeds: false }
    }

    /// Declared axes, in declaration order.
    pub fn axes(&self) -> &[Axis] {
        &self.axes
    }

    /// The base configuration the axes perturb.
    pub fn base(&self) -> &ExperimentConfig {
        &self.base
    }

    /// Number of scenarios the grid expands to (1 for an axis-free grid).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    /// True when expansion would yield no scenarios (never, today:
    /// empty-valued axes are rejected at declaration time).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Derive a distinct per-scenario seed (`mix_seed(base.seed, index)`)
    /// instead of sharing the base seed across cells.
    pub fn derive_seeds(mut self, yes: bool) -> Self {
        self.derive_seeds = yes;
        self
    }

    /// Declare an axis. Every value is type-checked against the key now,
    /// so a bad grid fails before any scenario runs.
    pub fn axis<S: AsRef<str>>(
        mut self,
        key: &str,
        values: impl IntoIterator<Item = S>,
    ) -> Result<Self> {
        let key = key.trim();
        let values: Vec<String> =
            values.into_iter().map(|v| v.as_ref().trim().to_string()).collect();
        ensure!(!values.is_empty(), "sweep axis '{key}' has no values");
        ensure!(!self.axes.iter().any(|a| a.key == key), "duplicate sweep axis '{key}'");
        let mut probe = self.base.clone();
        for v in &values {
            apply_key(&mut probe, key, v)?;
        }
        self.axes.push(Axis { key: key.to_string(), values });
        Ok(self)
    }

    /// Declare an axis of numeric values (formatting via `f64`'s
    /// round-trip `Display`, so `0.1` stays `0.1`).
    pub fn axis_f64(self, key: &str, values: &[f64]) -> Result<Self> {
        self.axis(key, values.iter().map(|v| v.to_string()))
    }

    /// Declare an axis from a `key=v1,v2,...` spec (the CLI `--axis` form).
    pub fn axis_spec(self, spec: &str) -> Result<Self> {
        let Some((key, values)) = spec.split_once('=') else {
            bail!("axis spec '{spec}' must be key=v1,v2,...");
        };
        let values: Vec<&str> =
            values.split(',').map(str::trim).filter(|v| !v.is_empty()).collect();
        self.axis(key, values)
    }

    /// Add every axis declared in an INI `[sweep]` section
    /// (`key = v1, v2, ...` per axis, expanded in the section's
    /// alphabetical key order). Reserved keys: `workers` (runner
    /// parallelism, read by the CLI) and `derive_seeds`.
    pub fn with_ini(mut self, ini: &Ini) -> Result<Self> {
        for key in ini.keys("sweep") {
            if key == "derive_seeds" {
                self.derive_seeds = ini.get_or("sweep", "derive_seeds", self.derive_seeds)?;
            } else if RESERVED_KEYS.contains(&key) {
                continue;
            } else {
                let values = ini.get_list("sweep", key).unwrap_or_default();
                self = self.axis(key, values)?;
            }
        }
        Ok(self)
    }

    /// Expand to the full scenario list (row-major, last axis fastest).
    /// An axis-free grid yields the single base scenario.
    pub fn expand(&self) -> Result<Vec<Scenario>> {
        let total = self.len();
        let width = total.to_string().len();
        let explicit_seed_axis = self.axes.iter().any(|a| a.key == "seed");
        let mut scenarios = Vec::with_capacity(total);
        for index in 0..total {
            // decode the row-major index into per-axis coordinates
            let mut coords = vec![0usize; self.axes.len()];
            let mut rem = index;
            for (ai, axis) in self.axes.iter().enumerate().rev() {
                coords[ai] = rem % axis.values.len();
                rem /= axis.values.len();
            }
            let mut cfg = self.base.clone();
            let mut assignment = Vec::with_capacity(self.axes.len());
            let mut id = format!("s{index:0width$}");
            for (axis, &ci) in self.axes.iter().zip(&coords) {
                let value = &axis.values[ci];
                apply_key(&mut cfg, &axis.key, value)?;
                id.push_str(&format!("__{}={}", axis.key, value));
                assignment.push((axis.key.clone(), value.clone()));
            }
            if self.derive_seeds && !explicit_seed_axis {
                cfg.seed = mix_seed(self.base.seed, index as u64);
            }
            cfg.validate().with_context(|| format!("scenario {id}"))?;
            scenarios.push(Scenario { index, id, assignment, cfg });
        }
        Ok(scenarios)
    }
}

fn parse_value<T: std::str::FromStr>(key: &str, raw: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    raw.parse().map_err(|e| anyhow!("sweep axis {key} = '{raw}': {e}"))
}

/// Apply one swept value to a config (the single source of truth for
/// which [`ExperimentConfig`] fields are sweepable).
fn apply_key(cfg: &mut ExperimentConfig, key: &str, raw: &str) -> Result<()> {
    match key {
        "nu" => {
            let v: f64 = parse_value(key, raw)?;
            cfg.nu_comp = v;
            cfg.nu_link = v;
        }
        "nu_comp" => cfg.nu_comp = parse_value(key, raw)?,
        "nu_link" => cfg.nu_link = parse_value(key, raw)?,
        "delta" => {
            cfg.delta =
                if raw.eq_ignore_ascii_case("auto") { None } else { Some(parse_value(key, raw)?) };
        }
        "n_devices" => cfg.n_devices = parse_value(key, raw)?,
        "points_per_device" => cfg.points_per_device = parse_value(key, raw)?,
        "model_dim" => cfg.model_dim = parse_value(key, raw)?,
        "snr_db" => cfg.snr_db = parse_value(key, raw)?,
        "seed" => cfg.seed = parse_value(key, raw)?,
        "erasure_prob" => cfg.erasure_prob = parse_value(key, raw)?,
        "client_fraction" => cfg.client_fraction = parse_value(key, raw)?,
        "target_nmse" => cfg.target_nmse = parse_value(key, raw)?,
        "max_epochs" => cfg.max_epochs = parse_value(key, raw)?,
        "learning_rate" => cfg.learning_rate = parse_value(key, raw)?,
        "base_throughput_kbps" => cfg.base_throughput_kbps = parse_value(key, raw)?,
        "base_mac_rate_kmacs" => cfg.base_mac_rate_kmacs = parse_value(key, raw)?,
        "master_speedup" => cfg.master_speedup = parse_value(key, raw)?,
        "header_overhead" => cfg.header_overhead = parse_value(key, raw)?,
        "mem_overhead_factor" => cfg.mem_overhead_factor = parse_value(key, raw)?,
        "c_up_fraction" => cfg.c_up_fraction = parse_value(key, raw)?,
        "epsilon" => cfg.epsilon = parse_value(key, raw)?,
        "sharding" => cfg.sharding = parse_value(key, raw)?,
        "generator" => cfg.generator = parse_value(key, raw)?,
        "setup_cost" => cfg.setup_cost = parse_value(key, raw)?,
        other => bail!(
            "unknown sweep axis '{other}' (sweepable keys: {})",
            SWEEPABLE_KEYS.join(", ")
        ),
    }
    Ok(())
}
