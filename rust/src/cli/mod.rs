//! Dependency-free command-line parsing (clap is unavailable offline).
//!
//! Supports the subset the `cfl` binary and examples need: subcommands,
//! `--flag`, `--key value` / `--key=value` options (repeatable —
//! [`Args::get`] sees the last occurrence, [`Args::get_all`] every one),
//! typed lookups with defaults, positional arguments, and generated
//! `--help` text.
//!
//! `--help`/`-h` is reported as [`Parsed::Help`] rather than printed —
//! the parser never exits the process, so library callers and tests can
//! drive it safely; only `main.rs` renders help and terminates.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec used for help text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Rendered in help; also used to mark value-taking options.
    pub value_hint: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    /// Every `--key value` occurrence in command-line order (repeatable
    /// options like `--axis`; `options` keeps only the last per key).
    multi_options: Vec<(String, String)>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Outcome of parsing: a normal invocation, or a help request the caller
/// is responsible for rendering (see [`Parser::help`]) and exiting on.
#[derive(Clone, Debug)]
pub enum Parsed {
    /// Normal invocation.
    Run(Args),
    /// `--help`/`-h` was present; `program` is argv[0] for the banner.
    Help { program: String },
}

impl Parsed {
    /// Unwrap the [`Parsed::Run`] case; panics on a help request
    /// (test/bench convenience — `main.rs` matches properly).
    #[track_caller]
    pub fn expect_run(self) -> Args {
        match self {
            Parsed::Run(args) => args,
            Parsed::Help { .. } => panic!("expected a run invocation, got --help"),
        }
    }
}

/// Command-line parser with a declared option set.
pub struct Parser {
    about: &'static str,
    subcommands: Vec<(&'static str, &'static str)>,
    opts: Vec<OptSpec>,
}

impl Parser {
    pub fn new(about: &'static str) -> Self {
        Self { about, subcommands: Vec::new(), opts: Vec::new() }
    }

    /// Declare a subcommand (first bare word on the command line).
    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    /// Declare a `--key <value>` option.
    pub fn opt(mut self, name: &'static str, value_hint: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, value_hint: Some(value_hint) });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, value_hint: None });
        self
    }

    /// Render help text.
    pub fn help(&self, program: &str) -> String {
        let mut s = format!("{}\n\nUsage: {program}", self.about);
        if !self.subcommands.is_empty() {
            s.push_str(" <command>");
        }
        s.push_str(" [options]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nCommands:\n");
            for (name, help) in &self.subcommands {
                s.push_str(&format!("  {name:<22} {help}\n"));
            }
        }
        s.push_str("\nOptions:\n");
        for o in &self.opts {
            let lhs = match o.value_hint {
                Some(hint) => format!("--{} <{}>", o.name, hint),
                None => format!("--{}", o.name),
            };
            s.push_str(&format!("  {lhs:<22} {}\n", o.help));
        }
        s.push_str("  --help                 show this message\n");
        s
    }

    /// Parse an argument vector (argv[0] included). `--help`/`-h`
    /// anywhere yields [`Parsed::Help`] instead of exiting.
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_else(|| "cfl".into()),
            ..Default::default()
        };
        let mut it = argv.iter().skip(1).peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Ok(Parsed::Help { program: args.program });
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(spec) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option --{name} (see --help)");
                };
                if spec.value_hint.is_some() {
                    let value = match inline {
                        Some(v) => v,
                        None => match it.next() {
                            // a help token is never an option value — keep
                            // the "--help anywhere" promise intact
                            Some(v) if v == "--help" || v == "-h" => {
                                return Ok(Parsed::Help { program: args.program });
                            }
                            Some(v) => v.clone(),
                            None => bail!("option --{name} requires a value"),
                        },
                    };
                    args.options.insert(name.clone(), value.clone());
                    args.multi_options.push((name, value));
                } else {
                    if inline.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    args.flags.push(name);
                }
            } else if args.subcommand.is_none()
                && args.positional.is_empty()
                && self.subcommands.iter().any(|(n, _)| n == tok)
            {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(Parsed::Run(args))
    }

    /// Parse `std::env::args()`.
    pub fn parse_env(&self) -> Result<Parsed> {
        let argv: Vec<String> = std::env::args().collect();
        self.parse(&argv)
    }
}

impl Args {
    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable `--name value` option, in
    /// command-line order ([`Args::get`] sees only the last).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.multi_options
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// Typed option lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{name} '{s}': {e}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests;
