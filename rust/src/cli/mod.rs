//! Dependency-free command-line parsing (clap is unavailable offline).
//!
//! Supports the subset the `cfl` binary and examples need: subcommands,
//! `--flag`, `--key value` / `--key=value` options, typed lookups with
//! defaults, positional arguments, and generated `--help` text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec used for help text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Rendered in help; also used to mark value-taking options.
    pub value_hint: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Command-line parser with a declared option set.
pub struct Parser {
    about: &'static str,
    subcommands: Vec<(&'static str, &'static str)>,
    opts: Vec<OptSpec>,
}

impl Parser {
    pub fn new(about: &'static str) -> Self {
        Self { about, subcommands: Vec::new(), opts: Vec::new() }
    }

    /// Declare a subcommand (first bare word on the command line).
    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    /// Declare a `--key <value>` option.
    pub fn opt(mut self, name: &'static str, value_hint: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, value_hint: Some(value_hint) });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, value_hint: None });
        self
    }

    /// Render help text.
    pub fn help(&self, program: &str) -> String {
        let mut s = format!("{}\n\nUsage: {program}", self.about);
        if !self.subcommands.is_empty() {
            s.push_str(" <command>");
        }
        s.push_str(" [options]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nCommands:\n");
            for (name, help) in &self.subcommands {
                s.push_str(&format!("  {name:<22} {help}\n"));
            }
        }
        s.push_str("\nOptions:\n");
        for o in &self.opts {
            let lhs = match o.value_hint {
                Some(hint) => format!("--{} <{}>", o.name, hint),
                None => format!("--{}", o.name),
            };
            s.push_str(&format!("  {lhs:<22} {}\n", o.help));
        }
        s.push_str("  --help                 show this message\n");
        s
    }

    /// Parse an argument vector (argv[0] included).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_else(|| "cfl".into()),
            ..Default::default()
        };
        let mut it = argv.iter().skip(1).peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                println!("{}", self.help(&args.program));
                std::process::exit(0);
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(spec) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option --{name} (see --help)");
                };
                if spec.value_hint.is_some() {
                    let value = match inline {
                        Some(v) => v,
                        None => match it.next() {
                            Some(v) => v.clone(),
                            None => bail!("option --{name} requires a value"),
                        },
                    };
                    args.options.insert(name, value);
                } else {
                    if inline.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    args.flags.push(name);
                }
            } else if args.subcommand.is_none()
                && args.positional.is_empty()
                && self.subcommands.iter().any(|(n, _)| n == tok)
            {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`.
    pub fn parse_env(&self) -> Result<Args> {
        let argv: Vec<String> = std::env::args().collect();
        self.parse(&argv)
    }
}

impl Args {
    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{name} '{s}': {e}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests;
