use super::*;

fn parser() -> Parser {
    Parser::new("test tool")
        .subcommand("train", "run training")
        .subcommand("optimize", "run the load optimizer")
        .opt("seed", "u64", "root seed")
        .opt("delta", "f64", "coding redundancy")
        .opt("axis", "key=v1,v2", "sweep axis (repeatable)")
        .flag("verbose", "chatty output")
}

fn argv(s: &str) -> Vec<String> {
    std::iter::once("cfl".to_string()).chain(s.split_whitespace().map(String::from)).collect()
}

fn parse_run(s: &str) -> Args {
    parser().parse(&argv(s)).unwrap().expect_run()
}

#[test]
fn parses_subcommand_options_flags() {
    let a = parse_run("train --seed 42 --delta=0.13 --verbose extra1 extra2");
    assert_eq!(a.subcommand(), Some("train"));
    assert_eq!(a.get_or("seed", 0u64).unwrap(), 42);
    assert_eq!(a.get_or("delta", 0.0f64).unwrap(), 0.13);
    assert!(a.has_flag("verbose"));
    assert_eq!(a.positional(), &["extra1".to_string(), "extra2".to_string()]);
}

#[test]
fn defaults_apply_when_absent() {
    let a = parse_run("optimize");
    assert_eq!(a.subcommand(), Some("optimize"));
    assert_eq!(a.get_or("seed", 7u64).unwrap(), 7);
    assert!(!a.has_flag("verbose"));
}

#[test]
fn unknown_option_rejected() {
    assert!(parser().parse(&argv("train --bogus 1")).is_err());
}

#[test]
fn missing_value_rejected() {
    assert!(parser().parse(&argv("train --seed")).is_err());
}

#[test]
fn flag_with_value_rejected() {
    assert!(parser().parse(&argv("train --verbose=yes")).is_err());
}

#[test]
fn type_error_reported_with_context() {
    let a = parse_run("train --seed abc");
    let err = a.get_or("seed", 0u64).unwrap_err().to_string();
    assert!(err.contains("--seed"), "{err}");
}

#[test]
fn non_subcommand_word_is_positional() {
    let a = parse_run("somefile.ini --seed 1");
    assert_eq!(a.subcommand(), None);
    assert_eq!(a.positional(), &["somefile.ini".to_string()]);
}

#[test]
fn help_text_lists_everything() {
    let h = parser().help("cfl");
    for needle in ["train", "optimize", "--seed", "--delta", "--verbose", "--help"] {
        assert!(h.contains(needle), "help missing {needle}:\n{h}");
    }
}

#[test]
fn help_is_a_result_variant_not_an_exit() {
    // the whole point of Parsed::Help: library callers survive --help;
    // the last case would otherwise swallow --help as --seed's value
    for line in ["--help", "-h", "train --seed 1 --help", "train --seed --help"] {
        match parser().parse(&argv(line)).unwrap() {
            Parsed::Help { program } => assert_eq!(program, "cfl"),
            Parsed::Run(_) => panic!("'{line}' should request help"),
        }
    }
}

#[test]
fn repeated_option_keeps_every_occurrence() {
    let a = parse_run("train --axis nu_comp=0,0.1 --axis nu_link=0,0.2 --seed 1");
    assert_eq!(a.get_all("axis"), vec!["nu_comp=0,0.1", "nu_link=0,0.2"]);
    // get() sees the last occurrence, get_all() preserves order
    assert_eq!(a.get("axis"), Some("nu_link=0,0.2"));
    assert!(a.get_all("seed") == vec!["1"]);
    assert!(a.get_all("delta").is_empty());
}

#[test]
#[should_panic(expected = "expected a run invocation")]
fn expect_run_panics_on_help() {
    let _ = parser().parse(&argv("--help")).unwrap().expect_run();
}
