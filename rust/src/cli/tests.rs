use super::*;

fn parser() -> Parser {
    Parser::new("test tool")
        .subcommand("train", "run training")
        .subcommand("optimize", "run the load optimizer")
        .opt("seed", "u64", "root seed")
        .opt("delta", "f64", "coding redundancy")
        .flag("verbose", "chatty output")
}

fn argv(s: &str) -> Vec<String> {
    std::iter::once("cfl".to_string()).chain(s.split_whitespace().map(String::from)).collect()
}

#[test]
fn parses_subcommand_options_flags() {
    let a = parser().parse(&argv("train --seed 42 --delta=0.13 --verbose extra1 extra2")).unwrap();
    assert_eq!(a.subcommand(), Some("train"));
    assert_eq!(a.get_or("seed", 0u64).unwrap(), 42);
    assert_eq!(a.get_or("delta", 0.0f64).unwrap(), 0.13);
    assert!(a.has_flag("verbose"));
    assert_eq!(a.positional(), &["extra1".to_string(), "extra2".to_string()]);
}

#[test]
fn defaults_apply_when_absent() {
    let a = parser().parse(&argv("optimize")).unwrap();
    assert_eq!(a.subcommand(), Some("optimize"));
    assert_eq!(a.get_or("seed", 7u64).unwrap(), 7);
    assert!(!a.has_flag("verbose"));
}

#[test]
fn unknown_option_rejected() {
    assert!(parser().parse(&argv("train --bogus 1")).is_err());
}

#[test]
fn missing_value_rejected() {
    assert!(parser().parse(&argv("train --seed")).is_err());
}

#[test]
fn flag_with_value_rejected() {
    assert!(parser().parse(&argv("train --verbose=yes")).is_err());
}

#[test]
fn type_error_reported_with_context() {
    let a = parser().parse(&argv("train --seed abc")).unwrap();
    let err = a.get_or("seed", 0u64).unwrap_err().to_string();
    assert!(err.contains("--seed"), "{err}");
}

#[test]
fn non_subcommand_word_is_positional() {
    let a = parser().parse(&argv("somefile.ini --seed 1")).unwrap();
    assert_eq!(a.subcommand(), None);
    assert_eq!(a.positional(), &["somefile.ini".to_string()]);
}

#[test]
fn help_text_lists_everything() {
    let h = parser().help("cfl");
    for needle in ["train", "optimize", "--seed", "--delta", "--verbose", "--help"] {
        assert!(h.contains(needle), "help missing {needle}:\n{h}");
    }
}
