use super::*;
use crate::data::Dataset;
use crate::linalg::{matmul, matmul_at_b, Mat};
use crate::rng::Rng;
use crate::testing::prop::{self, assert_that};

#[test]
fn native_partial_grad_matches_formula() {
    let mut rng = Rng::new(1);
    let x = Mat::randn(30, 8, &mut rng);
    let beta = Mat::randn(8, 1, &mut rng);
    let y = Mat::randn(30, 1, &mut rng);
    let mut b = NativeBackend;
    let got = b.partial_grad(&x, &beta, &y).unwrap();
    let mut resid = matmul(&x, &beta);
    resid.axpy(-1.0, &y);
    let want = matmul_at_b(&x, &resid);
    assert!(got.max_abs_diff(&want) < 1e-4);
}

#[test]
fn native_parity_grad_normalizes_by_c() {
    let mut rng = Rng::new(2);
    let xt = Mat::randn(64, 8, &mut rng);
    let beta = Mat::randn(8, 1, &mut rng);
    let yt = Mat::randn(64, 1, &mut rng);
    let mut b = NativeBackend;
    let unnorm = b.partial_grad(&xt, &beta, &yt).unwrap();
    let got = b.parity_grad(&xt, &beta, &yt, 48).unwrap(); // logical c < rows
    let mut want = unnorm.clone();
    want.scale(1.0 / 48.0);
    assert!(got.max_abs_diff(&want) < 1e-6);
    assert!(b.parity_grad(&xt, &beta, &yt, 0).is_err());
}

#[test]
fn native_encode_matches_two_pass() {
    let mut rng = Rng::new(3);
    let g = Mat::randn(6, 20, &mut rng);
    let x = Mat::randn(20, 5, &mut rng);
    let y = Mat::randn(20, 1, &mut rng);
    let w: Vec<f32> = (0..20).map(|i| 0.1 + 0.04 * i as f32).collect();
    let mut b = NativeBackend;
    let (xt, yt) = b.encode(&g, &w, &x, &y).unwrap();
    let mut xw = x.clone();
    xw.scale_rows(&w);
    let mut yw = y.clone();
    yw.scale_rows(&w);
    assert!(xt.max_abs_diff(&matmul(&g, &xw)) < 1e-5);
    assert!(yt.max_abs_diff(&matmul(&g, &yw)) < 1e-5);
    // dimension mismatches are rejected
    assert!(b.encode(&g, &w[..10], &x, &y).is_err());
}

#[test]
fn model_update_is_eq3() {
    let mut m = GlobalModel::zeros(4, 0.1, 100);
    let g = Mat::col_vec(&[1.0, -2.0, 0.0, 4.0]);
    m.apply_gradient(&g);
    // β ← 0 − (0.1/100)·g
    assert!((m.beta[(0, 0)] + 0.001).abs() < 1e-9);
    assert!((m.beta[(1, 0)] - 0.002).abs() < 1e-9);
    assert!((m.beta[(3, 0)] + 0.004).abs() < 1e-9);
}

#[test]
fn model_nmse_starts_at_one_with_zero_init() {
    let mut rng = Rng::new(4);
    let beta_star = Mat::randn(16, 1, &mut rng);
    let m = GlobalModel::zeros(16, 0.01, 10);
    assert!((m.nmse(&beta_star) - 1.0).abs() < 1e-12);
}

#[test]
fn assemble_combines_parity_and_devices() {
    let p = Mat::col_vec(&[1.0, 1.0]);
    let d1 = Mat::col_vec(&[0.5, 0.0]);
    let d2 = Mat::col_vec(&[0.0, 0.25]);
    let g = assemble_coded_gradient(2, Some(&p), &[&d1, &d2]);
    assert_eq!(g.as_slice(), &[1.5, 1.25]);
    let g2 = assemble_coded_gradient(2, None, &[&d1]);
    assert_eq!(g2.as_slice(), &[0.5, 0.0]);
    let g3 = assemble_coded_gradient(2, None, &[]);
    assert_eq!(g3.as_slice(), &[0.0, 0.0]);
}

#[test]
fn tree_assemble_fanin_zero_is_flat_sum() {
    let mut rng = Rng::new(21);
    let grads: Vec<Mat> = (0..37).map(|_| Mat::randn(8, 1, &mut rng)).collect();
    let refs: Vec<&Mat> = grads.iter().collect();
    let p = Mat::randn(8, 1, &mut rng);
    let flat = assemble_coded_gradient(8, Some(&p), &refs);
    let tree0 = assemble_coded_gradient_tree(8, Some(&p), &refs, 0);
    assert_eq!(flat.as_slice(), tree0.as_slice(), "fanin 0 must be byte-identical");
}

#[test]
fn tree_assemble_matches_flat_sum_numerically() {
    let mut rng = Rng::new(22);
    let grads: Vec<Mat> = (0..100).map(|_| Mat::randn(6, 1, &mut rng)).collect();
    let refs: Vec<&Mat> = grads.iter().collect();
    let p = Mat::randn(6, 1, &mut rng);
    let flat = assemble_coded_gradient(6, Some(&p), &refs);
    for fanin in [2usize, 3, 8, 32, 128] {
        let tree = assemble_coded_gradient_tree(6, Some(&p), &refs, fanin);
        assert!(
            tree.max_abs_diff(&flat) < 1e-4,
            "fanin {fanin} diverged from flat sum"
        );
    }
    // degenerate inputs
    let empty = assemble_coded_gradient_tree(6, None, &[], 4);
    assert_eq!(empty.as_slice(), Mat::zeros(6, 1).as_slice());
    let only_parity = assemble_coded_gradient_tree(6, Some(&p), &[], 4);
    assert_eq!(only_parity.as_slice(), p.as_slice());
}

#[test]
fn full_batch_gd_converges_on_clean_data() {
    // closed-loop sanity: iterating Eq. 2+3 on noiseless data drives NMSE→0
    let mut rng = Rng::new(5);
    let d = 12;
    let ds = Dataset::generate(240, d, 80.0, &mut rng); // ~noiseless
    let mut model = GlobalModel::zeros(d, 0.05, 240);
    let mut backend = NativeBackend;
    for _ in 0..600 {
        let g = backend.partial_grad(&ds.x, &model.beta, &ds.y).unwrap();
        model.apply_gradient(&g);
    }
    let nmse = model.nmse(&ds.beta_star);
    assert!(nmse < 1e-6, "GD did not converge: NMSE = {nmse:.3e}");
}

#[test]
fn prop_gd_step_is_linear_in_gradient() {
    prop::check("gd step linearity", prop::cfg_cases(30), |g| {
        let d = g.size_in(1, 16);
        let lr = g.f64_in(0.001, 0.5);
        let mpts = g.size_in(1, 500);
        let mut rng = g.rng();
        let ga = Mat::randn(d, 1, &mut rng);
        let gb = Mat::randn(d, 1, &mut rng);
        // apply(ga) then apply(gb) == apply(ga + gb)
        let mut m1 = GlobalModel::zeros(d, lr, mpts);
        m1.apply_gradient(&ga);
        m1.apply_gradient(&gb);
        let mut m2 = GlobalModel::zeros(d, lr, mpts);
        let mut gsum = ga.clone();
        gsum.add_assign(&gb);
        m2.apply_gradient(&gsum);
        assert_that(m1.beta.max_abs_diff(&m2.beta) < 1e-5, "update not additive")
    });
}

#[test]
fn coded_gradient_is_unbiased_estimate_of_full_gradient() {
    // The Eq. 18+19 claim, tested end-to-end over the randomness of both
    // the code (G) and the Bernoulli returns: averaging the assembled
    // coded gradient over many independent draws must approach the exact
    // full-data gradient Xᵀ(Xβ − y).
    use crate::coding::DeviceCode;
    use crate::config::GeneratorKind;

    let mut rng = Rng::new(11);
    let (l, d) = (60usize, 12usize);
    let ds = Dataset::generate(l, d, 10.0, &mut rng);
    let beta = Mat::randn(d, 1, &mut rng);
    let mut backend = NativeBackend;
    let full = backend.partial_grad(&ds.x, &beta, &ds.y).unwrap();

    let c = 512;
    let load = 40; // systematic points; the other 20 are punctured
    let p_return = 0.7; // P{T ≤ t*} ⇒ prob_miss = 0.3 ⇒ w² = 0.3
    let trials = 600;
    let mut mean = Mat::zeros(d, 1);
    for t in 0..trials {
        let mut trial_rng = Rng::new(1000 + t as u64);
        let code =
            DeviceCode::draw(l, c, load, 1.0 - p_return, GeneratorKind::Gaussian, &mut trial_rng);
        let (xt, yt) =
            backend.encode(&code.generator, &code.weights, &ds.x, &ds.y).unwrap();
        let parity = backend.parity_grad(&xt, &beta, &yt, c).unwrap();
        let mut combined = parity;
        if trial_rng.bernoulli(p_return) {
            // device made the deadline: its systematic partial gradient
            let mut xs = Mat::zeros(load, d);
            let mut ys = Mat::zeros(load, 1);
            for (r, &src) in code.systematic_rows().iter().enumerate() {
                xs.row_mut(r).copy_from_slice(ds.x.row(src));
                ys[(r, 0)] = ds.y[(src, 0)];
            }
            let dev = backend.partial_grad(&xs, &beta, &ys).unwrap();
            combined.add_assign(&dev);
        }
        mean.axpy(1.0 / trials as f32, &combined);
    }
    let rel = (mean.dist_sq(&full) / full.norm_sq()).sqrt();
    assert!(rel < 0.12, "coded gradient biased: rel err {rel:.3}");
}
