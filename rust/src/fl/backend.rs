//! Gradient-compute backends.

use crate::linalg::{self, Mat};
use anyhow::Result;

/// The three compute graphs of the system (mirroring
/// `python/compile/model.py` one-to-one). Implementations: the native
/// fused kernels below (oracle / fallback) and the PJRT artifact runtime.
///
/// `Send` is a supertrait so coordinators owning a `Box<dyn GradBackend>`
/// can be instantiated per worker thread — the [`crate::sweep`] engine
/// runs one [`crate::coordinator::Coordinator`] per scenario on a
/// thread pool.
pub trait GradBackend: Send {
    /// Device partial gradient over a systematic shard:
    /// g = Xᵀ(Xβ − y) (Eq. 2 inner sum). `x` already contains only the
    /// rows being processed (masking happened upstream).
    fn partial_grad(&mut self, x: &Mat, beta: &Mat, y: &Mat) -> Result<Mat>;

    /// Master parity gradient, normalized (Eq. 18 LHS):
    /// (1/c)·X̃ᵀ(X̃β − ỹ) with `c` the *logical* parity count.
    fn parity_grad(&mut self, xt: &Mat, beta: &Mat, yt: &Mat, c: usize) -> Result<Mat>;

    /// Device-side parity encode (Eq. 9): (G(w⊙X), G(w⊙y)).
    fn encode(&mut self, g: &Mat, w: &[f32], x: &Mat, y: &Mat) -> Result<(Mat, Mat)>;

    /// Hot-path optimization hook: register a *static* shard (X, y) whose
    /// gradient will be requested every epoch with a changing β. Backends
    /// that benefit (PJRT: pre-pad once, keep device-resident buffers so
    /// only β crosses the host boundary per epoch) return a handle;
    /// the default says "no fast path" and the caller falls back to
    /// [`GradBackend::partial_grad`].
    fn register_shard(&mut self, _x: &Mat, _y: &Mat) -> Result<Option<u64>> {
        Ok(None)
    }

    /// Gradient of a shard registered via [`GradBackend::register_shard`].
    fn partial_grad_registered(&mut self, _handle: u64, _beta: &Mat) -> Result<Mat> {
        anyhow::bail!("backend has no registered-shard fast path")
    }

    /// Like [`GradBackend::register_shard`] for the master's composite
    /// parity set (normalized-by-c gradient each epoch).
    fn register_parity(&mut self, _xt: &Mat, _yt: &Mat, _c: usize) -> Result<Option<u64>> {
        Ok(None)
    }

    /// Normalized parity gradient of a set registered via
    /// [`GradBackend::register_parity`].
    fn parity_grad_registered(&mut self, _handle: u64, _beta: &Mat) -> Result<Mat> {
        anyhow::bail!("backend has no registered-parity fast path")
    }

    /// Human-readable backend name (logging / EXPERIMENTS.md provenance).
    fn name(&self) -> &'static str;
}

/// Pure-rust backend built on [`crate::linalg`]'s fused kernels.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl GradBackend for NativeBackend {
    fn partial_grad(&mut self, x: &Mat, beta: &Mat, y: &Mat) -> Result<Mat> {
        Ok(linalg::partial_grad(x, beta, y))
    }

    fn parity_grad(&mut self, xt: &Mat, beta: &Mat, yt: &Mat, c: usize) -> Result<Mat> {
        anyhow::ensure!(c > 0, "parity count must be positive");
        let mut g = linalg::partial_grad(xt, beta, yt);
        g.scale(1.0 / c as f32);
        Ok(g)
    }

    fn encode(&mut self, g: &Mat, w: &[f32], x: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        anyhow::ensure!(g.cols() == x.rows(), "G cols must match X rows");
        anyhow::ensure!(w.len() == x.rows(), "weight diagonal length");
        // fused G·diag(w): scale a copy of X/y rows once, then GEMM —
        // mirrors the Pallas kernel's w-fused tile loop.
        let mut xw = x.clone();
        xw.scale_rows(w);
        let mut yw = y.clone();
        yw.scale_rows(w);
        Ok((linalg::matmul(g, &xw), linalg::matmul(g, &yw)))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}
