//! Global model state and the master's gradient combination.

use crate::linalg::Mat;

/// The global model β and its update rule.
#[derive(Clone, Debug)]
pub struct GlobalModel {
    pub beta: Mat,
    /// Learning rate μ (Eq. 3 divides by m at update time).
    pub learning_rate: f64,
    /// Total raw points m.
    pub total_points: usize,
}

impl GlobalModel {
    /// β⁽⁰⁾ = 0 (the paper allows arbitrary init; zero is standard and
    /// makes NMSE start at exactly 1).
    pub fn zeros(dim: usize, learning_rate: f64, total_points: usize) -> Self {
        Self { beta: Mat::zeros(dim, 1), learning_rate, total_points }
    }

    /// Eq. (3): β ← β − (μ/m)·g.
    pub fn apply_gradient(&mut self, grad: &Mat) {
        let scale = -(self.learning_rate / self.total_points as f64) as f32;
        self.beta.axpy(scale, grad);
    }

    /// NMSE against the ground truth (§IV metric).
    pub fn nmse(&self, beta_star: &Mat) -> f64 {
        self.beta.nmse(beta_star)
    }
}

/// Eq. 18 + Eq. 19 combination: the parity gradient (already normalized by
/// c) estimates `XᵀWᵀW(Xβ−y)`; the received device gradients contribute
/// the `(1 − w²)` complement in expectation. Their sum estimates the full
/// gradient of Eq. (2).
///
/// `device_grads` holds the partial gradients that arrived by t*;
/// `parity_grad` is `None` on the (rare, off-policy) epochs where the
/// master's own parity computation missed the deadline.
pub fn assemble_coded_gradient(
    dim: usize,
    parity_grad: Option<&Mat>,
    device_grads: &[&Mat],
) -> Mat {
    let mut g = Mat::zeros(dim, 1);
    if let Some(p) = parity_grad {
        g.add_assign(p);
    }
    for dg in device_grads {
        g.add_assign(dg);
    }
    g
}
