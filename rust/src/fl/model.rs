//! Global model state and the master's gradient combination.

use crate::linalg::Mat;

/// The global model β and its update rule.
#[derive(Clone, Debug)]
pub struct GlobalModel {
    pub beta: Mat,
    /// Learning rate μ (Eq. 3 divides by m at update time).
    pub learning_rate: f64,
    /// Total raw points m.
    pub total_points: usize,
}

impl GlobalModel {
    /// β⁽⁰⁾ = 0 (the paper allows arbitrary init; zero is standard and
    /// makes NMSE start at exactly 1).
    pub fn zeros(dim: usize, learning_rate: f64, total_points: usize) -> Self {
        Self { beta: Mat::zeros(dim, 1), learning_rate, total_points }
    }

    /// Eq. (3): β ← β − (μ/m)·g.
    pub fn apply_gradient(&mut self, grad: &Mat) {
        let scale = -(self.learning_rate / self.total_points as f64) as f32;
        self.beta.axpy(scale, grad);
    }

    /// NMSE against the ground truth (§IV metric).
    pub fn nmse(&self, beta_star: &Mat) -> f64 {
        self.beta.nmse(beta_star)
    }
}

/// Eq. 18 + Eq. 19 combination: the parity gradient (already normalized by
/// c) estimates `XᵀWᵀW(Xβ−y)`; the received device gradients contribute
/// the `(1 − w²)` complement in expectation. Their sum estimates the full
/// gradient of Eq. (2).
///
/// `device_grads` holds the partial gradients that arrived by t*;
/// `parity_grad` is `None` on the (rare, off-policy) epochs where the
/// master's own parity computation missed the deadline.
pub fn assemble_coded_gradient(
    dim: usize,
    parity_grad: Option<&Mat>,
    device_grads: &[&Mat],
) -> Mat {
    let mut g = Mat::zeros(dim, 1);
    if let Some(p) = parity_grad {
        g.add_assign(p);
    }
    for dg in device_grads {
        g.add_assign(dg);
    }
    g
}

/// [`assemble_coded_gradient`] with a hierarchical reduction: gradients
/// are summed in groups of `fanin`, then the group sums are summed in
/// groups of `fanin`, and so on — the aggregation-tree shape a real
/// million-device deployment would use (edge aggregators feeding regional
/// ones feeding the master). The parity gradient joins at the root.
///
/// `fanin = 0` (the default) delegates to the flat left-to-right sum and
/// is **byte-identical** to [`assemble_coded_gradient`]. With `fanin ≥ 2`
/// the result differs from the flat sum only by float association order
/// (same set of addends), while the depth drops from O(k) to
/// O(log_fanin k) — the per-epoch critical path of the Eq. 19 gather.
pub fn assemble_coded_gradient_tree(
    dim: usize,
    parity_grad: Option<&Mat>,
    device_grads: &[&Mat],
    fanin: usize,
) -> Mat {
    if fanin == 0 {
        return assemble_coded_gradient(dim, parity_grad, device_grads);
    }
    assert!(fanin >= 2, "fanin must be 0 (flat) or >= 2");
    // leaf level: sum each group of `fanin` gradients
    let mut level: Vec<Mat> = device_grads
        .chunks(fanin)
        .map(|group| {
            let mut s = Mat::zeros(dim, 1);
            for dg in group {
                s.add_assign(dg);
            }
            s
        })
        .collect();
    // inner levels
    while level.len() > 1 {
        level = level
            .chunks(fanin)
            .map(|group| {
                let mut it = group.iter();
                let mut s = it.next().expect("nonempty chunk").clone();
                for dg in it {
                    s.add_assign(dg);
                }
                s
            })
            .collect();
    }
    let mut g = level.pop().unwrap_or_else(|| Mat::zeros(dim, 1));
    if let Some(p) = parity_grad {
        g.add_assign(p);
    }
    g
}
