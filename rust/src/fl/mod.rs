//! Federated-learning engine: gradient backends, the model update rule,
//! and the gradient-assembly math of Eqs. (2)–(3) and (18)–(19).
//!
//! The *timing* of federated learning (who returns by when) lives in
//! [`crate::coordinator`]; this module owns the *numerics*:
//!
//! * [`GradBackend`] — the three compute graphs every epoch needs
//!   (device partial gradient, normalized parity gradient, parity encode),
//!   implemented natively ([`NativeBackend`], the oracle) and via PJRT
//!   artifacts ([`crate::runtime::PjrtBackend`]).
//! * [`GlobalModel`] — β and the Eq. (3) update `β ← β − (μ/m)·g`.
//! * [`assemble_coded_gradient`] — the master's Eq. 18+19 combination:
//!   normalized parity gradient + the on-time device partial gradients.

mod backend;
mod model;

pub use backend::{GradBackend, NativeBackend};
pub use model::{assemble_coded_gradient, GlobalModel};

#[cfg(test)]
mod tests;
