//! xoshiro256++ core with splitmix64 seeding.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2019). xoshiro256++ passes BigCrush, has a 2^256−1 period,
//! and is allocation- and branch-free on the hot path — it is sampled
//! millions of times per simulated training run.

/// splitmix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive a decorrelated child seed from a root seed and a stream label.
///
/// One splitmix64 step over `root ⊕ label·odd` — the same derivation
/// discipline as [`Rng::split`], but seed-to-seed, so callers that need a
/// *seed* per independent unit of work (e.g. one per sweep scenario) get
/// streams that are reproducible from `(root, label)` alone, independent
/// of evaluation order.
///
/// ```
/// use cfl::rng::{mix_seed, Rng};
///
/// // pure function of (root, stream); distinct streams decorrelate
/// assert_eq!(mix_seed(42, 7), mix_seed(42, 7));
/// assert_ne!(mix_seed(42, 7), mix_seed(42, 8));
///
/// // a derived seed drives a reproducible generator
/// let mut a = Rng::new(mix_seed(42, 7));
/// let mut b = Rng::new(mix_seed(42, 7));
/// assert_eq!(a.next_u64(), b.next_u64());
/// ```
pub fn mix_seed(root: u64, stream: u64) -> u64 {
    let mut sm = root ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
    splitmix64(&mut sm)
}

/// xoshiro256++ pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64,
    /// per the xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is the one invalid state; splitmix64 of any seed
        // cannot produce it (outputs are a bijection of the counter), but
        // guard anyway.
        if s == [0; 4] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Derive an independent substream labelled by `stream`.
    ///
    /// Uses splitmix64 over (state ⊕ label) so substreams of the same
    /// generator are decorrelated, and derivation does not disturb `self`.
    pub fn split(&self, stream: u64) -> Self {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
            ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Next raw 64 random bits (xoshiro256++ output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a `ln()` argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }
}

#[cfg(test)]
mod reference_vectors {
    use super::*;

    /// xoshiro256++ reference: with state {1,2,3,4} the first outputs are
    /// known (from the authors' C implementation).
    #[test]
    fn matches_published_sequence() {
        let mut r = Rng { s: [1, 2, 3, 4] };
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(got, vec![41943041, 58720359, 3588806011781223, 3591011842654386]);
    }
}
