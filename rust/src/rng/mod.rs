//! Deterministic pseudo-random number generation.
//!
//! The sandbox has no `rand` crate, so this module is a first-class
//! substrate: a xoshiro256++ core seeded via splitmix64, plus the exact
//! distributions the paper's models need — uniform, standard normal
//! (Box–Muller), exponential (compute-time tail, Eq. 4), geometric
//! (retransmission count, Eq. 5), Bernoulli/Rademacher (generator
//! matrices, §III-A) — and Fisher–Yates shuffling (the §IV "randomly
//! assign a unique value to each device" ladders).
//!
//! Every experiment takes an explicit `u64` seed; independent substreams
//! are derived with [`Rng::split`] so component randomness (data, codes,
//! delays) is decoupled — re-running any figure with the same seed is
//! bit-reproducible.

mod distributions; // impl blocks on Rng (normal, exponential, geometric, …)
mod xoshiro;

pub use xoshiro::{mix_seed, Rng};

#[cfg(test)]
mod tests;
