//! Sampling routines for the distributions in the paper's models.
//!
//! * [`normal`] — standard normal via Box–Muller (generator matrices §III-A,
//!   training data §IV).
//! * [`exponential`] — rate-λ exponential (stochastic compute component
//!   `T_{c_{i,2}}`, Eq. 4).
//! * [`geometric`] — number of transmissions until first success, support
//!   {1, 2, …} (Eq. 5).
//! * [`bernoulli`] / [`rademacher`] — coin flips; Rademacher (±1) is the
//!   normalized Bernoulli(½) generator-matrix variant.
//! * [`shuffle`] — Fisher–Yates, used to "randomly assign a unique value to
//!   each edge device" (§IV heterogeneity ladders).

use super::Rng;

impl Rng {
    /// Standard normal N(0, 1) via Box–Muller.
    ///
    /// The second variate of the pair is deliberately discarded: keeping a
    /// one-sample cache would make substream derivation (`split`) and
    /// clone-reproducibility subtly stateful for a ~1.6× speedup we don't
    /// need (gradient math runs through PJRT, not the RNG).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (core::f64::consts::TAU * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/λ).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0, "exponential rate must be positive");
        -self.next_f64_open().ln() / lambda
    }

    /// Geometric number of trials until first success, P{N = t} =
    /// p^(t−1)(1−p), t ≥ 1 — Eq. (5) with `p` the link erasure probability.
    ///
    /// Sampled by inversion: N = ⌈ln U / ln p⌉ clamped to ≥ 1, which is
    /// exact for the ceiling parameterization.
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!((0.0..1.0).contains(&p), "erasure probability in [0,1)");
        if p == 0.0 {
            return 1;
        }
        let u = self.next_f64_open();
        let n = (u.ln() / p.ln()).ceil();
        if n < 1.0 {
            1
        } else {
            n as u64
        }
    }

    /// Bernoulli(p) coin flip.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Rademacher ±1 (fair coin), the Bernoulli(½) generator-matrix entry
    /// normalized to zero mean and unit variance.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Fill a slice with standard-normal f32 samples (bulk helper for
    /// data/generator-matrix construction).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Sample `k` distinct indices from [0, n) (client-selection extension).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct of {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: only the first k positions are needed
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample `k` distinct indices from [0, n) in O(k) time and memory
    /// (Floyd's algorithm), returned **sorted ascending**.
    ///
    /// [`Rng::sample_indices`] scans all n positions, which is fine for
    /// the paper's 24-device fleet but not for sampling 256 participants
    /// out of a million-device sim fleet — this variant's cost depends
    /// only on `k`. Exactly `k` draws are consumed, and the sorted output
    /// makes the result independent of hash-set iteration order, so a
    /// given `(rng state, n, k)` always yields the same set.
    pub fn sample_indices_sparse(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct of {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut out: Vec<usize> = chosen.into_iter().collect();
        out.sort_unstable();
        out
    }
}
