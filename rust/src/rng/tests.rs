//! Statistical and determinism tests for the RNG substrate.

use super::Rng;

fn moments(samples: &[f64]) -> (f64, f64) {
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var)
}

#[test]
fn deterministic_for_seed() {
    let mut a = Rng::new(42);
    let mut b = Rng::new(42);
    for _ in 0..1000 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
}

#[test]
fn different_seeds_differ() {
    let mut a = Rng::new(1);
    let mut b = Rng::new(2);
    let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
    assert_eq!(same, 0);
}

#[test]
fn split_streams_are_decorrelated() {
    let root = Rng::new(7);
    let mut s1 = root.split(1);
    let mut s2 = root.split(2);
    let x1: Vec<f64> = (0..4096).map(|_| s1.next_f64()).collect();
    let x2: Vec<f64> = (0..4096).map(|_| s2.next_f64()).collect();
    let (m1, _) = moments(&x1);
    let (m2, _) = moments(&x2);
    let cov: f64 = x1
        .iter()
        .zip(&x2)
        .map(|(a, b)| (a - m1) * (b - m2))
        .sum::<f64>()
        / 4095.0;
    assert!(cov.abs() < 0.01, "cov={cov}");
}

#[test]
fn split_is_pure() {
    let root = Rng::new(9);
    let mut a = root.split(3);
    let mut b = root.split(3);
    assert_eq!(a.next_u64(), b.next_u64());
}

#[test]
fn uniform_f64_in_range_and_mean() {
    let mut r = Rng::new(3);
    let xs: Vec<f64> = (0..20000).map(|_| r.next_f64()).collect();
    assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    let (mean, var) = moments(&xs);
    assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
}

#[test]
fn normal_moments() {
    let mut r = Rng::new(4);
    let xs: Vec<f64> = (0..50000).map(|_| r.normal()).collect();
    let (mean, var) = moments(&xs);
    assert!(mean.abs() < 0.02, "mean={mean}");
    assert!((var - 1.0).abs() < 0.03, "var={var}");
}

#[test]
fn normal_scaled_moments() {
    let mut r = Rng::new(5);
    let xs: Vec<f64> = (0..50000).map(|_| r.normal_scaled(3.0, 2.0)).collect();
    let (mean, var) = moments(&xs);
    assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    assert!((var - 4.0).abs() < 0.15, "var={var}");
}

#[test]
fn exponential_moments() {
    let mut r = Rng::new(6);
    let lambda = 2.5;
    let xs: Vec<f64> = (0..50000).map(|_| r.exponential(lambda)).collect();
    let (mean, var) = moments(&xs);
    assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    assert!((var - 1.0 / (lambda * lambda)).abs() < 0.02, "var={var}");
    assert!(xs.iter().all(|&x| x >= 0.0));
}

#[test]
fn geometric_mean_and_support() {
    let mut r = Rng::new(7);
    let p = 0.1; // paper's link erasure probability
    let xs: Vec<f64> = (0..50000).map(|_| r.geometric(p) as f64).collect();
    assert!(xs.iter().all(|&x| x >= 1.0));
    let (mean, _) = moments(&xs);
    // E[N] = 1/(1−p) for "trials until first success" with failure prob p
    assert!((mean - 1.0 / (1.0 - p)).abs() < 0.01, "mean={mean}");
}

#[test]
fn geometric_zero_erasure_always_one() {
    let mut r = Rng::new(8);
    assert!((0..100).all(|_| r.geometric(0.0) == 1));
}

#[test]
fn geometric_matches_pmf() {
    let mut r = Rng::new(9);
    let p: f64 = 0.3;
    let n = 100000;
    let mut counts = [0usize; 6];
    for _ in 0..n {
        let t = r.geometric(p) as usize;
        if t < counts.len() {
            counts[t] += 1;
        }
    }
    for t in 1..5 {
        let want = p.powi(t as i32 - 1) * (1.0 - p);
        let got = counts[t] as f64 / n as f64;
        assert!((got - want).abs() < 0.01, "t={t} got={got} want={want}");
    }
}

#[test]
fn bernoulli_frequency() {
    let mut r = Rng::new(10);
    let hits = (0..50000).filter(|_| r.bernoulli(0.3)).count() as f64 / 50000.0;
    assert!((hits - 0.3).abs() < 0.01, "hits={hits}");
}

#[test]
fn rademacher_zero_mean_unit_var() {
    let mut r = Rng::new(11);
    let xs: Vec<f64> = (0..50000).map(|_| r.rademacher()).collect();
    assert!(xs.iter().all(|&x| x == 1.0 || x == -1.0));
    let (mean, var) = moments(&xs);
    assert!(mean.abs() < 0.02);
    assert!((var - 1.0).abs() < 0.01);
}

#[test]
fn shuffle_is_permutation() {
    let mut r = Rng::new(12);
    let mut v: Vec<usize> = (0..100).collect();
    r.shuffle(&mut v);
    let mut sorted = v.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
}

#[test]
fn shuffle_uniformity_first_position() {
    // each element should land in position 0 with probability ~1/4
    let mut r = Rng::new(13);
    let mut counts = [0usize; 4];
    for _ in 0..40000 {
        let mut v = [0usize, 1, 2, 3];
        r.shuffle(&mut v);
        counts[v[0]] += 1;
    }
    for &c in &counts {
        let f = c as f64 / 40000.0;
        assert!((f - 0.25).abs() < 0.02, "f={f}");
    }
}

#[test]
fn next_below_unbiased_small_range() {
    let mut r = Rng::new(14);
    let mut counts = [0usize; 3];
    for _ in 0..30000 {
        counts[r.next_below(3) as usize] += 1;
    }
    for &c in &counts {
        assert!((c as f64 / 30000.0 - 1.0 / 3.0).abs() < 0.02);
    }
}

#[test]
fn sample_indices_distinct_and_in_range() {
    let mut r = Rng::new(15);
    for _ in 0..100 {
        let idx = r.sample_indices(24, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10, "indices must be distinct");
        assert!(idx.iter().all(|&i| i < 24));
    }
}

#[test]
fn sample_indices_sparse_distinct_sorted_deterministic() {
    let mut r = Rng::new(17);
    for _ in 0..100 {
        let idx = r.sample_indices_sparse(1_000_000, 64);
        assert_eq!(idx.len(), 64);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
        assert!(idx.iter().all(|&i| i < 1_000_000));
    }
    // same rng state → same set (hash-order independent by construction)
    let mut a = Rng::new(18);
    let mut b = Rng::new(18);
    for _ in 0..50 {
        assert_eq!(a.sample_indices_sparse(500, 20), b.sample_indices_sparse(500, 20));
    }
    // exhaustive sample is the full range
    let mut r = Rng::new(19);
    assert_eq!(r.sample_indices_sparse(12, 12), (0..12).collect::<Vec<_>>());
}

#[test]
fn sample_indices_sparse_uniform_marginals() {
    // each of 8 indices should appear in a size-2 sample w.p. 1/4
    let mut r = Rng::new(20);
    let mut counts = [0usize; 8];
    let trials = 40000;
    for _ in 0..trials {
        for i in r.sample_indices_sparse(8, 2) {
            counts[i] += 1;
        }
    }
    for &c in &counts {
        let f = c as f64 / trials as f64;
        assert!((f - 0.25).abs() < 0.02, "f={f}");
    }
}

#[test]
fn fill_normal_f32_moments() {
    let mut r = Rng::new(16);
    let mut buf = vec![0f32; 40000];
    r.fill_normal_f32(&mut buf);
    let xs: Vec<f64> = buf.iter().map(|&x| x as f64).collect();
    let (mean, var) = moments(&xs);
    assert!(mean.abs() < 0.02 && (var - 1.0).abs() < 0.05);
}

#[test]
fn mix_seed_is_stable_and_label_sensitive() {
    use super::mix_seed;
    // pure function of (root, label)
    assert_eq!(mix_seed(42, 7), mix_seed(42, 7));
    // distinct labels and distinct roots give distinct seeds
    let seeds: Vec<u64> = (0..64).map(|i| mix_seed(0xCF1_2019, i)).collect();
    let mut dedup = seeds.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), seeds.len(), "derived seeds must not collide");
    assert_ne!(mix_seed(1, 0), mix_seed(2, 0));
    // label 0 is not the identity
    assert_ne!(mix_seed(42, 0), 42);
}
