//! # cfl — Coded Federated Learning
//!
//! A reproduction of *Coded Federated Learning* (Dhakal, Prakash, Yona,
//! Talwar, Himayat — IEEE GLOBECOM Workshops 2019) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordination contribution: master/device
//!   topology, the per-device load & coding-redundancy optimizer
//!   (Eqs. 13–16), parity encoding and composite aggregation (Eqs. 9–12),
//!   deadline-gated gradient aggregation (Eqs. 18–19), delay simulation
//!   (§II-A), and the uncoded-FL / least-squares baselines.
//! * **L2 (python/compile/model.py)** — the linear-regression gradient and
//!   parity-encode graphs, lowered once to HLO-text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the gradient and
//!   encode hot-spots, validated against a jnp oracle.
//!
//! The [`runtime`] module loads the artifacts via PJRT (`xla` crate) so the
//! entire training hot path runs in rust; [`linalg`] provides a native
//! oracle/fallback.
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use cfl::config::ExperimentConfig;
//! use cfl::coordinator::SimCoordinator;
//!
//! let cfg = ExperimentConfig::small();
//! let mut sim = SimCoordinator::new(&cfg).unwrap();
//! let coded = sim.train_cfl().unwrap();
//! let uncoded = sim.train_uncoded().unwrap();
//! println!("CFL reached NMSE {:.2e}", coded.trace.final_nmse().unwrap());
//! # let _ = uncoded;
//! ```
//!
//! Grid-scale evaluation goes through the [`sweep`] engine instead of
//! hand-rolled loops: declare axes over config fields, run the cartesian
//! product on a worker pool, and get per-scenario CSV plus coding-gain
//! reports — parallel results are byte-identical to serial. From the
//! CLI: `cfl sweep --config exp.ini` (a `[sweep]` section) or
//! `cfl sweep --axis nu_comp=0,0.1,0.2 --axis nu_link=0,0.1,0.2`.
//!
//! Both training backends — the DES-driven [`coordinator::SimCoordinator`]
//! and the [`coordinator::LiveCoordinator`] — build their setup phase
//! from the shared [`coordinator::Session`] and implement the
//! [`coordinator::Coordinator`] trait, so the sweep runner drives either:
//! `cfl sweep --live` runs the same grid on the live cluster. The live
//! fleet itself speaks a pluggable [`transport`] — in-process channel
//! threads by default, or TCP sockets so devices are real OS processes
//! (`cfl serve` / `cfl device`, or `cfl sweep --live --transport tcp`).
//! The [`conformance`] suite (`cfl conformance`) checks that all of
//! these execution paths still agree — fixture corpus, metamorphic
//! invariants, and a device fault-injection matrix under declared
//! tolerances. See `docs/ARCHITECTURE.md` for the crate map, the wire
//! format, and the paper-equation index.

pub mod analysis;
pub mod cli;
pub mod coding;
pub mod config;
pub mod conformance;
pub mod coordinator;
pub mod data;
pub mod des;
pub mod fl;
pub mod lb;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod simnet;
pub mod stats;
pub mod sweep;
pub mod testing;
pub mod transport;
