//! Per-device code material: generator matrix, weights, puncturing.

use crate::config::GeneratorKind;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::DeviceProfile;

/// A device's private code: the generator matrix, the weight-matrix
/// diagonal, and the systematic/punctured split.
///
/// Everything in here stays on the device in a real deployment; only the
/// encoded parity (`encode_device`) is ever shared.
#[derive(Clone, Debug)]
pub struct DeviceCode {
    /// Generator matrix Gᵢ, c×ℓᵢ (zero-mean, unit-variance entries so that
    /// GᵀG/c → I — the Eq. 18 identity).
    pub generator: Mat,
    /// Weight-matrix diagonal w_ik, length ℓᵢ, in *local row order*.
    pub weights: Vec<f32>,
    /// Private permutation of local rows; the first `systematic_count`
    /// entries are processed locally each epoch, the rest are punctured.
    pub permutation: Vec<usize>,
    /// ℓᵢ*(t*) — systematic load assigned by the optimizer.
    pub systematic_count: usize,
}

impl DeviceCode {
    /// Draw a fresh private code for a device holding `points` rows.
    ///
    /// * `parity_rows` — c, the optimizer's coding redundancy.
    /// * `systematic_count` — ℓᵢ*(t*).
    /// * `prob_miss` — P{Tᵢ ≥ t*} at the assigned load (Eq. 17 weight²).
    pub fn draw(
        points: usize,
        parity_rows: usize,
        systematic_count: usize,
        prob_miss: f64,
        kind: GeneratorKind,
        rng: &mut Rng,
    ) -> Self {
        assert!(systematic_count <= points, "load exceeds local data");
        let generator = match kind {
            GeneratorKind::Gaussian => Mat::randn(parity_rows, points, rng),
            GeneratorKind::Bernoulli => Mat::rademacher(parity_rows, points, rng),
        };
        let mut permutation: Vec<usize> = (0..points).collect();
        rng.shuffle(&mut permutation);
        let mut weights = vec![1.0f32; points]; // punctured default (Eq. 17)
        let w_sys = (prob_miss.clamp(0.0, 1.0)).sqrt() as f32;
        for &row in permutation.iter().take(systematic_count) {
            weights[row] = w_sys;
        }
        Self { generator, weights, permutation, systematic_count }
    }

    /// Prefix variant of [`DeviceCode::draw`] for memory-lean fleets:
    /// identical generator and weights model, but the permutation is the
    /// identity, so the systematic set is the *first*
    /// `systematic_count` local rows.
    ///
    /// With iid rows the private shuffle carries no statistical content —
    /// it only hides which rows are punctured, which the sim does not
    /// model — and a prefix systematic set lets a lean device materialize
    /// exactly its first ℓᵢ rows per epoch (the
    /// [`LeanDataset::shard_view`](crate::data::LeanDataset::shard_view)
    /// prefix) instead of scattered indices from the full shard. Skipping
    /// the shuffle also skips its `points − 1` RNG draws, keeping lean
    /// setup O(c·points) draws per device.
    pub fn draw_prefix(
        points: usize,
        parity_rows: usize,
        systematic_count: usize,
        prob_miss: f64,
        kind: GeneratorKind,
        rng: &mut Rng,
    ) -> Self {
        assert!(systematic_count <= points, "load exceeds local data");
        let generator = match kind {
            GeneratorKind::Gaussian => Mat::randn(parity_rows, points, rng),
            GeneratorKind::Bernoulli => Mat::rademacher(parity_rows, points, rng),
        };
        let permutation: Vec<usize> = (0..points).collect();
        let mut weights = vec![1.0f32; points];
        let w_sys = (prob_miss.clamp(0.0, 1.0)).sqrt() as f32;
        for w in weights.iter_mut().take(systematic_count) {
            *w = w_sys;
        }
        Self { generator, weights, permutation, systematic_count }
    }

    /// Local row indices processed each epoch (systematic set).
    pub fn systematic_rows(&self) -> &[usize] {
        &self.permutation[..self.systematic_count]
    }

    /// Local row indices never processed locally (punctured set).
    pub fn punctured_rows(&self) -> &[usize] {
        &self.permutation[self.systematic_count..]
    }
}

/// Eq. (17) weight for a device: `√P{T ≥ t*}` evaluated at its assigned
/// systematic load.
pub fn make_weights(profile: &DeviceProfile, load: usize, t_star: f64) -> f64 {
    profile.prob_miss(load, t_star).clamp(0.0, 1.0).sqrt()
}
