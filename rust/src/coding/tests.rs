use super::*;
use crate::config::GeneratorKind;
use crate::data::{split, Dataset};
use crate::fl::{GradBackend, NativeBackend};
use crate::linalg::{matmul, Mat};
use crate::rng::Rng;
use crate::simnet::{ComputeModel, DeviceProfile, LinkModel};
use crate::testing::prop::{self, assert_that};

fn test_profile() -> DeviceProfile {
    DeviceProfile {
        compute: ComputeModel { secs_per_point: 0.002, mem_rate: 1000.0 },
        link: LinkModel { secs_per_packet: 0.05, erasure_prob: 0.1 },
        points: 100,
    }
}

#[test]
fn device_code_shapes_and_weight_assignment() {
    let mut rng = Rng::new(1);
    let code = DeviceCode::draw(100, 30, 60, 0.25, GeneratorKind::Gaussian, &mut rng);
    assert_eq!(code.generator.rows(), 30);
    assert_eq!(code.generator.cols(), 100);
    assert_eq!(code.weights.len(), 100);
    assert_eq!(code.systematic_rows().len(), 60);
    assert_eq!(code.punctured_rows().len(), 40);
    // systematic rows carry √0.25 = 0.5; punctured carry 1.0 (Eq. 17)
    for &r in code.systematic_rows() {
        assert!((code.weights[r] - 0.5).abs() < 1e-6);
    }
    for &r in code.punctured_rows() {
        assert_eq!(code.weights[r], 1.0);
    }
}

#[test]
fn permutation_is_private_per_draw() {
    let c1 = DeviceCode::draw(50, 10, 25, 0.5, GeneratorKind::Gaussian, &mut Rng::new(2));
    let c2 = DeviceCode::draw(50, 10, 25, 0.5, GeneratorKind::Gaussian, &mut Rng::new(3));
    assert_ne!(c1.permutation, c2.permutation);
    // and is a permutation
    let mut sorted = c1.permutation.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..50).collect::<Vec<_>>());
}

#[test]
fn bernoulli_generator_is_rademacher() {
    let code = DeviceCode::draw(20, 8, 10, 0.3, GeneratorKind::Bernoulli, &mut Rng::new(4));
    assert!(code.generator.as_slice().iter().all(|&v| v == 1.0 || v == -1.0));
}

#[test]
fn make_weights_matches_profile_miss_prob() {
    let p = test_profile();
    let t = p.mean_total_delay(80);
    let w = make_weights(&p, 80, t);
    assert!((w * w - p.prob_miss(80, t)).abs() < 1e-12);
}

#[test]
fn composite_parity_accumulates_device_sums() {
    // Eq. 10/11: Σᵢ GᵢWᵢXⁱ must equal the block-matrix product G·W·X
    let mut rng = Rng::new(5);
    let ds = Dataset::generate(60, 8, 10.0, &mut rng);
    let shards = split(&ds, &[20, 25, 15]);
    let mut backend = NativeBackend;
    let c = 12;

    let mut composite = CompositeParity::zeros(c, 8);
    let mut codes = Vec::new();
    for sh in &shards {
        let code = DeviceCode::draw(sh.rows(), c, sh.rows() / 2, 0.4, GeneratorKind::Gaussian, &mut rng);
        let (xt, yt) = encode_device(sh, &code, &mut backend).unwrap();
        composite.accumulate(&xt, &yt);
        codes.push(code);
    }

    // block-matrix reference: G = [G₁ G₂ G₃], W block-diagonal
    let mut xw_all = Mat::zeros(60, 8);
    let mut yw_all = Mat::zeros(60, 1);
    let mut g_all = Mat::zeros(c, 60);
    let mut off = 0;
    for (sh, code) in shards.iter().zip(&codes) {
        for r in 0..sh.rows() {
            let w = code.weights[r];
            for col in 0..8 {
                xw_all[(off + r, col)] = sh.x[(r, col)] * w;
            }
            yw_all[(off + r, 0)] = sh.y[(r, 0)] * w;
            for cr in 0..c {
                g_all[(cr, off + r)] = code.generator[(cr, r)];
            }
        }
        off += sh.rows();
    }
    let want_xt = matmul(&g_all, &xw_all);
    let want_yt = matmul(&g_all, &yw_all);
    assert!(composite.xt.max_abs_diff(&want_xt) < 1e-3);
    assert!(composite.yt.max_abs_diff(&want_yt) < 1e-3);
}

#[test]
fn parity_gradient_lln_identity() {
    // Eq. 18: (1/c)·X̃ᵀ(X̃β − ỹ) → XᵀW²(Xβ − y) as c grows.
    let mut rng = Rng::new(6);
    let ds = Dataset::generate(40, 10, 20.0, &mut rng);
    let shards = split(&ds, &[40]);
    let beta = Mat::randn(10, 1, &mut rng);
    let mut backend = NativeBackend;

    let target = {
        // XᵀW²(Xβ − y) with the code's weights
        let code = DeviceCode::draw(40, 4096, 20, 0.36, GeneratorKind::Gaussian, &mut Rng::new(7));
        let mut xw = shards[0].x.clone();
        let w2: Vec<f32> = code.weights.iter().map(|w| w * w).collect();
        let mut resid = matmul(&shards[0].x, &beta);
        resid.axpy(-1.0, &shards[0].y);
        resid.scale_rows(&w2);
        let t = crate::linalg::matmul_at_b(&xw, &resid);
        xw.scale(1.0); // silence unused-mut lint path
        (code, t)
    };
    let (code, want) = target;

    let mut errs = Vec::new();
    for &c in &[64usize, 1024, 4096] {
        let sub = Mat::from_vec(
            c,
            40,
            code.generator.as_slice()[..c * 40].to_vec(),
        );
        let subcode = DeviceCode {
            generator: sub,
            weights: code.weights.clone(),
            permutation: code.permutation.clone(),
            systematic_count: code.systematic_count,
        };
        let (xt, yt) = encode_device(&shards[0], &subcode, &mut backend).unwrap();
        let got = backend.parity_grad(&xt, &beta, &yt, c).unwrap();
        errs.push((got.dist_sq(&want) / want.norm_sq()).sqrt());
    }
    assert!(errs[2] < errs[0], "error must shrink with c: {errs:?}");
    assert!(errs[2] < 0.25, "c=4096 relative error too large: {}", errs[2]);
}

#[test]
fn prop_encode_is_linear_in_generator() {
    prop::check("encode linearity", prop::cfg_cases(20), |g| {
        let l = g.size_in(4, 30);
        let d = g.size_in(1, 10);
        let c = g.size_in(1, 12);
        let mut rng = g.rng();
        let ds = Dataset::generate(l, d, 10.0, &mut rng);
        let shards = split(&ds, &[l]);
        let mut backend = NativeBackend;
        let mk = |rng: &mut Rng| DeviceCode::draw(l, c, l / 2, 0.5, GeneratorKind::Gaussian, rng);
        let mut c1 = mk(&mut rng);
        let c2 = {
            let mut c2 = mk(&mut rng);
            // same weights/permutation so only G differs
            c2.weights = c1.weights.clone();
            c2.permutation = c1.permutation.clone();
            c2
        };
        let (x1, y1) = encode_device(&shards[0], &c1, &mut backend).unwrap();
        let (x2, y2) = encode_device(&shards[0], &c2, &mut backend).unwrap();
        let mut csum = c1.clone();
        csum.generator.add_assign(&c2.generator);
        let (xs, ys) = encode_device(&shards[0], &csum, &mut backend).unwrap();
        let mut x12 = x1.clone();
        x12.add_assign(&x2);
        let mut y12 = y1.clone();
        y12.add_assign(&y2);
        c1.weights = vec![]; // moved-from marker, silence clippy-by-use
        assert_that(xs.max_abs_diff(&x12) < 1e-3, "X̃ additivity")?;
        assert_that(ys.max_abs_diff(&y12) < 1e-3, "ỹ additivity")
    });
}

#[test]
fn parity_reveals_no_raw_row_trivially() {
    // sanity privacy check: with c < ℓ the parity rows are random mixtures;
    // no parity row should be (anywhere near) proportional to a raw row.
    let mut rng = Rng::new(8);
    let ds = Dataset::generate(50, 12, 20.0, &mut rng);
    let shards = split(&ds, &[50]);
    let code = DeviceCode::draw(50, 10, 25, 0.5, GeneratorKind::Gaussian, &mut rng);
    let (xt, _) = encode_device(&shards[0], &code, &mut NativeBackend).unwrap();
    for pr in 0..xt.rows() {
        for rr in 0..50 {
            let p = xt.row(pr);
            let r = shards[0].x.row(rr);
            let dot: f32 = p.iter().zip(r).map(|(a, b)| a * b).sum();
            let np: f32 = p.iter().map(|v| v * v).sum::<f32>().sqrt();
            let nr: f32 = r.iter().map(|v| v * v).sum::<f32>().sqrt();
            let cos = (dot / (np * nr)).abs();
            assert!(cos < 0.95, "parity row {pr} ≈ raw row {rr} (cos={cos})");
        }
    }
}
