//! Distributed random linear coding (§III of the paper).
//!
//! Each device privately draws a generator matrix `Gᵢ (c×ℓᵢ)` and a weight
//! matrix `Wᵢ = diag(w_ik)` and uploads the parity data
//! `(X̃ⁱ, ỹⁱ) = (GᵢWᵢXⁱ, GᵢWᵢyⁱ)` once (Eq. 9). The master sums parity
//! across devices into the composite set (Eq. 10) — linearity makes the
//! sum equal to encoding the concatenated global dataset with the
//! block-row generator `G = [G₁ … G_n]` (Eq. 11), while `Gᵢ`, `Wᵢ`, and
//! the raw data never leave the device.
//!
//! Weights (Eq. 17): systematic points carry `w_ik = √P{Tᵢ ≥ t*}` so the
//! parity gradient supplies exactly the *expected missing fraction* of each
//! point's gradient; punctured points (never processed locally) carry
//! `w_ik = 1` so parity supplies them entirely. Puncturing position is a
//! private per-device permutation — a second privacy layer (§III-C).

mod code;
mod parity;

pub use code::{make_weights, DeviceCode};
pub use parity::{encode_device, CompositeParity};

#[cfg(test)]
mod tests;
