//! Parity generation and composite aggregation (Eqs. 9–12).

use super::DeviceCode;
use crate::data::Shard;
use crate::fl::GradBackend;
use crate::linalg::Mat;
use anyhow::Result;

/// The master's composite parity set (X̃, ỹ) — the sum over devices of
/// their parity uploads (Eq. 10).
#[derive(Clone, Debug)]
pub struct CompositeParity {
    pub xt: Mat,
    pub yt: Mat,
}

impl CompositeParity {
    /// Empty accumulator for `parity_rows` rows and model dim `d`.
    pub fn zeros(parity_rows: usize, d: usize) -> Self {
        Self { xt: Mat::zeros(parity_rows, d), yt: Mat::zeros(parity_rows, 1) }
    }

    /// Fold in one device's parity upload (the master's Eq. 10 sum).
    pub fn accumulate(&mut self, xt_i: &Mat, yt_i: &Mat) {
        self.xt.add_assign(xt_i);
        self.yt.add_assign(yt_i);
    }

    pub fn rows(&self) -> usize {
        self.xt.rows()
    }
}

/// Device-side encode (Eq. 9): (X̃ⁱ, ỹⁱ) = (GᵢWᵢXⁱ, GᵢWᵢyⁱ).
///
/// Runs through the backend so the PJRT `encode_dev` artifact (the L1
/// Pallas kernel) does the math when artifacts are loaded, with the
/// native fused path as oracle/fallback.
pub fn encode_device(
    shard: &Shard,
    code: &DeviceCode,
    backend: &mut dyn GradBackend,
) -> Result<(Mat, Mat)> {
    backend.encode(&code.generator, &code.weights, &shard.x, &shard.y)
}
