use super::*;
use crate::testing::prop::{self, assert_that};

#[test]
fn events_pop_in_time_order() {
    let mut sim = Simulator::new();
    sim.schedule_at(3.0, "c");
    sim.schedule_at(1.0, "a");
    sim.schedule_at(2.0, "b");
    let order: Vec<&str> = std::iter::from_fn(|| sim.next_event().map(|e| e.payload)).collect();
    assert_eq!(order, vec!["a", "b", "c"]);
    assert_eq!(sim.now(), 3.0);
    assert_eq!(sim.processed(), 3);
}

#[test]
fn ties_break_fifo() {
    let mut sim = Simulator::new();
    for i in 0..10 {
        sim.schedule_at(1.0, i);
    }
    let order: Vec<i32> = std::iter::from_fn(|| sim.next_event().map(|e| e.payload)).collect();
    assert_eq!(order, (0..10).collect::<Vec<_>>());
}

#[test]
fn run_until_partitions_at_deadline() {
    let mut sim = Simulator::new();
    for i in 1..=10 {
        sim.schedule_at(i as f64, i);
    }
    let early = sim.run_until(4.5);
    assert_eq!(early.iter().map(|e| e.payload).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
    assert_eq!(sim.now(), 4.5);
    assert_eq!(sim.pending(), 6);
    // deadline-boundary event is included (≤, matching P{T ≤ t*})
    sim.schedule_at(5.0, 99);
    let mid = sim.run_until(5.0);
    assert_eq!(mid.iter().map(|e| e.payload).collect::<Vec<_>>(), vec![5, 99]);
}

#[test]
fn run_to_completion_drains_everything() {
    let mut sim = Simulator::new();
    sim.schedule_at(2.0, "x");
    sim.schedule_at(1.0, "y");
    let all = sim.run_to_completion();
    assert_eq!(all.len(), 2);
    assert_eq!(sim.pending(), 0);
    assert_eq!(sim.now(), 2.0);
}

#[test]
fn schedule_in_is_relative() {
    let mut sim = Simulator::new();
    sim.schedule_at(5.0, "first");
    sim.next_event();
    sim.schedule_in(2.5, "second");
    let e = sim.next_event().unwrap();
    assert_eq!(e.time, 7.5);
}

#[test]
#[should_panic(expected = "past")]
fn scheduling_into_past_panics() {
    let mut sim = Simulator::new();
    sim.schedule_at(5.0, ());
    sim.next_event();
    sim.schedule_at(4.0, ());
}

#[test]
#[should_panic(expected = "finite")]
fn scheduling_nan_panics() {
    let mut sim: Simulator<()> = Simulator::new();
    sim.schedule_at(f64::NAN, ());
}

/// Regression for the old `partial_cmp(..).unwrap_or(Equal)` heap
/// order: with NaN collapsing to `Equal`, comparisons were not
/// transitive and a heap could silently misorder events. The queue's
/// ordering must be total over *every* f64, NaN included, even though
/// `schedule_at` rejects non-finite times at the API boundary.
#[test]
fn event_order_is_total_over_nan_times() {
    use super::sim::event_order;
    use std::cmp::Ordering;

    let keys = [
        (f64::NEG_INFINITY, 0u64),
        (-0.0, 1),
        (0.0, 2),
        (1.5, 3),
        (f64::INFINITY, 4),
        (f64::NAN, 5),
        (f64::NAN, 6),
        (-f64::NAN, 7),
    ];
    // totality: every pair is ordered, antisymmetrically
    for &a in &keys {
        for &b in &keys {
            let ab = event_order(a, b);
            let ba = event_order(b, a);
            assert_eq!(ab.reverse(), ba, "antisymmetry broke on {a:?} vs {b:?}");
            if a.1 == b.1 {
                assert_eq!(ab, Ordering::Equal);
            } else {
                assert_ne!(ab, Ordering::Equal, "{a:?} vs {b:?} must not tie");
            }
        }
    }
    // transitivity, exhaustively over the triple space
    for &a in &keys {
        for &b in &keys {
            for &c in &keys {
                if event_order(a, b).is_le() && event_order(b, c).is_le() {
                    assert!(
                        event_order(a, c).is_le(),
                        "transitivity broke on {a:?} ≤ {b:?} ≤ {c:?}"
                    );
                }
            }
        }
    }
    // NaN times sort deterministically: a sort under this order is
    // stable-by-key and never panics
    let mut v = keys.to_vec();
    v.sort_by(|a, b| event_order(*a, *b));
    let seqs: Vec<u64> = v.iter().map(|k| k.1).collect();
    // IEEE 754 totalOrder: -NaN < -inf < … < +inf < +NaN; seq breaks the NaN tie
    assert_eq!(seqs, vec![7, 0, 1, 2, 3, 4, 5, 6]);
}

#[test]
fn clear_and_reset() {
    let mut sim = Simulator::new();
    sim.schedule_at(1.0, ());
    sim.schedule_at(2.0, ());
    sim.next_event();
    sim.clear();
    assert_eq!(sim.pending(), 0);
    assert_eq!(sim.now(), 1.0); // clear keeps the clock
    sim.reset();
    assert_eq!(sim.now(), 0.0);
    assert_eq!(sim.processed(), 0);
}

#[test]
fn prop_pop_order_is_sorted_and_clock_monotone() {
    prop::check("des ordering", prop::cfg_cases(50), |g| {
        let mut sim = Simulator::new();
        let n = g.size_in(1, 60);
        for i in 0..n {
            sim.schedule_at(g.f64_in(0.0, 100.0), i);
        }
        let mut last = f64::NEG_INFINITY;
        let mut count = 0;
        while let Some(e) = sim.next_event() {
            assert_that(e.time >= last, format!("time went backwards: {} < {last}", e.time))?;
            assert_that(sim.now() == e.time, "clock must track event time")?;
            last = e.time;
            count += 1;
        }
        assert_that(count == n, format!("popped {count} of {n}"))
    });
}

#[test]
fn prop_run_until_equals_filtered_pop() {
    prop::check("run_until equivalence", prop::cfg_cases(40), |g| {
        let n = g.size_in(1, 40);
        let deadline = g.f64_in(0.0, 50.0);
        let times: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 100.0)).collect();

        let mut sim_a = Simulator::new();
        let mut sim_b = Simulator::new();
        for (i, &t) in times.iter().enumerate() {
            sim_a.schedule_at(t, i);
            sim_b.schedule_at(t, i);
        }
        let drained: Vec<usize> = sim_a.run_until(deadline).into_iter().map(|e| e.payload).collect();
        let mut expected: Vec<(f64, usize)> =
            times.iter().copied().enumerate().filter(|&(_, t)| t <= deadline).map(|(i, t)| (t, i)).collect();
        expected.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let expected: Vec<usize> = expected.into_iter().map(|(_, i)| i).collect();
        assert_that(drained == expected, format!("{drained:?} != {expected:?}"))?;
        assert_that(sim_a.now() == deadline, "clock must land on deadline")?;
        let _ = sim_b;
        Ok(())
    });
}
