//! Event queue + virtual clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event drawn from the queue.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduledEvent<T> {
    pub time: f64,
    /// Monotone sequence number: schedule order, used as tie-break.
    pub seq: u64,
    pub payload: T,
}

/// Total order on `(time, seq)` event keys: `total_cmp` on the time
/// (IEEE 754 totalOrder — NaN sorts deterministically instead of
/// collapsing to `Equal` and corrupting heap invariants), then FIFO on
/// the sequence number. [`Simulator::schedule_at`] rejects non-finite
/// times at the door, but the heap's ordering must be total on its own
/// — a partial fallback here would turn any future hole in that guard
/// into silent event reordering rather than a loud test failure.
pub(crate) fn event_order(a: (f64, u64), b: (f64, u64)) -> Ordering {
    a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1))
}

// BinaryHeap is a max-heap; invert ordering for earliest-first.
struct HeapEntry<T>(ScheduledEvent<T>);

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.0.time.total_cmp(&other.0.time).is_eq() && self.0.seq == other.0.seq
    }
}
impl<T> Eq for HeapEntry<T> {}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller (time, seq) = "greater" for the max-heap
        event_order((other.0.time, other.0.seq), (self.0.time, self.0.seq))
    }
}

/// Deterministic discrete-event simulator with a virtual clock.
pub struct Simulator<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    now: f64,
    next_seq: u64,
    processed: u64,
}

impl<T> Default for Simulator<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Simulator<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, next_seq: 0, processed: 0 }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (must not precede the
    /// current clock — the past is immutable).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        assert!(at.is_finite(), "event time must be finite");
        assert!(
            at >= self.now,
            "cannot schedule into the past: at={at} < now={}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry(ScheduledEvent { time: at, seq, payload }));
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next_event(&mut self) -> Option<ScheduledEvent<T>> {
        let e = self.heap.pop()?.0;
        self.now = e.time;
        self.processed += 1;
        Some(e)
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }

    /// Drain all events with `time ≤ deadline`, advancing the clock to each
    /// in turn, then set the clock to `deadline`. Returns the drained
    /// events in timestamp order. This is the master's deadline gather:
    /// everything arriving by t* is collected, stragglers stay queued.
    pub fn run_until(&mut self, deadline: f64) -> Vec<ScheduledEvent<T>> {
        assert!(deadline >= self.now, "deadline in the past");
        let mut out = Vec::new();
        while let Some(t) = self.peek_time() {
            if t > deadline {
                break;
            }
            out.push(self.next_event().expect("peeked event must pop"));
        }
        self.now = deadline;
        out
    }

    /// Drain the whole queue (the uncoded master's "wait for everyone").
    pub fn run_to_completion(&mut self) -> Vec<ScheduledEvent<T>> {
        let mut out = Vec::new();
        while let Some(e) = self.next_event() {
            out.push(e);
        }
        out
    }

    /// Unordered snapshot of pending `(time, payload)` pairs (diagnostics;
    /// does not disturb the queue).
    pub fn snapshot(&self) -> Vec<(f64, T)>
    where
        T: Clone,
    {
        self.heap.iter().map(|e| (e.0.time, e.0.payload.clone())).collect()
    }

    /// Drop every pending event (epoch reset) without touching the clock.
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Reset clock and queue (new simulation run).
    pub fn reset(&mut self) {
        self.heap.clear();
        self.now = 0.0;
        self.next_seq = 0;
        self.processed = 0;
    }
}
