//! Discrete-event simulation engine.
//!
//! The paper's time axis is *simulated* wireless-edge time, not host
//! wall-clock: per-epoch device delays are drawn from §II-A's models and
//! the training clock advances by deadline/straggler arithmetic. This
//! engine gives that arithmetic an explicit, deterministic event queue:
//!
//! * events are `(time, seq, payload)` ordered by time with FIFO
//!   tie-breaking on `seq`, so identical seeds give identical traces;
//! * the queue is a binary heap — O(log n) schedule/pop;
//! * [`Simulator::run_until`] drains events up to a deadline, which is
//!   exactly the master's "wait until t*" gather (Eq. 16's epoch window).
//!
//! The engine is generic over the payload so the unit tests, the epoch
//! simulator ([`crate::coordinator`]) and ad-hoc experiment harnesses can
//! each define their own event vocabulary.

mod sim;

pub use sim::{ScheduledEvent, Simulator};

#[cfg(test)]
mod tests;
