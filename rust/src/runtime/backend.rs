//! The PJRT-backed [`GradBackend`] implementation.

use super::{ArtifactSpec, Manifest};
use crate::fl::GradBackend;
use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// Executes the AOT artifacts through the PJRT CPU client.
///
/// Executables are compiled once per artifact and cached; operands are
/// zero-padded to the artifact's shape (exact — see module docs) and
/// results cropped back to logical shapes.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// name → compiled executable (compiled lazily on first use so that
    /// loading a manifest with many artifacts stays cheap).
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Registered static shards: device-resident (X, y, mask) buffers so
    /// the per-epoch gradient only uploads β (§Perf: saves the ~1 MiB
    /// pad+copy+transfer per device per epoch).
    registered: Vec<RegisteredShard>,
    /// Cumulative PJRT executions (perf accounting).
    pub executions: u64,
}

struct RegisteredShard {
    spec_name: String,
    x: xla::PjRtBuffer,
    y: xla::PjRtBuffer,
    /// Row mask (grad artifacts) or the 1/c scalar (pgrad artifacts).
    aux: xla::PjRtBuffer,
    /// pgrad (true) vs grad (false) — operand orders happen to coincide
    /// ((X, β, y, aux)); kept for introspection/debugging.
    #[allow(dead_code)]
    is_parity: bool,
    /// (padded D, logical D) for β padding and output cropping.
    dp: usize,
    d: usize,
}

impl PjrtBackend {
    /// Load a manifest directory and initialize the PJRT CPU client.
    pub fn load(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        anyhow::ensure!(!manifest.artifacts.is_empty(), "manifest at {dir} lists no artifacts");
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: HashMap::new(), registered: Vec::new(), executions: 0 })
    }

    /// The parsed manifest (introspection/tests).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn ensure_compiled(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if !self.cache.contains_key(&spec.name) {
            let path = spec
                .path
                .to_str()
                .with_context(|| format!("non-utf8 artifact path {:?}", spec.path))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{}'", spec.name))?;
            self.cache.insert(spec.name.clone(), exe);
        }
        Ok(())
    }

    /// Pad `m` to (rows, cols) and convert to a PJRT literal.
    fn literal(m: &Mat, rows: usize, cols: usize) -> Result<xla::Literal> {
        let padded;
        let src = if m.rows() == rows && m.cols() == cols {
            m
        } else {
            padded = m.pad_to(rows, cols);
            &padded
        };
        Ok(xla::Literal::vec1(src.as_slice()).reshape(&[rows as i64, cols as i64])?)
    }

    fn run(
        &mut self,
        spec_name: &str,
        spec: &ArtifactSpec,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        self.ensure_compiled(spec)?;
        self.executions += 1;
        let exe = &self.cache[&spec.name];
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{spec_name}'"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of '{spec_name}'"))?;
        Ok(lit)
    }

    fn crop(lit_vec: Vec<f32>, padded_rows: usize, cols: usize, rows: usize) -> Mat {
        let full = Mat::from_vec(padded_rows, cols, lit_vec);
        if padded_rows == rows {
            full
        } else {
            full.crop_to(rows, cols)
        }
    }
}

impl PjrtBackend {
    /// Largest row capacity among artifacts of the given selector.
    fn max_rows(&self, kind: super::ArtifactKind, d: usize) -> Option<usize> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dims[1] >= d)
            .map(|a| a.dims[0])
            .max()
    }

    /// Sum a row-chunked gradient: the partial gradient is additive over
    /// row blocks, so inputs taller than every artifact are split into
    /// artifact-sized chunks and accumulated (exact — no approximation).
    fn chunked<F>(&mut self, rows: usize, chunk: usize, d: usize, mut one: F) -> Result<Mat>
    where
        F: FnMut(&mut Self, usize, usize) -> Result<Mat>,
    {
        let mut acc = Mat::zeros(d, 1);
        let mut start = 0;
        while start < rows {
            let end = (start + chunk).min(rows);
            let g = one(self, start, end)?;
            acc.add_assign(&g);
            start = end;
        }
        Ok(acc)
    }
}

impl GradBackend for PjrtBackend {
    fn partial_grad(&mut self, x: &Mat, beta: &Mat, y: &Mat) -> Result<Mat> {
        let (l, d) = (x.rows(), x.cols());
        let spec = match self.manifest.best_grad(l, d) {
            Some(s) => s.clone(),
            None => {
                // taller than every artifact: chunk over rows
                let cap = self
                    .max_rows(super::ArtifactKind::Grad, d)
                    .with_context(|| format!("no grad artifact fits D={d}"))?;
                return self.chunked(l, cap, d, |me, s, e| {
                    me.partial_grad(&x.slice_rows(s, e), beta, &y.slice_rows(s, e))
                });
            }
        };
        let (lp, dp) = (spec.dims[0], spec.dims[1]);
        // mask: 1 for live rows, 0 for padding (padding rows are zero
        // anyway; the mask operand exists for puncturing flexibility)
        let mut mask = Mat::zeros(lp, 1);
        for r in 0..l {
            mask[(r, 0)] = 1.0;
        }
        let inputs = [
            Self::literal(x, lp, dp)?,
            Self::literal(beta, dp, 1)?,
            Self::literal(y, lp, 1)?,
            xla::Literal::vec1(mask.as_slice()).reshape(&[lp as i64, 1])?,
        ];
        let out = self.run("grad", &spec, &inputs)?.to_tuple1()?;
        Ok(Self::crop(out.to_vec::<f32>()?, dp, 1, d))
    }

    fn parity_grad(&mut self, xt: &Mat, beta: &Mat, yt: &Mat, c: usize) -> Result<Mat> {
        anyhow::ensure!(c > 0, "parity count must be positive");
        let (rows, d) = (xt.rows(), xt.cols());
        let spec = match self.manifest.best_parity_grad(rows, d) {
            Some(s) => s.clone(),
            None => {
                // each chunk is normalized by the same 1/c, so the chunk sum
                // equals the full normalized parity gradient
                let cap = self
                    .max_rows(super::ArtifactKind::ParityGrad, d)
                    .with_context(|| format!("no pgrad artifact fits D={d}"))?;
                return self.chunked(rows, cap, d, |me, s, e| {
                    me.parity_grad(&xt.slice_rows(s, e), beta, &yt.slice_rows(s, e), c)
                });
            }
        };
        let (cp, dp) = (spec.dims[0], spec.dims[1]);
        let inv_c = Mat::from_vec(1, 1, vec![1.0 / c as f32]);
        let inputs = [
            Self::literal(xt, cp, dp)?,
            Self::literal(beta, dp, 1)?,
            Self::literal(yt, cp, 1)?,
            Self::literal(&inv_c, 1, 1)?,
        ];
        let out = self.run("pgrad", &spec, &inputs)?.to_tuple1()?;
        Ok(Self::crop(out.to_vec::<f32>()?, dp, 1, d))
    }

    fn encode(&mut self, g: &Mat, w: &[f32], x: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        anyhow::ensure!(g.cols() == x.rows(), "G cols must match X rows");
        anyhow::ensure!(w.len() == x.rows(), "weight diagonal length");
        let (c, l, d) = (g.rows(), x.rows(), x.cols());
        let spec = match self.manifest.best_encode(c, l, d) {
            Some(s) => s.clone(),
            None => {
                // more parity rows than any artifact: each parity row only
                // depends on its own G row, so chunk over C and stack
                let cap = self
                    .manifest
                    .artifacts
                    .iter()
                    .filter(|a| {
                        a.kind == super::ArtifactKind::Encode && a.dims[1] >= l && a.dims[2] >= d
                    })
                    .map(|a| a.dims[0])
                    .max()
                    .with_context(|| format!("no encode artifact fits L={l}, D={d}"))?;
                anyhow::ensure!(cap > 0 && cap < c, "encode chunking logic");
                let mut xt = Mat::zeros(c, d);
                let mut yt = Mat::zeros(c, 1);
                let mut start = 0;
                while start < c {
                    let end = (start + cap).min(c);
                    let (xc, yc) = self.encode(&g.slice_rows(start, end), w, x, y)?;
                    for r in start..end {
                        xt.row_mut(r).copy_from_slice(xc.row(r - start));
                        yt[(r, 0)] = yc[(r - start, 0)];
                    }
                    start = end;
                }
                return Ok((xt, yt));
            }
        };
        let (cp, lp, dp) = (spec.dims[0], spec.dims[1], spec.dims[2]);
        let wm = Mat::from_vec(l, 1, w.to_vec());
        let inputs = [
            Self::literal(g, cp, lp)?,
            Self::literal(&wm, lp, 1)?,
            Self::literal(x, lp, dp)?,
            Self::literal(y, lp, 1)?,
        ];
        let (xt_l, yt_l) = self.run("encode", &spec, &inputs)?.to_tuple2()?;
        let xt = Self::crop(xt_l.to_vec::<f32>()?, cp, dp, c).crop_to(c, d);
        let yt = Self::crop(yt_l.to_vec::<f32>()?, cp, 1, c);
        Ok((xt, yt))
    }

    fn register_shard(&mut self, x: &Mat, y: &Mat) -> Result<Option<u64>> {
        let (l, d) = (x.rows(), x.cols());
        let spec = match self.manifest.best_grad(l, d) {
            Some(s) => s.clone(),
            None => return Ok(None), // taller than every artifact: slow path
        };
        self.ensure_compiled(&spec)?;
        let (lp, dp) = (spec.dims[0], spec.dims[1]);
        let xp = x.pad_to(lp, dp);
        let yp = y.pad_to(lp, 1);
        let mut mask = Mat::zeros(lp, 1);
        for r in 0..l {
            mask[(r, 0)] = 1.0;
        }
        let xb = self.client.buffer_from_host_buffer(xp.as_slice(), &[lp, dp], None)?;
        let yb = self.client.buffer_from_host_buffer(yp.as_slice(), &[lp, 1], None)?;
        let mb = self.client.buffer_from_host_buffer(mask.as_slice(), &[lp, 1], None)?;
        self.registered.push(RegisteredShard {
            spec_name: spec.name.clone(),
            x: xb,
            y: yb,
            aux: mb,
            is_parity: false,
            dp,
            d,
        });
        Ok(Some(self.registered.len() as u64 - 1))
    }

    fn partial_grad_registered(&mut self, handle: u64, beta: &Mat) -> Result<Mat> {
        self.run_registered(handle, beta)
    }

    fn register_parity(&mut self, xt: &Mat, yt: &Mat, c: usize) -> Result<Option<u64>> {
        anyhow::ensure!(c > 0, "parity count must be positive");
        let (rows, d) = (xt.rows(), xt.cols());
        let spec = match self.manifest.best_parity_grad(rows, d) {
            Some(s) => s.clone(),
            None => return Ok(None),
        };
        self.ensure_compiled(&spec)?;
        let (cp, dp) = (spec.dims[0], spec.dims[1]);
        let xp = xt.pad_to(cp, dp);
        let yp = yt.pad_to(cp, 1);
        let inv_c = [1.0f32 / c as f32];
        let xb = self.client.buffer_from_host_buffer(xp.as_slice(), &[cp, dp], None)?;
        let yb = self.client.buffer_from_host_buffer(yp.as_slice(), &[cp, 1], None)?;
        let cb = self.client.buffer_from_host_buffer(&inv_c[..], &[1, 1], None)?;
        self.registered.push(RegisteredShard {
            spec_name: spec.name.clone(),
            x: xb,
            y: yb,
            aux: cb,
            is_parity: true,
            dp,
            d,
        });
        Ok(Some(self.registered.len() as u64 - 1))
    }

    fn parity_grad_registered(&mut self, handle: u64, beta: &Mat) -> Result<Mat> {
        self.run_registered(handle, beta)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

impl PjrtBackend {
    fn run_registered(&mut self, handle: u64, beta: &Mat) -> Result<Mat> {
        let idx = handle as usize;
        anyhow::ensure!(idx < self.registered.len(), "unknown shard handle {handle}");
        let (dp, d, spec_name) = {
            let sh = &self.registered[idx];
            (sh.dp, sh.d, sh.spec_name.clone())
        };
        let bp = if beta.rows() == dp { beta.clone() } else { beta.pad_to(dp, 1) };
        let bb = self.client.buffer_from_host_buffer(bp.as_slice(), &[dp, 1], None)?;
        self.executions += 1;
        let sh = &self.registered[idx];
        let exe = self.cache.get(&spec_name).context("registered executable evicted")?;
        // operand order mirrors model.py: grad = (X, β, y, mask);
        // pgrad = (X̃, β, ỹ, 1/c)
        let outs = exe
            .execute_b(&[&sh.x, &bb, &sh.y, &sh.aux])
            .context("executing registered computation")?;
        let lit = outs[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(Self::crop(lit.to_vec::<f32>()?, dp, 1, d))
    }
}
