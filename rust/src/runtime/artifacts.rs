//! Artifact manifest parsing.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// What computation an artifact implements (mirrors `aot.py`'s registry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Device partial gradient: (X, β, y, mask) → g. Dims: [L, D].
    Grad,
    /// Master parity gradient: (X̃, β, ỹ, 1/c) → g. Dims: [C, D].
    ParityGrad,
    /// Parity encode: (G, w, X, y) → (X̃, ỹ). Dims: [C, L, D].
    Encode,
    /// Model update: (β, g, μ/m) → β′. Dims: `[D]`.
    GdStep,
    /// NMSE: (β̂, β*) → scalar. Dims: `[D]`.
    Nmse,
}

impl std::str::FromStr for ArtifactKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "grad" => Self::Grad,
            "pgrad" => Self::ParityGrad,
            "encode" => Self::Encode,
            "gd_step" => Self::GdStep,
            "nmse" => Self::Nmse,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub path: PathBuf,
    /// Padded dims, kind-specific (see [`ArtifactKind`]).
    pub dims: Vec<usize>,
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`. Format: `name kind file dims...` lines,
    /// `#` comments.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let mut artifacts = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() < 4 {
                bail!("manifest line {}: expected 'name kind file dims...'", lineno + 1);
            }
            let kind: ArtifactKind = fields[1].parse()?;
            let dims: Vec<usize> = fields[3..]
                .iter()
                .map(|s| s.parse().with_context(|| format!("line {}: bad dim", lineno + 1)))
                .collect::<Result<_>>()?;
            let expect = match kind {
                ArtifactKind::Grad | ArtifactKind::ParityGrad => 2,
                ArtifactKind::Encode => 3,
                ArtifactKind::GdStep | ArtifactKind::Nmse => 1,
            };
            if dims.len() != expect {
                bail!("manifest line {}: kind {:?} needs {expect} dims, got {}", lineno + 1, kind, dims.len());
            }
            artifacts.push(ArtifactSpec {
                name: fields[0].to_string(),
                kind,
                path: dir.join(fields[2]),
                dims,
            });
        }
        Ok(Self { artifacts })
    }

    /// Smallest `Grad` artifact with L ≥ rows and D ≥ dim (best-fit keeps
    /// padding waste low across the small/large artifact pair).
    pub fn best_grad(&self, rows: usize, dim: usize) -> Option<&ArtifactSpec> {
        self.best_fit(ArtifactKind::Grad, &[rows, dim])
    }

    /// Smallest `ParityGrad` artifact with C ≥ rows and D ≥ dim.
    pub fn best_parity_grad(&self, rows: usize, dim: usize) -> Option<&ArtifactSpec> {
        self.best_fit(ArtifactKind::ParityGrad, &[rows, dim])
    }

    /// Smallest `Encode` artifact covering (c, l, d).
    pub fn best_encode(&self, c: usize, l: usize, d: usize) -> Option<&ArtifactSpec> {
        self.best_fit(ArtifactKind::Encode, &[c, l, d])
    }

    fn best_fit(&self, kind: ArtifactKind, need: &[usize]) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dims.iter().zip(need).all(|(&have, &n)| have >= n))
            .min_by_key(|a| a.dims.iter().product::<usize>())
    }
}
