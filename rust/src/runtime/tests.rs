//! Runtime unit tests: manifest parsing and best-fit selection.
//!
//! Numeric parity of the PJRT backend against the native oracle lives in
//! `rust/tests/pjrt_integration.rs` (it needs built artifacts).

use super::*;

fn write_manifest(dir: &std::path::Path, body: &str) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), body).unwrap();
}

#[test]
fn manifest_parses_all_kinds() {
    let dir = std::env::temp_dir().join("cfl_manifest_ok");
    write_manifest(
        &dir,
        "# comment\n\
         grad_dev grad grad_dev.hlo.txt 512 512\n\
         grad_srv pgrad grad_srv.hlo.txt 2048 512\n\
         encode_dev encode encode_dev.hlo.txt 2048 512 512\n\
         gd_step gd_step gd_step.hlo.txt 512\n\
         nmse nmse nmse.hlo.txt 512\n",
    );
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.artifacts.len(), 5);
    assert_eq!(m.artifacts[0].kind, ArtifactKind::Grad);
    assert_eq!(m.artifacts[0].dims, vec![512, 512]);
    assert_eq!(m.artifacts[2].kind, ArtifactKind::Encode);
    assert!(m.artifacts[2].path.ends_with("encode_dev.hlo.txt"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_rejects_malformed() {
    let dir = std::env::temp_dir().join("cfl_manifest_bad1");
    write_manifest(&dir, "name grad\n");
    assert!(Manifest::load(&dir).is_err());
    write_manifest(&dir, "name bogus file.hlo.txt 1 2\n");
    assert!(Manifest::load(&dir).is_err());
    write_manifest(&dir, "name grad file.hlo.txt 1 2 3\n"); // wrong arity
    assert!(Manifest::load(&dir).is_err());
    write_manifest(&dir, "name grad file.hlo.txt twelve 2\n");
    assert!(Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_missing_dir_is_helpful() {
    let err = Manifest::load("/nonexistent/cfl_artifacts").unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}

#[test]
fn best_fit_prefers_smallest_covering_artifact() {
    let dir = std::env::temp_dir().join("cfl_manifest_fit");
    write_manifest(
        &dir,
        "small grad s.hlo.txt 128 128\n\
         large grad l.hlo.txt 512 512\n\
         srv pgrad p.hlo.txt 2048 512\n\
         enc_s encode es.hlo.txt 128 128 128\n\
         enc_l encode el.hlo.txt 2048 512 512\n",
    );
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.best_grad(100, 100).unwrap().name, "small");
    assert_eq!(m.best_grad(129, 100).unwrap().name, "large");
    assert_eq!(m.best_grad(300, 500).unwrap().name, "large");
    assert!(m.best_grad(600, 500).is_none(), "nothing fits L=600");
    assert_eq!(m.best_parity_grad(2000, 500).unwrap().name, "srv");
    assert_eq!(m.best_encode(128, 100, 64).unwrap().name, "enc_s");
    assert_eq!(m.best_encode(129, 100, 64).unwrap().name, "enc_l");
    std::fs::remove_dir_all(&dir).ok();
}
