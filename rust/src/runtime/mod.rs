//! PJRT runtime: load the AOT HLO-text artifacts and run them on the
//! training hot path.
//!
//! `make artifacts` (python, build-time only) lowers the L2 JAX graphs —
//! which call the L1 Pallas kernels — to HLO text under `artifacts/`,
//! together with a `manifest.txt` describing each artifact's kind and
//! padded shape. This module:
//!
//! * parses the manifest ([`Manifest`]),
//! * compiles each artifact once on the PJRT CPU client and caches the
//!   loaded executables ([`PjrtBackend`]),
//! * adapts logical shapes to artifact shapes by zero padding (exact for
//!   every graph here — padded rows/columns contribute zero; see
//!   `python/compile/model.py`) and crops the results back.
//!
//! The backend implements [`crate::fl::GradBackend`], so the coordinator
//! is oblivious to whether gradients come from XLA or the native oracle.

mod artifacts;
mod backend;

pub use artifacts::{ArtifactKind, ArtifactSpec, Manifest};
pub use backend::PjrtBackend;

#[cfg(test)]
mod tests;
