//! In-tree property-based testing harness.
//!
//! `proptest` is not available in the offline sandbox, so this module
//! provides the subset the test suite needs: composable generators over a
//! seeded [`crate::rng::Rng`], a configurable runner that reports the
//! failing case and its seed, and greedy shrinking for integers, floats
//! and vectors. Usage mirrors proptest closely:
//!
//! ```no_run
//! use cfl::testing::prop;
//! prop::check("sum is commutative", prop::cfg(), |g| {
//!     let a = g.int_in(0, 100);
//!     let b = g.int_in(0, 100);
//!     prop::assert_that(a + b == b + a, "a+b != b+a")
//! });
//! ```

pub mod prop;

#[cfg(test)]
mod tests;
