//! Minimal property-based testing: seeded generation + greedy shrinking.

use crate::config::ExperimentConfig;
use crate::linalg::Mat;
use crate::rng::Rng;

/// Committed regression-seed corpus, replayed by [`check`] before fresh
/// generation. One entry per line: the property name (spaces allowed)
/// followed by a base seed (decimal or `0x` hex); `#` starts a comment.
const CORPUS: &str = include_str!("corpus.txt");

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// Regression seeds recorded for `name` in the committed corpus.
pub fn corpus_seeds(name: &str) -> Vec<u64> {
    CORPUS
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| l.rsplit_once(char::is_whitespace))
        .filter(|(n, _)| n.trim() == name)
        .filter_map(|(_, s)| parse_seed(s))
        .collect()
}

/// Property outcome: `Ok(())` pass, `Err(msg)` failure (will be shrunk).
pub type PropResult = Result<(), String>;

/// Assertion helper producing a [`PropResult`].
pub fn assert_that(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper.
pub fn assert_close(a: f64, b: f64, tol: f64, label: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{label}: {a} != {b} (tol {tol})"))
    }
}

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses substream `i`.
    pub seed: u64,
    /// Maximum shrink iterations after a failure.
    pub max_shrink: usize,
}

/// Default config: 64 cases (each case typically runs a simulation or a
/// small linalg problem, so this stays fast), seed overridable via
/// `CFL_PROP_SEED` for reproducing CI failures.
pub fn cfg() -> Config {
    let seed = std::env::var("CFL_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0DE);
    Config { cases: 64, seed, max_shrink: 200 }
}

/// Config with a custom case count.
pub fn cfg_cases(cases: usize) -> Config {
    Config { cases, ..cfg() }
}

/// Value generator handed to properties. Records every drawn scalar so the
/// runner can replay and shrink the draw sequence ("choice sequence"
/// shrinking, the Hypothesis approach in miniature).
pub struct Gen<'a> {
    rng: &'a mut Rng,
    /// Draw log for the current case: (value as canonical u64, lo, hi).
    log: Vec<Draw>,
    /// When replaying a shrunk sequence, draws come from here instead.
    replay: Option<Vec<Draw>>,
    cursor: usize,
}

#[derive(Clone, Copy, Debug, PartialEq)]
struct Draw {
    value: i64,
    lo: i64,
    hi: i64,
}

impl<'a> Gen<'a> {
    fn new(rng: &'a mut Rng) -> Self {
        Self { rng, log: Vec::new(), replay: None, cursor: 0 }
    }

    fn with_replay(rng: &'a mut Rng, replay: Vec<Draw>) -> Self {
        Self { rng, log: Vec::new(), replay: Some(replay), cursor: 0 }
    }

    fn draw(&mut self, lo: i64, hi: i64) -> i64 {
        let v = if let Some(r) = &self.replay {
            match r.get(self.cursor) {
                // replayed draw, clamped into this draw's range in case the
                // shrunk prefix changed downstream ranges
                Some(d) => d.value.clamp(lo, hi),
                None => lo, // exhausted: minimal value
            }
        } else {
            lo + (self.rng.next_below((hi - lo + 1) as u64) as i64)
        };
        self.cursor += 1;
        self.log.push(Draw { value: v, lo, hi });
        v
    }

    /// Integer uniform in [lo, hi] (inclusive).
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        self.draw(lo, hi)
    }

    /// usize uniform in [lo, hi] (inclusive).
    pub fn size_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int_in(lo as i64, hi as i64) as usize
    }

    /// Float uniform in [lo, hi), drawn on a 2^20 lattice so it shrinks.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        const STEPS: i64 = 1 << 20;
        let t = self.draw(0, STEPS) as f64 / STEPS as f64;
        lo + (hi - lo) * t
    }

    /// Bernoulli(1/2) boolean.
    pub fn bool(&mut self) -> bool {
        self.draw(0, 1) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'s, T>(&mut self, items: &'s [T]) -> &'s T {
        assert!(!items.is_empty());
        &items[self.size_in(0, items.len() - 1)]
    }

    /// Vector of `n` values from `f`.
    pub fn vec_of<T>(&mut self, n: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Raw normal sample (not shrinkable — use for payload data, not sizes).
    pub fn normal(&mut self) -> f64 {
        // not logged as a draw: shrinking sizes/structure matters, payload
        // noise does not, and logging every matrix entry would explode the
        // shrink search space.
        self.rng.normal()
    }

    /// Seeded sub-RNG for bulk payload generation inside a property.
    pub fn rng(&mut self) -> Rng {
        let stream = self.draw(0, i64::MAX - 1) as u64;
        Rng::new(stream)
    }

    /// Seeded `rows × cols` f32 matrix with standard-normal entries. The
    /// payload comes from a sub-RNG ([`Gen::rng`]), so only the stream seed
    /// enters the shrink log, not every entry.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Mat {
        let mut r = self.rng();
        let data = (0..rows * cols).map(|_| r.normal() as f32).collect();
        Mat::from_vec(rows, cols, data)
    }

    /// Random fleet configuration, always within [`ExperimentConfig::validate`]
    /// ranges and small enough that a full training run takes milliseconds.
    /// Target NMSE is pinned to 0 so runs go to the epoch cap and traces
    /// from equal configs have equal lengths.
    pub fn fleet_config(&mut self) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::small();
        cfg.n_devices = self.size_in(2, 8);
        cfg.points_per_device = self.size_in(8, 40);
        cfg.model_dim = self.size_in(4, 24);
        cfg.nu_comp = self.f64_in(0.0, 0.6);
        cfg.nu_link = self.f64_in(0.0, 0.6);
        cfg.delta = if self.bool() { Some(self.f64_in(0.05, 0.25)) } else { None };
        cfg.max_epochs = self.size_in(3, 20);
        cfg.target_nmse = 0.0;
        cfg.seed = self.int_in(0, 0xFFFF) as u64;
        cfg
    }
}

/// Run `prop` for `cfg.cases` random cases; on failure, shrink the draw
/// sequence and panic with the minimal failing case and reproduction seed.
///
/// Before fresh generation, every seed recorded for `name` in the committed
/// regression corpus (`testing/corpus.txt`) is replayed as case 0 of that
/// seed, so once-seen failures stay fixed forever. A corpus failure reports
/// the corpus seed — `CFL_PROP_SEED=<seed>` reproduces it directly.
pub fn check(name: &str, cfg: Config, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    for seed in corpus_seeds(name) {
        run_case(name, &cfg, seed, 0, &mut prop);
    }
    for case in 0..cfg.cases {
        run_case(name, &cfg, cfg.seed, case, &mut prop);
    }
}

fn run_case(
    name: &str,
    cfg: &Config,
    seed: u64,
    case: usize,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) {
    let mut rng = Rng::new(seed).split(case as u64);
    let mut g = Gen::new(&mut rng);
    if let Err(msg) = prop(&mut g) {
        let draws = g.log.clone();
        let shrink_cfg = Config { seed, ..cfg.clone() };
        let (min_draws, min_msg) = shrink(&shrink_cfg, prop, draws, msg);
        panic!(
            "property '{name}' failed (case {case}, seed {seed:#x}, CFL_PROP_SEED={seed}):\n  \
             minimal draws: {min_draws:?}\n  error: {min_msg}",
        );
    }
}

/// Greedy choice-sequence shrinking: try to (a) shorten the sequence from
/// the tail, (b) move each draw toward its lower bound (halving steps).
fn shrink(
    cfg: &Config,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
    mut draws: Vec<Draw>,
    mut msg: String,
) -> (Vec<i64>, String) {
    let mut budget = cfg.max_shrink;
    let mut fails = |candidate: &[Draw], budget: &mut usize| -> Option<String> {
        if *budget == 0 {
            return None;
        }
        *budget -= 1;
        let mut rng = Rng::new(cfg.seed ^ 0xD00D);
        let mut g = Gen::with_replay(&mut rng, candidate.to_vec());
        prop(&mut g).err()
    };
    // (a) drop tail draws
    while draws.len() > 1 {
        let cand = &draws[..draws.len() - 1];
        if let Some(m) = fails(cand, &mut budget) {
            draws.pop();
            msg = m;
        } else {
            break;
        }
    }
    // (b) minimize each draw value: bisection toward lo, then a linear
    // refinement so boundary counterexamples (e.g. "fails iff x ≥ k") land
    // exactly on k rather than wherever halving stalled.
    for i in 0..draws.len() {
        while draws[i].value > draws[i].lo && budget > 0 {
            let mut cand = draws.clone();
            let mid = draws[i].lo + (draws[i].value - draws[i].lo) / 2;
            cand[i].value = mid;
            if let Some(m) = fails(&cand, &mut budget) {
                draws = cand;
                msg = m;
            } else {
                break;
            }
        }
        while draws[i].value > draws[i].lo && budget > 0 {
            let mut cand = draws.clone();
            cand[i].value -= 1;
            if let Some(m) = fails(&cand, &mut budget) {
                draws = cand;
                msg = m;
            } else {
                break;
            }
        }
    }
    (draws.iter().map(|d| d.value).collect(), msg)
}
