//! Tests for the property-testing harness itself.

use super::prop::{self, assert_close, assert_that};

#[test]
fn passing_property_runs_all_cases() {
    let mut runs = 0;
    prop::check("tautology", prop::cfg_cases(10), |g| {
        runs += 1;
        let a = g.int_in(0, 100);
        assert_that(a >= 0 && a <= 100, "range")
    });
    assert_eq!(runs, 10);
}

#[test]
#[should_panic(expected = "property 'always fails' failed")]
fn failing_property_panics_with_name() {
    prop::check("always fails", prop::cfg_cases(5), |g| {
        let _ = g.int_in(0, 10);
        Err("nope".to_string())
    });
}

#[test]
fn shrinking_finds_small_counterexample() {
    // property "x < 50" fails for x ≥ 50; shrinker should land near 50.
    let result = std::panic::catch_unwind(|| {
        prop::check("x < 50", prop::cfg_cases(200), |g| {
            let x = g.int_in(0, 1000);
            assert_that(x < 50, format!("x={x}"))
        });
    });
    let msg = *result.unwrap_err().downcast::<String>().unwrap();
    // minimal draws list should contain exactly the boundary value 50
    assert!(msg.contains("minimal draws: [50]"), "shrink did not minimize: {msg}");
}

#[test]
fn generators_respect_ranges() {
    prop::check("ranges", prop::cfg_cases(50), |g| {
        let i = g.int_in(-5, 5);
        assert_that((-5..=5).contains(&i), format!("int {i}"))?;
        let s = g.size_in(2, 4);
        assert_that((2..=4).contains(&s), format!("size {s}"))?;
        let f = g.f64_in(1.0, 2.0);
        assert_that((1.0..=2.0).contains(&f), format!("f64 {f}"))?;
        let c = *g.choose(&[7, 8, 9]);
        assert_that([7, 8, 9].contains(&c), format!("choose {c}"))?;
        let v = g.vec_of(s, |g| g.bool());
        assert_that(v.len() == s, "vec len")
    });
}

#[test]
fn sub_rng_is_usable() {
    prop::check("sub rng", prop::cfg_cases(10), |g| {
        let mut r = g.rng();
        let x = r.normal();
        assert_that(x.is_finite(), "normal finite")
    });
}

#[test]
fn assert_close_tolerates_scale() {
    assert!(assert_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
    assert!(assert_close(1.0, 1.1, 1e-6, "off").is_err());
}
