//! Tests for the property-testing harness itself.

use super::prop::{self, assert_close, assert_that};

#[test]
fn passing_property_runs_all_cases() {
    let mut runs = 0;
    prop::check("tautology", prop::cfg_cases(10), |g| {
        runs += 1;
        let a = g.int_in(0, 100);
        assert_that(a >= 0 && a <= 100, "range")
    });
    assert_eq!(runs, 10);
}

#[test]
#[should_panic(expected = "property 'always fails' failed")]
fn failing_property_panics_with_name() {
    prop::check("always fails", prop::cfg_cases(5), |g| {
        let _ = g.int_in(0, 10);
        Err("nope".to_string())
    });
}

#[test]
fn shrinking_finds_small_counterexample() {
    // property "x < 50" fails for x ≥ 50; shrinker should land near 50.
    let result = std::panic::catch_unwind(|| {
        prop::check("x < 50", prop::cfg_cases(200), |g| {
            let x = g.int_in(0, 1000);
            assert_that(x < 50, format!("x={x}"))
        });
    });
    let msg = *result.unwrap_err().downcast::<String>().unwrap();
    // minimal draws list should contain exactly the boundary value 50
    assert!(msg.contains("minimal draws: [50]"), "shrink did not minimize: {msg}");
}

#[test]
fn generators_respect_ranges() {
    prop::check("ranges", prop::cfg_cases(50), |g| {
        let i = g.int_in(-5, 5);
        assert_that((-5..=5).contains(&i), format!("int {i}"))?;
        let s = g.size_in(2, 4);
        assert_that((2..=4).contains(&s), format!("size {s}"))?;
        let f = g.f64_in(1.0, 2.0);
        assert_that((1.0..=2.0).contains(&f), format!("f64 {f}"))?;
        let c = *g.choose(&[7, 8, 9]);
        assert_that([7, 8, 9].contains(&c), format!("choose {c}"))?;
        let v = g.vec_of(s, |g| g.bool());
        assert_that(v.len() == s, "vec len")
    });
}

#[test]
fn sub_rng_is_usable() {
    prop::check("sub rng", prop::cfg_cases(10), |g| {
        let mut r = g.rng();
        let x = r.normal();
        assert_that(x.is_finite(), "normal finite")
    });
}

#[test]
fn assert_close_tolerates_scale() {
    assert!(assert_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
    assert!(assert_close(1.0, 1.1, 1e-6, "off").is_err());
}

#[test]
fn corpus_seeds_replay_before_fresh_cases() {
    // corpus.txt records two seeds for this name: they run first, then the
    // configured fresh cases
    assert_eq!(prop::corpus_seeds("corpus-replay-smoke"), vec![0x5EED, 12345]);
    let mut runs = 0;
    prop::check("corpus-replay-smoke", prop::cfg_cases(3), |g| {
        runs += 1;
        let _ = g.int_in(0, 10);
        Ok(())
    });
    assert_eq!(runs, 2 + 3, "2 corpus replays + 3 fresh cases");

    // a name with no corpus entries runs fresh cases only
    assert!(prop::corpus_seeds("no such property").is_empty());
}

#[test]
fn corpus_failure_reports_the_corpus_seed() {
    // zero fresh cases: the only execution is the corpus replay, and the
    // panic must carry the corpus seed as the reproduction command
    let result = std::panic::catch_unwind(|| {
        prop::check("corpus-always-fails", prop::cfg_cases(0), |g| {
            let _ = g.int_in(0, 10);
            Err("nope".to_string())
        });
    });
    let msg = *result.unwrap_err().downcast::<String>().unwrap();
    assert!(msg.contains("seed 0xbad5eed"), "missing corpus seed: {msg}");
    assert!(msg.contains("CFL_PROP_SEED=195911405"), "missing repro seed: {msg}");
}

#[test]
fn matrix_and_fleet_config_generators_are_valid() {
    prop::check("generators stay in range", prop::cfg_cases(20), |g| {
        let rows = g.size_in(1, 6);
        let cols = g.size_in(1, 6);
        let m = g.matrix(rows, cols);
        assert_that(m.rows() == rows && m.cols() == cols, "matrix dims")?;
        assert_that(m.as_slice().iter().all(|v| v.is_finite()), "matrix entries finite")?;
        let cfg = g.fleet_config();
        cfg.validate().map_err(|e| format!("generated config invalid: {e}"))?;
        assert_that(cfg.target_nmse == 0.0, "fleet configs run to the epoch cap")
    });
}
