//! Dense linear algebra substrate (row-major `f32`).
//!
//! Two roles:
//!
//! 1. **Oracle / fallback** for the PJRT runtime: every artifact graph has
//!    a native implementation here ([`partial_grad`], [`encode`] in
//!    `coding`), used by `cargo test` cross-checks and by hosts without
//!    built artifacts.
//! 2. **Baselines**: the closed-form least-squares bound of Fig. 2 needs a
//!    normal-equations solve ([`solve_ls`], Cholesky).
//!
//! The GEMM is cache-blocked and the gradient kernel is fused (residual
//! never materializes in a second pass over memory) — see `gemm.rs`.

mod gemm;
mod mat;
mod solve;

pub use gemm::{matmul, matmul_at_b, partial_grad};
pub use mat::Mat;
pub use solve::{cholesky_solve_in_place, solve_ls};

#[cfg(test)]
mod tests;
