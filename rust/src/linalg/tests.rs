//! linalg unit tests: construction, GEMM vs naive, fused gradient, solver.

use super::*;
use crate::rng::Rng;

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0f64;
            for k in 0..a.cols() {
                s += a[(i, k)] as f64 * b[(k, j)] as f64;
            }
            c[(i, j)] = s as f32;
        }
    }
    c
}

#[test]
fn mat_construction_and_indexing() {
    let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(m[(0, 0)], 1.0);
    assert_eq!(m[(1, 2)], 6.0);
    assert_eq!(m.row(1), &[4., 5., 6.]);
    assert_eq!(m.rows(), 2);
    assert_eq!(m.cols(), 3);
}

#[test]
#[should_panic(expected = "buffer len")]
fn mat_from_vec_rejects_bad_len() {
    Mat::from_vec(2, 3, vec![1.0; 5]);
}

#[test]
fn eye_and_matmul_identity() {
    let mut r = Rng::new(0);
    let a = Mat::randn(7, 7, &mut r);
    let i = Mat::eye(7);
    assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
    assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
}

#[test]
fn blocked_matmul_matches_naive() {
    let mut r = Rng::new(1);
    for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (70, 129, 65), (128, 300, 64)] {
        let a = Mat::randn(m, k, &mut r);
        let b = Mat::randn(k, n, &mut r);
        let got = matmul(&a, &b);
        let want = naive_matmul(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-3 * (k as f32).sqrt(), "({m},{k},{n})");
    }
}

#[test]
fn matmul_at_b_matches_transpose_matmul() {
    let mut r = Rng::new(2);
    for &(k, m, n) in &[(5, 3, 4), (64, 32, 16), (300, 50, 1)] {
        let a = Mat::randn(k, m, &mut r);
        let b = Mat::randn(k, n, &mut r);
        let got = matmul_at_b(&a, &b);
        let want = matmul(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-3 * (k as f32).sqrt(), "({k},{m},{n})");
    }
}

#[test]
fn partial_grad_matches_composed_ops() {
    let mut r = Rng::new(3);
    for &(l, d) in &[(1, 1), (10, 4), (300, 500), (128, 65)] {
        let x = Mat::randn(l, d, &mut r);
        let beta = Mat::randn(d, 1, &mut r);
        let y = Mat::randn(l, 1, &mut r);
        let mut xb = matmul(&x, &beta);
        xb.axpy(-1.0, &y);
        let want = matmul_at_b(&x, &xb);
        let got = partial_grad(&x, &beta, &y);
        let scale = want.as_slice().iter().fold(1.0f32, |m, &v| m.max(v.abs()));
        assert!(got.max_abs_diff(&want) < 2e-4 * scale, "({l},{d})");
    }
}

#[test]
fn partial_grad_zero_row_padding_exact() {
    let mut r = Rng::new(4);
    let x = Mat::randn(40, 8, &mut r);
    let beta = Mat::randn(8, 1, &mut r);
    let y = Mat::randn(40, 1, &mut r);
    let g0 = partial_grad(&x, &beta, &y);
    let g1 = partial_grad(&x.pad_to(64, 8), &beta, &y.pad_to(64, 1));
    assert_eq!(g0, g1);
}

#[test]
fn partial_grad_zero_col_padding_exact() {
    let mut r = Rng::new(5);
    let x = Mat::randn(20, 6, &mut r);
    let beta = Mat::randn(6, 1, &mut r);
    let y = Mat::randn(20, 1, &mut r);
    let g0 = partial_grad(&x, &beta, &y);
    let g1 = partial_grad(&x.pad_to(20, 10), &beta.pad_to(10, 1), &y);
    assert_eq!(g1.crop_to(6, 1), g0);
    for i in 6..10 {
        assert_eq!(g1[(i, 0)], 0.0);
    }
}

#[test]
fn pad_crop_roundtrip() {
    let mut r = Rng::new(6);
    let m = Mat::randn(5, 7, &mut r);
    assert_eq!(m.pad_to(8, 16).crop_to(5, 7), m);
}

#[test]
fn transpose_involution() {
    let mut r = Rng::new(7);
    let m = Mat::randn(9, 4, &mut r);
    assert_eq!(m.transpose().transpose(), m);
}

#[test]
fn scale_rows_matches_diagonal_matmul() {
    let mut r = Rng::new(8);
    let mut m = Mat::randn(6, 5, &mut r);
    let w: Vec<f32> = (0..6).map(|i| 0.5 + i as f32 * 0.1).collect();
    let mut diag = Mat::zeros(6, 6);
    for i in 0..6 {
        diag[(i, i)] = w[i];
    }
    let want = matmul(&diag, &m);
    m.scale_rows(&w);
    assert!(m.max_abs_diff(&want) < 1e-6);
}

#[test]
fn norms_and_nmse() {
    let a = Mat::col_vec(&[3.0, 4.0]);
    assert!((a.norm_sq() - 25.0).abs() < 1e-9);
    let b = Mat::col_vec(&[3.0, 0.0]);
    assert!((a.dist_sq(&b) - 16.0).abs() < 1e-9);
    assert!((b.nmse(&a) - 16.0 / 25.0).abs() < 1e-9);
    assert_eq!(a.nmse(&a), 0.0);
}

#[test]
fn slice_rows_extracts_block() {
    let m = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
    let s = m.slice_rows(1, 3);
    assert_eq!(s, Mat::from_vec(2, 2, vec![3., 4., 5., 6.]));
}

#[test]
fn cholesky_solves_known_system() {
    // A = [[4,2],[2,3]], b = [1, 2] → x = [−1/8, 3/4]
    let mut a = vec![4.0, 2.0, 2.0, 3.0];
    let mut b = vec![1.0, 2.0];
    cholesky_solve_in_place(&mut a, &mut b, 2).unwrap();
    assert!((b[0] + 0.125).abs() < 1e-12);
    assert!((b[1] - 0.75).abs() < 1e-12);
}

#[test]
fn cholesky_rejects_indefinite() {
    let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, −1
    let mut b = vec![1.0, 1.0];
    assert!(cholesky_solve_in_place(&mut a, &mut b, 2).is_err());
}

#[test]
fn solve_ls_recovers_noiseless_model() {
    let mut r = Rng::new(9);
    let d = 20;
    let x = Mat::randn(200, d, &mut r);
    let beta = Mat::randn(d, 1, &mut r);
    let y = matmul(&x, &beta);
    let hat = solve_ls(&x, &y).unwrap();
    assert!(hat.nmse(&beta) < 1e-8, "nmse={}", hat.nmse(&beta));
}

#[test]
fn solve_ls_beats_noise_floor() {
    // with noise, LS should land near the CRB-ish floor, far below NMSE=1
    let mut r = Rng::new(10);
    let d = 30;
    let x = Mat::randn(600, d, &mut r);
    let beta = Mat::randn(d, 1, &mut r);
    let mut y = matmul(&x, &beta);
    for v in y.as_mut_slice() {
        *v += r.normal() as f32; // SNR ≈ d (≫ 0 dB) per row
    }
    let hat = solve_ls(&x, &y).unwrap();
    assert!(hat.nmse(&beta) < 1e-2);
}

#[test]
fn add_assign_axpy_scale() {
    let mut a = Mat::col_vec(&[1.0, 2.0]);
    let b = Mat::col_vec(&[10.0, 20.0]);
    a.add_assign(&b);
    assert_eq!(a.as_slice(), &[11.0, 22.0]);
    a.axpy(-1.0, &b);
    assert_eq!(a.as_slice(), &[1.0, 2.0]);
    a.scale(3.0);
    assert_eq!(a.as_slice(), &[3.0, 6.0]);
}
