//! Row-major dense matrix.

use crate::rng::Rng;

/// Dense row-major `f32` matrix.
///
/// Row-major matches both the C-order numpy arrays the artifacts were
/// lowered for and the PJRT literal layout, so hand-off between the native
/// path and the runtime is a straight memcpy.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer len != rows*cols");
        Self { rows, cols, data }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix of iid standard normals.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data);
        m
    }

    /// Matrix of iid Rademacher ±1 entries (Bernoulli(½) generator).
    pub fn rademacher(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.rademacher() as f32;
        }
        m
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f32]) -> Self {
        Self::from_vec(v.len(), 1, v.to_vec())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Select a contiguous row range as a new matrix.
    pub fn slice_rows(&self, start: usize, end: usize) -> Mat {
        assert!(start <= end && end <= self.rows);
        Mat::from_vec(end - start, self.cols, self.data[start * self.cols..end * self.cols].to_vec())
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// Zero-pad to a larger shape (exactness argument: see model.py).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.rows && cols >= self.cols, "pad_to must grow");
        let mut out = Mat::zeros(rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
        }
        out
    }

    /// Top-left sub-matrix (inverse of [`Mat::pad_to`]).
    pub fn crop_to(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows <= self.rows && cols <= self.cols, "crop_to must shrink");
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[..cols]);
        }
        out
    }

    /// a ← a + b
    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (x, y) in self.data.iter_mut().zip(&b.data) {
            *x += y;
        }
    }

    /// a ← a + s·b (axpy)
    pub fn axpy(&mut self, s: f32, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (x, y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    /// Scale every entry.
    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// Scale each row `r` by `w[r]` (diagonal weighting, Eq. 9's `W_i X`).
    pub fn scale_rows(&mut self, w: &[f32]) {
        assert_eq!(w.len(), self.rows);
        for (r, &s) in w.iter().enumerate() {
            for x in self.row_mut(r) {
                *x *= s;
            }
        }
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// ‖a − b‖² (Frobenius).
    pub fn dist_sq(&self, b: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(&x, &y)| {
                let d = (x - y) as f64;
                d * d
            })
            .sum()
    }

    /// Normalized MSE ‖a − b‖²/‖b‖² — the paper's §IV metric.
    pub fn nmse(&self, truth: &Mat) -> f64 {
        self.dist_sq(truth) / truth.norm_sq()
    }

    /// Maximum absolute entry difference (test helper).
    pub fn max_abs_diff(&self, b: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(&x, &y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

impl core::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl core::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}
