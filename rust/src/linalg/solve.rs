//! Normal-equations least-squares solve (the Fig. 2 "LS bound" baseline).

use super::{matmul_at_b, Mat};
use anyhow::{bail, Result};

/// Solve A·x = b in place for symmetric positive-definite A via Cholesky
/// (A = L·Lᵀ). `a` is overwritten with L in its lower triangle. f64
/// accumulation — the normal equations square the condition number, and
/// the LS bound anchors every convergence plot.
pub fn cholesky_solve_in_place(a: &mut [f64], b: &mut [f64], n: usize) -> Result<()> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // factorize
    for j in 0..n {
        let mut diag = a[j * n + j];
        for k in 0..j {
            let ljk = a[j * n + k];
            diag -= ljk * ljk;
        }
        if diag <= 0.0 {
            bail!("matrix not positive definite at pivot {j} (diag={diag})");
        }
        let ljj = diag.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut v = a[i * n + j];
            for k in 0..j {
                v -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = v / ljj;
        }
    }
    // forward substitution L·z = b
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= a[i * n + k] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    // back substitution Lᵀ·x = z
    for i in (0..n).rev() {
        let mut v = b[i];
        for k in (i + 1)..n {
            v -= a[k * n + i] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    Ok(())
}

/// Least-squares estimate β̂ = (XᵀX)⁻¹Xᵀy — the best linear unbiased
/// estimate the gradient iterations converge toward; its NMSE is the noise
/// floor drawn in Fig. 2.
pub fn solve_ls(x: &Mat, y: &Mat) -> Result<Mat> {
    assert_eq!(y.cols(), 1);
    assert_eq!(x.rows(), y.rows());
    let d = x.cols();
    let xtx = matmul_at_b(x, x); // d×d
    let xty = matmul_at_b(x, y); // d×1
    let mut a: Vec<f64> = xtx.as_slice().iter().map(|&v| v as f64).collect();
    let mut b: Vec<f64> = xty.as_slice().iter().map(|&v| v as f64).collect();
    cholesky_solve_in_place(&mut a, &mut b, d)?;
    Ok(Mat::from_vec(d, 1, b.into_iter().map(|v| v as f32).collect()))
}
