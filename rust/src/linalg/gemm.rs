//! Blocked GEMM and the fused partial-gradient kernel.
//!
//! Hot-path notes (§Perf): the native path serves two jobs — the test
//! oracle, and the gradient fallback when artifacts are absent. The GEMM
//! uses i-k-j loop order (unit-stride inner loop over B's and C's rows)
//! with L1-sized k×j tiling; the fused [`partial_grad`] streams each row of
//! X exactly twice (once for the residual dot, once for the rank-1 gradient
//! update) with the residual kept in registers — the same fusion the L1
//! Pallas kernel performs in VMEM.

use super::Mat;
use crate::obs::Counter;
use std::sync::OnceLock;

/// Cache block edge for the k (reduction) dimension.
const BK: usize = 64;
/// Cache block edge for the j (output-column) dimension.
const BJ: usize = 256;

/// `(calls, fmas)` counters for the dense GEMMs, resolved once — the
/// per-call cost is two relaxed atomic adds, vanishing against the
/// O(m·k·n) flops they account for.
fn gemm_counters() -> &'static (Counter, Counter) {
    static C: OnceLock<(Counter, Counter)> = OnceLock::new();
    C.get_or_init(|| {
        let reg = crate::obs::registry();
        (reg.counter("linalg.gemm.calls"), reg.counter("linalg.gemm.fmas"))
    })
}

/// `(calls, fmas)` counters for the fused partial-gradient kernel.
fn partial_grad_counters() -> &'static (Counter, Counter) {
    static C: OnceLock<(Counter, Counter)> = OnceLock::new();
    C.get_or_init(|| {
        let reg = crate::obs::registry();
        (reg.counter("linalg.partial_grad.calls"), reg.counter("linalg.partial_grad.fmas"))
    })
}

/// C = A·B (blocked, row-major).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul inner dims: {}x{} · {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let ctr = gemm_counters();
    ctr.0.incr();
    ctr.1.add((m * k * n) as u64);
    let mut c = Mat::zeros(m, n);
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for j0 in (0..n).step_by(BJ) {
            let j1 = (j0 + BJ).min(n);
            for i in 0..m {
                let arow = a.row(i);
                let crow = c.row_mut(i);
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue; // zero-padded operands are common
                    }
                    let brow = b.row(kk);
                    for j in j0..j1 {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// C = Aᵀ·B without materializing Aᵀ (A is consumed row-wise, so this is a
/// sum of rank-1 outer products — unit stride throughout).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b row dims");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let ctr = gemm_counters();
    ctr.0.incr();
    ctr.1.add((k * m * n) as u64);
    let mut c = Mat::zeros(m, n);
    for r in 0..k {
        let arow = a.row(r);
        let brow = b.row(r);
        for i in 0..m {
            let ari = arow[i];
            if ari == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += ari * brow[j];
            }
        }
    }
    c
}

/// Fused partial gradient g = Xᵀ(Xβ − y) — native twin of the L1 Pallas
/// kernel (Eq. 2 inner sum / Eq. 18 numerator).
///
/// One pass per row: residual rᵢ = xᵢ·β − yᵢ (dot product), then
/// g += rᵢ·xᵢ (axpy). X is streamed once; g (d floats) stays hot.
pub fn partial_grad(x: &Mat, beta: &Mat, y: &Mat) -> Mat {
    assert_eq!(beta.cols(), 1, "beta must be a column vector");
    assert_eq!(y.cols(), 1, "y must be a column vector");
    assert_eq!(x.cols(), beta.rows(), "X/β dims");
    assert_eq!(x.rows(), y.rows(), "X/y dims");
    let d = x.cols();
    let ctr = partial_grad_counters();
    ctr.0.incr();
    ctr.1.add((2 * x.rows() * d) as u64);
    let mut g = Mat::zeros(d, 1);
    let bcol = beta.as_slice();
    let gcol = g.as_mut_slice();
    for r in 0..x.rows() {
        let xrow = x.row(r);
        let mut dot = 0.0f32;
        for (xv, bv) in xrow.iter().zip(bcol) {
            dot += xv * bv;
        }
        let resid = dot - y.as_slice()[r];
        if resid == 0.0 {
            continue;
        }
        for (gv, xv) in gcol.iter_mut().zip(xrow) {
            *gv += resid * xv;
        }
    }
    g
}
