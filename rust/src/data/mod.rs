//! Synthetic training data and sharding (§IV's workload).
//!
//! `y = Xβ + z` with iid standard-normal features, β ~ N(0, I_d), and
//! AWGN `z` at the configured SNR. The paper's "SNR is 0 dB" is
//! per-element: noise variance = feature variance = 1 (this is the only
//! convention under which the paper's LS-bound NMSE of ~1.4·10⁻⁴ at
//! m = 7200, d = 500 is reproducible — per-row SNR 0 dB would put the LS
//! floor at d/m ≈ 7·10⁻², far above every target the paper reports).
//!
//! Sharding policies distribute the m rows across devices: equal (§IV),
//! power-law sizes and Dirichlet feature skew (the non-iid knobs §I
//! motivates and the paper defers to future work).

mod dataset;
mod shard;
mod stream;

pub use dataset::Dataset;
pub use shard::{shard_sizes, split, Shard};
pub use stream::LeanDataset;

#[cfg(test)]
mod tests;
