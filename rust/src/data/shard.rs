//! Sharding: distributing the global dataset across devices.

use super::Dataset;
use crate::config::ShardingKind;
use crate::linalg::Mat;
use crate::rng::Rng;

/// One device's local database (Xⁱ, yⁱ) plus its offset into the global
/// row order (used by tests to reassemble the global problem).
#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Mat,
    pub y: Mat,
    /// First global row index of this shard.
    pub offset: usize,
}

impl Shard {
    pub fn rows(&self) -> usize {
        self.x.rows()
    }
}

/// Compute per-device shard sizes for `n` devices totalling `m` rows.
///
/// * `Equal` — m/n each (requires n | m, as in the paper's 24×300).
/// * `PowerLaw(α)` — sizes ∝ (i+1)^−α, largest first, shuffled; every
///   device keeps at least 1 row; rounding remainder goes to the largest.
/// * `Dirichlet(α)` — sizes ∝ Gamma(α) draws (symmetric Dirichlet);
///   α → ∞ approaches equal, small α is highly skewed.
pub fn shard_sizes(kind: ShardingKind, m: usize, n: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(n > 0 && m >= n, "need at least one row per device");
    match kind {
        ShardingKind::Equal => {
            assert!(m % n == 0, "equal sharding requires n | m ({m} rows, {n} devices)");
            vec![m / n; n]
        }
        ShardingKind::PowerLaw(alpha) => {
            let weights: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect();
            let mut sizes = apportion(&weights, m, n);
            rng.shuffle(&mut sizes);
            sizes
        }
        ShardingKind::Dirichlet(alpha) => {
            // Gamma(α) via Marsaglia–Tsang for α ≥ 1, boosted for α < 1.
            let weights: Vec<f64> = (0..n).map(|_| sample_gamma(alpha, rng)).collect();
            apportion(&weights, m, n)
        }
    }
}

/// Largest-remainder apportionment of `m` rows by weights, each ≥ 1.
fn apportion(weights: &[f64], m: usize, n: usize) -> Vec<usize> {
    let total: f64 = weights.iter().sum();
    // reserve one row per device, apportion the rest fractionally
    let spare = m - n;
    let mut sizes: Vec<usize> = vec![1; n];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let exact = spare as f64 * w / total;
        let base = exact.floor() as usize;
        sizes[i] += base;
        assigned += base;
        fracs.push((exact - base as f64, i));
    }
    // distribute the remainder to the largest fractional parts —
    // total_cmp keeps degenerate NaN weights (a pathological α) from
    // panicking the comparator: NaN fractions take a deterministic
    // position and the apportionment still sums to m
    fracs.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    for k in 0..(spare - assigned) {
        sizes[fracs[k % n].1] += 1;
    }
    sizes
}

fn sample_gamma(alpha: f64, rng: &mut Rng) -> f64 {
    assert!(alpha > 0.0);
    if alpha < 1.0 {
        // Johnk boost: Gamma(α) = Gamma(α+1) · U^(1/α)
        let g = sample_gamma(alpha + 1.0, rng);
        return g * rng.next_f64_open().powf(1.0 / alpha);
    }
    // Marsaglia–Tsang squeeze
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64_open();
        if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Split a dataset into shards of the given sizes (contiguous row blocks;
/// rows of X are iid so contiguity loses no generality for iid sharding).
pub fn split(ds: &Dataset, sizes: &[usize]) -> Vec<Shard> {
    assert_eq!(sizes.iter().sum::<usize>(), ds.rows(), "sizes must cover the dataset");
    let mut shards = Vec::with_capacity(sizes.len());
    let mut offset = 0;
    for &s in sizes {
        shards.push(Shard {
            x: ds.x.slice_rows(offset, offset + s),
            y: ds.y.slice_rows(offset, offset + s),
            offset,
        });
        offset += s;
    }
    shards
}
