//! Global dataset generation.

use crate::linalg::{matmul, Mat};
use crate::rng::Rng;

/// The global linear-regression problem: features, labels, ground truth.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Features, m×d, iid N(0,1).
    pub x: Mat,
    /// Labels, m×1: y = Xβ* + z.
    pub y: Mat,
    /// Ground-truth model β*, d×1 — the NMSE reference of §IV.
    pub beta_star: Mat,
    /// Noise standard deviation actually used.
    pub noise_std: f64,
}

impl Dataset {
    /// Generate a dataset: `m` rows, `d` features, AWGN at `snr_db`
    /// (per-element convention, see module docs).
    pub fn generate(m: usize, d: usize, snr_db: f64, rng: &mut Rng) -> Self {
        let mut data_rng = rng.split(0xDA7A);
        let x = Mat::randn(m, d, &mut data_rng);
        let beta_star = Mat::randn(d, 1, &mut data_rng);
        let noise_std = 10f64.powf(-snr_db / 20.0);
        let mut y = matmul(&x, &beta_star);
        for v in y.as_mut_slice() {
            *v += (noise_std * data_rng.normal()) as f32;
        }
        Self { x, y, beta_star, noise_std }
    }

    pub fn rows(&self) -> usize {
        self.x.rows()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Empirical SNR: ‖Xβ*‖² / ‖y − Xβ*‖² scaled per element
    /// (diagnostic; ≈ 10^(snr_db/10) · d for the per-element convention).
    pub fn empirical_snr(&self) -> f64 {
        let signal = matmul(&self.x, &self.beta_star);
        let noise_sq = self.y.dist_sq(&signal);
        signal.norm_sq() / noise_sq
    }
}
