use super::*;
use crate::config::ShardingKind;
use crate::linalg::solve_ls;
use crate::rng::Rng;
use crate::testing::prop::{self, assert_that};

#[test]
fn dataset_shapes_and_determinism() {
    let mut rng = Rng::new(1);
    let ds = Dataset::generate(120, 10, 0.0, &mut rng);
    assert_eq!(ds.rows(), 120);
    assert_eq!(ds.dim(), 10);
    assert_eq!(ds.y.rows(), 120);
    assert_eq!(ds.beta_star.rows(), 10);
    let ds2 = Dataset::generate(120, 10, 0.0, &mut Rng::new(1));
    assert_eq!(ds.x, ds2.x);
    assert_eq!(ds.y, ds2.y);
}

#[test]
fn snr_convention_gives_paper_ls_floor() {
    // m=7200, d=500, 0 dB per-element ⇒ LS NMSE ≈ σ²·d/(m·‖β‖²) ≈ 1.4e-4
    let mut rng = Rng::new(2);
    let ds = Dataset::generate(7200, 500, 0.0, &mut rng);
    let ls = solve_ls(&ds.x, &ds.y).unwrap();
    let nmse = ls.nmse(&ds.beta_star);
    assert!(
        (5e-5..5e-4).contains(&nmse),
        "LS NMSE {nmse:.3e} outside the paper's ~1.4e-4 ballpark"
    );
}

#[test]
fn noise_std_follows_snr() {
    let mut rng = Rng::new(3);
    let ds0 = Dataset::generate(100, 5, 0.0, &mut rng);
    assert!((ds0.noise_std - 1.0).abs() < 1e-12);
    let ds20 = Dataset::generate(100, 5, 20.0, &mut Rng::new(3));
    assert!((ds20.noise_std - 0.1).abs() < 1e-12);
}

#[test]
fn empirical_snr_tracks_config() {
    let mut rng = Rng::new(4);
    let d = 50;
    let ds = Dataset::generate(4000, d, 0.0, &mut rng);
    // per-element 0 dB ⇒ row signal power ≈ ‖β‖² ≈ d, noise power 1
    let got = ds.empirical_snr();
    let want = d as f64;
    assert!((got / want - 1.0).abs() < 0.3, "snr={got} want≈{want}");
}

#[test]
fn equal_sharding_matches_paper() {
    let mut rng = Rng::new(5);
    let sizes = shard_sizes(ShardingKind::Equal, 7200, 24, &mut rng);
    assert_eq!(sizes, vec![300; 24]);
}

#[test]
#[should_panic(expected = "requires n | m")]
fn equal_sharding_requires_divisibility() {
    shard_sizes(ShardingKind::Equal, 100, 7, &mut Rng::new(0));
}

#[test]
fn degenerate_nan_weights_still_apportion() {
    // a pathological α gives every device a NaN weight; the
    // largest-remainder sort used to panic in partial_cmp — it must now
    // produce a full, deterministic apportionment instead
    let mut rng = Rng::new(9);
    let sizes = shard_sizes(ShardingKind::PowerLaw(f64::NAN), 100, 8, &mut rng);
    assert_eq!(sizes.len(), 8);
    assert_eq!(sizes.iter().sum::<usize>(), 100, "NaN weights must still cover m");
    assert!(sizes.iter().all(|&s| s >= 1));
    let again = shard_sizes(ShardingKind::PowerLaw(f64::NAN), 100, 8, &mut Rng::new(9));
    assert_eq!(sizes, again, "NaN apportionment must stay deterministic");
}

#[test]
fn power_law_sharding_sums_and_skews() {
    let mut rng = Rng::new(6);
    let sizes = shard_sizes(ShardingKind::PowerLaw(1.2), 7200, 24, &mut rng);
    assert_eq!(sizes.iter().sum::<usize>(), 7200);
    assert!(sizes.iter().all(|&s| s >= 1));
    let max = *sizes.iter().max().unwrap();
    let min = *sizes.iter().min().unwrap();
    assert!(max > 4 * min, "power law should be skewed: max={max} min={min}");
}

#[test]
fn dirichlet_sharding_alpha_controls_skew() {
    let mut rng = Rng::new(7);
    let skew = |alpha: f64, rng: &mut Rng| {
        let sizes = shard_sizes(ShardingKind::Dirichlet(alpha), 7200, 24, rng);
        assert_eq!(sizes.iter().sum::<usize>(), 7200);
        let max = *sizes.iter().max().unwrap() as f64;
        let min = *sizes.iter().min().unwrap() as f64;
        max / min
    };
    let tight = skew(100.0, &mut rng);
    let loose = skew(0.3, &mut rng);
    assert!(tight < 2.0, "alpha=100 should be near-equal, ratio={tight}");
    assert!(loose > 5.0, "alpha=0.3 should be skewed, ratio={loose}");
}

#[test]
fn prop_sharding_always_partitions() {
    prop::check("shard partition", prop::cfg_cases(60), |g| {
        let n = g.size_in(1, 40);
        let per = g.size_in(1, 50);
        let m = n * per + g.size_in(0, n - 1) * usize::from(!matches!(0, 0usize)); // n·per + extra<n
        let m = m.max(n);
        let kind = *g.choose(&[
            ShardingKind::PowerLaw(1.0),
            ShardingKind::PowerLaw(2.5),
            ShardingKind::Dirichlet(0.5),
            ShardingKind::Dirichlet(5.0),
        ]);
        let mut rng = g.rng();
        let sizes = shard_sizes(kind, m, n, &mut rng);
        assert_that(sizes.len() == n, "one size per device")?;
        assert_that(sizes.iter().sum::<usize>() == m, "sizes must sum to m")?;
        assert_that(sizes.iter().all(|&s| s >= 1), "every device keeps ≥1 row")
    });
}

#[test]
fn split_reassembles_dataset() {
    let mut rng = Rng::new(8);
    let ds = Dataset::generate(60, 4, 10.0, &mut rng);
    let sizes = vec![10, 20, 30];
    let shards = split(&ds, &sizes);
    assert_eq!(shards.len(), 3);
    assert_eq!(shards[1].offset, 10);
    let mut row = 0;
    for sh in &shards {
        for r in 0..sh.rows() {
            assert_eq!(sh.x.row(r), ds.x.row(row));
            assert_eq!(sh.y.row(r), ds.y.row(row));
            row += 1;
        }
    }
    assert_eq!(row, 60);
}

#[test]
#[should_panic(expected = "cover the dataset")]
fn split_rejects_bad_sizes() {
    let ds = Dataset::generate(10, 2, 0.0, &mut Rng::new(9));
    split(&ds, &[3, 3]);
}

#[test]
fn lean_shapes_offsets_and_determinism() {
    let sizes = vec![10, 20, 30];
    let lean = LeanDataset::new(4, 10.0, sizes.clone(), &mut Rng::new(10));
    assert_eq!(lean.n_shards(), 3);
    assert_eq!(lean.dim(), 4);
    assert_eq!(lean.rows(), 60);
    assert!((lean.noise_std() - 10f64.powf(-0.5)).abs() < 1e-12);
    assert_eq!(lean.shard_offset(0), 0);
    assert_eq!(lean.shard_offset(2), 30);
    for i in 0..3 {
        let sh = lean.shard(i);
        assert_eq!(sh.rows(), sizes[i]);
        assert_eq!(sh.x.cols(), 4);
        assert_eq!(sh.y.rows(), sizes[i]);
        assert_eq!(sh.offset, lean.shard_offset(i));
    }
    // same seed ⇒ identical regeneration, every time
    let again = LeanDataset::new(4, 10.0, sizes, &mut Rng::new(10));
    assert_eq!(again.beta_star(), lean.beta_star());
    for i in 0..3 {
        assert_eq!(again.shard(i).x, lean.shard(i).x);
        assert_eq!(again.shard(i).y, lean.shard(i).y);
    }
    // distinct shards draw from decorrelated streams
    assert_ne!(lean.shard(0).x.row(0), lean.shard(1).x.row(0));
}

#[test]
fn lean_shard_view_prefix_is_bitwise_stable() {
    let lean = LeanDataset::new(6, 0.0, vec![40, 25], &mut Rng::new(11));
    for i in 0..2 {
        let full = lean.shard(i);
        for k in [1usize, 7, 25] {
            let view = lean.shard_view(i, k);
            assert_eq!(view.rows(), k);
            for r in 0..k {
                assert_eq!(view.x.row(r), full.x.row(r), "shard {i} x row {r} at k={k}");
                assert_eq!(view.y.row(r), full.y.row(r), "shard {i} y row {r} at k={k}");
            }
        }
    }
}

#[test]
fn lean_labels_follow_the_model() {
    // y − Xβ* must be N(0, σ²) noise: check empirical variance
    let lean = LeanDataset::new(8, 0.0, vec![4000], &mut Rng::new(12));
    let sh = lean.shard(0);
    let signal = crate::linalg::matmul(&sh.x, lean.beta_star());
    let noise_sq = sh.y.dist_sq(&signal) / 4000.0;
    assert!((noise_sq - 1.0).abs() < 0.1, "noise var {noise_sq} not ≈ 1");
}

#[test]
#[should_panic(expected = "exceeds shard")]
fn lean_view_rejects_overrun() {
    let lean = LeanDataset::new(2, 0.0, vec![5], &mut Rng::new(13));
    lean.shard_view(0, 6);
}
