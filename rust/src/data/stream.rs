//! Memory-lean dataset representation for million-device fleets.
//!
//! [`Dataset::generate`](super::Dataset::generate) + [`split`](super::split)
//! materialize the full m×d feature matrix and hold every device's shard
//! resident for the whole run — at the paper's scale (7200×500) that is
//! ~14 MB, but a million-device fleet at 4 points/device and d = 16 would
//! be 4M×16 f32 ≈ 256 MB of features *plus* a second copy sliced into
//! shards. [`LeanDataset`] stores none of it: each device holds only a
//! *shard descriptor* — a row count, a global offset, and a deterministic
//! RNG stream id — and shard contents are regenerated on demand, one
//! device at a time, from a per-shard counter-mode stream.
//!
//! # Prefix property
//!
//! Each shard draws from **two** split substreams: one for features, one
//! for label noise. Features fill row-major, noise is added one draw per
//! row — so materializing only the first `k` rows of a shard (a device's
//! assigned load ℓᵢ ≤ shard size) consumes prefixes of both streams and
//! is **bitwise identical** to the first `k` rows of the fully
//! materialized shard. Per-epoch gradient evaluation can therefore stream
//! exactly the rows it needs.
//!
//! Lean shards are generated per-shard rather than sliced from one global
//! matrix, so their bytes differ from [`Dataset`]'s (same distribution,
//! different RNG consumption order). That is why lean mode is a separate
//! [`DataMode`](crate::config::DataMode) — the materialized path remains
//! byte-identical to previous releases.

use super::Shard;
use crate::linalg::{matmul, Mat};
use crate::rng::{mix_seed, Rng};

/// The global regression problem held as generator state: β*, the noise
/// level, and one descriptor per shard. Total resident size is O(d + n),
/// independent of the number of data points.
#[derive(Clone, Debug)]
pub struct LeanDataset {
    /// Ground-truth model β*, d×1 — shared NMSE reference, always resident.
    beta_star: Mat,
    /// Noise standard deviation (same per-element SNR convention as
    /// [`Dataset`](super::Dataset)).
    noise_std: f64,
    /// Root of the per-shard stream family.
    stream_root: u64,
    /// Rows held by each shard.
    sizes: Vec<usize>,
    /// First global row index of each shard (prefix sums of `sizes`).
    offsets: Vec<usize>,
}

impl LeanDataset {
    /// Build descriptors for shards of the given `sizes` over a `d`-dim
    /// problem at `snr_db`. Draws β* and the stream root from `rng`;
    /// no data rows are generated here.
    pub fn new(d: usize, snr_db: f64, sizes: Vec<usize>, rng: &mut Rng) -> Self {
        let mut beta_rng = rng.split(0xBE7A);
        let beta_star = Mat::randn(d, 1, &mut beta_rng);
        let stream_root = rng.split(0x57E4).next_u64();
        let noise_std = 10f64.powf(-snr_db / 20.0);
        let mut offsets = Vec::with_capacity(sizes.len());
        let mut acc = 0usize;
        for &s in &sizes {
            offsets.push(acc);
            acc += s;
        }
        Self { beta_star, noise_std, stream_root, sizes, offsets }
    }

    pub fn n_shards(&self) -> usize {
        self.sizes.len()
    }

    pub fn dim(&self) -> usize {
        self.beta_star.rows()
    }

    /// Total rows across all shards (m of the paper).
    pub fn rows(&self) -> usize {
        self.sizes.iter().sum()
    }

    pub fn noise_std(&self) -> f64 {
        self.noise_std
    }

    pub fn beta_star(&self) -> &Mat {
        &self.beta_star
    }

    /// Rows held by shard `i`.
    pub fn shard_rows(&self, i: usize) -> usize {
        self.sizes[i]
    }

    /// First global row index of shard `i`.
    pub fn shard_offset(&self, i: usize) -> usize {
        self.offsets[i]
    }

    /// Materialize the first `rows` rows of shard `i` (ℓᵢ-row view).
    /// Bitwise-stable under the prefix property: the result's rows equal
    /// the corresponding rows of the full shard regardless of `rows`.
    pub fn shard_view(&self, i: usize, rows: usize) -> Shard {
        assert!(rows <= self.sizes[i], "view of {rows} rows exceeds shard {i}");
        let base = Rng::new(mix_seed(self.stream_root, i as u64));
        let mut x_rng = base.split(1);
        let mut noise_rng = base.split(2);
        let x = Mat::randn(rows, self.dim(), &mut x_rng);
        let mut y = matmul(&x, &self.beta_star);
        for v in y.as_mut_slice() {
            *v += (self.noise_std * noise_rng.normal()) as f32;
        }
        Shard { x, y, offset: self.offsets[i] }
    }

    /// Materialize all of shard `i`.
    pub fn shard(&self, i: usize) -> Shard {
        self.shard_view(i, self.sizes[i])
    }
}
