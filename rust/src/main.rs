//! `cfl` — Coded Federated Learning coordinator CLI.
//!
//! Subcommands:
//! * `train`    — run CFL (and optionally the uncoded baseline) on a
//!   configured problem; prints the convergence summary and writes
//!   NMSE-vs-time CSV traces.
//! * `optimize` — solve the Eq. 13–16 load/redundancy policy and print it.
//! * `live`     — run the threaded live-cluster demo.
//!
//! Configuration: paper-scale defaults (`--paper`) or test-scale
//! (`--small`, default), overridable by an INI file (`--config`) and then
//! by individual flags.

use anyhow::Result;
use cfl::cli::Parser;
use cfl::config::{ExperimentConfig, Ini};
use cfl::coordinator::{LiveCoordinator, SimCoordinator};
use cfl::metrics::Table;

fn parser() -> Parser {
    Parser::new("cfl — Coded Federated Learning (Dhakal et al., GLOBECOM'19 Workshops)")
        .subcommand("train", "train CFL (+ uncoded baseline) and report convergence")
        .subcommand("optimize", "print the load/redundancy policy (Eqs. 13-16)")
        .subcommand("live", "threaded live-cluster demo")
        .opt("config", "file.ini", "INI config file ([experiment] section)")
        .opt("seed", "u64", "root seed (default from config)")
        .opt("delta", "f64|auto", "coding redundancy δ = c/m (default: optimizer)")
        .opt("nu-comp", "f64", "compute heterogeneity in [0,1)")
        .opt("nu-link", "f64", "link heterogeneity in [0,1)")
        .opt("epochs", "usize", "max training epochs")
        .opt("target-nmse", "f64", "stopping NMSE")
        .opt("artifacts", "dir", "PJRT artifacts directory (default: native backend)")
        .opt("out", "dir", "output directory for CSV traces (default: results)")
        .opt("time-scale", "f64", "live mode: simulated→wall seconds factor")
        .flag("paper", "use the paper's §IV scale (24 devices, d=500)")
        .flag("skip-uncoded", "train: skip the uncoded baseline")
        .flag("quiet", "suppress the per-curve trace files")
}

fn build_config(args: &cfl::cli::Args) -> Result<ExperimentConfig> {
    let mut cfg =
        if args.has_flag("paper") { ExperimentConfig::paper() } else { ExperimentConfig::small() };
    if let Some(path) = args.get("config") {
        cfg.apply_ini(&Ini::load(path)?)?;
    }
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(s) = args.get("delta") {
        cfg.delta = if s.eq_ignore_ascii_case("auto") { None } else { Some(s.parse()?) };
    }
    cfg.nu_comp = args.get_or("nu-comp", cfg.nu_comp)?;
    cfg.nu_link = args.get_or("nu-link", cfg.nu_link)?;
    cfg.max_epochs = args.get_or("epochs", cfg.max_epochs)?;
    cfg.target_nmse = args.get_or("target-nmse", cfg.target_nmse)?;
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = Some(dir.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let out_dir = args.get_or("out", "results".to_string())?;
    let mut sim = SimCoordinator::new(&cfg)?;
    println!(
        "cfl train: n={} d={} m={} ν=({}, {}) backend={} seed={:#x}",
        cfg.n_devices,
        cfg.model_dim,
        cfg.total_points(),
        cfg.nu_comp,
        cfg.nu_link,
        sim.backend_name(),
        cfg.seed
    );

    let ls = sim.ls_bound()?;
    let coded = sim.train_cfl()?;
    let mut table = Table::new(&[
        "run", "δ", "t* (s)", "setup (s)", "epochs", "final NMSE", "t→target (s)",
    ]);
    let fmt_run = |r: &cfl::coordinator::RunResult| -> Vec<String> {
        vec![
            r.label.clone(),
            format!("{:.3}", r.delta),
            if r.epoch_deadline.is_finite() {
                format!("{:.3}", r.epoch_deadline)
            } else {
                "inf".into()
            },
            format!("{:.1}", r.setup_secs),
            format!("{}", r.epoch_times.len()),
            format!("{:.3e}", r.trace.final_nmse().unwrap_or(f64::NAN)),
            r.time_to(cfg.target_nmse).map(|t| format!("{t:.1}")).unwrap_or("—".into()),
        ]
    };
    table.row(&fmt_run(&coded));
    if !args.has_flag("quiet") {
        coded.trace.write_csv(&format!("{out_dir}/trace_cfl.csv"))?;
    }

    if !args.has_flag("skip-uncoded") {
        let uncoded = sim.train_uncoded()?;
        table.row(&fmt_run(&uncoded));
        if !args.has_flag("quiet") {
            uncoded.trace.write_csv(&format!("{out_dir}/trace_uncoded.csv"))?;
        }
        if let (Some(tc), Some(tu)) =
            (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
        {
            println!("coding gain at NMSE ≤ {:.1e}: {:.2}×", cfg.target_nmse, tu / tc);
        }
    }
    println!("LS bound NMSE: {ls:.3e}");
    println!("{}", table.render());
    if !args.has_flag("quiet") {
        println!("traces written to {out_dir}/");
    }
    Ok(())
}

fn cmd_optimize(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let sim = SimCoordinator::new(&cfg)?;
    let policy = sim.policy()?;
    println!(
        "policy: c = {} parity rows (δ = {:.3}), t* = {:.3} s, E[R] = {:.1} of m = {}",
        policy.parity_rows,
        policy.delta,
        policy.epoch_deadline,
        policy.expected_return,
        cfg.total_points()
    );
    let mut table = Table::new(&["device", "points", "load*", "P{miss}"]);
    for (i, (&load, &miss)) in policy.device_loads.iter().zip(&policy.miss_probs).enumerate() {
        table.row(&[
            format!("{i}"),
            format!("{}", sim.fleet.devices[i].points),
            format!("{load}"),
            format!("{miss:.3}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_live(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let scale = args.get_or("time-scale", 1e-3)?;
    let epochs = args.get_or("epochs", 100usize)?;
    println!("live cluster: {} device threads, time scale {scale}", cfg.n_devices);
    let report = LiveCoordinator::new(&cfg, scale).run(epochs)?;
    println!(
        "epochs={} wall={:.2}s on-time={} late={} final NMSE={:.3e}",
        report.epochs,
        report.wall_secs,
        report.on_time_gradients,
        report.late_gradients,
        report.final_nmse
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = parser().parse_env()?;
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("live") => cmd_live(&args),
        _ => {
            println!("{}", parser().help("cfl"));
            Ok(())
        }
    }
}
