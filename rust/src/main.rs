//! `cfl` — Coded Federated Learning coordinator CLI.
//!
//! Subcommands:
//! * `train`    — run CFL (and optionally the uncoded baseline) on a
//!   configured problem; prints the convergence summary and writes
//!   NMSE-vs-time CSV traces.
//! * `optimize` — solve the Eq. 13–16 load/redundancy policy and print it.
//! * `sweep`    — expand a scenario grid (INI `[sweep]` section and/or
//!   repeated `--axis key=v1,v2,…`; `--zip a+b` pairs correlated axes;
//!   `--scenario scale` starts from a named preset — the million-device
//!   scaling ladder of docs/SCALING.md)
//!   and run it on a worker pool; writes per-scenario CSV (streamed in
//!   grid order, so `--resume <csv>` restarts a killed grid where it
//!   left off) and an aggregate coding-gain report. `--traces-dir`
//!   exports each scenario's per-epoch NMSE trace. `--live` drives
//!   every scenario through the live coordinator instead of the DES
//!   backend (`--transport tcp` spawns real device subprocesses per
//!   scenario; `--placement file.ini` spreads the fleet across hosts);
//!   `--bench-out` adds the compact CI bench report.
//! * `live`     — run the threaded live-cluster demo.
//! * `serve`    — TCP coordinator: bind, wait for `cfl device` processes
//!   to connect, train, report.
//! * `device`   — TCP device worker: connect to a `cfl serve` master and
//!   compute partial gradients until the session shuts down. `--slots
//!   a,b,c` hosts several fleet slots over one connection; `--retry`
//!   rejoins after a lost link; `--persist` outlives Shutdown and waits
//!   for the next session.
//! * `bench-check` — compare a bench/sweep JSON report against a
//!   committed baseline and fail on coding-gain regressions (CI).
//! * `conformance` — run the cross-backend conformance suite: fixture
//!   corpus (sim vs live(chan) vs live(tcp), coded vs uncoded under
//!   declared tolerances), metamorphic invariants, and the device
//!   fault-injection matrix. `--full` adds the medium fixtures, a TCP
//!   leg per fixture, and the whole fault matrix; failures print a
//!   one-command replay line (`--only <id> --seed <s>`).
//! * `lint` — repo-native static analysis (docs/ANALYSIS.md): wall-clock
//!   discipline, obs-routed printing, panic-free fleet paths, total
//!   float ordering, seeded RNG, audited atomics. Exits nonzero on any
//!   finding, including stale suppressions.
//!
//! Configuration: paper-scale defaults (`--paper`) or test-scale
//! (`--small`, default), overridable by an INI file (`--config`) and then
//! by individual flags.

use anyhow::{Context, Result};
use cfl::cli::{Parsed, Parser};
use cfl::config::{ExperimentConfig, Ini};
use cfl::coordinator::{CoordinatorKind, LiveCoordinator, SimCoordinator};
use cfl::metrics::Table;
use cfl::sweep::{self, ScenarioGrid, SweepOptions};
use cfl::transport::{
    run_device, run_device_multi, run_device_multi_retry, run_device_retry, Placement, RetrySlots,
    TcpTransport, TransportKind,
};
use std::time::Duration;

fn parser() -> Parser {
    Parser::new("cfl — Coded Federated Learning (Dhakal et al., GLOBECOM'19 Workshops)")
        .subcommand("train", "train CFL (+ uncoded baseline) and report convergence")
        .subcommand("optimize", "print the load/redundancy policy (Eqs. 13-16)")
        .subcommand("sweep", "run a scenario grid in parallel and report coding gains")
        .subcommand("live", "threaded live-cluster demo")
        .subcommand("serve", "TCP coordinator: bind, wait for devices, train")
        .subcommand("device", "TCP device worker: join a cfl serve coordinator")
        .subcommand("bench-check", "compare a bench report against a committed baseline")
        .subcommand("conformance", "run the sim/live/tcp conformance suite (fixtures, invariants, faults)")
        .subcommand("lint", "repo-native static analysis (determinism, panic-freedom, atomics)")
        .opt("config", "file.ini", "INI config file ([experiment] + [sweep] sections)")
        .opt("seed", "u64", "root seed (default from config)")
        .opt("delta", "f64|auto", "coding redundancy δ = c/m (default: optimizer)")
        .opt("nu-comp", "f64", "compute heterogeneity in [0,1)")
        .opt("nu-link", "f64", "link heterogeneity in [0,1)")
        .opt("devices", "usize", "fleet size n_devices (default from config)")
        .opt("epochs", "usize", "max training epochs")
        .opt("target-nmse", "f64", "stopping NMSE")
        .opt("artifacts", "dir", "PJRT artifacts directory (default: native backend)")
        .opt("out", "dir", "output directory for CSV traces (default: results)")
        .opt("time-scale", "f64", "live/serve/sweep --live: simulated→wall seconds factor")
        .opt("scenario", "name", "sweep: start from a named preset grid (scale | scale-ci)")
        .opt("axis", "key=v1,v2,..", "sweep: add a grid axis (repeatable)")
        .opt("zip", "key1+key2", "sweep: pair declared axes so they sweep together (repeatable)")
        .opt("resume", "file.csv", "sweep: skip scenarios already in this CSV, run the rest")
        .opt("traces-dir", "dir", "sweep: write one per-epoch NMSE trace CSV per scenario")
        .opt("workers", "usize", "sweep: worker threads (default: all cores)")
        .opt("transport", "chan|tcp", "sweep --live: device transport (default chan)")
        .opt(
            "placement",
            "file.ini",
            "sweep --live --transport tcp / serve: cross-host slot manifest (docs/ARCHITECTURE.md)",
        )
        .opt("bench-out", "file.json", "sweep: also write the compact CI bench report")
        .opt("bind", "addr", "serve: listen address (default 127.0.0.1:7070; :0 = any port)")
        .opt("port-file", "path", "serve: write the bound address to this file")
        .opt("check-nmse", "f64", "serve: exit nonzero unless the final CFL NMSE ≤ this")
        .opt("connect", "addr", "device: coordinator address to join")
        .opt("id", "usize", "device: fleet slot to claim (default 0)")
        .opt("slots", "a,b,c", "device: claim several fleet slots over one connection")
        .opt("report", "file.json", "bench-check: current report (default BENCH_ci.json)")
        .opt("baseline", "file.json", "bench-check: baseline (default bench/baseline.json)")
        .opt("tolerance", "f64", "bench-check: allowed fractional gain drop (default 0.2)")
        .opt(
            "wall-tolerance",
            "f64|off",
            "bench-check: allowed fractional epochs/s drop (default 0.5; off = gain-only)",
        )
        .opt("only", "substr", "conformance: run only checks whose id contains this substring")
        .opt("rule", "id", "lint: run a single rule (ids in docs/ANALYSIS.md)")
        .opt("log-level", "error|warn|info|debug|trace", "stderr log level (default info; CFL_LOG env var works too)")
        .opt(
            "events-out",
            "path",
            "write structured JSONL events (sweep: a directory, one file per scenario; otherwise one file)",
        )
        .opt("trace-decimate", "N", "sweep --traces-dir: keep every Nth trace row (first and last always kept)")
        .flag("full", "conformance: run the full tier (tcp everywhere, medium fixtures, whole fault matrix)")
        .flag("json", "lint: emit JSONL findings and a summary line instead of text")
        .flag("retry", "device: reconnect with backoff after a lost link (rejoin the fleet)")
        .flag(
            "persist",
            "device: outlive Shutdown and await the next session (multi-scenario placement hosts)",
        )
        .flag("live", "sweep: run scenarios through the live coordinator")
        .flag("probe", "serve: just test that the address can be bound, then exit")
        .flag("paper", "use the paper's §IV scale (24 devices, d=500)")
        .flag("skip-uncoded", "train/serve/sweep: skip the uncoded baseline")
        .flag("quiet", "suppress trace files / sweep progress / device chatter")
}

/// Parse `--config` once; callers that need other sections (sweep) reuse
/// the same parsed document.
fn load_ini(args: &cfl::cli::Args) -> Result<Option<Ini>> {
    args.get("config").map(Ini::load).transpose()
}

/// Install the observability sinks before the subcommand runs.
///
/// Stderr renders events at `--log-level` (falling back to the `CFL_LOG`
/// env var, then to info — warn under `--quiet`). `--events-out` adds a
/// JSONL sink that always captures at least debug (the exported trace is
/// the point of asking for it): a directory with one file per scenario
/// for `sweep`, a single file for every other subcommand.
fn init_obs(args: &cfl::cli::Args) -> Result<()> {
    use cfl::obs::{self, Level, Sink};
    use std::sync::Arc;
    let explicit = match args.get("log-level") {
        Some(s) => Some(Level::parse(s)?),
        None => match std::env::var("CFL_LOG") {
            Ok(s) => Some(Level::parse(&s).context("CFL_LOG")?),
            Err(_) => None,
        },
    };
    let stderr_level = explicit
        .unwrap_or(if args.has_flag("quiet") { Level::Warn } else { Level::Info });
    let stderr_sink: Arc<dyn Sink> = Arc::new(obs::StderrSink);
    let mut sinks: Vec<(Arc<dyn Sink>, Level)> = vec![(stderr_sink, stderr_level)];
    if let Some(path) = args.get("events-out") {
        let file_level = match explicit {
            Some(l) if (l as u8) > (Level::Debug as u8) => l,
            _ => Level::Debug,
        };
        let sink: Arc<dyn Sink> = if args.subcommand() == Some("sweep") {
            Arc::new(obs::JsonlDirSink::create(path)?)
        } else {
            Arc::new(obs::JsonlFileSink::create(path)?)
        };
        sinks.push((sink, file_level));
    }
    obs::install(sinks);
    Ok(())
}

fn build_config(args: &cfl::cli::Args) -> Result<ExperimentConfig> {
    build_config_with(args, load_ini(args)?.as_ref())
}

fn build_config_with(args: &cfl::cli::Args, ini: Option<&Ini>) -> Result<ExperimentConfig> {
    let mut cfg =
        if args.has_flag("paper") { ExperimentConfig::paper() } else { ExperimentConfig::small() };
    if let Some(ini) = ini {
        cfg.apply_ini(ini)?;
    }
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(s) = args.get("delta") {
        cfg.delta = if s.eq_ignore_ascii_case("auto") { None } else { Some(s.parse()?) };
    }
    cfg.nu_comp = args.get_or("nu-comp", cfg.nu_comp)?;
    cfg.nu_link = args.get_or("nu-link", cfg.nu_link)?;
    cfg.n_devices = args.get_or("devices", cfg.n_devices)?;
    cfg.max_epochs = args.get_or("epochs", cfg.max_epochs)?;
    cfg.target_nmse = args.get_or("target-nmse", cfg.target_nmse)?;
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = Some(dir.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let out_dir = args.get_or("out", "results".to_string())?;
    let mut sim = SimCoordinator::new(&cfg)?;
    println!(
        "cfl train: n={} d={} m={} ν=({}, {}) backend={} seed={:#x}",
        cfg.n_devices,
        cfg.model_dim,
        cfg.total_points(),
        cfg.nu_comp,
        cfg.nu_link,
        sim.backend_name(),
        cfg.seed
    );

    let ls = sim.ls_bound()?;
    let coded = sim.train_cfl()?;
    let mut table = Table::new(&[
        "run", "δ", "t* (s)", "setup (s)", "epochs", "final NMSE", "t→target (s)",
    ]);
    let fmt_run = |r: &cfl::coordinator::RunResult| -> Vec<String> {
        vec![
            r.label.clone(),
            format!("{:.3}", r.delta),
            if r.epoch_deadline.is_finite() {
                format!("{:.3}", r.epoch_deadline)
            } else {
                "inf".into()
            },
            format!("{:.1}", r.setup_secs),
            format!("{}", r.epoch_times.len()),
            format!("{:.3e}", r.trace.final_nmse().unwrap_or(f64::NAN)),
            r.time_to(cfg.target_nmse).map(|t| format!("{t:.1}")).unwrap_or("—".into()),
        ]
    };
    table.row(&fmt_run(&coded));
    if !args.has_flag("quiet") {
        coded.write_trace_csv(&format!("{out_dir}/trace_cfl.csv"))?;
    }

    if !args.has_flag("skip-uncoded") {
        let uncoded = sim.train_uncoded()?;
        table.row(&fmt_run(&uncoded));
        if !args.has_flag("quiet") {
            uncoded.write_trace_csv(&format!("{out_dir}/trace_uncoded.csv"))?;
        }
        if let (Some(tc), Some(tu)) =
            (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
        {
            println!("coding gain at NMSE ≤ {:.1e}: {:.2}×", cfg.target_nmse, tu / tc);
        }
    }
    println!("LS bound NMSE: {ls:.3e}");
    println!("{}", table.render());
    if !args.has_flag("quiet") {
        println!("traces written to {out_dir}/");
    }
    Ok(())
}

fn cmd_optimize(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let sim = SimCoordinator::new(&cfg)?;
    let policy = sim.policy()?;
    println!(
        "policy: c = {} parity rows (δ = {:.3}), t* = {:.3} s, E[R] = {:.1} of m = {}",
        policy.parity_rows,
        policy.delta,
        policy.epoch_deadline,
        policy.expected_return,
        cfg.total_points()
    );
    let mut table = Table::new(&["device", "points", "load*", "P{miss}"]);
    for (i, (&load, &miss)) in policy.device_loads.iter().zip(&policy.miss_probs).enumerate() {
        table.row(&[
            format!("{i}"),
            format!("{}", sim.fleet().devices[i].points),
            format!("{load}"),
            format!("{miss:.3}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_sweep(args: &cfl::cli::Args) -> Result<()> {
    let ini = load_ini(args)?;
    // --scenario: start from a named preset grid (its own base config and
    // axes) instead of the flag/INI-built base; --axis/--zip still extend
    // it. Without a preset the grid's base comes from --config + flags.
    let preset = args.get("scenario").map(sweep::scenario_preset).transpose()?;
    let mut grid = match &preset {
        Some(p) => {
            println!("cfl sweep scenario '{}': {}", p.name, p.about);
            p.grid.clone()
        }
        None => ScenarioGrid::new(&build_config_with(args, ini.as_ref())?),
    };
    if let Some(ini) = &ini {
        grid = grid.with_ini(ini)?;
    }
    for spec in args.get_all("axis") {
        grid = grid.axis_spec(spec)?;
    }
    for spec in args.get_all("zip") {
        grid = grid.zip_spec(spec)?;
    }
    anyhow::ensure!(
        !grid.axes().is_empty(),
        "sweep needs at least one axis: repeat --axis key=v1,v2,..., add a [sweep] \
         section to --config, or pick a preset with --scenario"
    );

    let transport = match args.get("transport") {
        Some(spec) => {
            anyhow::ensure!(
                args.has_flag("live"),
                "--transport only applies to --live sweeps (the sim backend has no wire)"
            );
            TransportKind::parse(spec)?
        }
        None => TransportKind::Channel,
    };
    let placement = match args.get("placement") {
        Some(path) => {
            anyhow::ensure!(
                args.has_flag("live") && transport == TransportKind::Tcp,
                "--placement requires --live --transport tcp (it maps fleet slots onto hosts)"
            );
            Some(Placement::load(path)?)
        }
        None => None,
    };
    let backend = if args.has_flag("live") {
        CoordinatorKind::Live {
            time_scale: args.get_or("time-scale", 1e-3)?,
            transport,
            placement,
        }
    } else {
        CoordinatorKind::Sim
    };
    // sim precedence: --workers flag > [sweep] workers > all cores. The
    // live backend always runs one scenario at a time (enforced by the
    // runner — concurrent live scenarios would oversubscribe the host and
    // drop gradients as artificial stragglers).
    let workers = match backend {
        CoordinatorKind::Live { .. } => 1,
        CoordinatorKind::Sim => {
            let mut default_workers =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            if let Some(ini) = &ini {
                default_workers = ini.get_or("sweep", "workers", default_workers)?;
            }
            args.get_or("workers", default_workers)?
        }
    };
    let out_dir = args.get_or("out", "results".to_string())?;
    // stdout stays a pure function of the grid (byte-identical for any
    // --workers under the sim backend); runtime details go to stderr
    println!(
        "cfl sweep ({}): {} axes → {} scenarios",
        backend.tag(),
        grid.axes().len(),
        grid.len()
    );
    for axis in grid.axes() {
        println!("  axis {} = [{}]", axis.key, axis.values.join(", "));
    }
    for group in grid.zip_keys() {
        println!("  zip {}", group.join("+"));
    }
    cfl::obs_event!(Info, "sweep_start", workers = workers, scenarios = grid.len());
    // touch the fleet-traffic counters up front so the end-of-sweep
    // metrics snapshot carries the same keys for every backend (a sim
    // sweep sends no frames; zeros say so explicitly)
    {
        let reg = cfl::obs::registry();
        for name in [
            "transport.frames_sent",
            "transport.frames_recv",
            "transport.bytes_sent",
            "transport.bytes_recv",
            "transport.reactor.wakeups",
            "transport.reactor.readable",
            "transport.reactor.writable",
            "transport.reactor.backpressure_closes",
        ] {
            reg.counter(name);
        }
    }

    // a lean-mode preset cannot run the uncoded baseline (it needs the
    // dataset resident), so presets carry their own baseline policy
    let preset_uncoded = preset.as_ref().map(|p| p.uncoded_baseline).unwrap_or(true);
    let opts = SweepOptions {
        workers,
        uncoded_baseline: !args.has_flag("skip-uncoded") && preset_uncoded,
        progress: !args.has_flag("quiet"),
        backend,
    };

    // --resume: recover completed rows from the prior run's CSV (and
    // their report records from its sidecar) and run only the remainder;
    // a missing file just means nothing completed
    let header = sweep::scenario_csv_header(&grid);
    let scenarios = grid.expand()?;
    let (resume, records) = match args.get("resume") {
        Some(path) if std::path::Path::new(path).exists() => {
            let mut state = sweep::ResumeState::load(path, &header)?;
            // same columns is necessary but not sufficient: each row's
            // config fingerprint must match this grid's scenario too
            state.check_compat(&scenarios)?;
            // the record sidecar is what lets --resume regenerate the
            // JSON/bench reports too; a CSV row whose record is missing
            // (torn sidecar line) is simply re-run so all three
            // artifacts stay consistent. A sidecar-less CSV (from a
            // pre-sidecar sweep) still resumes, falling back to
            // fresh-outcome-only reports.
            let side = sweep::sidecar_path(path);
            let records = if std::path::Path::new(&side).exists() {
                let records = sweep::SidecarRecords::load(&side)?;
                state.retain(|id| records.contains(id));
                records
            } else {
                cfl::obs_event!(Warn, "resume_sidecar_missing", sidecar = side.as_str());
                sweep::SidecarRecords::empty()
            };
            let recovered = scenarios.iter().filter(|s| state.contains(&s.id)).count();
            cfl::obs_event!(Info, "resume_recovered", recovered = recovered, csv = path);
            if state.len() > recovered {
                cfl::obs_event!(
                    Warn,
                    "resume_foreign_rows_ignored",
                    ignored = state.len() - recovered,
                    csv = path,
                );
            }
            (state, records)
        }
        Some(path) => {
            cfl::obs_event!(Info, "resume_csv_missing", csv = path);
            (sweep::ResumeState::empty(), sweep::SidecarRecords::empty())
        }
        None => (sweep::ResumeState::empty(), sweep::SidecarRecords::empty()),
    };
    let ids: Vec<String> = scenarios.iter().map(|s| s.id.clone()).collect();
    let todo: Vec<_> = scenarios.into_iter().filter(|s| !resume.contains(&s.id)).collect();

    // the CSV streams to disk in grid order as scenarios complete, so a
    // killed sweep keeps every finished row for the next --resume
    let csv_path = format!("{out_dir}/sweep_scenarios.csv");
    let traces_dir = args.get("traces-dir");
    let decimate = args.get_or("trace-decimate", 1usize)?;
    anyhow::ensure!(decimate >= 1, "--trace-decimate must be ≥ 1, got {decimate}");
    if let Some(dir) = traces_dir {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir}"))?;
    }
    let mut merged = sweep::MergedScenarioCsv::create(&csv_path, &header, &ids, &resume)?;
    let mut recs =
        sweep::RecordLog::create(&sweep::sidecar_path(&csv_path), &ids, &resume, &records)?;
    let outcomes = sweep::run_scenarios_streaming(todo, &opts, |o| {
        merged.push(o)?;
        recs.push(o)?;
        if let Some(dir) = traces_dir {
            sweep::write_outcome_traces_decimated(dir, o, decimate)?;
        }
        Ok(())
    })?;
    merged.finish()?;

    let json_path = format!("{out_dir}/sweep_report.json");
    match recs.finish()? {
        Some(pairs) => {
            let (sweep_recs, bench_recs): (Vec<String>, Vec<String>) =
                pairs.into_iter().unzip();
            sweep::write_json_records(&json_path, &grid, &sweep_recs)?;
            if let Some(bench_path) = args.get("bench-out") {
                sweep::write_bench_json_records(bench_path, &bench_recs)?;
                cfl::obs_event!(Info, "bench_report_written", path = bench_path);
            }
        }
        None => {
            // pre-sidecar resume: the recovered scenarios' records are
            // gone, so the reports cover the freshly-run remainder only
            // (the merged CSV is still complete)
            cfl::obs_event!(Warn, "resume_reports_fresh_only", json = json_path.as_str());
            sweep::write_json(&json_path, &grid, &outcomes)?;
            if let Some(bench_path) = args.get("bench-out") {
                sweep::write_bench_json(bench_path, &outcomes)?;
                cfl::obs_event!(Info, "bench_report_written", path = bench_path);
            }
        }
    }
    if !resume.is_empty() {
        cfl::obs_event!(
            Info,
            "resume_summary_partial",
            fresh = outcomes.len(),
            merged_total = ids.len(),
            csv = csv_path.as_str(),
        );
    }
    if let Some(dir) = traces_dir {
        cfl::obs_event!(
            Info,
            "traces_written",
            dir = dir,
            scenarios = outcomes.len(),
            decimate = decimate,
        );
    }
    // the memory high-water mark is part of the scale-smoke contract:
    // record it as a gauge (Linux VmHWM) and print it alongside the wall
    // summary so budget gates can grep a single line
    if let Some(bytes) = cfl::obs::record_peak_rss() {
        println!("peak RSS: {:.1} MiB", bytes as f64 / (1024.0 * 1024.0));
    }
    cfl::obs::emit_metrics_snapshot();

    println!("{}", sweep::summary_table(&outcomes).render());
    if let Some(matrix) = sweep::gain_matrix(&grid, &outcomes) {
        println!("coding gain matrix (t_uncoded / t_CFL at target NMSE):");
        println!("{}", matrix.render());
    }
    match sweep::gain_stats(&outcomes) {
        Some((stats, best)) => println!(
            "gain over {} scenario(s): mean {:.2}×, min {:.2}×, max {:.2}× (best: {best})",
            stats.count(),
            stats.mean(),
            stats.min(),
            stats.max()
        ),
        None => println!("no scenario reached its target NMSE in both runs — no gains"),
    }
    println!("reports written to {csv_path} and {json_path}");
    Ok(())
}

fn cmd_live(args: &cfl::cli::Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    let scale = args.get_or("time-scale", 1e-3)?;
    // build_config already honored --epochs and any [experiment]
    // max_epochs. Only when the user supplied neither (pure built-in
    // defaults) cap the demo at 100 epochs so the training-scale
    // defaults don't run for minutes of wall sleep.
    if args.get("epochs").is_none() && args.get("config").is_none() {
        cfg.max_epochs = cfg.max_epochs.min(100);
    }
    println!("live cluster: {} device threads, time scale {scale}", cfg.n_devices);
    let report = LiveCoordinator::new(&cfg, scale)?.train_cfl()?;
    println!(
        "epochs={} wall={:.2}s on-time={} late={} final NMSE={:.3e}",
        report.epoch_times.len(),
        report.wall_secs,
        report.on_time_gradients,
        report.late_gradients,
        report.trace.final_nmse().unwrap_or(f64::NAN)
    );
    Ok(())
}

fn cmd_serve(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let placement = args.get("placement").map(Placement::load).transpose()?;
    // bind precedence: explicit --bind, else the manifest's bind, else
    // the loopback default
    let bind = args
        .get("bind")
        .or_else(|| placement.as_ref().and_then(Placement::explicit_bind))
        .unwrap_or("127.0.0.1:7070");
    let listener =
        std::net::TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    let addr = listener.local_addr().context("reading the bound address")?;
    if args.has_flag("probe") {
        // smoke scripts use this to detect sandboxes that deny bind
        println!("probe ok: {addr}");
        return Ok(());
    }
    if let Some(path) = args.get("port-file") {
        // publish atomically (write a sibling temp file, then rename):
        // a device polling the path must see either nothing or the full
        // address — never a torn/empty file between create and write
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, format!("{addr}\n")).with_context(|| format!("writing {tmp}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing {tmp} as {path}"))?;
    }
    let scale = args.get_or("time-scale", 1e-3)?;
    println!(
        "cfl serve: listening on {addr}, waiting for {} device(s) (cfl device --connect {addr} \
         --id K)",
        cfg.n_devices
    );
    let transport = match &placement {
        Some(p) => {
            // the manifest's local slots become one child process; its
            // remote slots are announced and awaited
            let bin = cfl::transport::local_device_bin()?;
            TcpTransport::serve_placed(listener, cfg.n_devices, p, &bin)?
        }
        None => TcpTransport::serve(listener, cfg.n_devices, Duration::from_secs(60))?,
    };
    let mut live = LiveCoordinator::with_transport(&cfg, scale, Box::new(transport))?;

    let coded = live.train_cfl()?;
    let n_devices = cfg.n_devices;
    let report = move |run: &cfl::coordinator::RunResult| {
        println!(
            "{}: epochs={} wall={:.2}s on-time={} late={} disconnects={} rejoins={} \
             members={}/{} final NMSE={:.3e}",
            run.label,
            run.epoch_times.len(),
            run.wall_secs,
            run.on_time_gradients,
            run.late_gradients,
            run.disconnects,
            run.rejoins,
            run.epoch_members.last().copied().unwrap_or(0),
            n_devices,
            run.trace.final_nmse().unwrap_or(f64::NAN)
        );
    };
    report(&coded);
    if !args.has_flag("skip-uncoded") {
        let uncoded = live.train_uncoded()?;
        report(&uncoded);
        if let (Some(tc), Some(tu)) =
            (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
        {
            println!("coding gain at NMSE ≤ {:.1e}: {:.2}×", cfg.target_nmse, tu / tc);
        }
    }
    if let Some(spec) = args.get("check-nmse") {
        let cap: f64 = spec.parse().with_context(|| format!("--check-nmse '{spec}'"))?;
        let got = coded.trace.final_nmse().unwrap_or(f64::NAN);
        anyhow::ensure!(got <= cap, "final NMSE {got:.3e} above the required {cap:.3e}");
        println!("check-nmse ok: {got:.3e} ≤ {cap:.3e}");
    }
    // fleet-traffic totals and phase histograms for the whole session
    cfl::obs::emit_metrics_snapshot();
    Ok(())
}

fn cmd_device(args: &cfl::cli::Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("cfl device needs --connect HOST:PORT"))?;
    let quiet = args.has_flag("quiet");
    let retry = args.has_flag("retry");
    let persist = args.has_flag("persist");
    let connect_timeout = Duration::from_secs(10);
    // --slots: one process, one connection, several fleet slots (the
    // placement-manifest host invocation)
    if let Some(spec) = args.get("slots") {
        anyhow::ensure!(
            args.get("id").is_none(),
            "--id and --slots are mutually exclusive (slots already name the claims)"
        );
        let slots = parse_slots(spec)?;
        let rep = slots.first().copied().unwrap_or(0);
        cfl::obs_event!(Info, "device_connecting", device = rep, addr = addr, slots = spec);
        if retry || persist {
            run_device_multi_retry(addr, RetrySlots::Multi(slots), connect_timeout, quiet, persist)?;
        } else {
            run_device_multi(addr, &slots, connect_timeout)?;
        }
        cfl::obs_event!(Info, "device_session_over", device = rep);
        return Ok(());
    }
    let id = args.get_or("id", 0usize)?;
    cfl::obs_event!(Info, "device_connecting", device = id, addr = addr);
    if persist {
        // outliving Shutdown implies the reconnect loop
        run_device_multi_retry(addr, RetrySlots::Single(id), connect_timeout, quiet, true)?;
    } else if retry {
        // survive a lost link: reconnect with backoff and re-claim the
        // slot until the coordinator sends an explicit Shutdown
        run_device_retry(addr, id, connect_timeout, quiet)?;
    } else {
        run_device(addr, id, connect_timeout)?;
    }
    cfl::obs_event!(Info, "device_session_over", device = id);
    Ok(())
}

/// Parse a `--slots a,b,c` list.
fn parse_slots(spec: &str) -> Result<Vec<usize>> {
    let slots: Vec<usize> = spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().with_context(|| format!("--slots '{spec}'")))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!slots.is_empty(), "--slots '{spec}' names no slots");
    Ok(slots)
}

fn cmd_bench_check(args: &cfl::cli::Args) -> Result<()> {
    let report = args.get("report").unwrap_or("BENCH_ci.json");
    let baseline = args.get("baseline").unwrap_or("bench/baseline.json");
    let tolerance = args.get_or("tolerance", 0.2)?;
    // the wall-clock gate defaults on with a loose 50% floor (CI hosts
    // are noisy; the gate is for halvings, not jitter); it only fires
    // for baseline scenarios that record an epochs_per_sec
    let wall_tolerance = match args.get("wall-tolerance") {
        Some(s) if s.eq_ignore_ascii_case("off") => None,
        Some(s) => Some(s.parse::<f64>().with_context(|| format!("--wall-tolerance '{s}'"))?),
        None => Some(0.5),
    };
    let current = std::fs::read_to_string(report).with_context(|| format!("reading {report}"))?;
    let base =
        std::fs::read_to_string(baseline).with_context(|| format!("reading {baseline}"))?;
    let table = sweep::check_regression(&base, &current, tolerance, wall_tolerance)?;
    println!("bench-check ok ({report} vs {baseline}, tolerance {tolerance}):");
    println!("{table}");
    Ok(())
}

fn cmd_conformance(args: &cfl::cli::Args) -> Result<()> {
    use cfl::conformance::{self, Options};
    let seed = args
        .get("seed")
        .map(|s| s.parse::<u64>().with_context(|| format!("--seed '{s}'")))
        .transpose()?;
    let opts = Options {
        full: args.has_flag("full"),
        only: args.get("only").map(String::from),
        seed,
        out_dir: Some(args.get_or("out", "results".to_string())?),
    };
    let report = conformance::run(&opts)?;
    println!("{}", conformance::render(&report));
    let (pass, fail, skip) = report.counts();
    let tier = if opts.full { "full" } else { "quick" };
    println!("conformance ({tier} tier): {pass} passed, {fail} failed, {skip} skipped");
    for c in report.failures() {
        println!("  FAIL {} — replay: {}", c.id, c.replay);
    }
    anyhow::ensure!(report.passed(), "{fail} conformance check(s) failed");
    Ok(())
}

/// `cfl lint [--json] [--rule <id>] [paths…]` — walk the tree (or the
/// given files/dirs), run every rule, print findings, and exit nonzero
/// if any survive their suppressions (stale allows included).
fn cmd_lint(args: &cfl::cli::Args) -> Result<()> {
    use cfl::analysis;
    let roots: Vec<std::path::PathBuf> = if args.positional().is_empty() {
        analysis::default_roots()
    } else {
        args.positional().iter().map(std::path::PathBuf::from).collect()
    };
    let report = analysis::run_paths(&roots, args.get("rule"))?;
    if args.has_flag("json") {
        print!("{}", analysis::render_json(&report));
    } else {
        print!("{}", analysis::render_text(&report));
    }
    anyhow::ensure!(
        report.clean(),
        "lint found {} problem(s) — fix them or allow with a reason",
        report.findings.len()
    );
    Ok(())
}

fn main() -> Result<()> {
    // --help is a parse outcome, not a parser-side exit (see cli docs) —
    // rendering and terminating are this binary's decisions alone
    let args = match parser().parse_env()? {
        Parsed::Run(args) => args,
        Parsed::Help { program } => {
            println!("{}", parser().help(&program));
            return Ok(());
        }
    };
    init_obs(&args)?;
    let result = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("live") => cmd_live(&args),
        Some("serve") => cmd_serve(&args),
        Some("device") => cmd_device(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("conformance") => cmd_conformance(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            println!("{}", parser().help("cfl"));
            Ok(())
        }
    };
    // flush buffered JSONL lines and tear the sinks down even on error
    cfl::obs::shutdown();
    result
}
