//! `cfl` — Coded Federated Learning coordinator CLI.
//!
//! Subcommands:
//! * `train`    — run CFL (and optionally the uncoded baseline) on a
//!   configured problem; prints the convergence summary and writes
//!   NMSE-vs-time CSV traces.
//! * `optimize` — solve the Eq. 13–16 load/redundancy policy and print it.
//! * `sweep`    — expand a scenario grid (INI `[sweep]` section and/or
//!   repeated `--axis key=v1,v2,…`) and run it on a worker pool; writes
//!   per-scenario CSV and an aggregate coding-gain report. `--live`
//!   drives every scenario through the threaded live coordinator instead
//!   of the DES backend.
//! * `live`     — run the threaded live-cluster demo.
//!
//! Configuration: paper-scale defaults (`--paper`) or test-scale
//! (`--small`, default), overridable by an INI file (`--config`) and then
//! by individual flags.

use anyhow::Result;
use cfl::cli::{Parsed, Parser};
use cfl::config::{ExperimentConfig, Ini};
use cfl::coordinator::{CoordinatorKind, LiveCoordinator, SimCoordinator};
use cfl::metrics::Table;
use cfl::sweep::{self, ScenarioGrid, SweepOptions};

fn parser() -> Parser {
    Parser::new("cfl — Coded Federated Learning (Dhakal et al., GLOBECOM'19 Workshops)")
        .subcommand("train", "train CFL (+ uncoded baseline) and report convergence")
        .subcommand("optimize", "print the load/redundancy policy (Eqs. 13-16)")
        .subcommand("sweep", "run a scenario grid in parallel and report coding gains")
        .subcommand("live", "threaded live-cluster demo")
        .opt("config", "file.ini", "INI config file ([experiment] + [sweep] sections)")
        .opt("seed", "u64", "root seed (default from config)")
        .opt("delta", "f64|auto", "coding redundancy δ = c/m (default: optimizer)")
        .opt("nu-comp", "f64", "compute heterogeneity in [0,1)")
        .opt("nu-link", "f64", "link heterogeneity in [0,1)")
        .opt("epochs", "usize", "max training epochs")
        .opt("target-nmse", "f64", "stopping NMSE")
        .opt("artifacts", "dir", "PJRT artifacts directory (default: native backend)")
        .opt("out", "dir", "output directory for CSV traces (default: results)")
        .opt("time-scale", "f64", "live/sweep --live: simulated→wall seconds factor")
        .opt("axis", "key=v1,v2,..", "sweep: add a grid axis (repeatable)")
        .opt("workers", "usize", "sweep: worker threads (default: all cores)")
        .flag("live", "sweep: run scenarios through the threaded live coordinator")
        .flag("paper", "use the paper's §IV scale (24 devices, d=500)")
        .flag("skip-uncoded", "train/sweep: skip the uncoded baseline")
        .flag("quiet", "suppress trace files / sweep progress")
}

/// Parse `--config` once; callers that need other sections (sweep) reuse
/// the same parsed document.
fn load_ini(args: &cfl::cli::Args) -> Result<Option<Ini>> {
    args.get("config").map(Ini::load).transpose()
}

fn build_config(args: &cfl::cli::Args) -> Result<ExperimentConfig> {
    build_config_with(args, load_ini(args)?.as_ref())
}

fn build_config_with(args: &cfl::cli::Args, ini: Option<&Ini>) -> Result<ExperimentConfig> {
    let mut cfg =
        if args.has_flag("paper") { ExperimentConfig::paper() } else { ExperimentConfig::small() };
    if let Some(ini) = ini {
        cfg.apply_ini(ini)?;
    }
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(s) = args.get("delta") {
        cfg.delta = if s.eq_ignore_ascii_case("auto") { None } else { Some(s.parse()?) };
    }
    cfg.nu_comp = args.get_or("nu-comp", cfg.nu_comp)?;
    cfg.nu_link = args.get_or("nu-link", cfg.nu_link)?;
    cfg.max_epochs = args.get_or("epochs", cfg.max_epochs)?;
    cfg.target_nmse = args.get_or("target-nmse", cfg.target_nmse)?;
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = Some(dir.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let out_dir = args.get_or("out", "results".to_string())?;
    let mut sim = SimCoordinator::new(&cfg)?;
    println!(
        "cfl train: n={} d={} m={} ν=({}, {}) backend={} seed={:#x}",
        cfg.n_devices,
        cfg.model_dim,
        cfg.total_points(),
        cfg.nu_comp,
        cfg.nu_link,
        sim.backend_name(),
        cfg.seed
    );

    let ls = sim.ls_bound()?;
    let coded = sim.train_cfl()?;
    let mut table = Table::new(&[
        "run", "δ", "t* (s)", "setup (s)", "epochs", "final NMSE", "t→target (s)",
    ]);
    let fmt_run = |r: &cfl::coordinator::RunResult| -> Vec<String> {
        vec![
            r.label.clone(),
            format!("{:.3}", r.delta),
            if r.epoch_deadline.is_finite() {
                format!("{:.3}", r.epoch_deadline)
            } else {
                "inf".into()
            },
            format!("{:.1}", r.setup_secs),
            format!("{}", r.epoch_times.len()),
            format!("{:.3e}", r.trace.final_nmse().unwrap_or(f64::NAN)),
            r.time_to(cfg.target_nmse).map(|t| format!("{t:.1}")).unwrap_or("—".into()),
        ]
    };
    table.row(&fmt_run(&coded));
    if !args.has_flag("quiet") {
        coded.trace.write_csv(&format!("{out_dir}/trace_cfl.csv"))?;
    }

    if !args.has_flag("skip-uncoded") {
        let uncoded = sim.train_uncoded()?;
        table.row(&fmt_run(&uncoded));
        if !args.has_flag("quiet") {
            uncoded.trace.write_csv(&format!("{out_dir}/trace_uncoded.csv"))?;
        }
        if let (Some(tc), Some(tu)) =
            (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
        {
            println!("coding gain at NMSE ≤ {:.1e}: {:.2}×", cfg.target_nmse, tu / tc);
        }
    }
    println!("LS bound NMSE: {ls:.3e}");
    println!("{}", table.render());
    if !args.has_flag("quiet") {
        println!("traces written to {out_dir}/");
    }
    Ok(())
}

fn cmd_optimize(args: &cfl::cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let sim = SimCoordinator::new(&cfg)?;
    let policy = sim.policy()?;
    println!(
        "policy: c = {} parity rows (δ = {:.3}), t* = {:.3} s, E[R] = {:.1} of m = {}",
        policy.parity_rows,
        policy.delta,
        policy.epoch_deadline,
        policy.expected_return,
        cfg.total_points()
    );
    let mut table = Table::new(&["device", "points", "load*", "P{miss}"]);
    for (i, (&load, &miss)) in policy.device_loads.iter().zip(&policy.miss_probs).enumerate() {
        table.row(&[
            format!("{i}"),
            format!("{}", sim.fleet().devices[i].points),
            format!("{load}"),
            format!("{miss:.3}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_sweep(args: &cfl::cli::Args) -> Result<()> {
    let ini = load_ini(args)?;
    let cfg = build_config_with(args, ini.as_ref())?;
    let mut grid = ScenarioGrid::new(&cfg);
    if let Some(ini) = &ini {
        grid = grid.with_ini(ini)?;
    }
    for spec in args.get_all("axis") {
        grid = grid.axis_spec(spec)?;
    }
    anyhow::ensure!(
        !grid.axes().is_empty(),
        "sweep needs at least one axis: repeat --axis key=v1,v2,... or add a [sweep] \
         section to --config"
    );

    let backend = if args.has_flag("live") {
        CoordinatorKind::Live { time_scale: args.get_or("time-scale", 1e-3)? }
    } else {
        CoordinatorKind::Sim
    };
    // sim precedence: --workers flag > [sweep] workers > all cores. The
    // live backend always runs one scenario at a time (enforced by the
    // runner — concurrent live scenarios would oversubscribe the host and
    // drop gradients as artificial stragglers).
    let workers = match backend {
        CoordinatorKind::Live { .. } => 1,
        CoordinatorKind::Sim => {
            let mut default_workers =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            if let Some(ini) = &ini {
                default_workers = ini.get_or("sweep", "workers", default_workers)?;
            }
            args.get_or("workers", default_workers)?
        }
    };
    let out_dir = args.get_or("out", "results".to_string())?;
    // stdout stays a pure function of the grid (byte-identical for any
    // --workers under the sim backend); runtime details go to stderr
    println!(
        "cfl sweep ({}): {} axes → {} scenarios",
        backend.tag(),
        grid.axes().len(),
        grid.len()
    );
    for axis in grid.axes() {
        println!("  axis {} = [{}]", axis.key, axis.values.join(", "));
    }
    eprintln!("running on {workers} worker thread(s)");

    let opts = SweepOptions {
        workers,
        uncoded_baseline: !args.has_flag("skip-uncoded"),
        progress: !args.has_flag("quiet"),
        backend,
    };
    let outcomes = sweep::run_grid(&grid, &opts)?;

    let csv_path = format!("{out_dir}/sweep_scenarios.csv");
    sweep::write_scenario_csv(&csv_path, &grid, &outcomes)?;
    let json_path = format!("{out_dir}/sweep_report.json");
    sweep::write_json(&json_path, &grid, &outcomes)?;

    println!("{}", sweep::summary_table(&outcomes).render());
    if let Some(matrix) = sweep::gain_matrix(&grid, &outcomes) {
        println!("coding gain matrix (t_uncoded / t_CFL at target NMSE):");
        println!("{}", matrix.render());
    }
    match sweep::gain_stats(&outcomes) {
        Some((stats, best)) => println!(
            "gain over {} scenario(s): mean {:.2}×, min {:.2}×, max {:.2}× (best: {best})",
            stats.count(),
            stats.mean(),
            stats.min(),
            stats.max()
        ),
        None => println!("no scenario reached its target NMSE in both runs — no gains"),
    }
    println!("reports written to {csv_path} and {json_path}");
    Ok(())
}

fn cmd_live(args: &cfl::cli::Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    let scale = args.get_or("time-scale", 1e-3)?;
    // build_config already honored --epochs and any [experiment]
    // max_epochs. Only when the user supplied neither (pure built-in
    // defaults) cap the demo at 100 epochs so the training-scale
    // defaults don't run for minutes of wall sleep.
    if args.get("epochs").is_none() && args.get("config").is_none() {
        cfg.max_epochs = cfg.max_epochs.min(100);
    }
    println!("live cluster: {} device threads, time scale {scale}", cfg.n_devices);
    let report = LiveCoordinator::new(&cfg, scale)?.train_cfl()?;
    println!(
        "epochs={} wall={:.2}s on-time={} late={} final NMSE={:.3e}",
        report.epoch_times.len(),
        report.wall_secs,
        report.on_time_gradients,
        report.late_gradients,
        report.trace.final_nmse().unwrap_or(f64::NAN)
    );
    Ok(())
}

fn main() -> Result<()> {
    // --help is a parse outcome, not a parser-side exit (see cli docs) —
    // rendering and terminating are this binary's decisions alone
    let args = match parser().parse_env()? {
        Parsed::Run(args) => args,
        Parsed::Help { program } => {
            println!("{}", parser().help(&program));
            return Ok(());
        }
    };
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("live") => cmd_live(&args),
        _ => {
            println!("{}", parser().help("cfl"));
            Ok(())
        }
    }
}
