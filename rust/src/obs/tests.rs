use super::*;
use std::sync::{Arc, Mutex};
use std::thread;

/// Sink installation is process-global, so tests that install sinks
/// serialize on this lock (and always `shutdown()` before releasing
/// it). Registry tests use unique metric names instead — the registry
/// is shared with every other concurrently-running test.
static SINK_LOCK: Mutex<()> = Mutex::new(());

fn with_memory_sink(level: Level, f: impl FnOnce(&MemorySink)) {
    let _guard = SINK_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let sink = Arc::new(MemorySink::new());
    let as_dyn: Arc<dyn Sink> = sink.clone();
    install(vec![(as_dyn, level)]);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&sink)));
    shutdown();
    if let Err(payload) = result {
        std::panic::resume_unwind(payload);
    }
}

#[test]
fn levels_parse_and_order() {
    assert_eq!(Level::parse("info").unwrap(), Level::Info);
    assert_eq!(Level::parse("WARN").unwrap(), Level::Warn);
    assert_eq!(Level::parse("Trace").unwrap(), Level::Trace);
    assert!(Level::parse("verbose").is_err());
    assert!(Level::Error < Level::Trace);
    assert_eq!(Level::Debug.tag(), "debug");
}

#[test]
fn disabled_by_default_and_filtered_by_level() {
    let _guard = SINK_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    shutdown();
    assert!(!enabled(Level::Error), "library default must be fully off");

    let sink = Arc::new(MemorySink::new());
    let as_dyn: Arc<dyn Sink> = sink.clone();
    install(vec![(as_dyn, Level::Info)]);
    assert!(enabled(Level::Info));
    assert!(!enabled(Level::Debug));
    crate::obs_event!(Info, "obs_test_lvl_kept");
    crate::obs_event!(Debug, "obs_test_lvl_dropped");
    assert_eq!(sink.lines_for("obs_test_lvl_kept").len(), 1);
    assert!(sink.lines_for("obs_test_lvl_dropped").is_empty());
    shutdown();
    assert!(!enabled(Level::Error));
}

#[test]
fn events_round_trip_schema_and_escaping() {
    with_memory_sink(Level::Debug, |sink| {
        crate::obs_event!(
            Info,
            "obs_test_roundtrip",
            n = 3usize,
            ratio = 0.5f64,
            bad = f64::NAN,
            ok = true,
            tag = "a \"quoted\"\nlabel",
        );
        let lines = sink.lines_for("obs_test_roundtrip");
        assert_eq!(lines.len(), 1);
        let line = &lines[0];
        for key in ["\"seq\":", "\"t_us\":", "\"level\":\"info\"", "\"kind\":\"event\""] {
            assert!(line.contains(key), "missing {key} in {line}");
        }
        assert!(line.contains("\"fields\":{"));
        assert!(line.contains("\"n\":3"));
        assert!(line.contains("\"ratio\":0.5"));
        assert!(line.contains("\"bad\":null"), "NaN must serialize as null: {line}");
        assert!(line.contains("\"ok\":true"));
        assert!(line.contains("\"tag\":\"a \\\"quoted\\\"\\nlabel\""), "bad escaping: {line}");
        assert!(!line.contains("dur_us"), "plain events carry no duration");
    });
}

#[test]
fn sequence_numbers_are_strictly_increasing() {
    with_memory_sink(Level::Debug, |sink| {
        for _ in 0..5 {
            crate::obs_event!(Info, "obs_test_seq");
        }
        let seqs: Vec<u64> = sink
            .lines_for("obs_test_seq")
            .iter()
            .map(|l| {
                let at = l.find("\"seq\":").unwrap() + 6;
                l[at..].chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().unwrap()
            })
            .collect();
        assert_eq!(seqs.len(), 5);
        for w in seqs.windows(2) {
            assert!(w[1] > w[0], "seq must be monotonic: {seqs:?}");
        }
    });
}

#[test]
fn spans_nest_and_time_correctly() {
    with_memory_sink(Level::Debug, |sink| {
        {
            let mut outer = crate::obs_span!(Debug, "obs_test_outer");
            outer.field("k", 1u64);
            {
                let _inner = crate::obs_span!(Debug, "obs_test_inner");
                thread::sleep(std::time::Duration::from_millis(5));
            } // inner closes first
        }
        let inner = sink.lines_for("obs_test_inner");
        let outer = sink.lines_for("obs_test_outer");
        assert_eq!((inner.len(), outer.len()), (1, 1));
        assert!(inner[0].contains("\"kind\":\"span\""));
        let dur = |l: &str| -> u64 {
            let at = l.find("\"dur_us\":").unwrap() + 9;
            l[at..].chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().unwrap()
        };
        assert!(dur(&inner[0]) >= 4_000, "inner span slept 5ms: {}", inner[0]);
        assert!(dur(&outer[0]) >= dur(&inner[0]), "outer span encloses inner");
        assert!(outer[0].contains("\"k\":1"));
        // inner emitted before outer (drop order), so its seq is lower
        let seq = |l: &str| -> u64 {
            let at = l.find("\"seq\":").unwrap() + 6;
            l[at..].chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().unwrap()
        };
        assert!(seq(&inner[0]) < seq(&outer[0]));
    });
}

#[test]
fn span_emits_during_panic_unwinding() {
    with_memory_sink(Level::Debug, |sink| {
        let unwound = std::panic::catch_unwind(|| {
            let _span = crate::obs_span!(Debug, "obs_test_unwind");
            panic!("boom");
        });
        assert!(unwound.is_err());
        let lines = sink.lines_for("obs_test_unwind");
        assert_eq!(lines.len(), 1, "span must emit while unwinding");
        assert!(lines[0].contains("\"kind\":\"span\""));
    });
}

#[test]
fn disabled_spans_are_inert_and_skip_fields() {
    let _guard = SINK_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    shutdown();
    let mut evaluated = false;
    {
        let _span = crate::obs_span!(Debug, "obs_test_inert", x = {
            evaluated = true;
            1u64
        });
        assert!(!_span.active());
    }
    assert!(!evaluated, "field expressions must not run when disabled");
    crate::obs_event!(Info, "obs_test_inert_event", x = {
        evaluated = true;
        1u64
    });
    assert!(!evaluated, "event fields must not run when disabled");
}

#[test]
fn scope_tags_records_and_restores_on_drop() {
    with_memory_sink(Level::Debug, |sink| {
        crate::obs_event!(Info, "obs_test_scope_none");
        {
            let _outer = scope("outer-scn");
            crate::obs_event!(Info, "obs_test_scope_outer");
            {
                let _inner = scope("inner-scn");
                crate::obs_event!(Info, "obs_test_scope_inner");
            }
            crate::obs_event!(Info, "obs_test_scope_restored");
        }
        crate::obs_event!(Info, "obs_test_scope_cleared");
        assert!(!sink.lines_for("obs_test_scope_none")[0].contains("\"scope\""));
        assert!(sink.lines_for("obs_test_scope_outer")[0].contains("\"scope\":\"outer-scn\""));
        assert!(sink.lines_for("obs_test_scope_inner")[0].contains("\"scope\":\"inner-scn\""));
        assert!(sink.lines_for("obs_test_scope_restored")[0].contains("\"scope\":\"outer-scn\""));
        assert!(!sink.lines_for("obs_test_scope_cleared")[0].contains("\"scope\""));
    });
}

#[test]
fn registry_counters_survive_concurrent_hammering() {
    // parallel sweep workers bump shared counters through the global
    // registry; 8 threads × 10k increments must lose nothing
    let name = "obs_test.concurrency.hits";
    let before = registry().counter(name).get();
    thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                let c = registry().counter(name);
                for _ in 0..10_000 {
                    c.incr();
                }
            });
        }
    });
    assert_eq!(registry().counter(name).get() - before, 80_000);
}

#[test]
fn registry_gauges_histograms_and_snapshot() {
    let reg = Registry::new();
    reg.counter("b.count").add(7);
    reg.gauge("a.level").set(0.25);
    let h = reg.histogram("c.delay", 0.0, 1.0, 4);
    h.record(0.1);
    h.record(0.9);
    assert_eq!(h.count(), 2);
    let snap = reg.snapshot();
    assert_eq!(
        snap,
        vec![
            ("a.level".to_string(), 0.25),
            ("b.count".to_string(), 7.0),
            ("c.delay.count".to_string(), 2.0),
        ]
    );
    // kind mismatch: detached handle, registry keeps the original
    let detached = reg.counter("a.level");
    detached.incr();
    assert_eq!(reg.gauge("a.level").get(), 0.25);
    reg.reset();
    assert!(reg.snapshot().is_empty());
}

#[test]
fn phase_book_summarizes_p50_p95() {
    let mut book = PhaseBook::with_capacity(100);
    for i in 1..=100 {
        book.record(Phase::LocalGrad, f64::from(i) / 1000.0);
    }
    book.record(Phase::ParityEncode, 0.5);
    assert_eq!(book.count(Phase::LocalGrad), 100);
    assert_eq!(book.last(Phase::ParityEncode), Some(0.5));
    assert_eq!(book.count(Phase::Calibrate), 0);

    let summaries = book.summaries();
    // only phases with samples appear, in PHASES order
    let names: Vec<&str> = summaries.iter().map(|s| s.phase).collect();
    assert_eq!(names, vec!["parity_encode", "local_grad"]);
    let grad = &summaries[1];
    assert_eq!(grad.count, 100);
    assert!((grad.total_s - 5.05).abs() < 1e-9);
    assert!((grad.p50_s - 0.0505).abs() < 1e-6, "p50 was {}", grad.p50_s);
    assert!((grad.p95_s - 0.09505).abs() < 1e-6, "p95 was {}", grad.p95_s);
}

#[test]
fn value_rendering() {
    assert_eq!(Value::from(3u32).json(), "3");
    assert_eq!(Value::from(-2i64).json(), "-2");
    assert_eq!(Value::from(true).json(), "true");
    assert_eq!(Value::from(f64::INFINITY).json(), "null");
    assert_eq!(Value::from("x\"y").json(), "\"x\\\"y\"");
    assert_eq!(Value::from("plain").text(), "plain");
}
