//! Event sinks: where dispatched records go.
//!
//! A [`Sink`] receives fully-assembled [`EventRecord`]s from the global
//! dispatcher; the built-ins cover the CLI's needs (human stderr lines,
//! JSONL files) plus an in-memory sink for tests. Sinks must be
//! `Send + Sync` — sweep workers and transport reader threads all emit
//! through the same installed set — and each built-in serializes its own
//! output behind a `Mutex`, so interleaved records never shear a line.
//!
//! JSONL sinks flush after every line: an event stream truncated by a
//! kill still parses up to the last complete record.

use super::{Level, Value};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A destination for event records. Implementations must tolerate
/// concurrent calls and should never panic — a sink failure (e.g. a
/// full disk) silently drops the record rather than killing training.
pub trait Sink: Send + Sync {
    fn event(&self, rec: &EventRecord<'_>);
    fn flush(&self) {}
}

/// One fully-assembled record, borrowed for the duration of dispatch.
pub struct EventRecord<'a> {
    /// Monotonic per-process sequence number.
    pub seq: u64,
    /// Microseconds since the first emission of the process.
    pub t_us: u64,
    pub level: Level,
    /// Event name (`epoch`, `endpoint_gone`, ...).
    pub name: &'a str,
    /// `"event"` or `"span"`.
    pub kind: &'static str,
    /// Span duration; `None` for plain events.
    pub dur_us: Option<u64>,
    /// Thread scope label (scenario id inside a sweep worker).
    pub scope: Option<&'a str>,
    pub fields: &'a [(&'a str, Value)],
}

impl EventRecord<'_> {
    /// One self-describing JSON object (no trailing newline). Keys
    /// `seq`/`t_us`/`level`/`event`/`kind` are always present.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut s = format!(
            "{{\"seq\":{},\"t_us\":{},\"level\":\"{}\",\"event\":\"{}\",\"kind\":\"{}\"",
            self.seq,
            self.t_us,
            self.level.tag(),
            crate::sweep::json::escape(self.name),
            self.kind,
        );
        if let Some(d) = self.dur_us {
            let _ = write!(s, ",\"dur_us\":{d}");
        }
        if let Some(scope) = self.scope {
            let _ = write!(s, ",\"scope\":\"{}\"", crate::sweep::json::escape(scope));
        }
        if !self.fields.is_empty() {
            s.push_str(",\"fields\":{");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                let _ = write!(s, "\"{}\":{}", crate::sweep::json::escape(k), v.json());
            }
            s.push('}');
        }
        s.push('}');
        s
    }

    /// One human line for the stderr sink.
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = format!("cfl[{}] {}", self.level.tag(), self.name);
        if let Some(scope) = self.scope {
            let _ = write!(s, " [{scope}]");
        }
        if let Some(d) = self.dur_us {
            let _ = write!(s, " dur={:.1}ms", d as f64 / 1000.0);
        }
        for (k, v) in self.fields.iter() {
            let _ = write!(s, " {k}={}", v.text());
        }
        s
    }
}

/// Human-readable lines on stderr — the CLI's default sink, replacing
/// the old scattered `eprintln!` diagnostics.
pub struct StderrSink;

impl Sink for StderrSink {
    fn event(&self, rec: &EventRecord<'_>) {
        eprintln!("{}", rec.to_text());
    }
}

/// All records appended to a single JSONL file (`cfl serve
/// --events-out FILE`).
pub struct JsonlFileSink {
    w: Mutex<BufWriter<File>>,
}

impl JsonlFileSink {
    /// Create (truncate) `path`, making parent directories as needed.
    pub fn create(path: &str) -> Result<Self> {
        let p = Path::new(path);
        if let Some(dir) = p.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
            }
        }
        let file = File::create(p).with_context(|| format!("creating event log {path}"))?;
        Ok(Self { w: Mutex::new(BufWriter::new(file)) })
    }
}

impl Sink for JsonlFileSink {
    fn event(&self, rec: &EventRecord<'_>) {
        let mut w = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let _ = writeln!(w, "{}", rec.to_json());
        let _ = w.flush();
    }

    fn flush(&self) {
        let mut w = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let _ = w.flush();
    }
}

/// Records routed into per-scope JSONL files under one directory
/// (`cfl sweep --events-out DIR`): a record scoped to scenario `id`
/// lands in `DIR/<stem(id)>.events.jsonl` (same filename sanitizer as
/// the trace CSVs), unscoped records in `DIR/run.events.jsonl`.
pub struct JsonlDirSink {
    dir: PathBuf,
    files: Mutex<HashMap<String, BufWriter<File>>>,
}

impl JsonlDirSink {
    pub fn create(dir: &str) -> Result<Self> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir}"))?;
        Ok(Self { dir: PathBuf::from(dir), files: Mutex::new(HashMap::new()) })
    }
}

impl Sink for JsonlDirSink {
    fn event(&self, rec: &EventRecord<'_>) {
        let stem = match rec.scope {
            Some(scope) => crate::sweep::trace_file_stem(scope),
            None => "run".to_string(),
        };
        let mut files = self.files.lock().unwrap_or_else(|p| p.into_inner());
        if !files.contains_key(&stem) {
            let path = self.dir.join(format!("{stem}.events.jsonl"));
            match File::create(&path) {
                Ok(f) => {
                    files.insert(stem.clone(), BufWriter::new(f));
                }
                Err(_) => return, // unwritable dir: drop, don't kill training
            }
        }
        if let Some(w) = files.get_mut(&stem) {
            let _ = writeln!(w, "{}", rec.to_json());
            let _ = w.flush();
        }
    }

    fn flush(&self) {
        let mut files = self.files.lock().unwrap_or_else(|p| p.into_inner());
        for w in files.values_mut() {
            let _ = w.flush();
        }
    }
}

/// Captures rendered JSONL lines in memory — the test sink.
#[derive(Default)]
pub struct MemorySink {
    lines: Mutex<Vec<String>>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Every captured line, in dispatch order.
    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Captured lines whose `event` key equals `name` (tests filter by
    /// unique names so concurrent emitters don't interfere).
    pub fn lines_for(&self, name: &str) -> Vec<String> {
        let tag = format!("\"event\":\"{name}\"");
        self.lines().into_iter().filter(|l| l.contains(&tag)).collect()
    }
}

impl Sink for MemorySink {
    fn event(&self, rec: &EventRecord<'_>) {
        self.lines.lock().unwrap_or_else(|p| p.into_inner()).push(rec.to_json());
    }
}
