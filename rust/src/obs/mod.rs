//! Structured observability: spans, events, counters, and phase timers.
//!
//! The paper's headline claim is a *wall-clock* one — CFL converges ~4×
//! faster because the master preempts stragglers — so the repo needs to
//! see where an epoch's time actually goes (parity encode vs. local
//! gradient vs. gather wait vs. aggregation) and what the live fleet is
//! doing (disconnects, rejoins, stale-incarnation discards). This module
//! is that layer, hand-rolled because the build is offline (no `tracing`
//! or `log` crates):
//!
//! * **Events and spans** — [`emit`] / [`span`], usually via the
//!   [`obs_event!`] / [`obs_span!`] macros. A span is an RAII timer: it
//!   records `Instant::now()` at creation and emits a single record with
//!   `dur_us` on drop (including panic unwinding, so a span around a
//!   crashing section still reports its duration). Every record carries
//!   a monotonic per-process sequence number and a microsecond timestamp
//!   relative to the first emission.
//! * **Levels and sinks** — [`install`] takes `(sink, level)` pairs; a
//!   record is dispatched to each sink whose level admits it. The global
//!   max level lives in one relaxed atomic, so the disabled path — the
//!   library default, no sinks installed — is a single atomic load with
//!   no locks and no allocation. Field expressions inside the macros are
//!   not evaluated when the level is off.
//! * **Scopes** — [`scope`] tags the current thread's records with a
//!   label (the sweep runner sets the scenario id), which the
//!   [`JsonlDirSink`](sink::JsonlDirSink) uses to route events into
//!   per-scenario files.
//! * **Metrics** — a process-global [`Registry`] of named counters,
//!   gauges, and histograms ([`registry`]); handles are lock-free after
//!   creation. Independent of sinks/levels: counters always count, and
//!   [`emit_metrics_snapshot`] publishes them as one `metrics` event.
//! * **Phase timing** — [`PhaseBook`] accumulates per-phase wall-clock
//!   samples inside a training run; its [`PhaseBook::summaries`]
//!   (count/total/p50/p95 per phase) land in
//!   [`RunResult::phases`](crate::coordinator::RunResult) and from there
//!   in the bench JSON that `cfl bench-check` gates on.
//!
//! Event records serialize to self-describing JSONL via the shared
//! [`sweep::json`](crate::sweep) escaper:
//!
//! ```json
//! {"seq":12,"t_us":48210,"level":"debug","event":"epoch","kind":"span",
//!  "dur_us":913,"scope":"s1__nu=0.2","fields":{"epoch":3,"nmse":0.41}}
//! ```
//!
//! `seq`, `t_us`, `level`, `event`, and `kind` are always present;
//! `dur_us` only on spans, `scope` only inside a [`scope`] guard,
//! `fields` only when non-empty.

mod metrics;
mod phase;
mod sink;

pub use metrics::{registry, Counter, Gauge, Histo, Registry};
pub use phase::{Phase, PhaseBook, PhaseSummary, Stopwatch, PHASES};
pub use sink::{EventRecord, JsonlDirSink, JsonlFileSink, MemorySink, Sink, StderrSink};

use anyhow::{bail, Result};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

#[cfg(test)]
mod tests;

/// Severity/verbosity of an event. Higher numeric value = more verbose;
/// a sink installed at `Debug` admits `Error..=Debug` but not `Trace`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    /// Parse a `--log-level` / `CFL_LOG` value.
    pub fn parse(s: &str) -> Result<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            "trace" => Ok(Level::Trace),
            _ => bail!("unknown log level '{s}' (expected error|warn|info|debug|trace)"),
        }
    }

    /// Lowercase name as it appears in JSONL and stderr output.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// One structured field value. Conversions exist for the usual numeric
/// types, `bool`, and strings, so macro call sites just write `k = v`.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    /// JSON rendering (strings escaped, non-finite floats become null).
    pub fn json(&self) -> String {
        match self {
            Value::U64(v) => v.to_string(),
            Value::I64(v) => v.to_string(),
            Value::F64(v) => crate::sweep::json::num(*v),
            Value::Bool(v) => v.to_string(),
            Value::Str(s) => format!("\"{}\"", crate::sweep::json::escape(s)),
        }
    }

    /// Human rendering for the stderr sink (strings unquoted).
    pub fn text(&self) -> String {
        match self {
            Value::Str(s) => s.clone(),
            Value::F64(v) => format!("{v:.6}"),
            other => other.json(),
        }
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::U64(v)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Value {
        Value::U64(u64::from(v))
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::U64(v as u64)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::I64(v)
    }
}

impl From<i32> for Value {
    fn from(v: i32) -> Value {
        Value::I64(i64::from(v))
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::F64(v)
    }
}

impl From<f32> for Value {
    fn from(v: f32) -> Value {
        Value::F64(f64::from(v))
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::Bool(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Value {
        Value::Str(v)
    }
}

/// Most-verbose level any installed sink admits; 0 = observability off
/// (the library default). Read with a relaxed load on every potential
/// emission — this atomic IS the "zero cost when disabled" guarantee.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Monotonic per-process record sequence.
static SEQ: AtomicU64 = AtomicU64::new(0);

/// Installed `(sink, level)` pairs. Only touched when [`enabled`] says
/// some sink wants the record, so the hot path never takes this lock.
static SINKS: RwLock<Vec<(Arc<dyn Sink>, Level)>> = RwLock::new(Vec::new());

/// `t_us` origin: the first emission after process start (or after the
/// clock is first read).
fn clock() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static SCOPE: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Would a record at `level` reach any installed sink? This is the
/// guard the macros evaluate before touching field expressions.
#[inline]
pub fn enabled(level: Level) -> bool {
    // Relaxed: the gate is advisory — a stale read only defers one event
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Install sinks (replacing any previous set) and raise the global
/// level to the most verbose one requested.
pub fn install(sinks: Vec<(Arc<dyn Sink>, Level)>) {
    let max = sinks.iter().map(|(_, l)| *l as u8).max().unwrap_or(0);
    let mut w = SINKS.write().unwrap_or_else(|p| p.into_inner());
    *w = sinks;
    // Relaxed: sink installation happens-before use via the SINKS lock
    MAX_LEVEL.store(max, Ordering::Relaxed);
}

/// Flush and remove every sink; observability returns to the disabled
/// (zero-cost) state.
pub fn shutdown() {
    // Relaxed: racing emitters still see live sinks through the lock below
    MAX_LEVEL.store(0, Ordering::Relaxed);
    let mut w = SINKS.write().unwrap_or_else(|p| p.into_inner());
    for (sink, _) in w.iter() {
        sink.flush();
    }
    w.clear();
}

/// Emit one structured event (no duration). Prefer [`obs_event!`],
/// which short-circuits field construction when the level is off.
pub fn emit(level: Level, name: &str, fields: &[(&str, Value)]) {
    if !enabled(level) {
        return;
    }
    dispatch(level, name, None, fields);
}

fn dispatch(level: Level, name: &str, dur_us: Option<u64>, fields: &[(&str, Value)]) {
    let sinks = SINKS.read().unwrap_or_else(|p| p.into_inner());
    if sinks.is_empty() {
        return;
    }
    let scope = SCOPE.with(|s| s.borrow().clone());
    let rec = EventRecord {
        // Relaxed: seq only needs uniqueness, not cross-thread ordering
        seq: SEQ.fetch_add(1, Ordering::Relaxed),
        t_us: clock().elapsed().as_micros() as u64,
        level,
        name,
        kind: if dur_us.is_some() { "span" } else { "event" },
        dur_us,
        scope: scope.as_deref(),
        fields,
    };
    for (sink, admit) in sinks.iter() {
        if level as u8 <= *admit as u8 {
            sink.event(&rec);
        }
    }
}

/// RAII span timer from [`span`]: emits one `kind:"span"` record with
/// `dur_us` when dropped — including during panic unwinding, so the
/// last span before a crash still lands in the event stream. Inert
/// (no clock read, fields ignored) when the level was off at creation.
pub struct SpanGuard {
    armed: Option<(Level, &'static str, Instant)>,
    fields: Vec<(&'static str, Value)>,
}

impl SpanGuard {
    /// Attach a field, reported when the span closes. No-op when the
    /// span is inert; guard expensive field computation with
    /// [`SpanGuard::active`].
    pub fn field(&mut self, key: &'static str, value: impl Into<Value>) {
        if self.armed.is_some() {
            self.fields.push((key, value.into()));
        }
    }

    /// Whether this span will emit on drop.
    pub fn active(&self) -> bool {
        self.armed.is_some()
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((level, name, start)) = self.armed.take() {
            let dur_us = start.elapsed().as_micros() as u64;
            let fields = std::mem::take(&mut self.fields);
            dispatch(level, name, Some(dur_us), &fields);
        }
    }
}

/// Open a span timer. Prefer [`obs_span!`].
pub fn span(level: Level, name: &'static str) -> SpanGuard {
    if enabled(level) {
        SpanGuard { armed: Some((level, name, Instant::now())), fields: Vec::new() }
    } else {
        SpanGuard { armed: None, fields: Vec::new() }
    }
}

/// Restores the thread's previous scope label on drop (see [`scope`]).
pub struct ScopeGuard {
    prev: Option<String>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        SCOPE.with(|s| *s.borrow_mut() = prev);
    }
}

/// Tag every record emitted by this thread (until the guard drops)
/// with `label` — e.g. the scenario id inside a sweep worker. Nests:
/// an inner scope shadows the outer one and restores it on drop.
pub fn scope(label: &str) -> ScopeGuard {
    let prev = SCOPE.with(|s| s.replace(Some(label.to_string())));
    ScopeGuard { prev }
}

/// The process' peak resident set size (Linux `VmHWM`, in bytes), or
/// `None` where `/proc/self/status` is unavailable or unparsable (other
/// platforms, restricted sandboxes). This is the number the scale-smoke
/// budget gates on, so it is read from the kernel rather than estimated.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Record [`peak_rss_bytes`] as the `process.peak_rss_bytes` gauge (a
/// no-op off Linux) so metrics snapshots carry the memory high-water
/// mark; returns the reading for callers that print it.
pub fn record_peak_rss() -> Option<u64> {
    let bytes = peak_rss_bytes()?;
    registry().gauge("process.peak_rss_bytes").set(bytes as f64);
    Some(bytes)
}

/// Publish the current [`registry`] contents as one `metrics` event
/// (info level) with a field per metric, in deterministic name order.
pub fn emit_metrics_snapshot() {
    if !enabled(Level::Info) {
        return;
    }
    let snap = registry().snapshot();
    if snap.is_empty() {
        return;
    }
    let fields: Vec<(&str, Value)> =
        snap.iter().map(|(name, v)| (name.as_str(), Value::F64(*v))).collect();
    dispatch(Level::Info, "metrics", None, &fields);
}

/// Emit a structured event: `obs_event!(Info, "name", key = value, ...)`.
///
/// The level check happens *before* any field expression is evaluated,
/// so call sites are free on the disabled path.
#[macro_export]
macro_rules! obs_event {
    ($level:ident, $name:expr) => {
        if $crate::obs::enabled($crate::obs::Level::$level) {
            $crate::obs::emit($crate::obs::Level::$level, $name, &[]);
        }
    };
    ($level:ident, $name:expr, $($key:ident = $val:expr),+ $(,)?) => {
        if $crate::obs::enabled($crate::obs::Level::$level) {
            $crate::obs::emit(
                $crate::obs::Level::$level,
                $name,
                &[$((stringify!($key), $crate::obs::Value::from($val))),+],
            );
        }
    };
}

/// Open an RAII span timer: `let _s = obs_span!(Debug, "epoch");`
/// optionally with initial fields (`obs_span!(Debug, "epoch", n = 3)`).
/// Field expressions are only evaluated when the span is active.
#[macro_export]
macro_rules! obs_span {
    ($level:ident, $name:expr) => {
        $crate::obs::span($crate::obs::Level::$level, $name)
    };
    ($level:ident, $name:expr, $($key:ident = $val:expr),+ $(,)?) => {{
        let mut guard = $crate::obs::span($crate::obs::Level::$level, $name);
        if guard.active() {
            $(guard.field(stringify!($key), $val);)+
        }
        guard
    }};
}
