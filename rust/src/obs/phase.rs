//! Per-phase wall-clock accounting inside a training run.
//!
//! Both coordinators carry a [`PhaseBook`] through training and record
//! how long each epoch spends in each [`Phase`]; the resulting
//! [`PhaseSummary`] list (count / total / p50 / p95 per phase) rides in
//! [`RunResult::phases`](crate::coordinator::RunResult) and surfaces in
//! the bench JSON, where `cfl bench-check` gates wall-clock throughput.
//!
//! The book is deliberately always-on (the bench gate needs the numbers
//! even with event sinks off) and hot-path-safe: recording a sample is
//! one `Vec::push` into storage preallocated for the run's epoch count —
//! no locks, no allocation, ~4 `Instant::now()` calls per epoch.

use crate::stats::quantile;
use std::time::Instant;

/// The phases of one training epoch (plus one-off setup phases). These
/// names are the keys of the bench JSON `phases` object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// §III-A parity encoding during setup (one sample per run).
    ParityEncode,
    /// Gradient computation: the master's composite-parity GEMM and, in
    /// the simulator, the per-device systematic gradients.
    LocalGrad,
    /// Waiting on / collecting device gradients up to the deadline.
    Gather,
    /// Assembling the aggregate, applying the model update, NMSE.
    Aggregate,
    /// Live-fleet RTT calibration before epoch 1 (one sample per run).
    Calibrate,
}

/// All phases, in reporting order.
pub const PHASES: [Phase; 5] =
    [Phase::ParityEncode, Phase::LocalGrad, Phase::Gather, Phase::Aggregate, Phase::Calibrate];

impl Phase {
    pub const fn name(self) -> &'static str {
        match self {
            Phase::ParityEncode => "parity_encode",
            Phase::LocalGrad => "local_grad",
            Phase::Gather => "gather",
            Phase::Aggregate => "aggregate",
            Phase::Calibrate => "calibrate",
        }
    }

    const fn index(self) -> usize {
        match self {
            Phase::ParityEncode => 0,
            Phase::LocalGrad => 1,
            Phase::Gather => 2,
            Phase::Aggregate => 3,
            Phase::Calibrate => 4,
        }
    }
}

/// Accumulates wall-clock samples per phase for one training run.
#[derive(Debug, Default)]
pub struct PhaseBook {
    samples: [Vec<f64>; 5],
}

impl PhaseBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Preallocate for `n` samples per phase (pass the run's epoch
    /// budget so per-epoch recording never allocates).
    pub fn with_capacity(n: usize) -> Self {
        Self { samples: std::array::from_fn(|_| Vec::with_capacity(n)) }
    }

    pub fn record(&mut self, phase: Phase, secs: f64) {
        self.samples[phase.index()].push(secs);
    }

    pub fn count(&self, phase: Phase) -> usize {
        self.samples[phase.index()].len()
    }

    pub fn total(&self, phase: Phase) -> f64 {
        self.samples[phase.index()].iter().sum()
    }

    /// The most recent sample for `phase`, if any.
    pub fn last(&self, phase: Phase) -> Option<f64> {
        self.samples[phase.index()].last().copied()
    }

    /// Count/total/p50/p95 for every phase that saw at least one
    /// sample, in [`PHASES`] order.
    pub fn summaries(&self) -> Vec<PhaseSummary> {
        PHASES
            .iter()
            .filter(|p| !self.samples[p.index()].is_empty())
            .map(|p| {
                let xs = &self.samples[p.index()];
                PhaseSummary {
                    phase: p.name(),
                    count: xs.len() as u64,
                    total_s: xs.iter().sum(),
                    p50_s: quantile(xs, 0.5),
                    p95_s: quantile(xs, 0.95),
                }
            })
            .collect()
    }
}

/// A wall-clock stopwatch for phase timing — the one sanctioned way
/// for training code to read the host clock (the `no-wall-clock` lint
/// rule bans `Instant::now()` outside obs and the live modules, so
/// simulated-time code measures *itself* through this seam instead of
/// coupling to `std::time` directly).
///
/// [`Stopwatch::lap_s`] advances a lap marker, which is exactly the
/// `t_epoch → t_gather → t_grad` delta chain the coordinators feed into
/// [`PhaseBook::record`].
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        let now = Instant::now();
        Self { start: now, last: now }
    }

    /// Seconds since the stopwatch started.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous lap (or start), advancing the marker.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }

    /// Reset the lap marker without taking a reading (start a new
    /// measured region after unmeasured work).
    pub fn mark(&mut self) {
        self.last = Instant::now();
    }
}

/// One phase's digest over a run — the shape that rides in
/// [`RunResult`](crate::coordinator::RunResult) and the bench JSON.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSummary {
    /// [`Phase::name`] of the phase.
    pub phase: &'static str,
    pub count: u64,
    pub total_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}
