//! Process-global metrics registry: named counters, gauges, histograms.
//!
//! Handles are get-or-created by name through [`registry`] — one mutexed
//! `BTreeMap` lookup at creation, after which [`Counter`]/[`Gauge`] are
//! a single relaxed atomic op per update and safe to bump from any
//! thread (sweep workers, transport reader threads, the gemm hot path
//! caches its handles in a `OnceLock`). Metrics are independent of the
//! event-sink level: counters always count; they only become *visible*
//! through [`Registry::snapshot`] / [`emit_metrics_snapshot`].
//!
//! [`emit_metrics_snapshot`]: super::emit_metrics_snapshot

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event count. Cheap to clone (an `Arc` around one atomic).
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // Relaxed: counters tolerate reordering; totals are read at rest
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // Relaxed: snapshot read, no other state depends on it
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        // Relaxed: last-write-wins gauge, torn updates are impossible on u64
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        // Relaxed: snapshot read, no other state depends on it
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A shared fixed-range histogram (see [`crate::stats::Histogram`]).
/// Updates take the histogram's own mutex — keep these off per-sample
/// hot paths and record aggregates instead.
#[derive(Clone)]
pub struct Histo(Arc<Mutex<crate::stats::Histogram>>);

impl Histo {
    pub fn record(&self, x: f64) {
        self.0.lock().unwrap_or_else(|p| p.into_inner()).push(x);
    }

    pub fn count(&self) -> u64 {
        self.0.lock().unwrap_or_else(|p| p.into_inner()).count()
    }

    /// A point-in-time copy for rendering/inspection.
    pub fn snapshot(&self) -> crate::stats::Histogram {
        self.0.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

enum Cell {
    Counter(Counter),
    Gauge(Gauge),
    Histo(Histo),
}

/// Name → metric map. `new` is `const`, so the process-global instance
/// ([`registry`]) needs no lazy-init machinery; tests can also build
/// private registries.
pub struct Registry {
    cells: Mutex<BTreeMap<String, Cell>>,
}

impl Registry {
    pub const fn new() -> Self {
        Self { cells: Mutex::new(BTreeMap::new()) }
    }

    /// Get or create the counter `name`. If `name` already holds a
    /// different metric kind, a detached (unregistered) counter is
    /// returned — it counts, but never appears in snapshots; don't
    /// reuse names across kinds.
    pub fn counter(&self, name: &str) -> Counter {
        let mut cells = self.cells.lock().unwrap_or_else(|p| p.into_inner());
        let cell = cells
            .entry(name.to_string())
            .or_insert_with(|| Cell::Counter(Counter::default()));
        match cell {
            Cell::Counter(c) => c.clone(),
            _ => Counter::default(),
        }
    }

    /// Get or create the gauge `name` (same kind-mismatch rule as
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut cells = self.cells.lock().unwrap_or_else(|p| p.into_inner());
        let cell =
            cells.entry(name.to_string()).or_insert_with(|| Cell::Gauge(Gauge::default()));
        match cell {
            Cell::Gauge(g) => g.clone(),
            _ => Gauge::default(),
        }
    }

    /// Get or create the histogram `name` over `[lo, hi)` with `nbins`
    /// bins. The range/bin arguments only matter on first creation.
    pub fn histogram(&self, name: &str, lo: f64, hi: f64, nbins: usize) -> Histo {
        let mut cells = self.cells.lock().unwrap_or_else(|p| p.into_inner());
        let cell = cells.entry(name.to_string()).or_insert_with(|| {
            Cell::Histo(Histo(Arc::new(Mutex::new(crate::stats::Histogram::new(lo, hi, nbins)))))
        });
        match cell {
            Cell::Histo(h) => h.clone(),
            _ => Histo(Arc::new(Mutex::new(crate::stats::Histogram::new(lo, hi, nbins)))),
        }
    }

    /// Every registered metric as `(name, value)` in name order:
    /// counter value, gauge value, or sample count for histograms
    /// (reported under `name.count`).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let cells = self.cells.lock().unwrap_or_else(|p| p.into_inner());
        cells
            .iter()
            .map(|(name, cell)| match cell {
                Cell::Counter(c) => (name.clone(), c.get() as f64),
                Cell::Gauge(g) => (name.clone(), g.get()),
                Cell::Histo(h) => (format!("{name}.count"), h.count() as f64),
            })
            .collect()
    }

    /// Drop every metric (tests; existing handles keep working but are
    /// detached from future snapshots).
    pub fn reset(&self) {
        self.cells.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-global registry every instrumented subsystem reports to.
pub fn registry() -> &'static Registry {
    static GLOBAL: Registry = Registry::new();
    &GLOBAL
}
