//! Wireless-edge delay models (§II-A of the paper).
//!
//! The paper evaluates CFL against *these exact stochastic models*, so
//! this module is the substrate on which every figure stands:
//!
//! * [`ComputeModel`] — shifted-exponential computation time (Eq. 4):
//!   deterministic `ℓ·aᵢ` plus `Exp(γᵢ)` with `γᵢ = μᵢ/ℓ` for the MAC
//!   memory-access jitter.
//! * [`LinkModel`] — geometric retransmissions (Eq. 5) over a rate-adapted
//!   link: each of the download/upload legs takes `N·τᵢ` with
//!   `P{N = t} = pᵗ⁻¹(1−p)` (Eq. 6).
//! * [`DeviceProfile`] — the tuple the optimizer and simulator consume:
//!   sampling (`sample_total_delay`), the analytic CDF `P{Tᵢ ≤ t}`
//!   (negative-binomial × exponential convolution — used by Eq. 14's
//!   expected return and Eq. 17's weights), and `E[Tᵢ]` (Eq. 8).
//! * [`Fleet`] — the §IV heterogeneity ladders: MAC rates
//!   `(1−ν_comp)^i · base` and link throughputs `(1−ν_link)^i · base`,
//!   shuffled over devices, plus the 10×-faster master node.

mod delay;
mod fleet;

pub use delay::{ComputeModel, DeviceProfile, LinkModel};
pub use fleet::{packet_bits, Fleet};

#[cfg(test)]
mod tests;
