//! Delay-model tests: analytic moments/CDFs vs Monte-Carlo, fleet ladders.

use super::*;
use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::testing::prop::{self, assert_that};

fn mc_mean(mut f: impl FnMut(&mut Rng) -> f64, rng: &mut Rng, n: usize) -> f64 {
    (0..n).map(|_| f(rng)).sum::<f64>() / n as f64
}

#[test]
fn compute_mean_matches_eq8() {
    let m = ComputeModel { secs_per_point: 0.01, mem_rate: 200.0 };
    // E[T_c] = ℓ(a + 1/μ)
    assert!((m.mean(300) - 300.0 * (0.01 + 1.0 / 200.0)).abs() < 1e-12);
    let mut rng = Rng::new(0);
    let mc = mc_mean(|r| m.sample(300, r), &mut rng, 40_000);
    assert!((mc - m.mean(300)).abs() / m.mean(300) < 0.02, "mc={mc}");
}

#[test]
fn compute_zero_points_is_instant() {
    let m = ComputeModel { secs_per_point: 0.01, mem_rate: 200.0 };
    let mut rng = Rng::new(1);
    assert_eq!(m.sample(0, &mut rng), 0.0);
    assert_eq!(m.mean(0), 0.0);
    assert_eq!(m.cdf(0, 0.0), 1.0);
}

#[test]
fn compute_cdf_matches_monte_carlo() {
    let m = ComputeModel { secs_per_point: 0.002, mem_rate: 500.0 };
    let mut rng = Rng::new(2);
    for &t in &[0.5, 0.7, 1.0, 1.5] {
        let hits = (0..30_000).filter(|_| m.sample(300, &mut rng) <= t).count();
        let mc = hits as f64 / 30_000.0;
        let analytic = m.cdf(300, t);
        assert!((mc - analytic).abs() < 0.015, "t={t}: mc={mc} analytic={analytic}");
    }
}

#[test]
fn compute_cdf_zero_before_deterministic_shift() {
    let m = ComputeModel { secs_per_point: 0.01, mem_rate: 100.0 };
    assert_eq!(m.cdf(100, 0.99), 0.0); // det = 1.0s
    assert!(m.cdf(100, 1.01) > 0.0);
}

#[test]
fn link_round_trip_mean_matches_eq8() {
    let l = LinkModel { secs_per_packet: 0.08, erasure_prob: 0.1 };
    assert!((l.mean_round_trip() - 2.0 * 0.08 / 0.9).abs() < 1e-12);
    let mut rng = Rng::new(3);
    let mc = mc_mean(|r| l.sample_round_trip(r), &mut rng, 40_000);
    assert!((mc - l.mean_round_trip()).abs() / l.mean_round_trip() < 0.02);
}

#[test]
fn link_zero_is_free() {
    let l = LinkModel::zero();
    let mut rng = Rng::new(4);
    assert_eq!(l.sample_round_trip(&mut rng), 0.0);
    assert_eq!(l.mean_round_trip(), 0.0);
    assert_eq!(l.sample_bulk_transfer(1000, &mut rng), 0.0);
}

#[test]
fn bulk_transfer_mean_scales_with_packets() {
    let l = LinkModel { secs_per_packet: 0.05, erasure_prob: 0.2 };
    let mut rng = Rng::new(5);
    let mc = mc_mean(|r| l.sample_bulk_transfer(50, r), &mut rng, 5_000);
    let want = 50.0 * 0.05 / 0.8;
    assert!((mc - want).abs() / want < 0.03, "mc={mc} want={want}");
}

fn paper_profile() -> DeviceProfile {
    // a mid-ladder paper device: MACR = 1536·0.8⁵ KMAC/s, link 216·0.8⁵ kbps
    let macr = 1536e3 * 0.8f64.powi(5);
    let a = 500.0 / macr;
    let thr = 216e3 * 0.8f64.powi(5);
    DeviceProfile {
        compute: ComputeModel { secs_per_point: a, mem_rate: 2.0 / a },
        link: LinkModel { secs_per_packet: packet_bits(500, 0.1) / thr, erasure_prob: 0.1 },
        points: 300,
    }
}

#[test]
fn total_delay_mean_matches_eq8() {
    let p = paper_profile();
    let want = p.compute.mean(300) + p.link.mean_round_trip();
    assert!((p.mean_total_delay(300) - want).abs() < 1e-12);
    let mut rng = Rng::new(6);
    let mc = mc_mean(|r| p.sample_total_delay(300, r), &mut rng, 40_000);
    assert!((mc - want).abs() / want < 0.02, "mc={mc} want={want}");
}

#[test]
fn delay_cdf_matches_monte_carlo() {
    let p = paper_profile();
    let mut rng = Rng::new(7);
    for &frac in &[0.8, 1.0, 1.3, 2.0] {
        let t = frac * p.mean_total_delay(300);
        let hits = (0..30_000).filter(|_| p.sample_total_delay(300, &mut rng) <= t).count();
        let mc = hits as f64 / 30_000.0;
        let analytic = p.delay_cdf(300, t);
        assert!((mc - analytic).abs() < 0.015, "t={t}: mc={mc} analytic={analytic}");
    }
}

#[test]
fn delay_cdf_is_monotone_in_t_and_decreasing_in_load() {
    prop::check("delay cdf monotonicity", prop::cfg_cases(40), |g| {
        let p = paper_profile();
        let l = g.size_in(1, 300);
        let t1 = g.f64_in(0.0, 5.0);
        let t2 = t1 + g.f64_in(0.0, 5.0);
        let c1 = p.delay_cdf(l, t1);
        let c2 = p.delay_cdf(l, t2);
        assert_that(c2 >= c1 - 1e-12, format!("cdf not monotone in t: {c1} > {c2}"))?;
        let l2 = (l + g.size_in(1, 100)).min(300);
        let cl = p.delay_cdf(l2, t1);
        assert_that(
            cl <= c1 + 1e-9,
            format!("cdf not decreasing in load: cdf({l2})={cl} > cdf({l})={c1}"),
        )?;
        assert_that((0.0..=1.0).contains(&c1), "cdf out of [0,1]")
    });
}

#[test]
fn prob_miss_complements_cdf() {
    let p = paper_profile();
    let t = p.mean_total_delay(300);
    assert!((p.prob_miss(300, t) + p.delay_cdf(300, t) - 1.0).abs() < 1e-12);
}

#[test]
fn expected_return_is_bounded_by_load() {
    let p = paper_profile();
    for l in [1usize, 50, 300] {
        for &t in &[0.1, 1.0, 10.0] {
            let r = p.expected_return(l, t);
            assert!(r >= 0.0 && r <= l as f64 + 1e-12);
        }
    }
}

#[test]
fn expected_return_is_concave_shaped_fig1() {
    // Fig. 1's qualitative claim: E[R(t; ℓ)] rises ~linearly, peaks at an
    // interior ℓ*, then collapses to ~0 once the deterministic compute time
    // alone exceeds t.
    let p = paper_profile();
    let t = 0.7 * p.mean_total_delay(300);
    let returns: Vec<f64> = (0..=300).step_by(5).map(|l| p.expected_return(l, t)).collect();
    let peak_idx = returns
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    assert!(peak_idx > 0, "peak should not be at zero load");
    assert!(peak_idx < returns.len() - 1, "peak should be interior (returns collapse)");
    assert!(returns[returns.len() - 1] < returns[peak_idx] * 0.5, "tail should collapse");
}

#[test]
fn fleet_ladders_match_paper() {
    let cfg = ExperimentConfig::paper();
    let mut rng = Rng::new(42);
    let fleet = Fleet::from_config(&cfg, &mut rng);
    assert_eq!(fleet.n_devices(), 24);
    assert_eq!(fleet.total_points(), 7200);

    // the set of per-point compute times must equal {d/(base·0.8^i)}
    let mut got: Vec<f64> = fleet.devices.iter().map(|p| p.compute.secs_per_point).collect();
    got.sort_by(f64::total_cmp);
    let mut want: Vec<f64> =
        (0..24).map(|i| 500.0 / (0.8f64.powi(i) * 1536e3)).collect();
    want.sort_by(f64::total_cmp);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() / w < 1e-12);
    }

    // master: 10× base rate, zero link
    assert!((fleet.master.compute.secs_per_point - 500.0 / 15360e3).abs() < 1e-15);
    assert_eq!(fleet.master.link, LinkModel::zero());

    // memory overhead: μᵢ = 2/aᵢ ⇒ mean stochastic = ℓ·aᵢ/2 (the "50%")
    for dev in &fleet.devices {
        assert!((dev.compute.mem_rate * dev.compute.secs_per_point - 2.0).abs() < 1e-12);
    }

    // packet: 500 × 32 bits × 1.1
    assert!((fleet.packet_bits - 17600.0).abs() < 1e-9);
}

#[test]
fn fleet_shuffles_are_seed_reproducible_and_independent() {
    let cfg = ExperimentConfig::paper();
    let f1 = Fleet::from_config(&cfg, &mut Rng::new(1));
    let f2 = Fleet::from_config(&cfg, &mut Rng::new(1));
    let f3 = Fleet::from_config(&cfg, &mut Rng::new(2));
    for (a, b) in f1.devices.iter().zip(&f2.devices) {
        assert_eq!(a, b);
    }
    // different seed ⇒ different assignment (overwhelmingly likely)
    assert!(f1.devices.iter().zip(&f3.devices).any(|(a, b)| a != b));
    // compute and link ladders shuffled independently: the device with the
    // fastest compute should not always also hold the fastest link
    let fastest_comp = f1
        .devices
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.compute.secs_per_point.total_cmp(&b.1.compute.secs_per_point))
        .unwrap()
        .0;
    let fastest_link = f1
        .devices
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.link.secs_per_packet.total_cmp(&b.1.link.secs_per_packet))
        .unwrap()
        .0;
    // not a hard guarantee per seed, but seed 1 is checked here explicitly
    assert!(fastest_comp != fastest_link || fleet_collision_ok());
    fn fleet_collision_ok() -> bool {
        true // tolerated: independence is statistical, asserted above via shuffles
    }
}

#[test]
fn ladder_tiers_tile_the_ladder() {
    // 48 devices on a 24-tier ladder: each rung appears exactly twice
    let mut cfg = ExperimentConfig::paper();
    cfg.n_devices = 48;
    cfg.ladder_tiers = 24;
    let fleet = Fleet::from_config(&cfg, &mut Rng::new(9));
    let mut got: Vec<f64> = fleet.devices.iter().map(|p| p.compute.secs_per_point).collect();
    got.sort_by(f64::total_cmp);
    let mut want: Vec<f64> =
        (0..48).map(|i| 500.0 / (0.8f64.powi((i % 24) as i32) * 1536e3)).collect();
    want.sort_by(f64::total_cmp);
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits(), "tiled rung must be bit-exact");
    }
}

#[test]
fn ladder_tiers_covering_fleet_is_identity() {
    // T = n means i mod T = i: bit-identical to the per-device ladder
    let mut cfg = ExperimentConfig::paper();
    let per_device = Fleet::from_config(&cfg, &mut Rng::new(10));
    cfg.ladder_tiers = cfg.n_devices;
    let tiled = Fleet::from_config(&cfg, &mut Rng::new(10));
    for (a, b) in per_device.devices.iter().zip(&tiled.devices) {
        assert_eq!(a, b);
    }
    assert_eq!(per_device.throughputs_bps, tiled.throughputs_bps);
}

#[test]
fn homogeneous_fleet_is_uniform() {
    let mut cfg = ExperimentConfig::paper();
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let fleet = Fleet::from_config(&cfg, &mut Rng::new(3));
    let a0 = fleet.devices[0].compute.secs_per_point;
    let t0 = fleet.devices[0].link.secs_per_packet;
    for d in &fleet.devices {
        assert!((d.compute.secs_per_point - a0).abs() < 1e-15);
        assert!((d.link.secs_per_packet - t0).abs() < 1e-15);
    }
}

#[test]
fn parity_upload_cost_analytic_vs_monte_carlo() {
    use crate::config::SetupCostKind;
    let mut cfg = ExperimentConfig::paper();
    let row_bits = 501.0 * 32.0 * 1.1;
    for kind in [SetupCostKind::BaseRate, SetupCostKind::AdaptedRate, SetupCostKind::PerPacket] {
        cfg.setup_cost = kind;
        let fleet = Fleet::from_config(&cfg, &mut Rng::new(4));
        let mut rng = Rng::new(5);
        let rows = 200;
        let mc = mc_mean(|r| fleet.sample_parity_upload_secs(3, rows, row_bits, r), &mut rng, 3_000);
        let want = fleet.mean_parity_upload_secs(3, rows, row_bits);
        assert!((mc - want).abs() / want < 0.05, "{kind:?}: mc={mc} want={want}");
    }
}

#[test]
fn setup_cost_models_are_ordered() {
    // base-rate ≤ adapted-rate ≈ per-packet mean, for every device
    use crate::config::SetupCostKind;
    let row_bits = 501.0 * 32.0 * 1.1;
    let mk = |kind| {
        let mut cfg = ExperimentConfig::paper();
        cfg.setup_cost = kind;
        Fleet::from_config(&cfg, &mut Rng::new(6))
    };
    let base = mk(SetupCostKind::BaseRate);
    let adapted = mk(SetupCostKind::AdaptedRate);
    let per_packet = mk(SetupCostKind::PerPacket);
    for i in 0..base.n_devices() {
        let b = base.mean_parity_upload_secs(i, 100, row_bits);
        let a = adapted.mean_parity_upload_secs(i, 100, row_bits);
        let p = per_packet.mean_parity_upload_secs(i, 100, row_bits);
        assert!(b <= a + 1e-9, "device {i}: base {b} > adapted {a}");
        assert!((a - p).abs() / a < 1e-9, "adapted and per-packet means agree: {a} vs {p}");
    }
}
