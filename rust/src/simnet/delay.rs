//! Per-device delay models: sampling and analytic distribution functions.

use crate::rng::Rng;

/// Shifted-exponential computation-time model (Eq. 4).
///
/// `T_c = ℓ·a + Exp(γ)` with `γ = mu / ℓ`: processing `ℓ` points costs a
/// deterministic `a` seconds each, plus one exponential term whose mean
/// `ℓ/mu` scales with the shard (the paper models memory read/write jitter
/// accumulated over the MAC operations of the whole shard).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeModel {
    /// Seconds of deterministic compute per training point (aᵢ = d/MACRᵢ).
    pub secs_per_point: f64,
    /// Memory access rate μᵢ (points per second); the stochastic component
    /// for an ℓ-point shard is Exp(μᵢ/ℓ), mean ℓ/μᵢ.
    pub mem_rate: f64,
}

impl ComputeModel {
    /// Sample T_c for a shard of `points` training points.
    pub fn sample(&self, points: usize, rng: &mut Rng) -> f64 {
        if points == 0 {
            return 0.0;
        }
        let det = points as f64 * self.secs_per_point;
        let gamma = self.mem_rate / points as f64;
        det + rng.exponential(gamma)
    }

    /// `E[T_c] = ℓ(a + 1/μ)` — the compute part of Eq. (8).
    pub fn mean(&self, points: usize) -> f64 {
        points as f64 * (self.secs_per_point + 1.0 / self.mem_rate)
    }

    /// P{T_c ≤ t} for an ℓ-point shard.
    pub fn cdf(&self, points: usize, t: f64) -> f64 {
        if points == 0 {
            return if t >= 0.0 { 1.0 } else { 0.0 };
        }
        let det = points as f64 * self.secs_per_point;
        let s = t - det;
        if s <= 0.0 {
            return 0.0;
        }
        let gamma = self.mem_rate / points as f64;
        1.0 - (-gamma * s).exp()
    }
}

/// Geometric-retransmission link model (Eqs. 5–6).
///
/// One packet (a model download or a gradient upload) takes `N·τ` seconds
/// where `P{N = t} = p^{t−1}(1−p)`. `τ = 0` models the master's in-process
/// "link" (no network), for which all delays are identically zero.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// Seconds per transmission attempt of one packet (τᵢ = x/(rᵢW)).
    pub secs_per_packet: f64,
    /// Erasure probability p ∈ [0, 1).
    pub erasure_prob: f64,
}

impl LinkModel {
    /// A degenerate zero-latency link (the master's own gradient path).
    pub fn zero() -> Self {
        Self { secs_per_packet: 0.0, erasure_prob: 0.0 }
    }

    /// Sample the one-way delay of a single packet: N·τ.
    pub fn sample_one_way(&self, rng: &mut Rng) -> f64 {
        if self.secs_per_packet == 0.0 {
            return 0.0;
        }
        rng.geometric(self.erasure_prob) as f64 * self.secs_per_packet
    }

    /// Sample a round trip (download + upload, Eq. 7's T_d + T_u).
    pub fn sample_round_trip(&self, rng: &mut Rng) -> f64 {
        self.sample_one_way(rng) + self.sample_one_way(rng)
    }

    /// E[T_d + T_u] = 2τ/(1−p) — the link part of Eq. (8).
    pub fn mean_round_trip(&self) -> f64 {
        if self.secs_per_packet == 0.0 {
            0.0
        } else {
            2.0 * self.secs_per_packet / (1.0 - self.erasure_prob)
        }
    }

    /// Seconds to push `bits` of bulk payload one way, *in expectation
    /// per packet* (each packet of the bulk transfer retransmits
    /// independently). Used for the one-time parity upload cost.
    pub fn sample_bulk_transfer(&self, packets: usize, rng: &mut Rng) -> f64 {
        if self.secs_per_packet == 0.0 {
            return 0.0;
        }
        let mut total = 0.0;
        for _ in 0..packets {
            total += self.sample_one_way(rng);
        }
        total
    }
}

/// Full per-device profile: compute + link (+ identity bookkeeping).
///
/// The end-to-end epoch delay (Eq. 7) is
/// `T = T_d + T_c + T_u = (N_d + N_u)·τ + ℓ·a + Exp(μ/ℓ)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    pub compute: ComputeModel,
    pub link: LinkModel,
    /// Raw training points held by this device (ℓᵢ); the master's profile
    /// uses the parity cap c^up here.
    pub points: usize,
}

impl DeviceProfile {
    /// Sample the total epoch delay T for a shard of `points` (Eq. 7).
    pub fn sample_total_delay(&self, points: usize, rng: &mut Rng) -> f64 {
        self.link.sample_round_trip(rng) + self.compute.sample(points, rng)
    }

    /// `E[T]` (Eq. 8).
    pub fn mean_total_delay(&self, points: usize) -> f64 {
        self.compute.mean(points) + self.link.mean_round_trip()
    }

    /// Analytic CDF  P{T ≤ t}  of the total delay for an ℓ-point shard.
    ///
    /// T = (N_d + N_u)·τ + D + E with D = ℓa deterministic, E ~ Exp(γ),
    /// N_d, N_u iid geometric (support ≥ 1). N_d + N_u = k has the
    /// negative-binomial pmf (k−1)·p^{k−2}·(1−p)² for k ≥ 2, so
    ///
    ///   P{T ≤ t} = Σ_{k≥2} (k−1) p^{k−2} (1−p)² · P{E ≤ t − D − kτ}.
    ///
    /// The sum terminates once `kτ > t − D` (later terms are zero); for a
    /// zero-latency link it degenerates to the compute CDF.
    pub fn delay_cdf(&self, points: usize, t: f64) -> f64 {
        let tau = self.link.secs_per_packet;
        if tau == 0.0 {
            return self.compute.cdf(points, t);
        }
        let p = self.link.erasure_prob;
        let det = points as f64 * self.compute.secs_per_point;
        let budget = t - det;
        if budget < 2.0 * tau {
            return 0.0; // at least one attempt per leg
        }
        let kmax = (budget / tau).floor() as u64;
        let q = 1.0 - p;
        let mut acc = 0.0;
        let mut pmf_scale = q * q; // (1−p)² · p^{k−2}, updated per k
        for k in 2..=kmax {
            let weight = (k - 1) as f64 * pmf_scale;
            let s = budget - k as f64 * tau;
            let e_cdf = if points == 0 {
                1.0
            } else {
                let gamma = self.compute.mem_rate / points as f64;
                1.0 - (-gamma * s).exp()
            };
            acc += weight * e_cdf;
            pmf_scale *= p;
            if weight < 1e-15 && k > 16 {
                break; // geometric tail is numerically dead
            }
        }
        acc.clamp(0.0, 1.0)
    }

    /// P{T ≥ t} — the weight-matrix quantity of Eq. (17).
    pub fn prob_miss(&self, points: usize, t: f64) -> f64 {
        1.0 - self.delay_cdf(points, t)
    }

    /// Expected return metric E[R(t; ℓ̃)] = ℓ̃ · P{T(ℓ̃) ≤ t} (Eq. 13's
    /// per-device term; the optimizer maximizes this over ℓ̃ — Eq. 14).
    pub fn expected_return(&self, points: usize, t: f64) -> f64 {
        points as f64 * self.delay_cdf(points, t)
    }
}
