//! Fleet construction: the §IV heterogeneity ladders.

use super::{ComputeModel, DeviceProfile, LinkModel};
use crate::config::{ExperimentConfig, SetupCostKind};
use crate::rng::Rng;

/// Bits of one model/gradient packet: d 32-bit floats + header overhead
/// (§IV: "packet size is calculated accordingly with additional 10%
/// overhead for header").
pub fn packet_bits(model_dim: usize, header_overhead: f64) -> f64 {
    model_dim as f64 * 32.0 * (1.0 + header_overhead)
}

/// The simulated edge deployment: n device profiles + the master profile.
#[derive(Clone, Debug)]
pub struct Fleet {
    /// Edge devices, index 0..n.
    pub devices: Vec<DeviceProfile>,
    /// The central server as the (n+1)-th "device" of Eq. (13): 10× the
    /// fastest device's MAC rate, zero-latency link.
    pub master: DeviceProfile,
    /// Link throughputs in bits/s (kept for comm-load accounting).
    pub throughputs_bps: Vec<f64>,
    /// Per-packet bits (one model or gradient vector).
    pub packet_bits: f64,
    /// Base (best) link throughput in bits/s.
    pub base_throughput_bps: f64,
    /// Erasure probability shared by all links.
    pub erasure_prob: f64,
    /// Setup-transfer accounting model (see [`SetupCostKind`]).
    pub setup_cost: SetupCostKind,
}

impl Fleet {
    /// Build the paper's fleet from a config:
    ///
    /// * MAC rates `MACRᵢ = (1−ν_comp)^i · base`, i = 0..n−1, shuffled —
    ///   `aᵢ = d / MACRᵢ`, `μᵢ = mem_overhead_factor / aᵢ`.
    /// * Link throughputs `(1−ν_link)^i · base`, shuffled independently —
    ///   `τᵢ = packet_bits / throughputᵢ`.
    /// * Master MAC rate = `master_speedup ×` the *base* (fastest) rate,
    ///   zero-latency link, same memory-overhead model.
    ///
    /// With `cfg.ladder_tiers = T > 0` the ladder exponent is `i mod T`
    /// instead of `i`: the fleet tiles T distinct rungs, so a
    /// million-device fleet keeps the paper's heterogeneity *spread*
    /// (T = 24 mirrors the §IV 24-device ladder) without the slowest
    /// rate underflowing to zero. T = 0 is the per-device ladder,
    /// byte-identical to the pre-tier construction.
    pub fn from_config(cfg: &ExperimentConfig, rng: &mut Rng) -> Self {
        let n = cfg.n_devices;
        let d = cfg.model_dim as f64;
        let pkt = packet_bits(cfg.model_dim, cfg.header_overhead);
        let rung = |i: usize| {
            if cfg.ladder_tiers > 0 { (i % cfg.ladder_tiers) as i32 } else { i as i32 }
        };

        // compute ladder
        let mut mac_rates: Vec<f64> = (0..n)
            .map(|i| (1.0 - cfg.nu_comp).powi(rung(i)) * cfg.base_mac_rate_kmacs * 1000.0)
            .collect();
        let mut comp_rng = rng.split(0xFEE7);
        comp_rng.shuffle(&mut mac_rates);

        // link ladder (independent shuffle)
        let mut throughputs: Vec<f64> = (0..n)
            .map(|i| (1.0 - cfg.nu_link).powi(rung(i)) * cfg.base_throughput_kbps * 1000.0)
            .collect();
        let mut link_rng = rng.split(0x11CC);
        link_rng.shuffle(&mut throughputs);

        let devices: Vec<DeviceProfile> = (0..n)
            .map(|i| {
                let a = d / mac_rates[i];
                DeviceProfile {
                    compute: ComputeModel {
                        secs_per_point: a,
                        mem_rate: cfg.mem_overhead_factor / a,
                    },
                    link: LinkModel {
                        secs_per_packet: pkt / throughputs[i],
                        erasure_prob: cfg.erasure_prob,
                    },
                    points: cfg.points_per_device,
                }
            })
            .collect();

        let a_master = d / (cfg.master_speedup * cfg.base_mac_rate_kmacs * 1000.0);
        let master = DeviceProfile {
            compute: ComputeModel {
                secs_per_point: a_master,
                mem_rate: cfg.mem_overhead_factor / a_master,
            },
            link: LinkModel::zero(),
            points: (cfg.c_up_fraction * cfg.total_points() as f64) as usize,
        };

        Self {
            devices,
            master,
            throughputs_bps: throughputs,
            packet_bits: pkt,
            base_throughput_bps: cfg.base_throughput_kbps * 1000.0,
            erasure_prob: cfg.erasure_prob,
            setup_cost: cfg.setup_cost,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Total raw points held by the edge (m of the paper).
    pub fn total_points(&self) -> usize {
        self.devices.iter().map(|p| p.points).sum()
    }

    /// Override per-device shard sizes (non-equal sharding policies).
    pub fn set_points(&mut self, points: &[usize]) {
        assert_eq!(points.len(), self.devices.len());
        for (dev, &p) in self.devices.iter_mut().zip(points) {
            dev.points = p;
        }
    }

    /// Simulated seconds for device `i` to upload `rows` parity rows —
    /// the one-time setup cost that delays the start of CFL training
    /// (the Fig. 2 initial offsets). `row_bits` is the size of one parity
    /// row ((d+1) floats + header).
    ///
    /// The accounting model is configurable (see [`SetupCostKind`]): the
    /// paper's figures imply base-rate bulk accounting; adapted-rate and
    /// per-packet are provided for the ablation bench.
    pub fn sample_parity_upload_secs(
        &self,
        device: usize,
        rows: usize,
        row_bits: f64,
        rng: &mut Rng,
    ) -> f64 {
        let q = 1.0 - self.erasure_prob;
        match self.setup_cost {
            SetupCostKind::BaseRate => rows as f64 * row_bits / self.base_throughput_bps / q,
            SetupCostKind::AdaptedRate => {
                rows as f64 * row_bits / self.throughputs_bps[device] / q
            }
            SetupCostKind::PerPacket => {
                // one geometric draw per row at the adapted per-packet time,
                // scaled to the parity row size
                let scale = row_bits / self.packet_bits;
                self.devices[device].link.sample_bulk_transfer(rows, rng) * scale
            }
        }
    }

    /// Expected parity upload seconds (analytic twin of
    /// [`Fleet::sample_parity_upload_secs`]).
    pub fn mean_parity_upload_secs(&self, device: usize, rows: usize, row_bits: f64) -> f64 {
        let q = 1.0 - self.erasure_prob;
        match self.setup_cost {
            SetupCostKind::BaseRate => rows as f64 * row_bits / self.base_throughput_bps / q,
            SetupCostKind::AdaptedRate => {
                rows as f64 * row_bits / self.throughputs_bps[device] / q
            }
            SetupCostKind::PerPacket => {
                let l = &self.devices[device].link;
                rows as f64 * l.secs_per_packet * (row_bits / self.packet_bits) / q
            }
        }
    }
}
