//! Cross-host placement manifests: which host runs which fleet slot.
//!
//! A placement file is the small INI document behind
//! `cfl sweep --live --transport tcp --placement <file>` and
//! `cfl serve --placement <file>`:
//!
//! ```ini
//! [placement]
//! bind = 0.0.0.0:7070       # where the coordinator listens
//! accept_timeout_secs = 120 # how long to wait for the fleet to form
//! device.0 = local          # slots the coordinator hosts itself
//! device.1 = hostB          # slots some other machine contributes
//! device.2 = hostB
//! ```
//!
//! Slots not listed default to `local`. The host *labels* are
//! documentation, not addresses: devices dial the coordinator (never the
//! reverse), so a label only groups slots into the one `cfl device
//! --slots a,b,c` invocation its host must run — the coordinator prints
//! that exact command for every remote label at startup and then waits
//! for the connections. A manifest with remote slots must therefore bind
//! a fixed, reachable address (`0.0.0.0:7070`, not the `127.0.0.1:0`
//! default that only loopback fleets can use).

use crate::config::Ini;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Default formation window: remote hosts are started by a human.
const DEFAULT_ACCEPT_TIMEOUT: Duration = Duration::from_secs(60);

/// A parsed placement manifest. Constructed by [`Placement::load`] /
/// [`Placement::from_ini`]; consumed by `TcpTransport::spawn_placed`.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    bind: Option<String>,
    accept_timeout: Duration,
    /// Explicit `device.K = <label>` assignments; `local` is stored
    /// verbatim. Unlisted slots are implicitly local.
    hosts: BTreeMap<usize, String>,
}

impl Placement {
    /// Load a manifest file.
    pub fn load(path: &str) -> Result<Self> {
        Self::from_ini(&Ini::load(path)?).with_context(|| format!("placement manifest {path}"))
    }

    /// Parse an already-loaded INI document's `[placement]` section.
    pub fn from_ini(ini: &Ini) -> Result<Self> {
        let mut hosts = BTreeMap::new();
        for key in ini.keys("placement") {
            if let Some(slot) = key.strip_prefix("device.") {
                let slot: usize = slot
                    .parse()
                    .map_err(|e| anyhow::anyhow!("[placement] {key}: bad slot number: {e}"))?;
                let label = ini.get("placement", key).unwrap_or("local").trim();
                if label.is_empty() {
                    bail!("[placement] {key}: empty host label");
                }
                hosts.insert(slot, label.to_string());
            } else if !matches!(key, "bind" | "accept_timeout_secs") {
                bail!("[placement] unknown key '{key}' (expected bind, accept_timeout_secs, or device.K)");
            }
        }
        let secs: u64 = ini.get_or(
            "placement",
            "accept_timeout_secs",
            DEFAULT_ACCEPT_TIMEOUT.as_secs(),
        )?;
        if secs == 0 {
            bail!("[placement] accept_timeout_secs must be positive");
        }
        Ok(Self {
            bind: ini.get("placement", "bind").map(str::to_string),
            accept_timeout: Duration::from_secs(secs),
            hosts,
        })
    }

    /// Where the coordinator should listen. Defaults to an ephemeral
    /// loopback port, which [`Placement::validate`] rejects whenever any
    /// slot is remote.
    pub fn bind_addr(&self) -> &str {
        self.bind.as_deref().unwrap_or("127.0.0.1:0")
    }

    /// The manifest's `bind`, only if it set one — `cfl serve` lets an
    /// explicit `--bind` override it and falls back to its own default
    /// otherwise.
    pub fn explicit_bind(&self) -> Option<&str> {
        self.bind.as_deref()
    }

    /// How long fleet formation may take.
    pub fn accept_timeout(&self) -> Duration {
        self.accept_timeout
    }

    /// Whether `slot` is assigned to a remote host label.
    pub fn is_remote(&self, slot: usize) -> bool {
        self.hosts.get(&slot).is_some_and(|h| h != "local")
    }

    /// Full validation for the path that also binds: slot range plus the
    /// remote-requires-reachable-bind rule.
    pub fn validate(&self, n: usize) -> Result<()> {
        self.validate_slots(n)?;
        let any_remote = (0..n).any(|s| self.is_remote(s));
        if any_remote {
            let bind = self.bind_addr();
            if self.bind.is_none() || bind.ends_with(":0") {
                bail!(
                    "placement assigns remote hosts but binds '{bind}': remote devices need a \
                     fixed, reachable address (e.g. bind = 0.0.0.0:7070)"
                );
            }
        }
        Ok(())
    }

    /// Range-check the explicit slot assignments against the fleet size
    /// (the serve path, where the caller already owns the listener).
    pub fn validate_slots(&self, n: usize) -> Result<()> {
        for (&slot, label) in &self.hosts {
            if slot >= n {
                bail!("[placement] device.{slot} = {label}: slot outside the {n}-device fleet");
            }
        }
        Ok(())
    }

    /// The slots the coordinator's own machine hosts (explicitly `local`
    /// or unlisted), in order.
    pub fn local_slots(&self, n: usize) -> Vec<usize> {
        (0..n).filter(|&s| !self.is_remote(s)).collect()
    }

    /// Remote label → its slots, in order — one `cfl device --slots`
    /// invocation per label.
    pub fn remote_hosts(&self, n: usize) -> BTreeMap<String, Vec<usize>> {
        let mut out: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (&slot, label) in &self.hosts {
            if slot < n && label != "local" {
                out.entry(label.clone()).or_default().push(slot);
            }
        }
        out
    }
}
