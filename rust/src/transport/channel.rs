//! In-process transport: one worker thread per device, `mpsc` channels.
//!
//! This is the transport the live coordinator always used, factored out
//! behind [`Transport`]. Workers are spawned once at construction and
//! persist across runs (mirroring a TCP fleet's long-lived connections):
//! each runs [`run_device_loop`] over a channel-backed [`DeviceLink`],
//! so the device-side behavior is byte-for-byte the one a `cfl device`
//! process exhibits — only the wire differs.
//!
//! The endpoint lifecycle mirrors TCP's too: a worker that dies surfaces
//! as [`Event::Gone`], and a *respawned* worker ([`ChannelCtl::respawn`])
//! surfaces as [`Event::Rejoined`] — the in-process analogue of a killed
//! `cfl device --retry` process reconnecting. Every incarnation of a
//! slot carries a generation tag; events queued by a previous
//! incarnation (a late reply, a stale death notice) are discarded when a
//! newer incarnation holds the slot, exactly like the TCP transport.

use super::{
    note_gone, note_rejoin, run_device_loop, stale_discard, DeviceInit, DeviceLink, Event,
    FromDevice, ToDevice, Transport,
};
use crate::obs::Counter;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Everything that can land on the transport's single event queue: a
/// worker upstream message (tagged with the incarnation that sent it) or
/// a fault-injection command from a [`ChannelCtl`]. One queue keeps the
/// ordering between a death notice and the respawn that follows it.
enum ChanEvent {
    Msg(usize, u64, FromDevice),
    Gone(usize, u64),
    Kill(usize),
    Respawn(usize),
}

/// A device worker's end of the channel pair.
struct ChannelLink {
    slot: usize,
    gen: u64,
    rx: mpsc::Receiver<ToDevice>,
    up: mpsc::Sender<ChanEvent>,
}

impl DeviceLink for ChannelLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        Ok(self.rx.recv().ok()) // a closed channel is a clean hang-up
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        // the coordinator dropping its receiver mid-reply is a hang-up,
        // not a device fault — swallow it and let the next recv() end us
        let _ = self.up.send(ChanEvent::Msg(self.slot, self.gen, msg));
        Ok(())
    }
}

/// Fault-injection handle onto a [`ChannelTransport`]: kill a worker
/// (the in-process stand-in for SIGKILLing a `cfl device` process) and
/// respawn a fresh incarnation into a dead slot (the stand-in for
/// restarting it with `--retry`). Clonable and `Send`, so tests drive
/// churn from another thread while the coordinator trains.
#[derive(Clone)]
pub struct ChannelCtl {
    tx: mpsc::Sender<ChanEvent>,
}

impl ChannelCtl {
    /// Kill the worker in `slot`: its command channel closes, the worker
    /// exits, and the coordinator observes [`Event::Gone`].
    pub fn kill(&self, slot: usize) {
        let _ = self.tx.send(ChanEvent::Kill(slot));
    }

    /// Respawn a fresh worker into a dead `slot`; the coordinator
    /// observes [`Event::Rejoined`] and must re-send `Setup`. A respawn
    /// of a still-live slot is ignored.
    pub fn respawn(&self, slot: usize) {
        let _ = self.tx.send(ChanEvent::Respawn(slot));
    }
}

/// Threaded in-process fleet: `n` persistent device workers.
pub struct ChannelTransport {
    to_devices: Vec<Option<mpsc::Sender<ToDevice>>>,
    /// Current incarnation per slot; bumped on respawn so stale events
    /// from an earlier incarnation can be recognized and dropped.
    gens: Vec<u64>,
    up_rx: mpsc::Receiver<ChanEvent>,
    up_tx: mpsc::Sender<ChanEvent>,
    handles: Vec<thread::JoinHandle<()>>,
    /// Public events decoded from the queue but not yet handed to the
    /// caller.
    pending: VecDeque<Event>,
    /// Fleet-traffic counters (message counts only — the in-process wire
    /// never serializes, so there are no byte totals to report). Shared
    /// names with the TCP transport, resolved once so the epoch hot path
    /// stays lock-free.
    frames_sent: Counter,
    frames_recv: Counter,
}

/// Spawn one worker incarnation; returns the coordinator-side sender.
fn spawn_worker(
    slot: usize,
    gen: u64,
    up_tx: &mpsc::Sender<ChanEvent>,
    handles: &mut Vec<thread::JoinHandle<()>>,
) -> mpsc::Sender<ToDevice> {
    let (tx, rx) = mpsc::channel::<ToDevice>();
    let up = up_tx.clone();
    handles.push(thread::spawn(move || {
        let mut link = ChannelLink { slot, gen, rx, up };
        // any exit — compute failure, protocol violation, or a closed
        // command channel (kill/Drop) — reports the incarnation as gone
        // so the gather degrades instead of waiting out its deadline.
        // After Shutdown/Drop nobody reads the queue, so the notice is
        // inert there; after a kill it is the death the coordinator must
        // observe.
        let _ = run_device_loop(&mut link);
        let _ = link.up.send(ChanEvent::Gone(slot, gen));
    }));
    tx
}

impl ChannelTransport {
    /// Spawn `n` device workers, all idle until their first `Setup`.
    pub fn new(n: usize) -> Self {
        let (up_tx, up_rx) = mpsc::channel::<ChanEvent>();
        let mut to_devices = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for slot in 0..n {
            let tx = spawn_worker(slot, 0, &up_tx, &mut handles);
            to_devices.push(Some(tx));
        }
        let reg = crate::obs::registry();
        Self {
            to_devices,
            gens: vec![0; n],
            up_rx,
            up_tx,
            handles,
            pending: VecDeque::new(),
            frames_sent: reg.counter("transport.frames_sent"),
            frames_recv: reg.counter("transport.frames_recv"),
        }
    }

    /// A fault-injection handle (see [`ChannelCtl`]).
    pub fn controller(&self) -> ChannelCtl {
        ChannelCtl { tx: self.up_tx.clone() }
    }
}

/// Apply one queued control/upstream event, buffering any public events
/// in `pending` (none for an internal event — a kill command, a
/// stale-incarnation notice to discard). A free function over the
/// transport's split fields so [`super::drive_queue`] can borrow the
/// receiver and this state simultaneously.
#[allow(clippy::too_many_arguments)]
fn process_event(
    ev: ChanEvent,
    to_devices: &mut [Option<mpsc::Sender<ToDevice>>],
    gens: &mut [u64],
    up_tx: &mpsc::Sender<ChanEvent>,
    handles: &mut Vec<thread::JoinHandle<()>>,
    frames_recv: &Counter,
    pending: &mut VecDeque<Event>,
) {
    match ev {
        ChanEvent::Msg(slot, gen, msg) => {
            // a reply from a dead incarnation must not be attributed
            // to its replacement
            if gens.get(slot).copied() != Some(gen) {
                stale_discard(slot, gen);
                return;
            }
            frames_recv.incr();
            pending.push_back(Event::Msg(slot, msg));
        }
        ChanEvent::Gone(slot, gen) => {
            if gens.get(slot).copied() != Some(gen) {
                stale_discard(slot, gen);
                return; // stale death notice: the slot respawned
            }
            // a death notice is one-shot: record it at the transport
            // level too, so the endpoint stays dead across runs until
            // a respawn re-claims the slot
            if let Some(tx) = to_devices.get_mut(slot) {
                *tx = None;
            }
            note_gone(slot, gen);
            pending.push_back(Event::Gone(slot));
        }
        ChanEvent::Kill(slot) => {
            // close the command channel; the worker exits and its own
            // Gone notice is the observable death
            if let Some(tx) = to_devices.get_mut(slot) {
                *tx = None;
            }
        }
        ChanEvent::Respawn(slot) => {
            let (Some(tx_slot), Some(gen)) = (to_devices.get_mut(slot), gens.get_mut(slot)) else {
                return; // out of range
            };
            if tx_slot.is_some() {
                return; // the slot is still live
            }
            *gen += 1;
            *tx_slot = Some(spawn_worker(slot, *gen, up_tx, handles));
            note_rejoin(slot, *gen);
            pending.push_back(Event::Rejoined(slot));
        }
    }
}

impl Transport for ChannelTransport {
    fn name(&self) -> &'static str {
        "chan"
    }

    fn n_endpoints(&self) -> usize {
        self.to_devices.len()
    }

    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<Vec<bool>> {
        let mut delivered = Vec::with_capacity(inits.len());
        for init in inits {
            let slot = init.device_index;
            anyhow::ensure!(
                slot < self.to_devices.len(),
                "device index {slot} outside the {}-endpoint fleet",
                self.to_devices.len()
            );
            // move the init into the worker's channel instead of going
            // through send()'s msg.clone() — Setup carries the device's
            // whole systematic shard, which must not be deep-copied per
            // run. A dead worker is skipped, not fatal: the coordinator
            // sees `false` here and treats the slot as awaiting rejoin.
            let Some(tx) = self.to_devices[slot].as_ref() else {
                delivered.push(false);
                continue;
            };
            if tx.send(ToDevice::Setup(Box::new(init))).is_err() {
                self.to_devices[slot] = None;
                delivered.push(false);
            } else {
                self.frames_sent.incr();
                delivered.push(true);
            }
        }
        Ok(delivered)
    }

    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool> {
        let Some(tx) = self.to_devices.get(slot).and_then(|t| t.as_ref()) else {
            return Ok(false);
        };
        if tx.send(msg.clone()).is_err() {
            self.to_devices[slot] = None;
            return Ok(false);
        }
        self.frames_sent.incr();
        Ok(true)
    }

    fn disconnect(&mut self, slot: usize) {
        // close the command channel: the worker exits and its death
        // notice (current generation) is deduplicated by the caller's
        // own bookkeeping — or discarded outright if a respawn bumps the
        // generation first
        if let Some(tx) = self.to_devices.get_mut(slot) {
            *tx = None;
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Event {
        let Self { up_rx, to_devices, gens, up_tx, handles, pending, frames_recv, .. } = self;
        super::drive_queue(up_rx, timeout, pending, |ev, pending| {
            process_event(ev, to_devices, gens, up_tx, handles, frames_recv, pending)
        })
    }

    fn end_run(&mut self) {
        for slot in 0..self.to_devices.len() {
            let _ = self.send(slot, &ToDevice::Stop);
        }
        // drop stale in-flight replies (a worker still sleeping out a
        // delay may reply after Stop; run tagging makes these inert, but
        // there is no reason to queue them into the next run) — while
        // still honoring lifecycle *side effects*: a death notice must
        // stick or a dead worker would be re-entered into the next run's
        // fleet, and a respawn admitted here is simply live for the next
        // run (its Setup arrives with the next begin_run). The public
        // events themselves are discarded — begin_run's per-slot delivery
        // flags carry that information into the next run instead.
        while let Ok(ev) = self.up_rx.try_recv() {
            let Self { to_devices, gens, up_tx, handles, pending, frames_recv, .. } = self;
            process_event(ev, to_devices, gens, up_tx, handles, frames_recv, pending);
        }
        self.pending.clear();
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        for slot in 0..self.to_devices.len() {
            let _ = self.send(slot, &ToDevice::Shutdown);
        }
        self.to_devices.clear(); // close the channels: belt and braces
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
