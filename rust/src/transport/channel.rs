//! In-process transport: one worker thread per device, `mpsc` channels.
//!
//! This is the transport the live coordinator always used, factored out
//! behind [`Transport`]. Workers are spawned once at construction and
//! persist across runs (mirroring a TCP fleet's long-lived connections):
//! each runs [`run_device_loop`] over a channel-backed [`DeviceLink`],
//! so the device-side behavior is byte-for-byte the one a `cfl device`
//! process exhibits — only the wire differs.

use super::{
    recv_event, run_device_loop, DeviceInit, DeviceLink, Event, FromDevice, ToDevice, Transport, Up,
};
use anyhow::Result;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// A device worker's end of the channel pair.
struct ChannelLink {
    slot: usize,
    rx: mpsc::Receiver<ToDevice>,
    up: mpsc::Sender<(usize, Up)>,
}

impl DeviceLink for ChannelLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        Ok(self.rx.recv().ok()) // a closed channel is a clean hang-up
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        // the coordinator dropping its receiver mid-reply is a hang-up,
        // not a device fault — swallow it and let the next recv() end us
        let _ = self.up.send((self.slot, Up::Msg(msg)));
        Ok(())
    }
}

/// Threaded in-process fleet: `n` persistent device workers.
pub struct ChannelTransport {
    to_devices: Vec<Option<mpsc::Sender<ToDevice>>>,
    up_rx: mpsc::Receiver<(usize, Up)>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ChannelTransport {
    /// Spawn `n` device workers, all idle until their first `Setup`.
    pub fn new(n: usize) -> Self {
        let (up_tx, up_rx) = mpsc::channel::<(usize, Up)>();
        let mut to_devices = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for slot in 0..n {
            let (tx, rx) = mpsc::channel::<ToDevice>();
            to_devices.push(Some(tx));
            let up = up_tx.clone();
            handles.push(thread::spawn(move || {
                let mut link = ChannelLink { slot, rx, up };
                if run_device_loop(&mut link).is_err() {
                    // compute failure / protocol violation: report the
                    // endpoint as gone so the gather degrades instead of
                    // waiting out its deadline every epoch
                    let _ = link.up.send((slot, Up::Gone));
                }
            }));
        }
        Self { to_devices, up_rx, handles }
    }
}

impl Transport for ChannelTransport {
    fn name(&self) -> &'static str {
        "chan"
    }

    fn n_endpoints(&self) -> usize {
        self.to_devices.len()
    }

    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<()> {
        for init in inits {
            let slot = init.device_index;
            anyhow::ensure!(
                slot < self.to_devices.len(),
                "device index {slot} outside the {}-endpoint fleet",
                self.to_devices.len()
            );
            // move the init into the worker's channel instead of going
            // through send()'s msg.clone() — Setup carries the device's
            // whole systematic shard, which must not be deep-copied per
            // run. A dead worker is skipped, not fatal: the coordinator
            // observes it via Gone/failed sends and degrades.
            let Some(tx) = self.to_devices[slot].as_ref() else { continue };
            if tx.send(ToDevice::Setup(Box::new(init))).is_err() {
                self.to_devices[slot] = None;
            }
        }
        Ok(())
    }

    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool> {
        let Some(tx) = self.to_devices.get(slot).and_then(|t| t.as_ref()) else {
            return Ok(false);
        };
        if tx.send(msg.clone()).is_err() {
            self.to_devices[slot] = None;
            return Ok(false);
        }
        Ok(true)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Event {
        let event = recv_event(&self.up_rx, timeout);
        // a death notice is one-shot: record it at the transport level
        // too, so the endpoint stays dead across runs
        if let Event::Gone(slot) = event {
            if let Some(tx) = self.to_devices.get_mut(slot) {
                *tx = None;
            }
        }
        event
    }

    fn end_run(&mut self) {
        for slot in 0..self.to_devices.len() {
            let _ = self.send(slot, &ToDevice::Stop);
        }
        // drop stale in-flight replies (a worker still sleeping out a
        // delay may reply after Stop; run tagging makes these inert, but
        // there is no reason to queue them into the next run) — except
        // death notices, which must outlive the drain or a dead worker
        // would be re-entered into the next run's fleet
        while let Ok((slot, up)) = self.up_rx.try_recv() {
            if let Up::Gone = up {
                if let Some(tx) = self.to_devices.get_mut(slot) {
                    *tx = None;
                }
            }
        }
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        for slot in 0..self.to_devices.len() {
            let _ = self.send(slot, &ToDevice::Shutdown);
        }
        self.to_devices.clear(); // close the channels: belt and braces
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
