//! Device transports: how the live coordinator reaches its fleet.
//!
//! The paper's protocol is a *client/server* one — Prakash et al. (2020)
//! describe the same CFL scheme explicitly as devices talking to an MEC
//! server over a wireless link — and this module makes the live
//! coordinator's wire pluggable so the fleet can be threads **or** real
//! OS processes:
//!
//! * [`ToDevice`] / [`FromDevice`] — the message vocabulary of one
//!   training session: per-run `Setup`, per-epoch `Model` broadcast and
//!   `Grad` reply, `Ping`/`Pong` deadline calibration, `Stop` (end of a
//!   run) and `Shutdown` (end of the session).
//! * [`frame`] — a hand-rolled length-prefixed binary encoding of those
//!   messages (no external serde; the build is offline).
//! * [`Transport`] — the coordinator-side abstraction: hand every device
//!   its frozen §III-A state ([`DeviceInit`]), broadcast models, gather
//!   replies with a timeout, and observe the endpoint lifecycle —
//!   death as [`Event::Gone`] (a disconnected device degrades to the
//!   paper's erasure case: parity stands in instead of stalling the
//!   gather) and re-admission as [`Event::Rejoined`] (a restarted device
//!   claims its old slot back and returns to the coded gather set).
//! * [`ChannelTransport`] — in-process `mpsc` channel pairs, one worker
//!   thread per device (the transport the live coordinator always had,
//!   factored out). [`ChannelCtl`] injects kill/respawn, mirroring a
//!   real process dying and reconnecting.
//! * [`TcpTransport`] — TCP with the [`frame`] wire format: `cfl serve`
//!   accepts one socket per device (or per multi-slot `cfl device
//!   --slots` process), `cfl device` joins from another process or
//!   another machine on a trusted network. All endpoint I/O runs on one
//!   readiness-driven event-loop thread ([`reactor`]) — O(1) threads in
//!   the fleet size. The listener keeps accepting after fleet formation,
//!   so `cfl device --retry` ([`run_device_retry`]) survives being
//!   killed mid-run. [`Placement`] maps fleet slots onto hosts for the
//!   cross-host case.
//!
//! Both transports drive the *same* device-side state machine,
//! [`run_device_loop`]: a device is Setup-configured, computes a partial
//! gradient per `Model`, sleeps out its simulated §II-A delay scaled by
//! `time_scale`, and replies. The coordinator never knows which transport
//! it is talking through.

use crate::fl::{GradBackend, NativeBackend};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::DeviceProfile;
use anyhow::Result;
use std::thread;
use std::time::Duration;

pub mod frame;
pub mod placement;

mod channel;
mod reactor;
mod tcp;

pub use channel::{ChannelCtl, ChannelTransport};
pub use placement::Placement;
pub use tcp::{
    run_device, run_device_multi, run_device_multi_retry, run_device_retry, RetrySlots,
    TcpTransport,
};

/// Account one discarded stale-incarnation event (a reply or death
/// notice from a generation that no longer holds its slot) — shared by
/// both transports' generation filters.
fn stale_discard(slot: usize, gen: u64) {
    crate::obs::registry().counter(&format!("transport.slot{slot}.stale_discards")).incr();
    crate::obs_event!(Trace, "stale_discard", slot = slot, gen = gen);
}

/// Account an endpoint death at the transport level — shared by both
/// transports so the per-slot counters and events stay identical.
fn note_gone(slot: usize, gen: u64) {
    crate::obs::registry().counter(&format!("transport.slot{slot}.disconnects")).incr();
    crate::obs_event!(Debug, "endpoint_gone", slot = slot, gen = gen);
}

/// Account a re-admission (a fresh incarnation claiming a slot).
fn note_rejoin(slot: usize, gen: u64) {
    crate::obs::registry().counter(&format!("transport.slot{slot}.rejoins")).incr();
    crate::obs_event!(Info, "endpoint_rejoined", slot = slot, gen = gen);
}

/// The shared receive loop both transports' `recv_timeout` converge on:
/// surface buffered public events first, then pump the upstream queue
/// until one event becomes public or the deadline passes. `process`
/// applies one queue item's side effects and pushes any public events
/// it produces onto `pending` (possibly none — a stale-generation item
/// is swallowed, so the loop keeps draining).
fn drive_queue<T>(
    rx: &std::sync::mpsc::Receiver<T>,
    timeout: Duration,
    pending: &mut std::collections::VecDeque<Event>,
    mut process: impl FnMut(T, &mut std::collections::VecDeque<Event>),
) -> Event {
    use std::sync::mpsc::RecvTimeoutError;
    if let Some(ev) = pending.pop_front() {
        return ev;
    }
    let deadline = std::time::Instant::now() + timeout;
    loop {
        let now = std::time::Instant::now();
        let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero()) else {
            return Event::Timeout;
        };
        match rx.recv_timeout(left) {
            Ok(item) => {
                process(item, pending);
                if let Some(ev) = pending.pop_front() {
                    return ev;
                }
            }
            Err(RecvTimeoutError::Timeout) => return Event::Timeout,
            Err(RecvTimeoutError::Disconnected) => return Event::Closed,
        }
    }
}

/// Which wire a live fleet speaks — the `--transport` CLI knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channel pairs, one worker thread per device.
    #[default]
    Channel,
    /// TCP loopback, one `cfl device` subprocess per device.
    Tcp,
}

impl TransportKind {
    /// Parse the CLI spelling (`chan` / `tcp`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "chan" | "channel" | "thread" => Ok(TransportKind::Channel),
            "tcp" => Ok(TransportKind::Tcp),
            other => anyhow::bail!("unknown transport '{other}' (expected chan or tcp)"),
        }
    }

    /// The CLI tag (`chan` / `tcp`).
    pub fn tag(&self) -> &'static str {
        match self {
            TransportKind::Channel => "chan",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Everything a device endpoint needs to run one training run: its frozen
/// §III-A systematic shard, the §II-A delay model it must emulate, and the
/// run bookkeeping that keeps replies attributable across runs.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceInit {
    /// Coordinator-side run counter, echoed in every [`FromDevice::Grad`]
    /// so a straggler from a finished run can never pollute the next one.
    pub run: u64,
    /// Fleet index of this device (also its transport slot).
    pub device_index: usize,
    /// Assigned systematic load ℓᵢ* (rows of `x_sys`).
    pub load: usize,
    /// Seed of this device's private delay stream for the run.
    pub delay_seed: u64,
    /// Simulated-seconds → wall-seconds factor for the slept-out delays.
    pub time_scale: f64,
    /// Ceiling on any single scaled sleep, wall seconds.
    pub max_scaled_secs: f64,
    /// The §II-A compute + link model this device emulates.
    pub profile: DeviceProfile,
    /// Systematic submatrix (rows processed each epoch), ℓᵢ*×d.
    pub x_sys: Mat,
    /// Matching labels, ℓᵢ*×1.
    pub y_sys: Mat,
}

/// Coordinator → device messages.
#[derive(Clone, Debug, PartialEq)]
pub enum ToDevice {
    /// Begin a run with this frozen state (boxed: the shard payload dwarfs
    /// every other variant).
    Setup(Box<DeviceInit>),
    /// (epoch, β) — compute a partial gradient and reply with `Grad`.
    Model { epoch: usize, beta: Mat },
    /// Deadline-calibration echo request; answer `Pong` immediately.
    Ping { nonce: u64 },
    /// End of the current run; await the next `Setup`.
    Stop,
    /// End of the session; the endpoint exits.
    Shutdown,
}

/// Device → coordinator messages.
#[derive(Clone, Debug, PartialEq)]
pub enum FromDevice {
    /// First message on a fresh TCP connection: claim a fleet slot.
    Hello { device_id: usize, protocol: u32 },
    /// Echo reply to `Ping`.
    Pong { nonce: u64 },
    /// A partial gradient, tagged with the run/epoch it belongs to and
    /// the §II-A delay (uncapped, simulated seconds) it emulated.
    Grad { run: u64, epoch: usize, grad: Mat, delay: f64 },
    /// First message on a fresh multi-slot TCP connection: one `cfl
    /// device --slots a,b,c` process claims several fleet slots at once.
    /// All subsequent traffic on the connection is slot-wrapped (see
    /// [`frame::wrap_slot`]).
    HelloMulti { device_ids: Vec<usize>, protocol: u32 },
}

/// What the coordinator's gather loop observes on one receive call.
#[derive(Debug)]
pub enum Event {
    /// A message from the device in `slot`.
    Msg(usize, FromDevice),
    /// The endpoint in `slot` is gone (thread death, socket EOF, framing
    /// error). The coordinator degrades that device to parity-only until
    /// the endpoint rejoins.
    Gone(usize),
    /// A fresh endpoint re-claimed the previously dead `slot` (a
    /// restarted `cfl device --retry` process, a respawned channel
    /// worker). The new incarnation holds no run state: the coordinator
    /// must re-send `Setup` before the next `Model` reaches it.
    Rejoined(usize),
    /// Nothing arrived within the timeout.
    Timeout,
    /// Every endpoint is gone and no more events can ever arrive. With a
    /// re-admission-capable transport (both built-ins, since a rejoin
    /// may always arrive later) this never fires — a dead fleet surfaces
    /// as individual [`Event::Gone`]s followed by [`Event::Timeout`]s —
    /// but callers should keep handling it: a transport without
    /// re-admission uses it to let the gather bail immediately.
    Closed,
}

/// Coordinator-side handle on a device fleet. One instance spans a whole
/// session (several runs — e.g. `train_cfl` then `train_uncoded` reuse
/// the same endpoints); [`Transport::begin_run`] re-arms the endpoints
/// named by its [`DeviceInit`] batch, and slots not named simply sit out
/// that run (zero-load devices under a coded policy).
///
/// **Endpoint lifecycle.** A slot is *live* until the transport observes
/// its death (socket EOF, worker exit, failed write), which surfaces
/// once as [`Event::Gone`]. Death is not terminal: a transport that
/// supports re-admission (both built-ins do) may later surface
/// [`Event::Rejoined`] for the same slot when a fresh incarnation claims
/// it — the TCP listener keeps accepting after fleet formation and
/// re-admits a `Hello{id}` for its slot (severing a lingering half-open
/// link whose death notice never landed); the channel transport
/// respawns a worker on [`ChannelCtl::respawn`]. A rejoined incarnation
/// starts blank: it must receive a new `Setup` before any `Model`, and
/// events queued by the *previous* incarnation (its death notice, any
/// in-flight replies) are discarded at the transport level via
/// per-incarnation generation tags, so a stale `Gone` can never kill the
/// replacement and a stale reply can never be attributed to it.
pub trait Transport: Send {
    /// Transport tag for logs ("chan" / "tcp").
    fn name(&self) -> &'static str;

    /// Total endpoint slots (== the fleet size).
    fn n_endpoints(&self) -> usize;

    /// Start a run: deliver each [`DeviceInit`] to its endpoint. Returns
    /// per-init delivery flags aligned with the batch — `false` marks an
    /// endpoint that is currently dead (its `Setup` was not delivered;
    /// the coordinator treats the slot as awaiting a rejoin). `Err` is a
    /// transport-fatal fault.
    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<Vec<bool>>;

    /// Send to the endpoint in `slot`. `Ok(false)` means the endpoint is
    /// gone (the message was dropped); `Err` is a transport-fatal fault.
    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool>;

    /// Send one message to many endpoints, returning per-slot delivery
    /// flags aligned with `slots` (the epoch broadcast hot path).
    /// Implementations may serialize the message once for the whole
    /// fleet; the default just loops over [`Transport::send`].
    fn broadcast(&mut self, slots: &[usize], msg: &ToDevice) -> Result<Vec<bool>> {
        slots.iter().map(|&slot| self.send(slot, msg)).collect()
    }

    /// Wait up to `timeout` for the next event from any endpoint.
    fn recv_timeout(&mut self, timeout: Duration) -> Event;

    /// Forcibly sever the endpoint in `slot`. The coordinator calls this
    /// for an endpoint it has declared dead without a transport-level
    /// death (a silently-partitioned socket that answers no pings but
    /// whose writes still land in the kernel buffer): the half-open link
    /// would otherwise linger and block a restarted device from
    /// rejoining its slot. After this call the slot is immediately
    /// re-admittable; any later death notice from the old incarnation is
    /// deduplicated as usual.
    fn disconnect(&mut self, slot: usize);

    /// End the current run: `Stop` every live endpoint and discard any
    /// stale in-flight replies. Best-effort by design.
    fn end_run(&mut self);
}

/// How one device session ended, from the device's point of view — the
/// signal [`run_device_retry`] uses to decide between exiting and
/// reconnecting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionEnd {
    /// The coordinator sent an explicit `Shutdown`: the session is over.
    Shutdown,
    /// The link closed without a `Shutdown` (coordinator hang-up, or this
    /// connection was never admitted). A retrying device reconnects.
    HangUp,
}

/// One side of a device's conversation with its coordinator — the only
/// surface [`run_device_loop`] needs, so channel workers and TCP device
/// processes share one state machine.
pub trait DeviceLink {
    /// Next coordinator message; `Ok(None)` means the coordinator hung up
    /// (a clean end of session).
    fn recv(&mut self) -> Result<Option<ToDevice>>;

    /// Send a reply upstream.
    fn send(&mut self, msg: FromDevice) -> Result<()>;
}

/// Per-run device state established by [`ToDevice::Setup`].
struct RunState {
    run: u64,
    load: usize,
    time_scale: f64,
    max_scaled_secs: f64,
    profile: DeviceProfile,
    x_sys: Mat,
    y_sys: Mat,
    rng: Rng,
}

/// The device-side state machine, identical for every transport:
///
/// * `Setup` freezes the run state (shard, delay model, RNG stream);
/// * `Ping` is answered immediately (no simulated delay — the RTT *is*
///   the host overhead being calibrated);
/// * `Model` computes the partial gradient, sleeps out the sampled §II-A
///   delay scaled by `time_scale`, and replies with `Grad`;
/// * `Stop` clears the run state; `Shutdown` (or a hang-up) returns.
///
/// Returns which way the session ended ([`SessionEnd::Shutdown`] vs a
/// bare [`SessionEnd::HangUp`] — retry loops reconnect only on the
/// latter); `Err` only on a protocol violation or compute failure — the
/// caller should treat that as this endpoint dying.
pub fn run_device_loop(link: &mut dyn DeviceLink) -> Result<SessionEnd> {
    let mut backend = NativeBackend;
    let mut state: Option<RunState> = None;
    loop {
        let Some(msg) = link.recv()? else {
            return Ok(SessionEnd::HangUp); // coordinator hung up
        };
        match msg {
            ToDevice::Setup(init) => {
                state = Some(RunState {
                    run: init.run,
                    load: init.load,
                    time_scale: init.time_scale,
                    max_scaled_secs: init.max_scaled_secs,
                    profile: init.profile,
                    x_sys: init.x_sys,
                    y_sys: init.y_sys,
                    rng: Rng::new(init.delay_seed),
                });
            }
            ToDevice::Ping { nonce } => link.send(FromDevice::Pong { nonce })?,
            ToDevice::Model { epoch, beta } => {
                let st = state
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("protocol violation: Model before Setup"))?;
                let grad = backend.partial_grad(&st.x_sys, &beta, &st.y_sys)?;
                // sleep out the simulated delay (compute + link)
                let delay = st.profile.sample_total_delay(st.load, &mut st.rng);
                thread::sleep(Duration::from_secs_f64(
                    (delay * st.time_scale).min(st.max_scaled_secs),
                ));
                link.send(FromDevice::Grad { run: st.run, epoch, grad, delay })?;
            }
            ToDevice::Stop => state = None,
            ToDevice::Shutdown => return Ok(SessionEnd::Shutdown),
        }
    }
}

/// The binary that hosts `cfl device` subprocesses for locally-spawned
/// TCP fleets (`cfl sweep --live --transport tcp`): the `CFL_BIN`
/// environment override if set, else the current executable — correct
/// whenever the spawner *is* the `cfl` binary; test harnesses set
/// `CFL_BIN` explicitly.
pub fn local_device_bin() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("CFL_BIN") {
        return Ok(p.into());
    }
    std::env::current_exe().map_err(|e| anyhow::anyhow!("resolving the cfl binary: {e}"))
}

#[cfg(test)]
mod tests;
