//! Length-prefixed binary framing for [`ToDevice`] / [`FromDevice`].
//!
//! The build is offline (no serde), so the wire format is hand-rolled and
//! deliberately dull: every frame is
//!
//! ```text
//! frame   := len:u32le payload            (len = payload bytes, ≤ 64 MiB)
//! payload := tag:u8 body
//! ```
//!
//! with all integers little-endian, floats as IEEE-754 LE bit patterns,
//! and matrices as `rows:u32 cols:u32 data:f32le×(rows·cols)`. Message
//! bodies (see the tag constants for the full table):
//!
//! | tag | message  | body |
//! |-----|----------|------|
//! | 1   | Setup    | run:u64 device:u32 load:u32 seed:u64 time_scale:f64 max_scaled:f64 profile(5 fields) x_sys:mat y_sys:mat |
//! | 2   | Model    | epoch:u64 beta:mat |
//! | 3   | Ping     | nonce:u64 |
//! | 4   | Stop     | — |
//! | 5   | Shutdown | — |
//! | 64  | Hello    | device:u32 protocol:u32 |
//! | 65  | Pong     | nonce:u64 |
//! | 66  | Grad     | run:u64 epoch:u64 delay:f64 grad:mat |
//!
//! (a device profile is `secs_per_point:f64 mem_rate:f64
//! secs_per_packet:f64 erasure_prob:f64 points:u32`.)
//!
//! Decoding is defensive: an oversized length prefix, a truncated frame,
//! an unknown tag, or matrix dimensions that don't fit the payload are
//! all hard errors — the reader treats them as the peer dying, never as
//! something to resynchronize past.

use super::{DeviceInit, FromDevice, ToDevice};
use crate::linalg::Mat;
use crate::simnet::{ComputeModel, DeviceProfile, LinkModel};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Bump on any wire-format change; exchanged in `Hello` — both at
/// initial fleet formation and on every *rejoin* (a restarted
/// `cfl device --retry` re-claims its slot with the same `Hello`
/// handshake; there is no separate reconnect message, so version
/// checking covers both paths for free).
pub const PROTOCOL_VERSION: u32 = 1;

/// Ceiling on one frame's payload (a paper-scale β is ~2 KB; 64 MiB is
/// orders of magnitude of headroom while still rejecting garbage length
/// prefixes before they turn into huge allocations).
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

const TAG_SETUP: u8 = 1;
const TAG_MODEL: u8 = 2;
const TAG_PING: u8 = 3;
const TAG_STOP: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_HELLO: u8 = 64;
const TAG_PONG: u8 = 65;
const TAG_GRAD: u8 = 66;

// --- frame I/O -------------------------------------------------------

/// Write one frame (length prefix + payload).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES,
        "refusing to send an oversized frame ({} bytes > {MAX_FRAME_BYTES})",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("writing frame length")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` is a clean end-of-stream (EOF
/// exactly at a frame boundary); EOF anywhere else is an error, as are
/// oversized length prefixes.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("truncated frame: stream ended inside the length prefix"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow::anyhow!("reading frame length: {e}")),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(len <= MAX_FRAME_BYTES, "oversized frame: length prefix {len} > {MAX_FRAME_BYTES}");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("truncated frame: stream ended inside the payload: {e}"))?;
    Ok(Some(payload))
}

// --- encoding --------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Self {
        Self { buf: vec![tag] }
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for &v in m.as_slice() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn profile(&mut self, p: &DeviceProfile) {
        self.f64(p.compute.secs_per_point);
        self.f64(p.compute.mem_rate);
        self.f64(p.link.secs_per_packet);
        self.f64(p.link.erasure_prob);
        self.u32(p.points as u32);
    }
}

/// Encode a coordinator → device message as one frame payload.
pub fn encode_to_device(msg: &ToDevice) -> Vec<u8> {
    match msg {
        ToDevice::Setup(init) => {
            let mut e = Enc::new(TAG_SETUP);
            e.u64(init.run);
            e.u32(init.device_index as u32);
            e.u32(init.load as u32);
            e.u64(init.delay_seed);
            e.f64(init.time_scale);
            e.f64(init.max_scaled_secs);
            e.profile(&init.profile);
            e.mat(&init.x_sys);
            e.mat(&init.y_sys);
            e.buf
        }
        ToDevice::Model { epoch, beta } => {
            let mut e = Enc::new(TAG_MODEL);
            e.u64(*epoch as u64);
            e.mat(beta);
            e.buf
        }
        ToDevice::Ping { nonce } => {
            let mut e = Enc::new(TAG_PING);
            e.u64(*nonce);
            e.buf
        }
        ToDevice::Stop => Enc::new(TAG_STOP).buf,
        ToDevice::Shutdown => Enc::new(TAG_SHUTDOWN).buf,
    }
}

/// Encode a device → coordinator message as one frame payload.
pub fn encode_from_device(msg: &FromDevice) -> Vec<u8> {
    match msg {
        FromDevice::Hello { device_id, protocol } => {
            let mut e = Enc::new(TAG_HELLO);
            e.u32(*device_id as u32);
            e.u32(*protocol);
            e.buf
        }
        FromDevice::Pong { nonce } => {
            let mut e = Enc::new(TAG_PONG);
            e.u64(*nonce);
            e.buf
        }
        FromDevice::Grad { run, epoch, grad, delay } => {
            let mut e = Enc::new(TAG_GRAD);
            e.u64(*run);
            e.u64(*epoch as u64);
            e.f64(*delay);
            e.mat(grad);
            e.buf
        }
    }
}

// --- decoding --------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.buf.len() >= n, "truncated message body: wanted {n} more bytes");
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }
    /// Like [`Dec::take`], but with the length in the type: the slice →
    /// array conversion cannot fail, so fixed-width readers stay panic-free.
    fn take_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        let head = self.take(N)?;
        let mut arr = [0u8; N];
        arr.copy_from_slice(head);
        Ok(arr)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_arr()?))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_arr()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take_arr()?))
    }
    fn mat(&mut self) -> Result<Mat> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let bytes_needed = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(4))
            .filter(|&b| b <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "corrupt matrix header: {rows}×{cols} does not fit the remaining \
                     {} payload bytes",
                    self.buf.len()
                )
            })?;
        let bytes = self.take(bytes_needed)?;
        // cfl-lint: allow(no-panic-paths) — chunks_exact(4) yields exactly-4-byte slices
        let data = bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap()));
        Ok(Mat::from_vec(rows, cols, data.collect()))
    }
    fn profile(&mut self) -> Result<DeviceProfile> {
        Ok(DeviceProfile {
            compute: ComputeModel { secs_per_point: self.f64()?, mem_rate: self.f64()? },
            link: LinkModel { secs_per_packet: self.f64()?, erasure_prob: self.f64()? },
            points: self.u32()? as usize,
        })
    }
    fn done(&self) -> Result<()> {
        ensure!(self.buf.is_empty(), "{} trailing bytes after the message body", self.buf.len());
        Ok(())
    }
}

/// Decode a coordinator → device frame payload.
pub fn decode_to_device(payload: &[u8]) -> Result<ToDevice> {
    let (&tag, body) = payload.split_first().context("empty frame payload")?;
    let mut d = Dec { buf: body };
    let msg = match tag {
        TAG_SETUP => ToDevice::Setup(Box::new(DeviceInit {
            run: d.u64()?,
            device_index: d.u32()? as usize,
            load: d.u32()? as usize,
            delay_seed: d.u64()?,
            time_scale: d.f64()?,
            max_scaled_secs: d.f64()?,
            profile: d.profile()?,
            x_sys: d.mat()?,
            y_sys: d.mat()?,
        })),
        TAG_MODEL => ToDevice::Model { epoch: d.u64()? as usize, beta: d.mat()? },
        TAG_PING => ToDevice::Ping { nonce: d.u64()? },
        TAG_STOP => ToDevice::Stop,
        TAG_SHUTDOWN => ToDevice::Shutdown,
        t => bail!("unknown coordinator message tag {t}"),
    };
    d.done()?;
    Ok(msg)
}

/// Decode a device → coordinator frame payload.
pub fn decode_from_device(payload: &[u8]) -> Result<FromDevice> {
    let (&tag, body) = payload.split_first().context("empty frame payload")?;
    let mut d = Dec { buf: body };
    let msg = match tag {
        TAG_HELLO => FromDevice::Hello { device_id: d.u32()? as usize, protocol: d.u32()? },
        TAG_PONG => FromDevice::Pong { nonce: d.u64()? },
        TAG_GRAD => FromDevice::Grad {
            run: d.u64()?,
            epoch: d.u64()? as usize,
            delay: d.f64()?,
            grad: d.mat()?,
        },
        t => bail!("unknown device message tag {t}"),
    };
    d.done()?;
    Ok(msg)
}
