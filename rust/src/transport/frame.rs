//! Length-prefixed binary framing for [`ToDevice`] / [`FromDevice`].
//!
//! The build is offline (no serde), so the wire format is hand-rolled and
//! deliberately dull: every frame is
//!
//! ```text
//! frame   := len:u32le payload            (len = payload bytes, ≤ 64 MiB)
//! payload := tag:u8 body
//! ```
//!
//! with all integers little-endian, floats as IEEE-754 LE bit patterns,
//! and matrices as `rows:u32 cols:u32 data:f32le×(rows·cols)`. Message
//! bodies (see the tag constants for the full table):
//!
//! | tag | message  | body |
//! |-----|----------|------|
//! | 1   | Setup    | run:u64 device:u32 load:u32 seed:u64 time_scale:f64 max_scaled:f64 profile(5 fields) x_sys:mat y_sys:mat |
//! | 2   | Model    | epoch:u64 beta:mat |
//! | 3   | Ping     | nonce:u64 |
//! | 4   | Stop     | — |
//! | 5   | Shutdown | — |
//! | 64  | Hello    | device:u32 protocol:u32 |
//! | 65  | Pong     | nonce:u64 |
//! | 66  | Grad     | run:u64 epoch:u64 delay:f64 grad:mat |
//! | 67  | HelloMulti | protocol:u32 count:u32 device:u32×count |
//! | 68  | Wrap     | slot:u32 inner-payload |
//!
//! (a device profile is `secs_per_point:f64 mem_rate:f64
//! secs_per_packet:f64 erasure_prob:f64 points:u32`.)
//!
//! `Wrap` is an envelope, not a message: on a multi-slot connection
//! (one `cfl device --slots a,b,c` process hosting several fleet
//! slots) every payload in both directions is wrapped so the two ends
//! can demultiplex by slot. Single-slot connections never wrap.
//!
//! Decoding is defensive: an oversized length prefix, a truncated frame,
//! an unknown tag, or matrix dimensions that don't fit the payload are
//! all hard errors — the reader treats them as the peer dying, never as
//! something to resynchronize past.

use super::{DeviceInit, FromDevice, ToDevice};
use crate::linalg::Mat;
use crate::simnet::{ComputeModel, DeviceProfile, LinkModel};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Bump on any wire-format change; exchanged in `Hello` — both at
/// initial fleet formation and on every *rejoin* (a restarted
/// `cfl device --retry` re-claims its slot with the same `Hello`
/// handshake; there is no separate reconnect message, so version
/// checking covers both paths for free).
///
/// v2: multi-slot connections (`HelloMulti`, the `Wrap` envelope).
pub const PROTOCOL_VERSION: u32 = 2;

/// Ceiling on one frame's payload (a paper-scale β is ~2 KB; 64 MiB is
/// orders of magnitude of headroom while still rejecting garbage length
/// prefixes before they turn into huge allocations).
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

const TAG_SETUP: u8 = 1;
const TAG_MODEL: u8 = 2;
const TAG_PING: u8 = 3;
const TAG_STOP: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_HELLO: u8 = 64;
const TAG_PONG: u8 = 65;
const TAG_GRAD: u8 = 66;
const TAG_HELLO_MULTI: u8 = 67;
const TAG_WRAP: u8 = 68;

// --- frame I/O -------------------------------------------------------

/// Write one frame (length prefix + payload).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES,
        "refusing to send an oversized frame ({} bytes > {MAX_FRAME_BYTES})",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("writing frame length")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Incremental frame reassembly: feed it byte chunks as they arrive
/// (in any split — one byte at a time, mid-prefix, mid-payload) and it
/// emits completed frame payloads. This is the single decode path for
/// both the blocking [`read_frame`] reader and the non-blocking
/// reactor, so partial-read behaviour cannot drift between them.
///
/// The decoder is a two-phase state machine: accumulating the 4-byte
/// length prefix, then accumulating `want` payload bytes. An oversized
/// length prefix is a hard error and poisons nothing beyond the value
/// returned — callers treat it as the peer dying, exactly like
/// [`read_frame`] always has.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    head: [u8; 4],
    head_len: usize,
    want: usize,
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// True between frames: no prefix bytes buffered, no payload owed.
    /// EOF is only clean when the decoder is idle.
    pub fn is_idle(&self) -> bool {
        self.head_len == 0 && self.buf.is_empty()
    }

    /// True once the length prefix is complete but the payload is not:
    /// the peer has committed to a frame it has not finished sending.
    pub fn mid_payload(&self) -> bool {
        self.head_len == 4 && self.buf.len() < self.want
    }

    /// How many bytes the decoder needs before it can make progress on
    /// the *current* frame: the rest of the prefix, or the rest of the
    /// payload. Blocking readers use this to read exactly one frame and
    /// never consume bytes belonging to the next one.
    pub fn bytes_needed(&self) -> usize {
        if self.head_len < 4 {
            4 - self.head_len
        } else {
            self.want - self.buf.len()
        }
    }

    /// Consume a chunk, returning every frame payload it completed (zero
    /// or more — a big chunk can carry several small frames).
    pub fn push(&mut self, mut chunk: &[u8]) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::new();
        loop {
            if self.head_len < 4 {
                if chunk.is_empty() {
                    break;
                }
                let take = (4 - self.head_len).min(chunk.len());
                let (head, rest) = chunk.split_at(take);
                self.head[self.head_len..self.head_len + take].copy_from_slice(head);
                self.head_len += take;
                chunk = rest;
                if self.head_len < 4 {
                    break;
                }
                let len = u32::from_le_bytes(self.head) as usize;
                ensure!(
                    len <= MAX_FRAME_BYTES,
                    "oversized frame: length prefix {len} > {MAX_FRAME_BYTES}"
                );
                self.want = len;
                self.buf = Vec::with_capacity(len);
            }
            // payload phase (want == 0 falls straight through to emit)
            let take = (self.want - self.buf.len()).min(chunk.len());
            let (body, rest) = chunk.split_at(take);
            self.buf.extend_from_slice(body);
            chunk = rest;
            if self.buf.len() == self.want {
                out.push(std::mem::take(&mut self.buf));
                self.head_len = 0;
                self.want = 0;
            } else {
                break;
            }
        }
        Ok(out)
    }
}

/// Read one frame's payload. `Ok(None)` is a clean end-of-stream (EOF
/// exactly at a frame boundary); EOF anywhere else is an error, as are
/// oversized length prefixes.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut dec = FrameDecoder::new();
    let mut tmp = [0u8; 8 * 1024];
    loop {
        // never ask for more than the current frame still needs, so a
        // following frame's bytes are left unread for the next call
        let want = dec.bytes_needed().min(tmp.len());
        match r.read(&mut tmp[..want]) {
            Ok(0) if dec.is_idle() => return Ok(None),
            Ok(0) if dec.mid_payload() => {
                bail!("truncated frame: stream ended inside the payload")
            }
            Ok(0) => bail!("truncated frame: stream ended inside the length prefix"),
            Ok(n) => {
                if let Some(payload) = dec.push(&tmp[..n])?.into_iter().next() {
                    return Ok(Some(payload));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow::anyhow!("reading frame: {e}")),
        }
    }
}

// --- the multi-slot envelope -----------------------------------------

/// Wrap a payload for one slot of a multi-slot connection:
/// `TAG_WRAP slot:u32le inner`. The envelope nests *inside* the normal
/// length-prefixed frame, so framing and reassembly are identical for
/// wrapped and bare traffic.
pub fn wrap_slot(slot: usize, inner: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + inner.len());
    out.push(TAG_WRAP);
    out.extend_from_slice(&(slot as u32).to_le_bytes());
    out.extend_from_slice(inner);
    out
}

/// Peel the multi-slot envelope off a frame payload. `Ok(None)` means
/// the payload is bare (single-slot traffic); `Ok(Some((slot, inner)))`
/// is a wrapped payload; a wrapped payload too short to carry its slot
/// header is a hard error.
pub fn unwrap_slot(payload: &[u8]) -> Result<Option<(usize, &[u8])>> {
    match payload.split_first() {
        Some((&TAG_WRAP, rest)) => {
            ensure!(rest.len() >= 4, "truncated wrap envelope: {} bytes", rest.len());
            let (slot_bytes, inner) = rest.split_at(4);
            let mut arr = [0u8; 4];
            arr.copy_from_slice(slot_bytes);
            Ok(Some((u32::from_le_bytes(arr) as usize, inner)))
        }
        _ => Ok(None),
    }
}

// --- encoding --------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Self {
        Self { buf: vec![tag] }
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for &v in m.as_slice() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn profile(&mut self, p: &DeviceProfile) {
        self.f64(p.compute.secs_per_point);
        self.f64(p.compute.mem_rate);
        self.f64(p.link.secs_per_packet);
        self.f64(p.link.erasure_prob);
        self.u32(p.points as u32);
    }
}

/// Encode a coordinator → device message as one frame payload.
pub fn encode_to_device(msg: &ToDevice) -> Vec<u8> {
    match msg {
        ToDevice::Setup(init) => {
            let mut e = Enc::new(TAG_SETUP);
            e.u64(init.run);
            e.u32(init.device_index as u32);
            e.u32(init.load as u32);
            e.u64(init.delay_seed);
            e.f64(init.time_scale);
            e.f64(init.max_scaled_secs);
            e.profile(&init.profile);
            e.mat(&init.x_sys);
            e.mat(&init.y_sys);
            e.buf
        }
        ToDevice::Model { epoch, beta } => {
            let mut e = Enc::new(TAG_MODEL);
            e.u64(*epoch as u64);
            e.mat(beta);
            e.buf
        }
        ToDevice::Ping { nonce } => {
            let mut e = Enc::new(TAG_PING);
            e.u64(*nonce);
            e.buf
        }
        ToDevice::Stop => Enc::new(TAG_STOP).buf,
        ToDevice::Shutdown => Enc::new(TAG_SHUTDOWN).buf,
    }
}

/// Encode a device → coordinator message as one frame payload.
pub fn encode_from_device(msg: &FromDevice) -> Vec<u8> {
    match msg {
        FromDevice::Hello { device_id, protocol } => {
            let mut e = Enc::new(TAG_HELLO);
            e.u32(*device_id as u32);
            e.u32(*protocol);
            e.buf
        }
        FromDevice::Pong { nonce } => {
            let mut e = Enc::new(TAG_PONG);
            e.u64(*nonce);
            e.buf
        }
        FromDevice::Grad { run, epoch, grad, delay } => {
            let mut e = Enc::new(TAG_GRAD);
            e.u64(*run);
            e.u64(*epoch as u64);
            e.f64(*delay);
            e.mat(grad);
            e.buf
        }
        FromDevice::HelloMulti { device_ids, protocol } => {
            let mut e = Enc::new(TAG_HELLO_MULTI);
            e.u32(*protocol);
            e.u32(device_ids.len() as u32);
            for &id in device_ids {
                e.u32(id as u32);
            }
            e.buf
        }
    }
}

// --- decoding --------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.buf.len() >= n, "truncated message body: wanted {n} more bytes");
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }
    /// Like [`Dec::take`], but with the length in the type: the slice →
    /// array conversion cannot fail, so fixed-width readers stay panic-free.
    fn take_arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        let head = self.take(N)?;
        let mut arr = [0u8; N];
        arr.copy_from_slice(head);
        Ok(arr)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_arr()?))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_arr()?))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take_arr()?))
    }
    fn mat(&mut self) -> Result<Mat> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let bytes_needed = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(4))
            .filter(|&b| b <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "corrupt matrix header: {rows}×{cols} does not fit the remaining \
                     {} payload bytes",
                    self.buf.len()
                )
            })?;
        let bytes = self.take(bytes_needed)?;
        // cfl-lint: allow(no-panic-paths) — chunks_exact(4) yields exactly-4-byte slices
        let data = bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap()));
        Ok(Mat::from_vec(rows, cols, data.collect()))
    }
    fn profile(&mut self) -> Result<DeviceProfile> {
        Ok(DeviceProfile {
            compute: ComputeModel { secs_per_point: self.f64()?, mem_rate: self.f64()? },
            link: LinkModel { secs_per_packet: self.f64()?, erasure_prob: self.f64()? },
            points: self.u32()? as usize,
        })
    }
    fn done(&self) -> Result<()> {
        ensure!(self.buf.is_empty(), "{} trailing bytes after the message body", self.buf.len());
        Ok(())
    }
}

/// Decode a coordinator → device frame payload.
pub fn decode_to_device(payload: &[u8]) -> Result<ToDevice> {
    let (&tag, body) = payload.split_first().context("empty frame payload")?;
    let mut d = Dec { buf: body };
    let msg = match tag {
        TAG_SETUP => ToDevice::Setup(Box::new(DeviceInit {
            run: d.u64()?,
            device_index: d.u32()? as usize,
            load: d.u32()? as usize,
            delay_seed: d.u64()?,
            time_scale: d.f64()?,
            max_scaled_secs: d.f64()?,
            profile: d.profile()?,
            x_sys: d.mat()?,
            y_sys: d.mat()?,
        })),
        TAG_MODEL => ToDevice::Model { epoch: d.u64()? as usize, beta: d.mat()? },
        TAG_PING => ToDevice::Ping { nonce: d.u64()? },
        TAG_STOP => ToDevice::Stop,
        TAG_SHUTDOWN => ToDevice::Shutdown,
        t => bail!("unknown coordinator message tag {t}"),
    };
    d.done()?;
    Ok(msg)
}

/// Decode a device → coordinator frame payload.
pub fn decode_from_device(payload: &[u8]) -> Result<FromDevice> {
    let (&tag, body) = payload.split_first().context("empty frame payload")?;
    let mut d = Dec { buf: body };
    let msg = match tag {
        TAG_HELLO => FromDevice::Hello { device_id: d.u32()? as usize, protocol: d.u32()? },
        TAG_PONG => FromDevice::Pong { nonce: d.u64()? },
        TAG_GRAD => FromDevice::Grad {
            run: d.u64()?,
            epoch: d.u64()? as usize,
            delay: d.f64()?,
            grad: d.mat()?,
        },
        TAG_HELLO_MULTI => {
            let protocol = d.u32()?;
            let count = d.u32()? as usize;
            let mut device_ids = Vec::with_capacity(count.min(1024));
            for _ in 0..count {
                device_ids.push(d.u32()? as usize);
            }
            FromDevice::HelloMulti { device_ids, protocol }
        }
        t => bail!("unknown device message tag {t}"),
    };
    d.done()?;
    Ok(msg)
}
