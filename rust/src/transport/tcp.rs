//! TCP loopback/network transport: real processes on real sockets.
//!
//! Coordinator side ([`TcpTransport`]): accept one connection per fleet
//! slot (each opened by a `cfl device` process announcing itself with
//! `Hello`), then speak the [`frame`] wire format — a reader thread per
//! socket feeds replies into one queue, and socket EOF/corruption is
//! surfaced as [`Event::Gone`] so the epoch loop degrades that device to
//! parity-only instead of stalling.
//!
//! Device side ([`run_device`]): connect (with retry while the
//! coordinator is still starting), `Hello`, then hand the socket to the
//! shared [`run_device_loop`] state machine.
//!
//! [`TcpTransport::spawn_local`] packages the loopback case the sweep
//! engine uses (`cfl sweep --live --transport tcp`): bind an ephemeral
//! port, spawn `cfl device` subprocesses, accept them, and reap the
//! children when the transport drops.

use super::{
    frame, recv_event, run_device_loop, DeviceInit, DeviceLink, Event, FromDevice, ToDevice,
    Transport, Up,
};
use anyhow::{ensure, Context, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// How long a freshly-accepted connection gets to present its `Hello`.
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);

/// How long [`TcpTransport::spawn_local`] waits for its own subprocesses
/// to connect back.
const SPAWN_ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side TCP fleet: one framed socket per device slot.
pub struct TcpTransport {
    /// Write halves, slot-indexed; `None` = endpoint gone.
    links: Vec<Option<TcpStream>>,
    up_rx: mpsc::Receiver<(usize, Up)>,
    /// Locally-spawned `cfl device` subprocesses (empty under `serve`).
    children: Vec<Child>,
}

impl TcpTransport {
    /// Accept `n` device connections on an already-bound listener (the
    /// `cfl serve` path — devices are started by someone else).
    pub fn serve(listener: TcpListener, n: usize, accept_timeout: Duration) -> Result<Self> {
        let (links, up_rx) = accept_fleet(&listener, n, accept_timeout)?;
        Ok(Self { links, up_rx, children: Vec::new() })
    }

    /// Write one already-encoded frame to a slot; `false` marks the
    /// endpoint dead (shared by [`Transport::send`] and the
    /// encode-once [`Transport::broadcast`]).
    fn write_payload(&mut self, slot: usize, payload: &[u8]) -> bool {
        let Some(stream) = self.links.get_mut(slot).and_then(|l| l.as_mut()) else {
            return false;
        };
        if frame::write_frame(stream, payload).is_err() {
            self.links[slot] = None;
            return false;
        }
        true
    }

    /// Bind an ephemeral loopback port, spawn `n` `cfl device`
    /// subprocesses of `bin` pointed at it, and accept them — the
    /// self-contained fleet behind `cfl sweep --live --transport tcp`.
    pub fn spawn_local(bin: &std::path::Path, n: usize) -> Result<Self> {
        ensure!(n > 0, "a TCP fleet needs at least one device");
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding a loopback listener")?;
        let addr = listener.local_addr().context("reading the bound address")?.to_string();
        let mut children: Vec<Child> = Vec::with_capacity(n);
        let spawn = |k: usize| -> Result<Child> {
            Command::new(bin)
                .args(["device", "--connect", &addr, "--id", &k.to_string(), "--quiet"])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .with_context(|| format!("spawning {} device {k}", bin.display()))
        };
        for k in 0..n {
            match spawn(k) {
                Ok(child) => children.push(child),
                Err(e) => {
                    reap(&mut children, Duration::ZERO);
                    return Err(e);
                }
            }
        }
        match accept_fleet(&listener, n, SPAWN_ACCEPT_TIMEOUT) {
            Ok((links, up_rx)) => Ok(Self { links, up_rx, children }),
            Err(e) => {
                reap(&mut children, Duration::ZERO);
                Err(e)
            }
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn n_endpoints(&self) -> usize {
        self.links.len()
    }

    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<()> {
        for init in inits {
            let slot = init.device_index;
            ensure!(
                slot < self.links.len(),
                "device index {slot} outside the {}-endpoint fleet",
                self.links.len()
            );
            // a dead endpoint is skipped, not fatal: the coordinator
            // observes it via Gone/failed sends and degrades
            let _ = self.send(slot, &ToDevice::Setup(Box::new(init)))?;
        }
        Ok(())
    }

    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool> {
        Ok(self.write_payload(slot, &frame::encode_to_device(msg)))
    }

    fn broadcast(&mut self, slots: &[usize], msg: &ToDevice) -> Result<Vec<bool>> {
        // serialize once for the whole fleet — the epoch hot path sends
        // the same β to every device
        let payload = frame::encode_to_device(msg);
        Ok(slots.iter().map(|&slot| self.write_payload(slot, &payload)).collect())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Event {
        let event = recv_event(&self.up_rx, timeout);
        // a death notice is one-shot (the reader thread is gone): record
        // it at the transport level too, so the endpoint stays dead
        // across runs instead of being re-entered into the next fleet
        if let Event::Gone(slot) = event {
            if let Some(link) = self.links.get_mut(slot) {
                *link = None;
            }
        }
        event
    }

    fn end_run(&mut self) {
        for slot in 0..self.links.len() {
            let _ = self.send(slot, &ToDevice::Stop);
        }
        // discard stale replies, but keep death notices: a Gone drained
        // here must still kill the link, or the dead device would be
        // re-entered into the next run's fleet (its reader thread is
        // gone, so the notice would never repeat)
        while let Ok((slot, up)) = self.up_rx.try_recv() {
            if let Up::Gone = up {
                if let Some(link) = self.links.get_mut(slot) {
                    *link = None;
                }
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for slot in 0..self.links.len() {
            let _ = self.send(slot, &ToDevice::Shutdown);
        }
        for link in self.links.iter_mut() {
            if let Some(s) = link.take() {
                let _ = s.shutdown(std::net::Shutdown::Write);
            }
        }
        reap(&mut self.children, Duration::from_secs(10));
    }
}

/// Wait for spawned device subprocesses to exit (they do so on
/// `Shutdown`/EOF), killing any that outlive the deadline.
fn reap(children: &mut Vec<Child>, patience: Duration) {
    let deadline = Instant::now() + patience;
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => thread::sleep(Duration::from_millis(20)),
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
            }
        }
    }
    children.clear();
}

/// Accept `n` devices: each must `Hello` with a distinct in-range id and
/// a matching protocol version; each then gets a reader thread feeding
/// the shared event queue.
#[allow(clippy::type_complexity)]
fn accept_fleet(
    listener: &TcpListener,
    n: usize,
    accept_timeout: Duration,
) -> Result<(Vec<Option<TcpStream>>, mpsc::Receiver<(usize, Up)>)> {
    listener.set_nonblocking(true).context("making the listener pollable")?;
    let deadline = Instant::now() + accept_timeout;
    let (up_tx, up_rx) = mpsc::channel::<(usize, Up)>();
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < n {
        match listener.accept() {
            Ok((stream, peer)) => match admit(stream, &links, &up_tx)? {
                Admitted::Device(slot, writer) => {
                    links[slot] = Some(writer);
                    connected += 1;
                }
                // a stray connection (port scanner, health probe, a
                // device started twice) must not strand the fleet —
                // drop it and keep accepting until the deadline
                Admitted::Rejected(reason) => {
                    eprintln!("cfl: ignoring a connection from {peer}: {reason}");
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for devices: {connected}/{n} connected"
                );
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow::anyhow!("accepting a device connection: {e}")),
        }
    }
    Ok((links, up_rx))
}

/// Outcome of one connection handshake: an admitted device, or a
/// connection to drop while the accept loop keeps going.
enum Admitted {
    Device(usize, TcpStream),
    Rejected(String),
}

/// Handshake one fresh connection: read `Hello`, validate, start its
/// reader thread. Garbage, timeouts, duplicate or out-of-range ids are
/// [`Admitted::Rejected`] (non-fatal — keep accepting); a *protocol*
/// mismatch is a hard `Err`, since it means a real device of the wrong
/// version and the session should fail fast and loudly.
fn admit(
    mut stream: TcpStream,
    links: &[Option<TcpStream>],
    up_tx: &mpsc::Sender<(usize, Up)>,
) -> Result<Admitted> {
    let reject = |reason: String| Ok(Admitted::Rejected(reason));
    let configured = stream.set_nonblocking(false).is_ok()
        && stream.set_read_timeout(Some(HELLO_TIMEOUT)).is_ok();
    if !configured {
        return reject("could not configure the socket".into());
    }
    stream.set_nodelay(true).ok();
    let payload = match frame::read_frame(&mut stream) {
        Ok(Some(p)) => p,
        Ok(None) => return reject("peer closed before sending Hello".into()),
        Err(e) => return reject(format!("unreadable Hello frame: {e}")),
    };
    let hello = match frame::decode_from_device(&payload) {
        Ok(h) => h,
        Err(e) => return reject(format!("corrupt Hello frame: {e}")),
    };
    let FromDevice::Hello { device_id, protocol } = hello else {
        return reject(format!("expected Hello as the first message, got {hello:?}"));
    };
    ensure!(
        protocol == frame::PROTOCOL_VERSION,
        "protocol mismatch: device speaks v{protocol}, coordinator v{}",
        frame::PROTOCOL_VERSION
    );
    if device_id >= links.len() {
        return reject(format!(
            "device id {device_id} outside the {}-device fleet",
            links.len()
        ));
    }
    if links[device_id].is_some() {
        return reject(format!("device id {device_id} claimed twice"));
    }
    stream.set_read_timeout(None).context("disarming the Hello timeout")?;
    let writer = stream.try_clone().context("splitting the device socket")?;
    let tx = up_tx.clone();
    thread::spawn(move || reader_loop(device_id, stream, tx));
    Ok(Admitted::Device(device_id, writer))
}

/// Per-socket reader: frames in, events out; any EOF or framing fault
/// ends the endpoint with a `Gone`.
fn reader_loop(slot: usize, stream: TcpStream, tx: mpsc::Sender<(usize, Up)>) {
    let mut reader = BufReader::new(stream);
    loop {
        match frame::read_frame(&mut reader) {
            Ok(Some(payload)) => match frame::decode_from_device(&payload) {
                Ok(msg) => {
                    if tx.send((slot, Up::Msg(msg))).is_err() {
                        return; // transport dropped; nobody is listening
                    }
                }
                Err(_) => break, // corrupt frame: treat the peer as dead
            },
            Ok(None) | Err(_) => break,
        }
    }
    let _ = tx.send((slot, Up::Gone));
}

/// A device process's end of the socket.
struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpLink {
    fn new(stream: TcpStream) -> Result<Self> {
        let writer = stream.try_clone().context("splitting the coordinator socket")?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }
}

impl DeviceLink for TcpLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        match frame::read_frame(&mut self.reader)? {
            Some(payload) => Ok(Some(frame::decode_to_device(&payload)?)),
            None => Ok(None),
        }
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        frame::write_frame(&mut self.writer, &frame::encode_from_device(&msg))
    }
}

/// The `cfl device` entry point: connect to a coordinator (retrying while
/// it finishes starting up), claim fleet slot `device_id`, and serve
/// [`run_device_loop`] until the coordinator shuts the session down.
pub fn run_device(addr: &str, device_id: usize, connect_timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + connect_timeout;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                ensure!(Instant::now() < deadline, "connecting to {addr}: {e}");
                thread::sleep(Duration::from_millis(50));
            }
        }
    };
    stream.set_nodelay(true).ok();
    let mut link = TcpLink::new(stream)?;
    link.send(FromDevice::Hello { device_id, protocol: frame::PROTOCOL_VERSION })?;
    run_device_loop(&mut link)
}
