//! TCP loopback/network transport: real processes on real sockets.
//!
//! Coordinator side ([`TcpTransport`]): accept one connection per fleet
//! slot — or one connection per *group* of slots, when a multi-slot
//! `cfl device --slots a,b,c` process claims several with one
//! `HelloMulti` — then hand every accepted socket to the readiness
//! reactor ([`super::reactor`]). A single event-loop thread owns all
//! endpoint I/O: non-blocking sockets multiplexed with `poll(2)`,
//! per-endpoint partial-frame reassembly, bounded write queues with
//! backpressure. Socket EOF/corruption surfaces as [`Event::Gone`] so
//! the epoch loop degrades that device to parity-only instead of
//! stalling. I/O thread count is O(1) in the fleet size: one reactor +
//! one acceptor, however many devices join.
//!
//! Death is not a one-way door: after fleet formation the listener stays
//! open on a background acceptor thread, and a fresh connection whose
//! `Hello{id}` (or `HelloMulti`) names currently-dead slots is
//! **re-admitted** — the reactor adopts the socket and an
//! [`Event::Rejoined`] per slot tells the coordinator to re-arm the
//! device with `Setup`. Every incarnation of a slot carries a generation
//! tag; events from a previous incarnation (a straggling reply, a late
//! death notice from a silently-partitioned socket) are discarded at the
//! transport level, so they can neither be attributed to nor kill the
//! replacement. A valid `Hello` for a slot whose old link is still open
//! takes the slot over (*newest wins*): a half-open socket whose death
//! notice never landed — a silent network partition — must not block the
//! genuine device from reconnecting, so the corpse is severed and the
//! newcomer admitted.
//!
//! Device side ([`run_device`]): connect (with retry while the
//! coordinator is still starting), `Hello`, then hand the socket to the
//! shared [`run_device_loop`] state machine. [`run_device_retry`]
//! (`cfl device --retry`) wraps that in a reconnect loop whose backoff
//! carries deterministic per-slot jitter (seeded off the slot id), so a
//! mass-kill does not redial in lockstep. [`run_device_multi`] hosts
//! several slots over one connection: a demux reader fans wrapped
//! frames out to per-slot worker threads that each run the same state
//! machine.
//!
//! [`TcpTransport::spawn_local`] packages the loopback case the sweep
//! engine uses (`cfl sweep --live --transport tcp`);
//! [`TcpTransport::spawn_placed`] is its cross-host sibling, driven by a
//! [`Placement`] manifest: local slots become one multi-slot child,
//! remote slots are announced and awaited.

use super::placement::Placement;
use super::reactor::Reactor;
use super::{
    frame, note_gone, note_rejoin, run_device_loop, stale_discard, DeviceInit, DeviceLink, Event,
    FromDevice, SessionEnd, ToDevice, Transport,
};
use crate::obs::Counter;
use crate::rng::{mix_seed, Rng};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// How long a freshly-accepted connection gets to present its `Hello`.
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);

/// How long [`TcpTransport::spawn_local`] waits for its own subprocesses
/// to connect back.
const SPAWN_ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Accept-poll interval of the post-formation acceptor thread.
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Everything the coordinator-side event queue carries: reactor
/// upstream traffic tagged `(slot, generation)`, plus rejoin candidates
/// from the acceptor thread. One queue keeps a connection's EOF notice
/// ordered against the reconnection that follows it (and the generation
/// tags settle the races the queue cannot).
pub(crate) enum TcpUp {
    Msg(FromDevice),
    Gone,
    /// A fresh connection presented a valid `Hello`/`HelloMulti` for
    /// these slots; the stream is shipped to the transport, which bumps
    /// the slots' generations and registers it with the reactor.
    /// `wrapped` records which handshake was spoken (multi-slot
    /// connections envelope every frame).
    Rejoin(TcpStream, Vec<usize>, bool),
}

/// Downstream fleet-traffic counters (wire bytes include the 4-byte
/// length prefix), resolved once so the per-frame accounting on the
/// broadcast hot path is a pair of relaxed atomic adds. The upstream
/// counterparts live in the reactor's event loop.
struct WireCounters {
    frames_sent: Counter,
    bytes_sent: Counter,
}

impl WireCounters {
    fn new() -> Self {
        let reg = crate::obs::registry();
        Self {
            frames_sent: reg.counter("transport.frames_sent"),
            bytes_sent: reg.counter("transport.bytes_sent"),
        }
    }
}

/// Coordinator-side TCP fleet: every endpoint socket lives inside the
/// reactor; this struct holds the slot table (liveness + generation),
/// the upstream event queue, and the buffered public events.
pub struct TcpTransport {
    /// Slot liveness; `false` = endpoint gone (awaiting rejoin).
    live: Vec<bool>,
    /// Current incarnation per slot; bumped on rejoin so stale events
    /// from an earlier incarnation can be recognized and dropped.
    gens: Vec<u64>,
    up_rx: mpsc::Receiver<(usize, u64, TcpUp)>,
    up_tx: mpsc::Sender<(usize, u64, TcpUp)>,
    /// The readiness event loop owning every endpoint socket.
    reactor: Reactor,
    /// Public events decoded from the queue but not yet handed to the
    /// caller (the queue can complete several at once).
    pending: VecDeque<Event>,
    /// Post-formation acceptor thread (owns the listener) + its stop flag.
    acceptor: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Locally-spawned `cfl device` subprocesses (empty under `serve`).
    children: Vec<Child>,
    ctr: WireCounters,
}

/// One formed connection out of [`accept_fleet`].
struct Formed {
    stream: TcpStream,
    slots: Vec<usize>,
    wrapped: bool,
}

impl TcpTransport {
    /// Accept `n` device connections on an already-bound listener (the
    /// `cfl serve` path — devices are started by someone else), then
    /// keep the listener accepting in the background so restarted
    /// devices can rejoin.
    pub fn serve(listener: TcpListener, n: usize, accept_timeout: Duration) -> Result<Self> {
        let (up_tx, up_rx) = mpsc::channel::<(usize, u64, TcpUp)>();
        let (formed, gens) = accept_fleet(&listener, n, accept_timeout)?;
        let reactor = Reactor::spawn(up_tx.clone())?;
        for f in formed {
            let claims: Vec<(usize, u64)> =
                f.slots.iter().map(|&s| (s, gens.get(s).copied().unwrap_or(0))).collect();
            reactor.register(f.stream, claims, f.wrapped);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let tx = up_tx.clone();
            let stop = Arc::clone(&stop);
            thread::spawn(move || acceptor_loop(listener, n, stop, tx))
        };
        Ok(Self {
            live: vec![true; n],
            gens,
            up_rx,
            up_tx,
            reactor,
            pending: VecDeque::new(),
            acceptor: Some(acceptor),
            stop,
            children: Vec::new(),
            ctr: WireCounters::new(),
        })
    }

    /// Bind an ephemeral loopback port, spawn `n` `cfl device`
    /// subprocesses of `bin` pointed at it, and accept them — the
    /// self-contained fleet behind `cfl sweep --live --transport tcp`.
    pub fn spawn_local(bin: &std::path::Path, n: usize) -> Result<Self> {
        ensure!(n > 0, "a TCP fleet needs at least one device");
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding a loopback listener")?;
        let addr = listener.local_addr().context("reading the bound address")?.to_string();
        let mut children: Vec<Child> = Vec::with_capacity(n);
        let spawn = |k: usize| -> Result<Child> {
            Command::new(bin)
                .args(["device", "--connect", &addr, "--id", &k.to_string(), "--quiet"])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .with_context(|| format!("spawning {} device {k}", bin.display()))
        };
        for k in 0..n {
            match spawn(k) {
                Ok(child) => children.push(child),
                Err(e) => {
                    reap(&mut children, Duration::ZERO);
                    return Err(e);
                }
            }
        }
        match Self::serve(listener, n, SPAWN_ACCEPT_TIMEOUT) {
            Ok(mut t) => {
                t.children = children;
                Ok(t)
            }
            Err(e) => {
                reap(&mut children, Duration::ZERO);
                Err(e)
            }
        }
    }

    /// Bind the manifest's address and serve a placement-described
    /// fleet: local slots become one multi-slot child process, remote
    /// slots are announced (with the exact `cfl device` invocation each
    /// host must run) and awaited — the fleet behind
    /// `cfl sweep --live --transport tcp --placement <file>`.
    pub fn spawn_placed(bin: &std::path::Path, n: usize, placement: &Placement) -> Result<Self> {
        ensure!(n > 0, "a TCP fleet needs at least one device");
        placement.validate(n)?;
        let listener = bind_retrying(placement.bind_addr(), placement.accept_timeout())?;
        Self::serve_placed(listener, n, placement, bin)
    }

    /// [`TcpTransport::spawn_placed`] minus the bind: serve a placement
    /// fleet on a listener the caller already bound (the
    /// `cfl serve --placement` path, where `--bind`/`--port-file` own
    /// the socket).
    pub fn serve_placed(
        listener: TcpListener,
        n: usize,
        placement: &Placement,
        bin: &std::path::Path,
    ) -> Result<Self> {
        placement.validate_slots(n)?;
        let addr = listener.local_addr().context("reading the bound address")?.to_string();
        let locals = placement.local_slots(n);
        let mut children: Vec<Child> = Vec::new();
        if !locals.is_empty() {
            let csv = slots_csv(&locals);
            let child = Command::new(bin)
                .args(["device", "--connect", &addr, "--slots", &csv, "--retry", "--quiet"])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .with_context(|| {
                    format!("spawning {} local slots {csv}", bin.display())
                })?;
            children.push(child);
        }
        for (host, slots) in placement.remote_hosts(n) {
            let csv = slots_csv(&slots);
            crate::obs_event!(
                Info,
                "placement_waiting",
                host = host.clone(),
                slots = csv.clone(),
                join = format!(
                    "cfl device --connect {addr} --slots {csv} --retry --persist --quiet"
                ),
            );
        }
        match Self::serve(listener, n, placement.accept_timeout()) {
            Ok(mut t) => {
                t.children = children;
                Ok(t)
            }
            Err(e) => {
                reap(&mut children, Duration::ZERO);
                Err(e)
            }
        }
    }

    /// Apply every event already sitting in the upstream queue (public
    /// ones buffer in `pending`): sends consult slot liveness, so they
    /// must observe deaths the reactor has already reported.
    fn drain(&mut self) {
        while let Ok((slot, gen, up)) = self.up_rx.try_recv() {
            let Self { gens, live, reactor, pending, .. } = self;
            process_up(slot, gen, up, gens, live, reactor, pending);
        }
    }

    /// Queue one message for a slot; `false` marks the endpoint dead.
    fn push_payload(&mut self, slot: usize, payload: Arc<Vec<u8>>) -> bool {
        if !self.live.get(slot).copied().unwrap_or(false) {
            return false;
        }
        self.ctr.frames_sent.incr();
        self.ctr.bytes_sent.add(payload.len() as u64 + 4);
        self.reactor.send(slot, payload);
        true
    }
}

/// Apply one upstream queue item to the slot table, buffering any
/// public events in `pending`. A free function over the transport's
/// split fields so [`super::drive_queue`] can borrow the receiver and
/// this state simultaneously.
fn process_up(
    slot: usize,
    gen: u64,
    up: TcpUp,
    gens: &mut [u64],
    live: &mut [bool],
    reactor: &Reactor,
    pending: &mut VecDeque<Event>,
) {
    match up {
        // a reply from a dead incarnation must not be attributed to its
        // replacement
        TcpUp::Msg(msg) => {
            if gens.get(slot).copied() != Some(gen) {
                stale_discard(slot, gen);
                return;
            }
            pending.push_back(Event::Msg(slot, msg));
        }
        TcpUp::Gone => {
            if gens.get(slot).copied() != Some(gen) {
                stale_discard(slot, gen);
                return; // stale death notice: the slot rejoined
            }
            if let Some(l) = live.get_mut(slot) {
                *l = false;
            }
            note_gone(slot, gen);
            pending.push_back(Event::Gone(slot));
        }
        TcpUp::Rejoin(stream, slots, wrapped) => {
            // newest wins: admission bumps each claimed slot's
            // generation, so the corpse connection the reactor severs on
            // register reports deaths that are already stale
            let mut claims: Vec<(usize, u64)> = Vec::with_capacity(slots.len());
            for &s in &slots {
                let Some(g) = gens.get_mut(s) else { continue };
                *g += 1;
                if let Some(l) = live.get_mut(s) {
                    *l = true;
                }
                claims.push((s, *g));
                note_rejoin(s, *g);
                pending.push_back(Event::Rejoined(s));
            }
            if !claims.is_empty() {
                reactor.register(stream, claims, wrapped);
            }
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn n_endpoints(&self) -> usize {
        self.live.len()
    }

    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<Vec<bool>> {
        let mut delivered = Vec::with_capacity(inits.len());
        for init in inits {
            let slot = init.device_index;
            ensure!(
                slot < self.live.len(),
                "device index {slot} outside the {}-endpoint fleet",
                self.live.len()
            );
            // a dead endpoint is skipped, not fatal: the coordinator
            // sees `false` here and treats the slot as awaiting rejoin
            delivered.push(self.send(slot, &ToDevice::Setup(Box::new(init)))?);
        }
        Ok(delivered)
    }

    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool> {
        self.drain();
        let payload = Arc::new(frame::encode_to_device(msg));
        Ok(self.push_payload(slot, payload))
    }

    fn broadcast(&mut self, slots: &[usize], msg: &ToDevice) -> Result<Vec<bool>> {
        // serialize once for the whole fleet — the epoch hot path sends
        // the same β to every device
        self.drain();
        let payload = Arc::new(frame::encode_to_device(msg));
        Ok(slots.iter().map(|&slot| self.push_payload(slot, Arc::clone(&payload))).collect())
    }

    fn disconnect(&mut self, slot: usize) {
        // mark the slot dead immediately (sends stop landing) and have
        // the reactor sever the socket: its death notice comes back at
        // the same generation, so it is deduplicated or — after a
        // rejoin — discarded, and the slot is immediately re-admittable
        if let Some(l) = self.live.get_mut(slot) {
            *l = false;
        }
        self.reactor.disconnect(slot);
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Event {
        let Self { up_rx, gens, live, reactor, pending, .. } = self;
        super::drive_queue(up_rx, timeout, pending, |(slot, gen, up), pending| {
            process_up(slot, gen, up, gens, live, reactor, pending)
        })
    }

    fn end_run(&mut self) {
        for slot in 0..self.live.len() {
            let _ = self.send(slot, &ToDevice::Stop);
        }
        // apply lifecycle side effects (a death stays a death, a rejoin
        // is live for the next run), but do not replay between-run
        // events into the next run's gather — begin_run's per-slot
        // delivery flags carry that information instead
        self.drain();
        self.pending.clear();
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // cfl-lint: allow(atomic-ordering-audit) — lone stop flag, no data published through it
        self.stop.store(true, Ordering::Relaxed);
        for slot in 0..self.live.len() {
            let _ = self.send(slot, &ToDevice::Shutdown);
        }
        // orderly reactor exit: flush the queued Shutdown frames
        // (bounded), half-close every socket so devices see EOF after
        // them, drain, join
        self.reactor.stop();
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        reap(&mut self.children, Duration::from_secs(10));
    }
}

/// `3,1,4` — the `--slots` argument format.
fn slots_csv(slots: &[usize]) -> String {
    slots.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
}

/// Bind, retrying `AddrInUse` for up to `patience`: successive sweep
/// scenarios re-bind the manifest's fixed port while the previous
/// scenario's connections sit in TIME_WAIT.
fn bind_retrying(addr: &str, patience: Duration) -> Result<TcpListener> {
    let deadline = Instant::now() + patience;
    loop {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && Instant::now() < deadline => {
                thread::sleep(Duration::from_millis(250));
            }
            Err(e) => return Err(anyhow::anyhow!("binding {addr}: {e}")),
        }
    }
}

/// Wait for spawned device subprocesses to exit (they do so on
/// `Shutdown`/EOF), killing any that outlive the deadline.
fn reap(children: &mut Vec<Child>, patience: Duration) {
    let deadline = Instant::now() + patience;
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => thread::sleep(Duration::from_millis(20)),
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
            }
        }
    }
    children.clear();
}

/// Accept connections until all `n` slots are claimed: each connection
/// must `Hello` (one slot) or `HelloMulti` (several) with distinct
/// in-range ids and a matching protocol version. A re-claim of an
/// already-filled slot follows the same *newest wins* rule as
/// post-formation rejoins — a device that crashed right after its Hello
/// and reconnected must not be stranded by its own corpse; evicting a
/// multi-slot connection un-claims *all* its slots (they died together)
/// and bumps each one's generation, which is returned so the transport
/// continues the numbering.
fn accept_fleet(
    listener: &TcpListener,
    n: usize,
    accept_timeout: Duration,
) -> Result<(Vec<Formed>, Vec<u64>)> {
    listener.set_nonblocking(true).context("making the listener pollable")?;
    let deadline = Instant::now() + accept_timeout;
    let mut conns: Vec<Option<Formed>> = Vec::new();
    // slot → index into `conns`
    let mut claimed: Vec<Option<usize>> = vec![None; n];
    let mut gens = vec![0u64; n];
    let mut connected = 0usize;
    while connected < n {
        match listener.accept() {
            Ok((stream, peer)) => match handshake(stream, n) {
                Handshake::Candidate { slots, wrapped, stream } => {
                    let mut evict: Vec<usize> = slots.iter().filter_map(|&s| claimed[s]).collect();
                    evict.sort_unstable();
                    evict.dedup();
                    for token in evict {
                        let Some(old) = conns.get_mut(token).and_then(Option::take) else {
                            continue;
                        };
                        let _ = old.stream.shutdown(std::net::Shutdown::Both);
                        for s in old.slots {
                            crate::obs_event!(
                                Warn,
                                "slot_reclaimed",
                                slot = s,
                                peer = peer.to_string(),
                            );
                            claimed[s] = None;
                            gens[s] += 1;
                            connected -= 1;
                        }
                    }
                    let token = conns.len();
                    for &s in &slots {
                        claimed[s] = Some(token);
                    }
                    connected += slots.len();
                    conns.push(Some(Formed { stream, slots, wrapped }));
                }
                // during formation a protocol mismatch means a real device
                // of the wrong version: fail fast and loudly
                Handshake::VersionMismatch(v) => anyhow::bail!(
                    "protocol mismatch: device speaks v{v}, coordinator v{}",
                    frame::PROTOCOL_VERSION
                ),
                // a stray connection (port scanner, health probe, a
                // device started twice) must not strand the fleet —
                // drop it and keep accepting until the deadline
                Handshake::Rejected(reason) => {
                    crate::obs_event!(
                        Debug,
                        "conn_rejected",
                        peer = peer.to_string(),
                        reason = reason,
                    );
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for devices: {connected}/{n} connected"
                );
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow::anyhow!("accepting a device connection: {e}")),
        }
    }
    Ok((conns.into_iter().flatten().collect(), gens))
}

/// The post-formation accept loop: validate each newcomer's handshake
/// and ship it to the transport as a rejoin candidate. Admission
/// (generation bumps, reactor registration) happens on the transport's
/// own thread, which owns the slot table — the acceptor never races it.
/// Version mismatches can't fail the session here; they are logged and
/// dropped.
fn acceptor_loop(
    listener: TcpListener,
    n: usize,
    stop: Arc<AtomicBool>,
    tx: mpsc::Sender<(usize, u64, TcpUp)>,
) {
    // cfl-lint: allow(atomic-ordering-audit) — stop flag read guards no shared state
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => match handshake(stream, n) {
                Handshake::Candidate { slots, wrapped, stream } => {
                    // generation is assigned at admission; 0 here is inert
                    let rep = slots.first().copied().unwrap_or(0);
                    if tx.send((rep, 0, TcpUp::Rejoin(stream, slots, wrapped))).is_err() {
                        return; // transport dropped; nobody is listening
                    }
                }
                Handshake::VersionMismatch(v) => {
                    crate::obs_event!(
                        Warn,
                        "rejoin_version_mismatch",
                        peer = peer.to_string(),
                        device_protocol = v,
                        coordinator_protocol = frame::PROTOCOL_VERSION,
                    );
                }
                Handshake::Rejected(reason) => {
                    crate::obs_event!(
                        Debug,
                        "conn_rejected",
                        peer = peer.to_string(),
                        reason = reason,
                    );
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Outcome of one connection handshake.
enum Handshake {
    /// A valid in-range `Hello`/`HelloMulti`: the slots it claims, the
    /// framing it committed to (`wrapped` = slot envelopes), and the
    /// configured stream (read timeout disarmed, nodelay set).
    Candidate { slots: Vec<usize>, wrapped: bool, stream: TcpStream },
    /// The peer speaks a different wire version.
    VersionMismatch(u32),
    /// Garbage, timeout, or an out-of-range id — drop the connection.
    Rejected(String),
}

/// Handshake one fresh connection: read `Hello` or `HelloMulti` within
/// [`HELLO_TIMEOUT`] and validate it. Shared by initial fleet formation
/// and the post-formation acceptor (which differ only in how they
/// react).
fn handshake(mut stream: TcpStream, n: usize) -> Handshake {
    let reject = Handshake::Rejected;
    let configured = stream.set_nonblocking(false).is_ok()
        && stream.set_read_timeout(Some(HELLO_TIMEOUT)).is_ok();
    if !configured {
        return reject("could not configure the socket".into());
    }
    stream.set_nodelay(true).ok();
    let payload = match frame::read_frame(&mut stream) {
        Ok(Some(p)) => p,
        Ok(None) => return reject("peer closed before sending Hello".into()),
        Err(e) => return reject(format!("unreadable Hello frame: {e}")),
    };
    let hello = match frame::decode_from_device(&payload) {
        Ok(h) => h,
        Err(e) => return reject(format!("corrupt Hello frame: {e}")),
    };
    let (slots, wrapped) = match hello {
        FromDevice::Hello { device_id, protocol } => {
            if protocol != frame::PROTOCOL_VERSION {
                return Handshake::VersionMismatch(protocol);
            }
            (vec![device_id], false)
        }
        FromDevice::HelloMulti { device_ids, protocol } => {
            if protocol != frame::PROTOCOL_VERSION {
                return Handshake::VersionMismatch(protocol);
            }
            (device_ids, true)
        }
        other => {
            return reject(format!("expected Hello as the first message, got {other:?}"));
        }
    };
    if slots.is_empty() {
        return reject("multi-slot Hello claiming no slots".into());
    }
    let mut seen = vec![false; n];
    for &s in &slots {
        if s >= n {
            return reject(format!("device id {s} outside the {n}-device fleet"));
        }
        if seen[s] {
            return reject(format!("duplicate slot {s} in a multi-slot Hello"));
        }
        seen[s] = true;
    }
    if stream.set_read_timeout(None).is_err() {
        return reject("disarming the Hello timeout".into());
    }
    Handshake::Candidate { slots, wrapped, stream }
}

// --- device side -----------------------------------------------------

/// A device process's end of the socket.
struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Whether the coordinator ever spoke to us on this connection — the
    /// admission signal [`run_device_retry`] uses to tell a live session
    /// that later broke (retry) from a connection dropped unseen (an
    /// unadmitted duplicate, a rejected version: strike and eventually
    /// give up).
    got_any: bool,
}

impl TcpLink {
    fn new(stream: TcpStream) -> Result<Self> {
        let writer = stream.try_clone().context("splitting the coordinator socket")?;
        Ok(Self { reader: BufReader::new(stream), writer, got_any: false })
    }
}

impl DeviceLink for TcpLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        match frame::read_frame(&mut self.reader)? {
            Some(payload) => {
                self.got_any = true;
                Ok(Some(frame::decode_to_device(&payload)?))
            }
            None => Ok(None),
        }
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        frame::write_frame(&mut self.writer, &frame::encode_from_device(&msg))
    }
}

/// One slot's end of a *multi-slot* connection: coordinator messages
/// arrive demultiplexed through a channel (the session's reader thread
/// peels the slot envelopes), replies go out slot-wrapped through the
/// shared writer.
struct MuxLink {
    slot: usize,
    rx: mpsc::Receiver<ToDevice>,
    writer: Arc<Mutex<TcpStream>>,
}

impl DeviceLink for MuxLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        Ok(self.rx.recv().ok())
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        let payload = frame::wrap_slot(self.slot, &frame::encode_from_device(&msg));
        let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
        frame::write_frame(&mut *w, &payload)
    }
}

/// Dial the coordinator, retrying while it finishes starting up (or, on
/// a rejoin, while the old incarnation's death notice propagates).
fn connect_stream(addr: &str, connect_timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + connect_timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                ensure!(Instant::now() < deadline, "connecting to {addr}: {e}");
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One device session over one connection: `Hello`, then the shared
/// state machine until the link ends. The boolean reports whether the
/// coordinator ever spoke to us (i.e. this connection was admitted).
fn device_session(stream: TcpStream, device_id: usize) -> (Result<SessionEnd>, bool) {
    let mut link = match TcpLink::new(stream) {
        Ok(l) => l,
        Err(e) => return (Err(e), false),
    };
    let hello = FromDevice::Hello { device_id, protocol: frame::PROTOCOL_VERSION };
    if let Err(e) = link.send(hello) {
        return (Err(e), false);
    }
    let end = run_device_loop(&mut link);
    (end, link.got_any)
}

/// One multi-slot session over one connection: `HelloMulti`, then a
/// per-slot worker thread each running the shared state machine while
/// this thread demultiplexes incoming slot-wrapped frames. The session
/// ends `Shutdown` only when *every* slot was explicitly shut down.
fn multi_device_session(stream: TcpStream, slots: &[usize]) -> (Result<SessionEnd>, bool) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            return (Err(anyhow::anyhow!("splitting the coordinator socket: {e}")), false);
        }
    };
    {
        let hello =
            FromDevice::HelloMulti { device_ids: slots.to_vec(), protocol: frame::PROTOCOL_VERSION };
        let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
        if let Err(e) = frame::write_frame(&mut *w, &frame::encode_from_device(&hello)) {
            return (Err(e), false);
        }
    }
    let mut workers: Vec<(usize, mpsc::Sender<ToDevice>, thread::JoinHandle<Result<SessionEnd>>)> =
        Vec::with_capacity(slots.len());
    for &slot in slots {
        let (tx, rx) = mpsc::channel::<ToDevice>();
        let writer = Arc::clone(&writer);
        let handle = thread::spawn(move || {
            let mut link = MuxLink { slot, rx, writer };
            run_device_loop(&mut link)
        });
        workers.push((slot, tx, handle));
    }
    // demultiplex on this thread until the connection ends
    let mut reader = BufReader::new(stream);
    let mut got_any = false;
    let fault: Option<anyhow::Error> = loop {
        match frame::read_frame(&mut reader) {
            Ok(Some(payload)) => {
                got_any = true;
                match frame::unwrap_slot(&payload) {
                    Ok(Some((slot, inner))) => match frame::decode_to_device(inner) {
                        Ok(msg) => {
                            // a send error just means that worker already
                            // exited (it saw Shutdown); keep demuxing for
                            // the others
                            if let Some((_, tx, _)) = workers.iter().find(|(s, _, _)| *s == slot) {
                                let _ = tx.send(msg);
                            }
                        }
                        Err(e) => break Some(e),
                    },
                    Ok(None) => {
                        break Some(anyhow::anyhow!(
                            "protocol violation: bare frame on a multi-slot connection"
                        ));
                    }
                    Err(e) => break Some(e),
                }
            }
            Ok(None) => break None, // clean EOF
            Err(e) => break Some(e),
        }
    };
    // dropping the senders ends each worker's recv stream; a worker that
    // already saw Shutdown reports it, the rest report HangUp
    let mut ends: Vec<Result<SessionEnd>> = Vec::with_capacity(workers.len());
    for (_, tx, handle) in workers {
        drop(tx);
        ends.push(handle.join().unwrap_or(Ok(SessionEnd::HangUp)));
    }
    if let Some(e) = fault {
        return (Err(e), got_any);
    }
    let mut end = SessionEnd::Shutdown;
    for r in ends {
        match r {
            Ok(SessionEnd::Shutdown) => {}
            Ok(SessionEnd::HangUp) => end = SessionEnd::HangUp,
            Err(e) => return (Err(e), got_any),
        }
    }
    (Ok(end), got_any)
}

/// The `cfl device` entry point: connect to a coordinator (retrying while
/// it finishes starting up), claim fleet slot `device_id`, and serve
/// [`run_device_loop`] until the session ends one way or the other.
pub fn run_device(addr: &str, device_id: usize, connect_timeout: Duration) -> Result<()> {
    let stream = connect_stream(addr, connect_timeout)?;
    device_session(stream, device_id).0.map(|_| ())
}

/// The `cfl device --slots a,b,c` entry point: one process, one
/// connection, several fleet slots.
pub fn run_device_multi(addr: &str, slots: &[usize], connect_timeout: Duration) -> Result<()> {
    ensure!(!slots.is_empty(), "--slots needs at least one slot");
    let stream = connect_stream(addr, connect_timeout)?;
    multi_device_session(stream, slots).0.map(|_| ())
}

/// Consecutive never-admitted connections after which a retrying device
/// gives up: a coordinator that drops us without ever speaking is
/// rejecting deterministically (wrong `--id`, a protocol-version
/// mismatch, a slot that is genuinely claimed by someone else), and
/// redialing it forever would just fill both logs.
const MAX_SILENT_REJECTIONS: u32 = 5;

/// Reconnect backoff with deterministic per-slot jitter: a mass-kill
/// restarts many devices at once, and identical backoff schedules would
/// redial (and collide at the acceptor) in lockstep. The jitter stream
/// is seeded off the slot id and attempt counter — fully reproducible,
/// no wall-clock entropy — and spreads each sleep over [0.5×, 1.5×].
fn jittered(backoff: Duration, slot: usize, attempt: u32) -> Duration {
    let mut rng = Rng::new(mix_seed(slot as u64, u64::from(attempt)));
    backoff.mul_f64(rng.uniform(0.5, 1.5))
}

/// The `cfl device --retry` entry point: like [`run_device`], but a
/// session that ends in anything other than an explicit `Shutdown` — the
/// socket broke mid-run, the coordinator dropped this connection as a
/// duplicate while the old incarnation's death was still propagating —
/// reconnects with jittered exponential backoff (see [`jittered`]) and
/// re-claims the slot. Exits `Ok` on `Shutdown`; errors when the
/// coordinator stays unreachable for a whole `connect_timeout` window,
/// or after [`MAX_SILENT_REJECTIONS`] consecutive connections the
/// coordinator dropped without ever speaking to us (a deterministic
/// rejection, not a transient rejoin race).
pub fn run_device_retry(
    addr: &str,
    device_id: usize,
    connect_timeout: Duration,
    quiet: bool,
) -> Result<()> {
    run_device_multi_retry(addr, RetrySlots::Single(device_id), connect_timeout, quiet, false)
}

/// Which handshake a retrying device speaks each time it reconnects.
pub enum RetrySlots {
    /// Plain `Hello{id}` — bare frames.
    Single(usize),
    /// `HelloMulti` — slot-enveloped frames, even for one slot.
    Multi(Vec<usize>),
}

impl RetrySlots {
    /// The jitter/backoff identity: the first (or only) slot.
    fn rep(&self) -> usize {
        match self {
            RetrySlots::Single(id) => *id,
            RetrySlots::Multi(slots) => slots.first().copied().unwrap_or(0),
        }
    }
}

/// The retry/persist loop shared by `cfl device --retry` (single slot)
/// and `cfl device --slots a,b,c --retry [--persist]`. With `persist`,
/// an explicit `Shutdown` does not end the process either: the device
/// redials and waits for the *next* session (successive sweep scenarios
/// re-bind the same placement port), and only exits — cleanly — once
/// the coordinator stays unreachable for a whole `connect_timeout`
/// window after at least one completed session.
pub fn run_device_multi_retry(
    addr: &str,
    slots: RetrySlots,
    connect_timeout: Duration,
    quiet: bool,
    persist: bool,
) -> Result<()> {
    if let RetrySlots::Multi(s) = &slots {
        ensure!(!s.is_empty(), "--slots needs at least one slot");
    }
    let rep = slots.rep();
    let mut backoff = Duration::from_millis(50);
    let mut attempt = 0u32;
    let mut silent_rejections = 0u32;
    let mut had_session = false;
    loop {
        let stream = match connect_stream(addr, connect_timeout) {
            Ok(s) => s,
            Err(e) => {
                // a persisting device that already served a session and
                // now finds the coordinator gone for a whole connect
                // window is done, not broken
                if persist && had_session {
                    return Ok(());
                }
                return Err(e);
            }
        };
        let (end, admitted) = match &slots {
            RetrySlots::Single(id) => device_session(stream, *id),
            RetrySlots::Multi(s) => multi_device_session(stream, s),
        };
        if admitted {
            // a real session happened: this is churn, not rejection —
            // start the next episode from a fresh, fast backoff
            had_session = true;
            silent_rejections = 0;
            backoff = Duration::from_millis(50);
        } else {
            silent_rejections += 1;
            ensure!(
                silent_rejections < MAX_SILENT_REJECTIONS,
                "coordinator at {addr} dropped {silent_rejections} consecutive connections \
                 for device {rep} without speaking (wrong --id/--slots, protocol mismatch, \
                 or the slot is claimed); giving up"
            );
        }
        match end {
            Ok(SessionEnd::Shutdown) if !persist => return Ok(()),
            Ok(SessionEnd::Shutdown) => {
                if !quiet {
                    crate::obs_event!(
                        Info,
                        "device_persisting",
                        device = rep,
                        reason = "session shut down; awaiting the next one",
                    );
                }
            }
            Ok(SessionEnd::HangUp) => {
                if !quiet {
                    crate::obs_event!(
                        Info,
                        "device_rejoining",
                        device = rep,
                        reason = "link closed without Shutdown",
                    );
                }
            }
            Err(e) => {
                if !quiet {
                    crate::obs_event!(
                        Info,
                        "device_rejoining",
                        device = rep,
                        reason = format!("session error: {e}"),
                    );
                }
            }
        }
        attempt = attempt.wrapping_add(1);
        thread::sleep(jittered(backoff, rep, attempt));
        backoff = (backoff * 2).min(Duration::from_secs(1));
    }
}
