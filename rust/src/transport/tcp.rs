//! TCP loopback/network transport: real processes on real sockets.
//!
//! Coordinator side ([`TcpTransport`]): accept one connection per fleet
//! slot (each opened by a `cfl device` process announcing itself with
//! `Hello`), then speak the [`frame`] wire format — a reader thread per
//! socket feeds replies into one queue, and socket EOF/corruption is
//! surfaced as [`Event::Gone`] so the epoch loop degrades that device to
//! parity-only instead of stalling.
//!
//! Death is not a one-way door: after fleet formation the listener stays
//! open on a background acceptor thread, and a fresh connection whose
//! `Hello{id}` names a currently-dead slot is **re-admitted** — new
//! reader thread, new writer half, and an [`Event::Rejoined`] so the
//! coordinator re-arms the device with `Setup`. Every incarnation of a
//! slot carries a generation tag; events from a previous incarnation (a
//! straggling reply, a late death notice from a silently-partitioned
//! socket) are discarded at the transport level, so they can neither be
//! attributed to nor kill the replacement. A valid `Hello` for a slot
//! whose old link is still open takes the slot over (*newest wins*): a
//! half-open socket whose death notice never landed — a silent network
//! partition — must not block the genuine device from reconnecting, so
//! the corpse is severed and the newcomer admitted. (During initial
//! fleet formation a duplicate claim is still dropped.)
//!
//! Device side ([`run_device`]): connect (with retry while the
//! coordinator is still starting), `Hello`, then hand the socket to the
//! shared [`run_device_loop`] state machine. [`run_device_retry`]
//! (`cfl device --retry`) wraps that in a reconnect/backoff loop: a
//! session that ends in anything but an explicit `Shutdown` — the socket
//! broke, the process was restarted after a crash, the coordinator
//! dropped an unadmitted duplicate — dials again and re-claims its slot.
//!
//! [`TcpTransport::spawn_local`] packages the loopback case the sweep
//! engine uses (`cfl sweep --live --transport tcp`): bind an ephemeral
//! port, spawn `cfl device` subprocesses, accept them, and reap the
//! children when the transport drops.

use super::{
    frame, run_device_loop, stale_discard, DeviceInit, DeviceLink, Event, FromDevice, SessionEnd,
    ToDevice, Transport,
};
use crate::obs::Counter;
use anyhow::{ensure, Context, Result};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// How long a freshly-accepted connection gets to present its `Hello`.
const HELLO_TIMEOUT: Duration = Duration::from_secs(5);

/// How long [`TcpTransport::spawn_local`] waits for its own subprocesses
/// to connect back.
const SPAWN_ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Accept-poll interval of the post-formation acceptor thread.
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// Everything the coordinator-side event queue carries: reader upstream
/// traffic tagged `(slot, generation)`, plus rejoin candidates from the
/// acceptor thread. One queue keeps a reader's EOF notice ordered before
/// the reconnection that follows it.
enum TcpUp {
    Msg(FromDevice),
    Gone,
    /// A fresh connection presented a valid `Hello` for this slot; the
    /// stream is shipped to the transport, which admits it only if the
    /// slot is currently dead.
    Rejoin(TcpStream),
}

/// Downstream fleet-traffic counters (wire bytes include the 4-byte
/// length prefix), resolved once so the per-frame accounting on the
/// broadcast hot path is a pair of relaxed atomic adds. The upstream
/// counterparts live in each [`reader_loop`] thread.
struct WireCounters {
    frames_sent: Counter,
    bytes_sent: Counter,
}

impl WireCounters {
    fn new() -> Self {
        let reg = crate::obs::registry();
        Self {
            frames_sent: reg.counter("transport.frames_sent"),
            bytes_sent: reg.counter("transport.bytes_sent"),
        }
    }
}

/// Coordinator-side TCP fleet: one framed socket per device slot.
pub struct TcpTransport {
    /// Write halves, slot-indexed; `None` = endpoint gone.
    links: Vec<Option<TcpStream>>,
    /// Current incarnation per slot; bumped on rejoin so stale events
    /// from an earlier incarnation can be recognized and dropped.
    gens: Vec<u64>,
    up_rx: mpsc::Receiver<(usize, u64, TcpUp)>,
    up_tx: mpsc::Sender<(usize, u64, TcpUp)>,
    /// Post-formation acceptor thread (owns the listener) + its stop flag.
    acceptor: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Locally-spawned `cfl device` subprocesses (empty under `serve`).
    children: Vec<Child>,
    ctr: WireCounters,
}

impl TcpTransport {
    /// Accept `n` device connections on an already-bound listener (the
    /// `cfl serve` path — devices are started by someone else), then
    /// keep the listener accepting in the background so restarted
    /// devices can rejoin.
    pub fn serve(listener: TcpListener, n: usize, accept_timeout: Duration) -> Result<Self> {
        let (up_tx, up_rx) = mpsc::channel::<(usize, u64, TcpUp)>();
        let (links, gens) = accept_fleet(&listener, n, accept_timeout, &up_tx)?;
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let tx = up_tx.clone();
            let stop = Arc::clone(&stop);
            thread::spawn(move || acceptor_loop(listener, n, stop, tx))
        };
        Ok(Self {
            links,
            gens,
            up_rx,
            up_tx,
            acceptor: Some(acceptor),
            stop,
            children: Vec::new(),
            ctr: WireCounters::new(),
        })
    }

    /// Write one already-encoded frame to a slot; `false` marks the
    /// endpoint dead (shared by [`Transport::send`] and the
    /// encode-once [`Transport::broadcast`]).
    fn write_payload(&mut self, slot: usize, payload: &[u8]) -> bool {
        let Some(stream) = self.links.get_mut(slot).and_then(|l| l.as_mut()) else {
            return false;
        };
        if frame::write_frame(stream, payload).is_err() {
            self.links[slot] = None;
            return false;
        }
        self.ctr.frames_sent.incr();
        self.ctr.bytes_sent.add(payload.len() as u64 + 4);
        true
    }

    /// Bind an ephemeral loopback port, spawn `n` `cfl device`
    /// subprocesses of `bin` pointed at it, and accept them — the
    /// self-contained fleet behind `cfl sweep --live --transport tcp`.
    pub fn spawn_local(bin: &std::path::Path, n: usize) -> Result<Self> {
        ensure!(n > 0, "a TCP fleet needs at least one device");
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding a loopback listener")?;
        let addr = listener.local_addr().context("reading the bound address")?.to_string();
        let mut children: Vec<Child> = Vec::with_capacity(n);
        let spawn = |k: usize| -> Result<Child> {
            Command::new(bin)
                .args(["device", "--connect", &addr, "--id", &k.to_string(), "--quiet"])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .with_context(|| format!("spawning {} device {k}", bin.display()))
        };
        for k in 0..n {
            match spawn(k) {
                Ok(child) => children.push(child),
                Err(e) => {
                    reap(&mut children, Duration::ZERO);
                    return Err(e);
                }
            }
        }
        match Self::serve(listener, n, SPAWN_ACCEPT_TIMEOUT) {
            Ok(mut t) => {
                t.children = children;
                Ok(t)
            }
            Err(e) => {
                reap(&mut children, Duration::ZERO);
                Err(e)
            }
        }
    }

    /// Process one queued event. Returns the public event to surface, or
    /// `None` when the event was internal (stale-incarnation traffic to
    /// discard, a rejoin candidate for a still-live slot).
    fn process(&mut self, slot: usize, gen: u64, up: TcpUp) -> Option<Event> {
        match up {
            // a reply from a dead incarnation must not be attributed to
            // its replacement
            TcpUp::Msg(msg) => {
                if gen != self.gens[slot] {
                    stale_discard(slot, gen);
                    return None;
                }
                Some(Event::Msg(slot, msg))
            }
            TcpUp::Gone => {
                if gen != self.gens[slot] {
                    stale_discard(slot, gen);
                    return None; // stale death notice: the slot rejoined
                }
                // a death notice is one-shot (the reader thread is gone):
                // record it at the transport level too, so the endpoint
                // stays dead across runs until a rejoin re-claims it
                self.links[slot] = None;
                crate::obs::registry()
                    .counter(&format!("transport.slot{slot}.disconnects"))
                    .incr();
                crate::obs_event!(Debug, "endpoint_gone", slot = slot, gen = gen);
                Some(Event::Gone(slot))
            }
            TcpUp::Rejoin(stream) => {
                // newest wins: if the slot's old link is still open, it
                // is a half-open socket whose death notice never landed
                // (silent partition, kernel buffers swallowing writes) —
                // on a trusted network a valid Hello for the slot is
                // overwhelmingly the genuine device reconnecting, so
                // sever the corpse and admit the newcomer. The old
                // incarnation's eventual death notice is filtered by the
                // generation bump below.
                if let Some(old) = self.links.get_mut(slot).and_then(|l| l.take()) {
                    let _ = old.shutdown(std::net::Shutdown::Both);
                }
                let Ok(writer) = stream.try_clone() else { return None };
                self.gens[slot] += 1;
                let gen = self.gens[slot];
                let tx = self.up_tx.clone();
                thread::spawn(move || reader_loop(slot, gen, stream, tx));
                self.links[slot] = Some(writer);
                crate::obs::registry()
                    .counter(&format!("transport.slot{slot}.rejoins"))
                    .incr();
                crate::obs_event!(Info, "endpoint_rejoined", slot = slot, gen = gen);
                Some(Event::Rejoined(slot))
            }
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn n_endpoints(&self) -> usize {
        self.links.len()
    }

    fn begin_run(&mut self, inits: Vec<DeviceInit>) -> Result<Vec<bool>> {
        let mut delivered = Vec::with_capacity(inits.len());
        for init in inits {
            let slot = init.device_index;
            ensure!(
                slot < self.links.len(),
                "device index {slot} outside the {}-endpoint fleet",
                self.links.len()
            );
            // a dead endpoint is skipped, not fatal: the coordinator
            // sees `false` here and treats the slot as awaiting rejoin
            delivered.push(self.send(slot, &ToDevice::Setup(Box::new(init)))?);
        }
        Ok(delivered)
    }

    fn send(&mut self, slot: usize, msg: &ToDevice) -> Result<bool> {
        Ok(self.write_payload(slot, &frame::encode_to_device(msg)))
    }

    fn broadcast(&mut self, slots: &[usize], msg: &ToDevice) -> Result<Vec<bool>> {
        // serialize once for the whole fleet — the epoch hot path sends
        // the same β to every device
        let payload = frame::encode_to_device(msg);
        Ok(slots.iter().map(|&slot| self.write_payload(slot, &payload)).collect())
    }

    fn disconnect(&mut self, slot: usize) {
        // drop the write half and shut the socket both ways: the reader
        // thread unblocks into its death notice (same generation, so it
        // is deduplicated or — after a rejoin — discarded), and the slot
        // becomes immediately re-admittable
        if let Some(s) = self.links.get_mut(slot).and_then(|l| l.take()) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    // NB: this deadline-drain loop is intentionally mirrored in
    // channel.rs::recv_timeout — a generic helper would need a
    // split-borrow closure over half the struct; keep the two in sync.
    fn recv_timeout(&mut self, timeout: Duration) -> Event {
        let deadline = Instant::now() + timeout;
        loop {
            let wait = deadline.saturating_duration_since(Instant::now());
            match self.up_rx.recv_timeout(wait) {
                Ok((slot, gen, up)) => {
                    if let Some(public) = self.process(slot, gen, up) {
                        return public;
                    }
                    // internal event consumed: keep draining within the
                    // caller's original deadline (a zero remaining wait
                    // still picks up already-queued events)
                }
                Err(mpsc::RecvTimeoutError::Timeout) => return Event::Timeout,
                Err(mpsc::RecvTimeoutError::Disconnected) => return Event::Closed,
            }
        }
    }

    fn end_run(&mut self) {
        for slot in 0..self.links.len() {
            let _ = self.send(slot, &ToDevice::Stop);
        }
        // discard stale replies, but keep lifecycle events: a Gone
        // drained here must still kill the link (its reader thread is
        // gone, so the notice would never repeat), and a rejoin admitted
        // here is simply live for the next run (its Setup arrives with
        // the next begin_run).
        while let Ok((slot, gen, up)) = self.up_rx.try_recv() {
            let _ = self.process(slot, gen, up);
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // cfl-lint: allow(atomic-ordering-audit) — lone stop flag, no data published through it
        self.stop.store(true, Ordering::Relaxed);
        for slot in 0..self.links.len() {
            let _ = self.send(slot, &ToDevice::Shutdown);
        }
        for link in self.links.iter_mut() {
            if let Some(s) = link.take() {
                let _ = s.shutdown(std::net::Shutdown::Write);
            }
        }
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        reap(&mut self.children, Duration::from_secs(10));
    }
}

/// Wait for spawned device subprocesses to exit (they do so on
/// `Shutdown`/EOF), killing any that outlive the deadline.
fn reap(children: &mut Vec<Child>, patience: Duration) {
    let deadline = Instant::now() + patience;
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < deadline => thread::sleep(Duration::from_millis(20)),
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                    break;
                }
            }
        }
    }
    children.clear();
}

/// Accept `n` devices: each must `Hello` with a distinct in-range id and
/// a matching protocol version; each then gets a reader thread feeding
/// the shared event queue. A re-claim of an already-filled slot follows
/// the same *newest wins* rule as post-formation rejoins — a device that
/// crashed right after its Hello and reconnected must not be stranded by
/// its own corpse (formation never reads the event queue, so the old
/// incarnation's death notice cannot land here); the per-slot generation
/// counter keeps the corpse's queued events attributable, and is
/// returned so the transport continues the numbering.
#[allow(clippy::type_complexity)]
fn accept_fleet(
    listener: &TcpListener,
    n: usize,
    accept_timeout: Duration,
    up_tx: &mpsc::Sender<(usize, u64, TcpUp)>,
) -> Result<(Vec<Option<TcpStream>>, Vec<u64>)> {
    listener.set_nonblocking(true).context("making the listener pollable")?;
    let deadline = Instant::now() + accept_timeout;
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut gens: Vec<u64> = vec![0; n];
    let mut connected = 0usize;
    while connected < n {
        match listener.accept() {
            Ok((stream, peer)) => match handshake(stream, n) {
                Handshake::Candidate(slot, stream) => {
                    if let Some(old) = links[slot].take() {
                        crate::obs_event!(
                            Warn,
                            "slot_reclaimed",
                            slot = slot,
                            peer = peer.to_string(),
                        );
                        let _ = old.shutdown(std::net::Shutdown::Both);
                        gens[slot] += 1;
                    } else {
                        connected += 1;
                    }
                    let writer = stream.try_clone().context("splitting the device socket")?;
                    let tx = up_tx.clone();
                    let gen = gens[slot];
                    thread::spawn(move || reader_loop(slot, gen, stream, tx));
                    links[slot] = Some(writer);
                }
                // during formation a protocol mismatch means a real device
                // of the wrong version: fail fast and loudly
                Handshake::VersionMismatch(v) => anyhow::bail!(
                    "protocol mismatch: device speaks v{v}, coordinator v{}",
                    frame::PROTOCOL_VERSION
                ),
                // a stray connection (port scanner, health probe, a
                // device started twice) must not strand the fleet —
                // drop it and keep accepting until the deadline
                Handshake::Rejected(reason) => {
                    crate::obs_event!(
                        Debug,
                        "conn_rejected",
                        peer = peer.to_string(),
                        reason = reason,
                    );
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for devices: {connected}/{n} connected"
                );
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow::anyhow!("accepting a device connection: {e}")),
        }
    }
    Ok((links, gens))
}

/// The post-formation accept loop: validate each newcomer's `Hello` and
/// ship it to the transport as a rejoin candidate. Admission (is the
/// slot actually dead?) happens on the transport's own thread, which
/// owns the link table — the acceptor never races it. Version mismatches
/// can't fail the session here; they are logged and dropped.
fn acceptor_loop(
    listener: TcpListener,
    n: usize,
    stop: Arc<AtomicBool>,
    tx: mpsc::Sender<(usize, u64, TcpUp)>,
) {
    // cfl-lint: allow(atomic-ordering-audit) — stop flag read guards no shared state
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => match handshake(stream, n) {
                Handshake::Candidate(slot, stream) => {
                    // generation is assigned at admission; 0 here is inert
                    if tx.send((slot, 0, TcpUp::Rejoin(stream))).is_err() {
                        return; // transport dropped; nobody is listening
                    }
                }
                Handshake::VersionMismatch(v) => {
                    crate::obs_event!(
                        Warn,
                        "rejoin_version_mismatch",
                        peer = peer.to_string(),
                        device_protocol = v,
                        coordinator_protocol = frame::PROTOCOL_VERSION,
                    );
                }
                Handshake::Rejected(reason) => {
                    crate::obs_event!(
                        Debug,
                        "conn_rejected",
                        peer = peer.to_string(),
                        reason = reason,
                    );
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Outcome of one connection handshake.
enum Handshake {
    /// A valid in-range `Hello`: the slot it claims and the configured
    /// stream (read timeout disarmed, nodelay set).
    Candidate(usize, TcpStream),
    /// The peer speaks a different wire version.
    VersionMismatch(u32),
    /// Garbage, timeout, or an out-of-range id — drop the connection.
    Rejected(String),
}

/// Handshake one fresh connection: read `Hello` within [`HELLO_TIMEOUT`]
/// and validate it. Shared by initial fleet formation and the
/// post-formation acceptor (which differ only in how they react).
fn handshake(mut stream: TcpStream, n: usize) -> Handshake {
    let reject = Handshake::Rejected;
    let configured = stream.set_nonblocking(false).is_ok()
        && stream.set_read_timeout(Some(HELLO_TIMEOUT)).is_ok();
    if !configured {
        return reject("could not configure the socket".into());
    }
    stream.set_nodelay(true).ok();
    let payload = match frame::read_frame(&mut stream) {
        Ok(Some(p)) => p,
        Ok(None) => return reject("peer closed before sending Hello".into()),
        Err(e) => return reject(format!("unreadable Hello frame: {e}")),
    };
    let hello = match frame::decode_from_device(&payload) {
        Ok(h) => h,
        Err(e) => return reject(format!("corrupt Hello frame: {e}")),
    };
    let FromDevice::Hello { device_id, protocol } = hello else {
        return reject(format!("expected Hello as the first message, got {hello:?}"));
    };
    if protocol != frame::PROTOCOL_VERSION {
        return Handshake::VersionMismatch(protocol);
    }
    if device_id >= n {
        return reject(format!("device id {device_id} outside the {n}-device fleet"));
    }
    if stream.set_read_timeout(None).is_err() {
        return reject("disarming the Hello timeout".into());
    }
    Handshake::Candidate(device_id, stream)
}

/// Per-socket reader: frames in, events out; any EOF or framing fault
/// ends the endpoint with a `Gone` carrying this incarnation's tag.
fn reader_loop(slot: usize, gen: u64, stream: TcpStream, tx: mpsc::Sender<(usize, u64, TcpUp)>) {
    // upstream counters resolved once per incarnation, then lock-free
    let reg = crate::obs::registry();
    let frames_recv = reg.counter("transport.frames_recv");
    let bytes_recv = reg.counter("transport.bytes_recv");
    let mut reader = BufReader::new(stream);
    loop {
        match frame::read_frame(&mut reader) {
            Ok(Some(payload)) => match frame::decode_from_device(&payload) {
                Ok(msg) => {
                    frames_recv.incr();
                    bytes_recv.add(payload.len() as u64 + 4);
                    if tx.send((slot, gen, TcpUp::Msg(msg))).is_err() {
                        return; // transport dropped; nobody is listening
                    }
                }
                Err(_) => break, // corrupt frame: treat the peer as dead
            },
            Ok(None) | Err(_) => break,
        }
    }
    let _ = tx.send((slot, gen, TcpUp::Gone));
}

/// A device process's end of the socket.
struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Whether the coordinator ever spoke to us on this connection — the
    /// admission signal [`run_device_retry`] uses to tell a live session
    /// that later broke (retry) from a connection dropped unseen (an
    /// unadmitted duplicate, a rejected version: strike and eventually
    /// give up).
    got_any: bool,
}

impl TcpLink {
    fn new(stream: TcpStream) -> Result<Self> {
        let writer = stream.try_clone().context("splitting the coordinator socket")?;
        Ok(Self { reader: BufReader::new(stream), writer, got_any: false })
    }
}

impl DeviceLink for TcpLink {
    fn recv(&mut self) -> Result<Option<ToDevice>> {
        match frame::read_frame(&mut self.reader)? {
            Some(payload) => {
                self.got_any = true;
                Ok(Some(frame::decode_to_device(&payload)?))
            }
            None => Ok(None),
        }
    }

    fn send(&mut self, msg: FromDevice) -> Result<()> {
        frame::write_frame(&mut self.writer, &frame::encode_from_device(&msg))
    }
}

/// Dial the coordinator, retrying while it finishes starting up (or, on
/// a rejoin, while the old incarnation's death notice propagates).
fn connect_stream(addr: &str, connect_timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + connect_timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                ensure!(Instant::now() < deadline, "connecting to {addr}: {e}");
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One device session over one connection: `Hello`, then the shared
/// state machine until the link ends. The boolean reports whether the
/// coordinator ever spoke to us (i.e. this connection was admitted).
fn device_session(stream: TcpStream, device_id: usize) -> (Result<SessionEnd>, bool) {
    let mut link = match TcpLink::new(stream) {
        Ok(l) => l,
        Err(e) => return (Err(e), false),
    };
    let hello = FromDevice::Hello { device_id, protocol: frame::PROTOCOL_VERSION };
    if let Err(e) = link.send(hello) {
        return (Err(e), false);
    }
    let end = run_device_loop(&mut link);
    (end, link.got_any)
}

/// The `cfl device` entry point: connect to a coordinator (retrying while
/// it finishes starting up), claim fleet slot `device_id`, and serve
/// [`run_device_loop`] until the session ends one way or the other.
pub fn run_device(addr: &str, device_id: usize, connect_timeout: Duration) -> Result<()> {
    let stream = connect_stream(addr, connect_timeout)?;
    device_session(stream, device_id).0.map(|_| ())
}

/// Consecutive never-admitted connections after which a retrying device
/// gives up: a coordinator that drops us without ever speaking is
/// rejecting deterministically (wrong `--id`, a protocol-version
/// mismatch, a slot that is genuinely claimed by someone else), and
/// redialing it forever would just fill both logs.
const MAX_SILENT_REJECTIONS: u32 = 5;

/// The `cfl device --retry` entry point: like [`run_device`], but a
/// session that ends in anything other than an explicit `Shutdown` — the
/// socket broke mid-run, the coordinator dropped this connection as a
/// duplicate while the old incarnation's death was still propagating —
/// reconnects with exponential backoff and re-claims the slot. Exits
/// `Ok` on `Shutdown`; errors when the coordinator stays unreachable for
/// a whole `connect_timeout` window, or after
/// [`MAX_SILENT_REJECTIONS`] consecutive connections the coordinator
/// dropped without ever speaking to us (a deterministic rejection, not a
/// transient rejoin race).
pub fn run_device_retry(
    addr: &str,
    device_id: usize,
    connect_timeout: Duration,
    quiet: bool,
) -> Result<()> {
    let mut backoff = Duration::from_millis(50);
    let mut silent_rejections = 0u32;
    loop {
        let stream = connect_stream(addr, connect_timeout)?;
        let (end, admitted) = device_session(stream, device_id);
        if admitted {
            // a real session happened: this is churn, not rejection —
            // start the next episode from a fresh, fast backoff
            silent_rejections = 0;
            backoff = Duration::from_millis(50);
        } else {
            silent_rejections += 1;
            ensure!(
                silent_rejections < MAX_SILENT_REJECTIONS,
                "coordinator at {addr} dropped {silent_rejections} consecutive connections \
                 for device {device_id} without speaking (wrong --id, protocol mismatch, \
                 or the slot is claimed); giving up"
            );
        }
        match end {
            Ok(SessionEnd::Shutdown) => return Ok(()),
            Ok(SessionEnd::HangUp) => {
                if !quiet {
                    crate::obs_event!(
                        Info,
                        "device_rejoining",
                        device = device_id,
                        reason = "link closed without Shutdown",
                    );
                }
            }
            Err(e) => {
                if !quiet {
                    crate::obs_event!(
                        Info,
                        "device_rejoining",
                        device = device_id,
                        reason = format!("session error: {e}"),
                    );
                }
            }
        }
        thread::sleep(backoff);
        backoff = (backoff * 2).min(Duration::from_secs(1));
    }
}
