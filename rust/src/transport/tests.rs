use super::frame::{
    decode_from_device, decode_to_device, encode_from_device, encode_to_device, read_frame,
    write_frame, MAX_FRAME_BYTES, PROTOCOL_VERSION,
};
use super::*;
use crate::fl::{GradBackend, NativeBackend};
use crate::linalg::Mat;
use crate::simnet::{ComputeModel, DeviceProfile, LinkModel};
use std::io::Cursor;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

fn profile() -> DeviceProfile {
    DeviceProfile {
        compute: ComputeModel { secs_per_point: 0.25, mem_rate: 8.0 },
        link: LinkModel { secs_per_packet: 0.125, erasure_prob: 0.1 },
        points: 60,
    }
}

fn init(slot: usize) -> DeviceInit {
    DeviceInit {
        run: 7,
        device_index: slot,
        load: 3,
        delay_seed: 0xDEAD + slot as u64,
        // effectively no wall sleep: keep the tests instant
        time_scale: 1e-9,
        max_scaled_secs: 0.25,
        profile: profile(),
        x_sys: Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
        y_sys: Mat::from_vec(3, 1, vec![1.0, -1.0, 0.5]),
    }
}

// ---------------------------------------------------------------------
// framing

#[test]
fn every_message_roundtrips_through_the_wire_format() {
    let to_device = [
        ToDevice::Setup(Box::new(init(4))),
        ToDevice::Model { epoch: 12, beta: Mat::from_vec(2, 1, vec![0.5, -0.5]) },
        ToDevice::Ping { nonce: 0xABCD },
        ToDevice::Stop,
        ToDevice::Shutdown,
    ];
    for msg in &to_device {
        let decoded = decode_to_device(&encode_to_device(msg)).unwrap();
        assert_eq!(&decoded, msg);
    }
    let from_device = [
        FromDevice::Hello { device_id: 3, protocol: PROTOCOL_VERSION },
        FromDevice::Pong { nonce: 99 },
        FromDevice::Grad {
            run: 7,
            epoch: 12,
            grad: Mat::from_vec(2, 1, vec![1.25, -0.75]),
            delay: 3.5,
        },
    ];
    for msg in &from_device {
        let decoded = decode_from_device(&encode_from_device(msg)).unwrap();
        assert_eq!(&decoded, msg);
    }
}

#[test]
fn frames_roundtrip_through_a_byte_stream() {
    let mut wire = Vec::new();
    let a = encode_to_device(&ToDevice::Ping { nonce: 1 });
    let b = encode_to_device(&ToDevice::Model { epoch: 0, beta: Mat::zeros(4, 1) });
    write_frame(&mut wire, &a).unwrap();
    write_frame(&mut wire, &b).unwrap();
    let mut r = Cursor::new(wire);
    assert_eq!(read_frame(&mut r).unwrap().unwrap(), a);
    assert_eq!(read_frame(&mut r).unwrap().unwrap(), b);
    // EOF exactly at a frame boundary is a clean end of stream
    assert!(read_frame(&mut r).unwrap().is_none());
}

#[test]
fn truncated_payload_is_an_error_not_an_eof() {
    let mut wire = Vec::new();
    write_frame(&mut wire, &encode_to_device(&ToDevice::Ping { nonce: 5 })).unwrap();
    wire.truncate(wire.len() - 3); // chop the payload mid-message
    let err = read_frame(&mut Cursor::new(wire)).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
}

#[test]
fn truncated_length_prefix_is_an_error() {
    let err = read_frame(&mut Cursor::new(vec![9u8, 0])).unwrap_err().to_string();
    assert!(err.contains("length prefix"), "{err}");
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    let mut wire = Vec::new();
    wire.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    wire.extend_from_slice(&[0u8; 16]);
    let err = read_frame(&mut Cursor::new(wire)).unwrap_err().to_string();
    assert!(err.contains("oversized"), "{err}");
}

#[test]
fn corrupt_frames_are_decode_errors() {
    // unknown tag
    assert!(decode_to_device(&[0xFF]).is_err());
    assert!(decode_from_device(&[0xFF]).is_err());
    // empty payload
    assert!(decode_to_device(&[]).is_err());
    // truncated body: a Ping missing most of its nonce
    assert!(decode_to_device(&encode_to_device(&ToDevice::Ping { nonce: 1 })[..3]).is_err());
    // trailing garbage after a complete body
    let mut payload = encode_to_device(&ToDevice::Stop);
    payload.push(0);
    assert!(decode_to_device(&payload).is_err());
    // matrix header promising more data than the payload carries
    let mut grad = encode_from_device(&FromDevice::Grad {
        run: 1,
        epoch: 1,
        grad: Mat::zeros(2, 2),
        delay: 0.0,
    });
    let rows_at = 1 + 8 + 8 + 8; // tag, run, epoch, delay
    grad[rows_at..rows_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = decode_from_device(&grad).unwrap_err().to_string();
    assert!(err.contains("matrix header"), "{err}");
}

// ---------------------------------------------------------------------
// channel transport

/// Drive one Setup→Ping→Model→reply cycle and return the grad message.
fn one_cycle(t: &mut dyn Transport, slot: usize, epoch: usize) -> FromDevice {
    let beta = Mat::from_vec(2, 1, vec![0.1, 0.2]);
    assert!(t.send(slot, &ToDevice::Model { epoch, beta }).unwrap());
    loop {
        match t.recv_timeout(Duration::from_secs(5)) {
            Event::Msg(s, msg @ FromDevice::Grad { .. }) => {
                assert_eq!(s, slot);
                return msg;
            }
            Event::Msg(_, _) => continue,
            other => panic!("expected a gradient, got {other:?}"),
        }
    }
}

#[test]
fn channel_transport_runs_the_device_state_machine() {
    let mut t = ChannelTransport::new(2);
    assert_eq!(t.n_endpoints(), 2);
    t.begin_run(vec![init(0), init(1)]).unwrap();

    // ping/echo works (the calibration path)
    assert!(t.send(1, &ToDevice::Ping { nonce: 42 }).unwrap());
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Msg(1, FromDevice::Pong { nonce: 42 }) => {}
        other => panic!("expected pong, got {other:?}"),
    }

    // a model broadcast produces the exact native partial gradient
    let FromDevice::Grad { run, epoch, grad, delay } = one_cycle(&mut t, 0, 3) else {
        unreachable!()
    };
    assert_eq!((run, epoch), (7, 3));
    assert!(delay > 0.0, "delay must be sampled from the §II-A model");
    let d0 = init(0);
    let beta = Mat::from_vec(2, 1, vec![0.1, 0.2]);
    let expect = NativeBackend.partial_grad(&d0.x_sys, &beta, &d0.y_sys).unwrap();
    assert_eq!(grad, expect);

    // a second run re-arms the same endpoints with a fresh run tag
    t.end_run();
    let mut re = init(0);
    re.run = 8;
    t.begin_run(vec![re]).unwrap();
    let FromDevice::Grad { run, .. } = one_cycle(&mut t, 0, 0) else { unreachable!() };
    assert_eq!(run, 8);
}

#[test]
fn channel_protocol_violation_surfaces_as_gone() {
    let mut t = ChannelTransport::new(1);
    // Model before Setup is a protocol violation: the worker errors out
    assert!(t.send(0, &ToDevice::Model { epoch: 0, beta: Mat::zeros(2, 1) }).unwrap());
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Gone(0) => {}
        other => panic!("expected Gone(0), got {other:?}"),
    }
    // and the endpoint is dead for subsequent sends
    assert!(!t.send(0, &ToDevice::Ping { nonce: 0 }).unwrap());
}

#[test]
fn channel_kill_and_respawn_rejoins_the_slot() {
    let mut t = ChannelTransport::new(2);
    let ctl = t.controller();
    t.begin_run(vec![init(0), init(1)]).unwrap();

    // kill: the worker's command channel closes, the worker exits, and
    // its own death notice is the observable event
    ctl.kill(1);
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Gone(1) => {}
        other => panic!("expected Gone(1), got {other:?}"),
    }
    assert!(!t.send(1, &ToDevice::Ping { nonce: 0 }).unwrap());
    // a dead slot is skipped by begin_run and reported as undelivered
    assert_eq!(t.begin_run(vec![init(1)]).unwrap(), vec![false]);

    // respawn: a fresh incarnation claims the dead slot
    ctl.respawn(1);
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Rejoined(1) => {}
        other => panic!("expected Rejoined(1), got {other:?}"),
    }
    // the fresh incarnation is blank: re-Setup, then it computes again
    assert!(t.send(1, &ToDevice::Setup(Box::new(init(1)))).unwrap());
    let FromDevice::Grad { run, epoch, .. } = one_cycle(&mut t, 1, 9) else { unreachable!() };
    assert_eq!((run, epoch), (7, 9));

    // respawning a live slot is a no-op (no spurious Rejoined)
    ctl.respawn(1);
    match t.recv_timeout(Duration::from_millis(200)) {
        Event::Timeout => {}
        other => panic!("respawn of a live slot surfaced {other:?}"),
    }
}

#[test]
fn stale_replies_from_a_previous_incarnation_are_discarded() {
    let mut t = ChannelTransport::new(1);
    let ctl = t.controller();
    // arm the worker with a real sleep (any delay draw hits the scaled
    // cap), so its reply lands well after the kill below
    let mut slow = init(0);
    slow.time_scale = 1e9;
    slow.max_scaled_secs = 0.3;
    t.begin_run(vec![slow]).unwrap();
    let beta = Mat::from_vec(2, 1, vec![0.1, 0.2]);
    assert!(t.send(0, &ToDevice::Model { epoch: 0, beta }).unwrap());

    // while incarnation 0 sleeps out its delay, kill the slot and admit
    // a fresh incarnation
    ctl.kill(0);
    ctl.respawn(0);
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Rejoined(0) => {}
        other => panic!("expected Rejoined(0), got {other:?}"),
    }

    // incarnation 0 now wakes, replies, and dies — all of it tagged with
    // the stale generation: neither its gradient (which would be
    // attributed to the new incarnation) nor its death notice (which
    // would kill the new incarnation) may surface
    match t.recv_timeout(Duration::from_millis(700)) {
        Event::Timeout => {}
        other => panic!("stale-incarnation event surfaced: {other:?}"),
    }
    // and the respawned endpoint is fully functional
    assert!(t.send(0, &ToDevice::Setup(Box::new(init(0)))).unwrap());
    let FromDevice::Grad { run, epoch, .. } = one_cycle(&mut t, 0, 1) else { unreachable!() };
    assert_eq!((run, epoch), (7, 1));
}

// ---------------------------------------------------------------------
// tcp transport (skipped silently where the sandbox denies loopback bind)

fn loopback() -> Option<TcpListener> {
    match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("skipping TCP transport test: loopback bind denied ({e})");
            None
        }
    }
}

#[test]
fn tcp_transport_speaks_the_same_protocol_as_channels() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    let mut devices = Vec::new();
    for id in 0..2 {
        let addr = addr.clone();
        devices.push(std::thread::spawn(move || {
            run_device(&addr, id, Duration::from_secs(5))
        }));
    }
    let mut t = TcpTransport::serve(listener, 2, Duration::from_secs(5)).unwrap();
    t.begin_run(vec![init(0), init(1)]).unwrap();

    assert!(t.send(0, &ToDevice::Ping { nonce: 9 }).unwrap());
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Msg(0, FromDevice::Pong { nonce: 9 }) => {}
        other => panic!("expected pong, got {other:?}"),
    }

    // gradients arrive framed and tagged exactly like the channel path
    let FromDevice::Grad { run, epoch, grad, .. } = one_cycle(&mut t, 1, 5) else {
        unreachable!()
    };
    assert_eq!((run, epoch), (7, 5));
    let d1 = init(1);
    let beta = Mat::from_vec(2, 1, vec![0.1, 0.2]);
    let expect = NativeBackend.partial_grad(&d1.x_sys, &beta, &d1.y_sys).unwrap();
    assert_eq!(grad, expect);

    t.end_run();
    drop(t); // sends Shutdown: device loops exit cleanly
    for h in devices {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_disconnect_surfaces_as_gone() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    let hello = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let payload =
            encode_from_device(&FromDevice::Hello { device_id: 0, protocol: PROTOCOL_VERSION });
        write_frame(&mut s, &payload).unwrap();
        // drop the socket: a mid-session disconnect
    });
    let mut t = TcpTransport::serve(listener, 1, Duration::from_secs(5)).unwrap();
    hello.join().unwrap();
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Gone(0) => {}
        other => panic!("expected Gone(0), got {other:?}"),
    }
    // writes into a closed socket keep succeeding until the RST lands;
    // poll until the endpoint reads as dead
    let mut dead = false;
    for _ in 0..100 {
        if !t.send(0, &ToDevice::Ping { nonce: 0 }).unwrap() {
            dead = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(dead, "writes to a disconnected endpoint never failed");
}

#[test]
fn tcp_dead_slot_is_readmitted_on_reconnect() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    // incarnation A: Hello, then drop the socket (a device that dies
    // right after joining)
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let hello =
            encode_from_device(&FromDevice::Hello { device_id: 0, protocol: PROTOCOL_VERSION });
        write_frame(&mut s, &hello).unwrap();
    }
    let mut t = TcpTransport::serve(listener, 1, Duration::from_secs(5)).unwrap();
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Gone(0) => {}
        other => panic!("expected Gone(0), got {other:?}"),
    }

    // incarnation B: a real device loop dials the same coordinator and
    // re-claims the dead slot through the post-formation acceptor
    let addr2 = addr.clone();
    let dev = std::thread::spawn(move || run_device(&addr2, 0, Duration::from_secs(5)));
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Rejoined(0) => {}
        other => panic!("expected Rejoined(0), got {other:?}"),
    }
    // the rejoined incarnation is blank: Setup, then it serves epochs
    assert_eq!(t.begin_run(vec![init(0)]).unwrap(), vec![true]);
    let FromDevice::Grad { run, epoch, .. } = one_cycle(&mut t, 0, 2) else { unreachable!() };
    assert_eq!((run, epoch), (7, 2));

    drop(t); // Shutdown: the rejoined device exits cleanly
    dev.join().unwrap().unwrap();
}

#[test]
fn tcp_rejects_a_protocol_mismatch() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    let bad = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let payload = encode_from_device(&FromDevice::Hello { device_id: 0, protocol: 999 });
        write_frame(&mut s, &payload).unwrap();
        // hold the socket open until the coordinator reacts
        let _ = read_frame(&mut s);
    });
    let err = match TcpTransport::serve(listener, 1, Duration::from_secs(5)) {
        Ok(_) => panic!("a v999 device must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("protocol mismatch"), "{err}");
    bad.join().unwrap();
}

// ---------------------------------------------------------------------
// wire-format properties: the primary guard for the frame codec. The
// hand-enumerated corruption cases above pin historically seen inputs;
// these sweep the space. All properties lean on a structural fact of the
// format (no optional fields, `done()` rejects trailing bytes): decoding
// is positional and bijective, so a payload that decodes at all must
// re-encode to the exact same bytes — which sidesteps NaN-equality holes
// a value-level comparison would have.

use crate::testing::prop::{self, assert_that};

fn arb_mat(g: &mut prop::Gen) -> Mat {
    let rows = g.size_in(0, 5);
    let cols = g.size_in(0, 5);
    g.matrix(rows, cols)
}

fn arb_profile(g: &mut prop::Gen) -> DeviceProfile {
    DeviceProfile {
        compute: ComputeModel {
            secs_per_point: g.f64_in(0.0, 1.0),
            mem_rate: g.f64_in(0.1, 16.0),
        },
        link: LinkModel {
            secs_per_packet: g.f64_in(0.0, 1.0),
            erasure_prob: g.f64_in(0.0, 0.9),
        },
        points: g.size_in(0, 256),
    }
}

fn arb_to_device(g: &mut prop::Gen) -> ToDevice {
    match g.int_in(0, 4) {
        0 => ToDevice::Setup(Box::new(DeviceInit {
            run: g.int_in(0, 1 << 40) as u64,
            device_index: g.size_in(0, 64),
            load: g.size_in(0, 512),
            delay_seed: g.int_in(0, i64::MAX - 1) as u64,
            time_scale: g.f64_in(1e-9, 1.0),
            max_scaled_secs: g.f64_in(0.0, 1.0),
            profile: arb_profile(g),
            x_sys: arb_mat(g),
            y_sys: arb_mat(g),
        })),
        1 => ToDevice::Model { epoch: g.size_in(0, 100_000), beta: arb_mat(g) },
        2 => ToDevice::Ping { nonce: g.int_in(0, i64::MAX - 1) as u64 },
        3 => ToDevice::Stop,
        _ => ToDevice::Shutdown,
    }
}

fn arb_from_device(g: &mut prop::Gen) -> FromDevice {
    match g.int_in(0, 3) {
        0 => FromDevice::Hello {
            device_id: g.size_in(0, 1 << 20),
            protocol: g.int_in(0, u32::MAX as i64) as u32,
        },
        1 => FromDevice::Pong { nonce: g.int_in(0, i64::MAX - 1) as u64 },
        2 => FromDevice::HelloMulti {
            device_ids: (0..g.size_in(0, 6)).map(|_| g.size_in(0, 1 << 20)).collect(),
            protocol: g.int_in(0, u32::MAX as i64) as u32,
        },
        _ => FromDevice::Grad {
            run: g.int_in(0, 1 << 40) as u64,
            epoch: g.size_in(0, 100_000),
            delay: g.f64_in(0.0, 60.0),
            grad: arb_mat(g),
        },
    }
}

#[test]
fn prop_to_device_frames_round_trip() {
    prop::check("frame to-device round-trip", prop::cfg(), |g| {
        let msg = arb_to_device(g);
        let bytes = encode_to_device(&msg);
        let decoded = decode_to_device(&bytes).map_err(|e| format!("decode failed: {e}"))?;
        assert_that(decoded == msg, "decoded message differs from the original")?;
        assert_that(encode_to_device(&decoded) == bytes, "re-encode changed the bytes")
    });
}

#[test]
fn prop_from_device_frames_round_trip() {
    prop::check("frame from-device round-trip", prop::cfg(), |g| {
        let msg = arb_from_device(g);
        let bytes = encode_from_device(&msg);
        let decoded = decode_from_device(&bytes).map_err(|e| format!("decode failed: {e}"))?;
        assert_that(decoded == msg, "decoded message differs from the original")?;
        assert_that(encode_from_device(&decoded) == bytes, "re-encode changed the bytes")
    });
}

#[test]
fn prop_truncated_frames_never_decode() {
    prop::check("frame truncation never decodes", prop::cfg(), |g| {
        let to = g.bool();
        let bytes = if to {
            encode_to_device(&arb_to_device(g))
        } else {
            encode_from_device(&arb_from_device(g))
        };
        let cut = g.size_in(0, bytes.len() - 1);
        let err = if to {
            decode_to_device(&bytes[..cut]).is_err()
        } else {
            decode_from_device(&bytes[..cut]).is_err()
        };
        assert_that(err, format!("a strict {cut}/{}-byte prefix decoded", bytes.len()))
    });
}

#[test]
fn prop_corrupt_byte_is_rejected_or_bijective() {
    prop::check("frame corrupt byte is rejected or bijective", prop::cfg(), |g| {
        let to = g.bool();
        let mut bytes = if to {
            encode_to_device(&arb_to_device(g))
        } else {
            encode_from_device(&arb_from_device(g))
        };
        let idx = g.size_in(0, bytes.len() - 1);
        let delta = g.int_in(1, 255) as u8;
        bytes[idx] = bytes[idx].wrapping_add(delta);
        // a flipped byte may land on another valid message (e.g. a float
        // payload bit, or Stop→Shutdown in the tag) — that is fine as long
        // as the decode is exact; what must never happen is a panic or a
        // message that re-encodes differently than what was on the wire
        if to {
            match decode_to_device(&bytes) {
                Err(_) => Ok(()),
                Ok(msg) => assert_that(
                    encode_to_device(&msg) == bytes,
                    format!("byte {idx} corrupted, decode not bijective"),
                ),
            }
        } else {
            match decode_from_device(&bytes) {
                Err(_) => Ok(()),
                Ok(msg) => assert_that(
                    encode_from_device(&msg) == bytes,
                    format!("byte {idx} corrupted, decode not bijective"),
                ),
            }
        }
    });
}

#[test]
fn prop_frame_streams_round_trip() {
    prop::check("frame stream round-trip", prop::cfg_cases(32), |g| {
        let n = g.size_in(0, 5);
        let msgs: Vec<ToDevice> = (0..n).map(|_| arb_to_device(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, &encode_to_device(m)).map_err(|e| e.to_string())?;
        }
        let mut r = Cursor::new(wire);
        let mut count = 0usize;
        while let Some(payload) = read_frame(&mut r).map_err(|e| e.to_string())? {
            assert_that(count < n, "more frames than were written")?;
            let msg = decode_to_device(&payload).map_err(|e| e.to_string())?;
            assert_that(msg == msgs[count], format!("stream frame {count} mismatch"))?;
            count += 1;
        }
        assert_that(count == n, "clean EOF must come after the last frame")
    });
}

// ---------------------------------------------------------------------
// resumable frame decoder: the reactor's read-side state machine. The
// stream tests above cover whole-frame reads; these fuzz the *chunking*
// — a readiness loop receives frames in whatever pieces the kernel
// hands it, so reassembly must be byte-for-byte insensitive to splits.

use super::frame::FrameDecoder;

#[test]
fn decoder_reassembles_byte_at_a_time() {
    let msgs = [
        encode_to_device(&ToDevice::Ping { nonce: 7 }),
        encode_to_device(&ToDevice::Model { epoch: 3, beta: Mat::zeros(4, 2) }),
        encode_to_device(&ToDevice::Stop),
    ];
    let mut wire = Vec::new();
    for m in &msgs {
        write_frame(&mut wire, m).unwrap();
    }
    let mut dec = FrameDecoder::new();
    let mut out = Vec::new();
    for &b in &wire {
        out.extend(dec.push(&[b]).unwrap());
    }
    assert!(dec.is_idle(), "decoder must be idle after the last complete frame");
    assert_eq!(out, msgs);
}

#[test]
fn decoder_reassembles_across_every_split_offset() {
    let msgs = [
        encode_from_device(&FromDevice::Pong { nonce: 1 }),
        encode_from_device(&FromDevice::Grad {
            run: 2,
            epoch: 9,
            grad: Mat::from_vec(2, 1, vec![0.5, -0.5]),
            delay: 1.5,
        }),
    ];
    let mut wire = Vec::new();
    for m in &msgs {
        write_frame(&mut wire, m).unwrap();
    }
    for cut in 0..=wire.len() {
        let mut dec = FrameDecoder::new();
        let mut out = dec.push(&wire[..cut]).unwrap();
        out.extend(dec.push(&wire[cut..]).unwrap());
        assert_eq!(out, msgs, "split at byte {cut}");
        assert!(dec.is_idle());
    }
}

#[test]
fn decoder_tracks_mid_frame_state() {
    let payload = encode_to_device(&ToDevice::Ping { nonce: 1 });
    let mut wire = Vec::new();
    write_frame(&mut wire, &payload).unwrap();
    let mut dec = FrameDecoder::new();
    assert!(dec.is_idle());
    assert!(!dec.mid_payload());
    // two bytes of length prefix: busy but not yet inside the payload
    assert!(dec.push(&wire[..2]).unwrap().is_empty());
    assert!(!dec.is_idle());
    assert!(!dec.mid_payload());
    // prefix complete plus a couple of payload bytes: mid-payload
    assert!(dec.push(&wire[2..6]).unwrap().is_empty());
    assert!(dec.mid_payload());
    let out = dec.push(&wire[6..]).unwrap();
    assert_eq!(out, vec![payload]);
    assert!(dec.is_idle());
}

#[test]
fn decoder_rejects_an_oversized_prefix_mid_stream() {
    let mut dec = FrameDecoder::new();
    let mut wire = Vec::new();
    write_frame(&mut wire, &encode_to_device(&ToDevice::Stop)).unwrap();
    wire.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    // the poisoned prefix behind the valid frame fails the whole push:
    // callers treat it as the peer dying, so nothing else matters
    let err = dec.push(&wire).unwrap_err().to_string();
    assert!(err.contains("oversized"), "{err}");
}

#[test]
fn prop_decoder_is_chunking_insensitive() {
    prop::check("frame decoder chunking-insensitive", prop::cfg_cases(32), |g| {
        let n = g.size_in(0, 4);
        let msgs: Vec<ToDevice> = (0..n).map(|_| arb_to_device(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, &encode_to_device(m)).map_err(|e| e.to_string())?;
        }
        let mut dec = FrameDecoder::new();
        let mut out: Vec<Vec<u8>> = Vec::new();
        let mut off = 0usize;
        while off < wire.len() {
            let take = g.size_in(1, (wire.len() - off).min(64));
            out.extend(dec.push(&wire[off..off + take]).map_err(|e| e.to_string())?);
            off += take;
        }
        assert_that(dec.is_idle(), "decoder not idle after a whole stream")?;
        assert_that(out.len() == n, format!("{} frames out of {n}", out.len()))?;
        for (i, (payload, msg)) in out.iter().zip(&msgs).enumerate() {
            let decoded = decode_to_device(payload).map_err(|e| e.to_string())?;
            assert_that(decoded == *msg, format!("chunked frame {i} mismatch"))?;
        }
        Ok(())
    });
}

#[test]
fn wrap_envelope_roundtrips_and_rejects_truncation() {
    use super::frame::{unwrap_slot, wrap_slot};
    let inner = encode_from_device(&FromDevice::Pong { nonce: 5 });
    let wrapped = wrap_slot(3, &inner);
    match unwrap_slot(&wrapped).unwrap() {
        Some((slot, body)) => {
            assert_eq!(slot, 3);
            assert_eq!(body, &inner[..]);
        }
        None => panic!("a wrapped frame must unwrap"),
    }
    // a bare (unwrapped) frame passes through as None
    assert!(unwrap_slot(&inner).unwrap().is_none());
    // a wrap tag with a chopped slot header is an error
    let err = unwrap_slot(&wrapped[..3]).unwrap_err().to_string();
    assert!(err.contains("truncated wrap"), "{err}");
}

// ---------------------------------------------------------------------
// reactor endpoint state machine (pure: no sockets involved)

use super::reactor::EndpointState;

#[test]
fn endpoint_write_overflow_is_backpressure_not_queueing() {
    let mut ep = EndpointState::with_write_cap(64);
    assert!(ep.enqueue(vec![0u8; 40]));
    assert_eq!(ep.queued_bytes(), 40);
    // the second frame would blow the cap: refused, NOT queued
    assert!(!ep.enqueue(vec![0u8; 40]));
    assert_eq!(ep.queued_bytes(), 40);
    // small frames still fit under the cap
    assert!(ep.enqueue(vec![0u8; 24]));
    assert_eq!(ep.queued_bytes(), 64);
}

#[test]
fn endpoint_advance_accounts_partial_writes() {
    let mut ep = EndpointState::new();
    assert!(!ep.wants_write());
    assert!(ep.next_chunk().is_none());
    assert!(ep.enqueue(vec![1u8; 10]));
    assert!(ep.enqueue(vec![2u8; 6]));
    assert_eq!(ep.queued_bytes(), 16);
    // partial write of the front frame
    ep.advance(4);
    assert_eq!(ep.next_chunk().map(<[u8]>::len), Some(6));
    assert_eq!(ep.queued_bytes(), 12);
    // finishing the front frame pops it; the next one is whole
    ep.advance(6);
    assert_eq!(ep.next_chunk().map(<[u8]>::len), Some(6));
    assert_eq!(ep.queued_bytes(), 6);
    ep.advance(6);
    assert!(!ep.wants_write());
    assert_eq!(ep.queued_bytes(), 0);
}

#[test]
fn endpoint_read_side_flags_mid_frame() {
    let mut ep = EndpointState::new();
    let mut wire = Vec::new();
    write_frame(&mut wire, &encode_to_device(&ToDevice::Ping { nonce: 3 })).unwrap();
    assert!(!ep.mid_frame());
    assert!(ep.ingest(&wire[..5]).unwrap().is_empty());
    assert!(ep.mid_frame(), "an EOF here would be a truncation");
    let frames = ep.ingest(&wire[5..]).unwrap();
    assert_eq!(frames.len(), 1);
    assert!(!ep.mid_frame());
}

// ---------------------------------------------------------------------
// multi-slot connections and thread census

#[test]
fn tcp_multi_slot_device_serves_several_slots() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    let dev = std::thread::spawn(move || {
        run_device_multi(&addr, &[0, 1, 2], Duration::from_secs(5))
    });
    let mut t = TcpTransport::serve(listener, 3, Duration::from_secs(5)).unwrap();
    t.begin_run(vec![init(0), init(1), init(2)]).unwrap();
    // each slot answers on its own envelope, through one connection
    for slot in 0..3 {
        assert!(t.send(slot, &ToDevice::Ping { nonce: 40 + slot as u64 }).unwrap());
        loop {
            match t.recv_timeout(Duration::from_secs(5)) {
                Event::Msg(s, FromDevice::Pong { nonce }) => {
                    assert_eq!((s, nonce), (slot, 40 + slot as u64));
                    break;
                }
                Event::Msg(_, _) => continue,
                other => panic!("expected pong from slot {slot}, got {other:?}"),
            }
        }
    }
    let FromDevice::Grad { run, epoch, .. } = one_cycle(&mut t, 1, 4) else { unreachable!() };
    assert_eq!((run, epoch), (7, 4));
    drop(t); // Shutdown reaches every slot; the one process exits clean
    dev.join().unwrap().unwrap();
}

#[test]
fn tcp_half_open_write_close_surfaces_as_gone() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    let sock = TcpStream::connect(&addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    let hello = encode_from_device(&FromDevice::Hello { device_id: 0, protocol: PROTOCOL_VERSION });
    write_frame(&mut w, &hello).unwrap();
    let mut t = TcpTransport::serve(listener, 1, Duration::from_secs(5)).unwrap();
    // half-close: our write side sends FIN but the socket stays open for
    // reads — the coordinator must treat the EOF as a death, not hang
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Gone(0) => {}
        other => panic!("expected Gone(0) on half-open close, got {other:?}"),
    }
}

#[test]
fn tcp_rejoin_supersedes_a_connection_stuck_mid_frame() {
    let Some(listener) = loopback() else { return };
    let addr = listener.local_addr().unwrap().to_string();
    // incarnation A: Hello, then a *partial* frame (a length prefix
    // promising 100 bytes, with only a few delivered) — then it stalls,
    // socket open: the worst kind of corpse
    let mut a = TcpStream::connect(&addr).unwrap();
    let hello = encode_from_device(&FromDevice::Hello { device_id: 0, protocol: PROTOCOL_VERSION });
    write_frame(&mut a, &hello).unwrap();
    let mut t = TcpTransport::serve(listener, 1, Duration::from_secs(5)).unwrap();
    use std::io::Write as _;
    a.write_all(&100u32.to_le_bytes()).unwrap();
    a.write_all(&[65u8; 7]).unwrap(); // tag + 6 of 100 promised bytes
    // incarnation B: a genuine device re-claims the slot; newest wins,
    // A is severed mid-reassembly and its buffered bytes discarded
    let addr2 = addr.clone();
    let dev = std::thread::spawn(move || run_device(&addr2, 0, Duration::from_secs(5)));
    match t.recv_timeout(Duration::from_secs(5)) {
        Event::Rejoined(0) => {}
        other => panic!("expected Rejoined(0), got {other:?}"),
    }
    assert_eq!(t.begin_run(vec![init(0)]).unwrap(), vec![true]);
    let FromDevice::Grad { run, epoch, .. } = one_cycle(&mut t, 0, 6) else { unreachable!() };
    assert_eq!((run, epoch), (7, 6));
    drop(a);
    drop(t);
    dev.join().unwrap().unwrap();
}

/// Thread count of this process, per /proc (the reactor's O(1)-threads
/// contract is only cheaply observable on Linux).
#[cfg(target_os = "linux")]
fn process_threads() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

#[cfg(target_os = "linux")]
#[test]
fn tcp_coordinator_io_threads_are_constant_in_fleet_size() {
    // forms an n-device fleet (devices run on n in-process threads) and
    // reports the process thread count at steady state
    fn fleet_threads(n: usize) -> Option<usize> {
        let listener = loopback()?;
        let addr = listener.local_addr().unwrap().to_string();
        let mut devices = Vec::new();
        for id in 0..n {
            let addr = addr.clone();
            devices.push(std::thread::spawn(move || {
                run_device(&addr, id, Duration::from_secs(5))
            }));
        }
        let t = TcpTransport::serve(listener, n, Duration::from_secs(5)).unwrap();
        let count = process_threads();
        drop(t);
        for h in devices {
            h.join().unwrap().unwrap();
        }
        Some(count)
    }
    let Some(small) = fleet_threads(2) else { return };
    let Some(big) = fleet_threads(8) else { return };
    // 6 extra *device* threads are expected (they live in-process here);
    // the coordinator side must add none — under the old thread-per-
    // socket model the delta would be 12
    let delta = big.saturating_sub(small);
    assert!(
        delta <= 7,
        "coordinator I/O threads scale with the fleet: {small} threads at n=2, {big} at n=8"
    );
}

// ---------------------------------------------------------------------
// placement manifests

#[test]
fn placement_parses_hosts_and_defaults() {
    let ini = crate::config::Ini::parse(
        "[placement]\n\
         bind = 0.0.0.0:7070\n\
         accept_timeout_secs = 120\n\
         device.0 = local\n\
         device.1 = hostB\n\
         device.2 = hostB\n",
    )
    .unwrap();
    let p = Placement::from_ini(&ini).unwrap();
    assert_eq!(p.bind_addr(), "0.0.0.0:7070");
    assert_eq!(p.accept_timeout(), Duration::from_secs(120));
    assert!(!p.is_remote(0));
    assert!(p.is_remote(1));
    assert!(!p.is_remote(3)); // unlisted slots default to local
    assert_eq!(p.local_slots(4), vec![0, 3]);
    let remote = p.remote_hosts(4);
    assert_eq!(remote.len(), 1);
    assert_eq!(remote["hostB"], [1, 2]);
    p.validate(4).unwrap();
}

#[test]
fn placement_defaults_are_all_local() {
    let p = Placement::from_ini(&crate::config::Ini::parse("").unwrap()).unwrap();
    assert_eq!(p.bind_addr(), "127.0.0.1:0");
    assert!(p.explicit_bind().is_none());
    assert_eq!(p.local_slots(3), vec![0, 1, 2]);
    assert!(p.remote_hosts(3).is_empty());
    p.validate(3).unwrap();
}

#[test]
fn placement_rejects_bad_manifests() {
    let parse = |text: &str| Placement::from_ini(&crate::config::Ini::parse(text).unwrap());
    // unknown key
    let err = parse("[placement]\ngadget.0 = x\n").unwrap_err().to_string();
    assert!(err.contains("unknown key"), "{err}");
    // unparsable slot number
    assert!(parse("[placement]\ndevice.x = local\n").is_err());
    // zero formation window
    assert!(parse("[placement]\naccept_timeout_secs = 0\n").is_err());
    // remote slots demand a fixed, reachable bind
    let remote = parse("[placement]\ndevice.1 = hostB\n").unwrap();
    let err = remote.validate(2).unwrap_err().to_string();
    assert!(err.contains("reachable"), "{err}");
    let ephemeral =
        parse("[placement]\nbind = 0.0.0.0:0\ndevice.1 = hostB\n").unwrap();
    assert!(ephemeral.validate(2).is_err());
    // out-of-range assignment
    let oob = parse("[placement]\ndevice.9 = hostB\n").unwrap();
    let err = oob.validate_slots(2).unwrap_err().to_string();
    assert!(err.contains("outside"), "{err}");
}
