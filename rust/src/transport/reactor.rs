//! The readiness-driven I/O core of [`super::TcpTransport`]: one event
//! loop thread owns every endpoint socket, multiplexing them with
//! `poll(2)` through a thin FFI shim (the build is offline — no tokio,
//! no mio, no libc crate).
//!
//! Design in one paragraph: the transport thread talks to the reactor
//! over a command channel ([`Cmd`]) paired with a one-byte self-wakeup
//! pipe (a `UnixStream` pair the poll set always watches), and the
//! reactor reports upward on the same `(slot, generation, TcpUp)` queue
//! the acceptor uses, so the transport's event ordering and
//! generation-tag filtering are unchanged from the thread-per-socket
//! era. Each connection carries a per-endpoint state machine
//! ([`EndpointState`]): a resumable [`FrameDecoder`] for partial-frame
//! reassembly on the read side, and a bounded write queue with explicit
//! backpressure on the write side — a peer that stops draining its
//! socket accumulates queued frames until [`WRITE_QUEUE_MAX_BYTES`], at
//! which point the reactor severs the connection (a slow-to-death peer
//! degrades to the paper's erasure case rather than blocking the gather
//! loop or growing without bound).
//!
//! Thread census: one reactor + one acceptor per fleet, regardless of
//! fleet size — O(1) where the old model was O(n) reader threads.

use super::frame::{self, FrameDecoder};
use super::tcp::TcpUp;
use anyhow::Result;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

// --- poll(2) FFI shim ------------------------------------------------

/// `struct pollfd` from `<poll.h>` — identical layout on every libc the
/// repo targets (Linux and the BSD family, macOS included).
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = std::os::raw::c_uint;

// event bits share their values across Linux and the BSDs
const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
}

/// Block until a registered fd is ready. `timeout_ms < 0` waits
/// forever. Returns the number of ready fds (0 on timeout).
fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        // SAFETY: `fds` is a valid exclusively-borrowed slice of
        // `#[repr(C)]` pollfd records; the kernel writes only `revents`.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

// --- per-endpoint state machine --------------------------------------

/// Bound on one endpoint's queued-but-unsent bytes. Two maximum frames
/// of headroom: a model broadcast plus a re-sent `Setup` can sit queued
/// behind a stalled socket without tripping the breaker, but a peer
/// that stops reading for good cannot grow the queue without bound.
pub(crate) const WRITE_QUEUE_MAX_BYTES: usize = 2 * frame::MAX_FRAME_BYTES;

/// The pure per-connection state machine: resumable frame reassembly on
/// the read side, a bounded byte-accounted write queue on the write
/// side. It owns no socket — the reactor drives it with whatever bytes
/// `poll` says can move — which is what makes it unit-testable.
pub(crate) struct EndpointState {
    decoder: FrameDecoder,
    /// Fully composed wire frames (length prefix included), oldest first.
    wq: VecDeque<Vec<u8>>,
    /// Total bytes across `wq` (the partially-written front frame counts
    /// in full; `front_off` tracks how much of it already left).
    wq_bytes: usize,
    front_off: usize,
    write_cap: usize,
}

impl EndpointState {
    pub fn new() -> Self {
        Self::with_write_cap(WRITE_QUEUE_MAX_BYTES)
    }

    /// Test hook: a tiny cap makes overflow reachable without queueing
    /// hundreds of megabytes.
    pub fn with_write_cap(write_cap: usize) -> Self {
        Self {
            decoder: FrameDecoder::new(),
            wq: VecDeque::new(),
            wq_bytes: 0,
            front_off: 0,
            write_cap,
        }
    }

    /// Feed received bytes through the frame decoder; returns completed
    /// frame payloads. An error (oversized prefix) means the peer is
    /// garbage-framing and the connection must die.
    pub fn ingest(&mut self, bytes: &[u8]) -> Result<Vec<Vec<u8>>> {
        self.decoder.push(bytes)
    }

    /// True when the read side is mid-frame (reassembly state buffered):
    /// an EOF here is a truncation, not a clean close.
    pub fn mid_frame(&self) -> bool {
        !self.decoder.is_idle()
    }

    /// Queue one composed frame for writing. `false` means the bounded
    /// queue is full — the backpressure breaker — and the frame was NOT
    /// queued; the caller severs the connection.
    pub fn enqueue(&mut self, frame_bytes: Vec<u8>) -> bool {
        if self.wq_bytes.saturating_add(frame_bytes.len()) > self.write_cap {
            return false;
        }
        self.wq_bytes += frame_bytes.len();
        self.wq.push_back(frame_bytes);
        true
    }

    /// Bytes still owed to the socket.
    pub fn queued_bytes(&self) -> usize {
        self.wq_bytes - self.front_off
    }

    pub fn wants_write(&self) -> bool {
        !self.wq.is_empty()
    }

    /// The unwritten tail of the oldest queued frame.
    pub fn next_chunk(&self) -> Option<&[u8]> {
        self.wq.front().map(|f| &f[self.front_off..])
    }

    /// Record `n` bytes of the front frame as written.
    pub fn advance(&mut self, n: usize) {
        self.front_off += n;
        if let Some(front_len) = self.wq.front().map(Vec::len) {
            if self.front_off >= front_len {
                self.wq_bytes -= front_len;
                self.front_off = 0;
                self.wq.pop_front();
            }
        }
    }
}

// --- reactor commands ------------------------------------------------

/// Transport → reactor instructions, paired with a wakeup byte so the
/// event loop notices them even while parked in `poll`.
pub(crate) enum Cmd {
    /// Adopt a connection serving these `(slot, generation)` claims.
    /// Any existing connection overlapping the claimed slots is severed
    /// first (newest wins — same re-admission rule as the acceptor).
    /// `wrapped` records the handshake the peer spoke: a `HelloMulti`
    /// connection envelopes every frame in the slot wrapper, even when
    /// it claims a single slot.
    Register { stream: TcpStream, slots: Vec<(usize, u64)>, wrapped: bool },
    /// Queue one message payload for `slot`'s connection. The payload is
    /// the *bare* message payload; the reactor composes the wire frame
    /// (and the multi-slot envelope where the connection needs one).
    Send { slot: usize, payload: Arc<Vec<u8>> },
    /// Sever `slot`'s connection (half-open corpse eviction).
    Disconnect { slot: usize },
    /// Flush what can be flushed (bounded), close everything, exit.
    Shutdown,
}

/// How long the reactor keeps flushing write queues on shutdown before
/// closing sockets anyway — long enough for a `Shutdown` frame to reach
/// every live device over loopback or a LAN, short enough that a wedged
/// peer cannot hold process exit hostage.
const SHUTDOWN_FLUSH: Duration = Duration::from_secs(2);

/// After the write-side half-close, how long the reactor keeps draining
/// incoming bytes: closing a socket with unread data in its receive
/// buffer RSTs the peer, which could destroy the flushed `Shutdown`
/// frame still in flight toward it.
const SHUTDOWN_DRAIN: Duration = Duration::from_secs(1);

/// Cap on bytes pulled from one connection per readiness wakeup, so a
/// firehosing endpoint cannot starve the rest of the fleet (poll is
/// level-triggered: leftover bytes re-arm readability immediately).
const READ_BUDGET: usize = 1 << 20;

// --- the reactor handle ----------------------------------------------

/// Owner handle for the event-loop thread. Dropping it shuts the loop
/// down (bounded flush, then close).
pub(crate) struct Reactor {
    cmd_tx: Sender<Cmd>,
    wake_tx: UnixStream,
    handle: Option<thread::JoinHandle<()>>,
}

impl Reactor {
    /// Spawn the event loop. `up_tx` is the transport's upstream event
    /// queue — the same one the acceptor feeds, so ordering between
    /// reactor events and re-admissions is whatever the queue says.
    pub fn spawn(up_tx: Sender<(usize, u64, TcpUp)>) -> Result<Self> {
        let (wake_tx, wake_rx) = UnixStream::pair()
            .map_err(|e| anyhow::anyhow!("creating the reactor wakeup pipe: {e}"))?;
        wake_tx
            .set_nonblocking(true)
            .and_then(|_| wake_rx.set_nonblocking(true))
            .map_err(|e| anyhow::anyhow!("arming the reactor wakeup pipe: {e}"))?;
        let (cmd_tx, cmd_rx) = std::sync::mpsc::channel();
        let handle = thread::Builder::new()
            .name("cfl-reactor".into())
            .spawn(move || EventLoop::new(wake_rx, cmd_rx, up_tx).run())
            .map_err(|e| anyhow::anyhow!("spawning the reactor thread: {e}"))?;
        Ok(Self { cmd_tx, wake_tx, handle: Some(handle) })
    }

    pub fn register(&self, stream: TcpStream, slots: Vec<(usize, u64)>, wrapped: bool) {
        self.cmd(Cmd::Register { stream, slots, wrapped });
    }

    pub fn send(&self, slot: usize, payload: Arc<Vec<u8>>) {
        self.cmd(Cmd::Send { slot, payload });
    }

    pub fn disconnect(&self, slot: usize) {
        self.cmd(Cmd::Disconnect { slot });
    }

    /// Idempotent orderly shutdown: flush, close, join.
    pub fn stop(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.cmd(Cmd::Shutdown);
            let _ = handle.join();
        }
    }

    fn cmd(&self, c: Cmd) {
        // send-then-wake: the loop always drains the whole command queue
        // after a wakeup byte, and a WouldBlock on the pipe means a
        // wakeup is already pending, which is just as good
        let _ = self.cmd_tx.send(c);
        let _ = (&self.wake_tx).write(&[1u8]);
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.stop();
    }
}

// --- the event loop --------------------------------------------------

/// One registered connection: the socket, its state machine, and the
/// slot claims (with generation tags) it serves. `multi` connections
/// wrap every frame in the slot envelope.
struct Conn {
    stream: TcpStream,
    ep: EndpointState,
    slots: Vec<(usize, u64)>,
    multi: bool,
}

struct Counters {
    wakeups: crate::obs::Counter,
    readable: crate::obs::Counter,
    writable: crate::obs::Counter,
    backpressure_closes: crate::obs::Counter,
    frames_recv: crate::obs::Counter,
    bytes_recv: crate::obs::Counter,
}

struct EventLoop {
    wake_rx: UnixStream,
    cmd_rx: Receiver<Cmd>,
    up_tx: Sender<(usize, u64, TcpUp)>,
    /// Token-indexed connection table; `None` entries are free tokens.
    conns: Vec<Option<Conn>>,
    ctr: Counters,
}

impl EventLoop {
    fn new(wake_rx: UnixStream, cmd_rx: Receiver<Cmd>, up_tx: Sender<(usize, u64, TcpUp)>) -> Self {
        let reg = crate::obs::registry();
        Self {
            wake_rx,
            cmd_rx,
            up_tx,
            conns: Vec::new(),
            ctr: Counters {
                wakeups: reg.counter("transport.reactor.wakeups"),
                readable: reg.counter("transport.reactor.readable"),
                writable: reg.counter("transport.reactor.writable"),
                backpressure_closes: reg.counter("transport.reactor.backpressure_closes"),
                frames_recv: reg.counter("transport.frames_recv"),
                bytes_recv: reg.counter("transport.bytes_recv"),
            },
        }
    }

    fn run(mut self) {
        let mut fds: Vec<PollFd> = Vec::new();
        // fds[i] pairs with tokens[i]; usize::MAX marks the wakeup pipe
        let mut tokens: Vec<usize> = Vec::new();
        loop {
            fds.clear();
            tokens.clear();
            fds.push(PollFd { fd: self.wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
            tokens.push(usize::MAX);
            for (token, conn) in self.conns.iter().enumerate() {
                if let Some(c) = conn {
                    let mut events = POLLIN;
                    if c.ep.wants_write() {
                        events |= POLLOUT;
                    }
                    fds.push(PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
                    tokens.push(token);
                }
            }
            match poll_fds(&mut fds, -1) {
                Ok(0) => continue,
                Ok(_) => {}
                Err(e) => {
                    // a failing poll(2) on our own fd set is unrecoverable;
                    // dropping up_tx surfaces Closed upstream
                    crate::obs_event!(Error, "reactor_poll_failed", error = format!("{e}"));
                    return;
                }
            }
            let ready: Vec<(usize, i16)> = fds
                .iter()
                .zip(tokens.iter())
                .skip(1)
                .filter(|(fd, _)| fd.revents != 0)
                .map(|(fd, &token)| (token, fd.revents))
                .collect();
            if fds.first().is_some_and(|f| f.revents != 0) {
                self.ctr.wakeups.incr();
                self.drain_wakeups();
                if !self.drain_commands() {
                    return; // Shutdown
                }
            }
            for (token, revents) in ready {
                if revents & POLLNVAL != 0 {
                    self.sever(token, "pollnval");
                    continue;
                }
                if revents & (POLLIN | POLLHUP | POLLERR) != 0 {
                    self.ctr.readable.incr();
                    self.pump_read(token);
                }
                if revents & POLLOUT != 0 {
                    self.ctr.writable.incr();
                    self.pump_write(token);
                }
            }
        }
    }

    /// Swallow pending wakeup bytes (each command writes at most one).
    fn drain_wakeups(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => return, // transport handle gone entirely
                Ok(_) => continue,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return, // WouldBlock: drained
            }
        }
    }

    /// Apply queued commands; `false` means Shutdown was received.
    fn drain_commands(&mut self) -> bool {
        loop {
            match self.cmd_rx.try_recv() {
                Ok(Cmd::Register { stream, slots, wrapped }) => {
                    self.register(stream, slots, wrapped)
                }
                Ok(Cmd::Send { slot, payload }) => self.send_to_slot(slot, payload),
                Ok(Cmd::Disconnect { slot }) => {
                    if let Some(token) = self.token_of(slot) {
                        self.sever(token, "disconnect");
                    }
                }
                Ok(Cmd::Shutdown) => {
                    self.shutdown();
                    return false;
                }
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Disconnected) => {
                    // the transport died without an orderly Shutdown
                    // (shouldn't happen — Drop sends one); don't spin
                    self.shutdown();
                    return false;
                }
            }
        }
    }

    fn token_of(&self, slot: usize) -> Option<usize> {
        self.conns
            .iter()
            .position(|c| c.as_ref().is_some_and(|c| c.slots.iter().any(|&(s, _)| s == slot)))
    }

    fn register(&mut self, stream: TcpStream, slots: Vec<(usize, u64)>, wrapped: bool) {
        // newest wins: sever any connection overlapping the new claims
        // (its Gone notices carry the old generations, so the transport
        // discards them as stale for the re-admitted slots)
        let overlapping: Vec<usize> = self
            .conns
            .iter()
            .enumerate()
            .filter(|(_, c)| {
                c.as_ref().is_some_and(|c| {
                    c.slots.iter().any(|&(s, _)| slots.iter().any(|&(ns, _)| ns == s))
                })
            })
            .map(|(t, _)| t)
            .collect();
        for token in overlapping {
            self.sever(token, "superseded");
        }
        if stream.set_nonblocking(true).is_err() {
            for &(slot, gen) in &slots {
                let _ = self.up_tx.send((slot, gen, TcpUp::Gone));
            }
            return;
        }
        let conn = Conn { stream, ep: EndpointState::new(), slots, multi: wrapped };
        match self.conns.iter().position(Option::is_none) {
            Some(token) => self.conns[token] = Some(conn),
            None => self.conns.push(Some(conn)),
        }
    }

    fn send_to_slot(&mut self, slot: usize, payload: Arc<Vec<u8>>) {
        let Some(token) = self.token_of(slot) else {
            return; // racing a death the transport hasn't seen yet
        };
        let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
            return;
        };
        let wire = if conn.multi {
            compose_frame(&frame::wrap_slot(slot, &payload))
        } else {
            compose_frame(&payload)
        };
        let queued = conn.ep.queued_bytes();
        if !conn.ep.enqueue(wire) {
            self.ctr.backpressure_closes.incr();
            crate::obs_event!(Warn, "reactor_backpressure_close", slot = slot, queued = queued);
            self.sever(token, "write queue overflow");
            return;
        }
        // eager write: most frames fit the socket buffer whole, so the
        // common case never waits for a POLLOUT round-trip
        self.pump_write(token);
    }

    /// Close a connection and report Gone for every slot it served, at
    /// the generations it held (stale ones are filtered upstream).
    fn sever(&mut self, token: usize, why: &str) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::take) else {
            return;
        };
        let _ = conn.stream.shutdown(Shutdown::Both);
        for &(slot, gen) in &conn.slots {
            crate::obs_event!(Trace, "reactor_sever", slot = slot, gen = gen, why = why);
            let _ = self.up_tx.send((slot, gen, TcpUp::Gone));
        }
    }

    fn pump_read(&mut self, token: usize) {
        let mut buf = [0u8; 64 * 1024];
        let mut budget = READ_BUDGET;
        loop {
            let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
                return;
            };
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    let why = if conn.ep.mid_frame() { "eof mid-frame" } else { "eof" };
                    self.sever(token, why);
                    return;
                }
                Ok(n) => {
                    self.ctr.bytes_recv.add(n as u64);
                    let (multi, slots) = (conn.multi, conn.slots.clone());
                    match conn.ep.ingest(&buf[..n]) {
                        Ok(payloads) => {
                            for payload in payloads {
                                self.ctr.frames_recv.incr();
                                if !self.route(token, multi, &slots, &payload) {
                                    return; // severed while routing
                                }
                            }
                        }
                        Err(_) => {
                            self.sever(token, "garbage framing");
                            return;
                        }
                    }
                    budget = budget.saturating_sub(n);
                    if budget == 0 {
                        return; // level-triggered poll re-arms readability
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.sever(token, "read error");
                    return;
                }
            }
        }
    }

    /// Decode one frame payload and ship it upstream. Returns `false`
    /// if the connection had to be severed (protocol violation).
    fn route(&mut self, token: usize, multi: bool, slots: &[(usize, u64)], payload: &[u8]) -> bool {
        let (envelope_slot, inner) = match frame::unwrap_slot(payload) {
            Ok(Some((slot, inner))) => (Some(slot), inner),
            Ok(None) => (None, payload),
            Err(_) => {
                self.sever(token, "truncated wrap envelope");
                return false;
            }
        };
        let claim = match (multi, envelope_slot) {
            // multi connections must wrap, and the envelope slot must be
            // one of the connection's own claims (no cross-slot spoofing)
            (true, Some(s)) => slots.iter().find(|&&(cs, _)| cs == s).copied(),
            (false, None) => slots.first().copied(),
            _ => None,
        };
        let Some((slot, gen)) = claim else {
            self.sever(token, "wrap envelope mismatch");
            return false;
        };
        match frame::decode_from_device(inner) {
            Ok(msg) => {
                let _ = self.up_tx.send((slot, gen, TcpUp::Msg(msg)));
                true
            }
            Err(_) => {
                self.sever(token, "undecodable message");
                false
            }
        }
    }

    fn pump_write(&mut self, token: usize) {
        loop {
            let Some(conn) = self.conns.get_mut(token).and_then(|c| c.as_mut()) else {
                return;
            };
            let Some(chunk) = conn.ep.next_chunk() else {
                return; // queue drained
            };
            match conn.stream.write(chunk) {
                Ok(0) => {
                    self.sever(token, "write returned 0");
                    return;
                }
                Ok(n) => conn.ep.advance(n),
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.sever(token, "write error");
                    return;
                }
            }
        }
    }

    /// Orderly exit: flush write queues (bounded), half-close so peers
    /// see a clean EOF after the final frames, then briefly drain
    /// incoming bytes so unread data cannot RST the flushed frames away.
    fn shutdown(&mut self) {
        let deadline = Instant::now() + SHUTDOWN_FLUSH;
        loop {
            let backlog: Vec<usize> = self
                .conns
                .iter()
                .enumerate()
                .filter(|(_, c)| c.as_ref().is_some_and(|c| c.ep.wants_write()))
                .map(|(t, _)| t)
                .collect();
            if backlog.is_empty() || Instant::now() >= deadline {
                break;
            }
            let mut fds: Vec<PollFd> = backlog
                .iter()
                .filter_map(|&t| self.conns.get(t).and_then(|c| c.as_ref()))
                .map(|c| PollFd { fd: c.stream.as_raw_fd(), events: POLLOUT, revents: 0 })
                .collect();
            if poll_fds(&mut fds, 50).is_err() {
                break;
            }
            for token in backlog {
                self.pump_write(token);
            }
        }
        for conn in self.conns.iter().flatten() {
            let _ = conn.stream.shutdown(Shutdown::Write);
        }
        let deadline = Instant::now() + SHUTDOWN_DRAIN;
        let mut buf = [0u8; 64 * 1024];
        while Instant::now() < deadline {
            let mut fds: Vec<PollFd> = self
                .conns
                .iter()
                .flatten()
                .map(|c| PollFd { fd: c.stream.as_raw_fd(), events: POLLIN, revents: 0 })
                .collect();
            if fds.is_empty() {
                break;
            }
            match poll_fds(&mut fds, 50) {
                Ok(0) => continue,
                Ok(_) => {}
                Err(_) => break,
            }
            let mut eofed = Vec::new();
            for (i, conn) in self.conns.iter_mut().flatten().enumerate() {
                if !fds.get(i).is_some_and(|f| f.revents != 0) {
                    continue;
                }
                loop {
                    match conn.stream.read(&mut buf) {
                        Ok(0) => {
                            eofed.push(i);
                            break;
                        }
                        Ok(_) => continue,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => break, // WouldBlock or a real error: move on
                    }
                }
            }
            if !eofed.is_empty() {
                // EOF'd peers are finished; drop them from the drain set
                let mut live_idx = 0usize;
                for c in self.conns.iter_mut() {
                    if c.is_some() {
                        if eofed.contains(&live_idx) {
                            *c = None;
                        }
                        live_idx += 1;
                    }
                }
            }
        }
        self.conns.clear();
    }
}

/// Compose the wire bytes of one frame: length prefix + payload.
fn compose_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}
