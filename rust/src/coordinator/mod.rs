//! Training coordination: the master's event loop.
//!
//! One protocol, two executions, one shared core:
//!
//! * [`core`] — the backend-independent layer: [`Session`] (fleet, data,
//!   shards, and the §III-A setup phase both coordinators build from),
//!   the unified [`RunResult`], and the [`Coordinator`] trait /
//!   [`CoordinatorKind`] factory the [`crate::sweep`] runner drives.
//! * [`SimCoordinator`] — discrete-event-simulated time (the paper's
//!   evaluation methodology): per-epoch device delays are sampled from
//!   §II-A's models and fed through the DES queue; gradients are computed
//!   for real (PJRT artifacts or native). All five figures come from this
//!   path, deterministically per seed.
//! * [`LiveCoordinator`] — real concurrency over a pluggable
//!   [`crate::transport`]: one worker thread per device on in-process
//!   channels by default, or one OS process per device over TCP
//!   (`cfl serve` / `cfl device`). Wall-clock deadlines are scaled down
//!   from the policy and auto-calibrated against the transport's real
//!   round-trip overhead. Demonstrates that the coordination logic is not
//!   simulation-bound (see `examples/live_cluster.rs`), and runs scenario
//!   grids via `cfl sweep --live [--transport tcp]`.

pub mod core;
mod live;
mod sim;

pub use self::core::{
    CflSetup, Coordinator, CoordinatorKind, DeviceSetup, RunResult, Session,
};
pub use live::LiveCoordinator;
pub use sim::SimCoordinator;

#[cfg(test)]
mod tests;
