//! Training coordination: the master's event loop.
//!
//! Two coordinators share the same numerics ([`crate::fl`]) and policy
//! ([`crate::lb`]):
//!
//! * [`SimCoordinator`] — discrete-event-simulated time (the paper's
//!   evaluation methodology): per-epoch device delays are sampled from
//!   §II-A's models and fed through the DES queue; gradients are computed
//!   for real (PJRT artifacts or native). All five figures come from this
//!   path, deterministically per seed.
//! * [`LiveCoordinator`] — real concurrency: one `std::thread` per device,
//!   channels to the master, wall-clock deadlines scaled down from the
//!   policy. Demonstrates that the coordination logic is not
//!   simulation-bound (see `examples/live_cluster.rs`).

mod live;
mod sim;

pub use live::{LiveCoordinator, LiveReport};
pub use sim::{RunResult, SimCoordinator};

#[cfg(test)]
mod tests;
