//! DES-driven training coordinator (the paper's evaluation harness).

use crate::coding::{CompositeParity, DeviceCode};
use crate::config::ExperimentConfig;
use crate::data::{shard_sizes, split, Dataset, Shard};
use crate::des::Simulator;
use crate::fl::{assemble_coded_gradient, GlobalModel, GradBackend, NativeBackend};
use crate::lb::{optimize, optimize_fixed_c, LoadPolicy};
use crate::linalg::{solve_ls, Mat};
use crate::metrics::ConvergenceTrace;
use crate::rng::Rng;
use crate::simnet::Fleet;
use anyhow::{Context, Result};

/// Outcome of one training run (one curve of Fig. 2, one cell of Fig. 4/5).
#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    /// NMSE vs simulated time (time includes `setup_secs` for CFL — the
    /// Fig. 2 initial offsets).
    pub trace: ConvergenceTrace,
    /// Per-epoch gather durations (Fig. 3 histograms).
    pub epoch_times: Vec<f64>,
    /// One-time parity-transfer delay before epoch 0 (0 for uncoded).
    pub setup_secs: f64,
    /// Bits uploaded as parity during setup (0 for uncoded).
    pub parity_upload_bits: f64,
    /// Round-trip model/gradient bits per epoch, summed over devices.
    pub per_epoch_bits: f64,
    /// (epoch, simulated time) at which `target_nmse` was first reached.
    pub converged: Option<(usize, f64)>,
    /// δ actually used (0 for uncoded).
    pub delta: f64,
    /// t* actually used (∞ for uncoded).
    pub epoch_deadline: f64,
    /// For CFL: per-epoch times until the devices alone had returned
    /// m − c points (Fig. 3 bottom); +∞ when an epoch never got there.
    pub gather_mc_times: Vec<f64>,
}

impl RunResult {
    /// Convergence time to a target NMSE (Figs. 4/5 metric).
    pub fn time_to(&self, target: f64) -> Option<f64> {
        self.trace.time_to_nmse(target)
    }
}

/// Per-device state frozen at setup time.
struct DeviceState {
    /// Systematic submatrix (the rows processed each epoch), ℓᵢ*×d.
    x_sys: Mat,
    y_sys: Mat,
    /// Assigned systematic load ℓᵢ*(t*).
    load: usize,
    /// Backend fast-path handle (PJRT: device-resident buffers) — §Perf.
    handle: Option<u64>,
}

/// DES-driven coordinator. Owns the problem instance (fleet, data,
/// shards), the gradient backend, and the randomness streams.
pub struct SimCoordinator {
    pub cfg: ExperimentConfig,
    pub fleet: Fleet,
    pub dataset: Dataset,
    shards: Vec<Shard>,
    backend: Box<dyn GradBackend>,
    root_rng: Rng,
    run_counter: u64,
}

impl SimCoordinator {
    /// Build the problem instance from a config. Loads PJRT artifacts when
    /// `cfg.artifacts_dir` is set, otherwise uses the native backend.
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let backend: Box<dyn GradBackend> = match &cfg.artifacts_dir {
            Some(dir) => Box::new(
                crate::runtime::PjrtBackend::load(dir)
                    .with_context(|| format!("loading artifacts from {dir}"))?,
            ),
            None => Box::new(NativeBackend),
        };
        Self::with_backend(cfg, backend)
    }

    /// Build with an explicit backend (tests inject oracles/mocks here).
    pub fn with_backend(cfg: &ExperimentConfig, backend: Box<dyn GradBackend>) -> Result<Self> {
        cfg.validate()?;
        let mut root_rng = Rng::new(cfg.seed);
        let mut fleet = Fleet::from_config(cfg, &mut root_rng);
        let dataset =
            Dataset::generate(cfg.total_points(), cfg.model_dim, cfg.snr_db, &mut root_rng);
        let sizes = shard_sizes(cfg.sharding, cfg.total_points(), cfg.n_devices, &mut root_rng);
        fleet.set_points(&sizes);
        let shards = split(&dataset, &sizes);
        Ok(Self { cfg: cfg.clone(), fleet, dataset, shards, backend, root_rng, run_counter: 0 })
    }

    /// The backend actually in use ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Fresh RNG stream per run so `train_cfl(); train_uncoded()` order
    /// doesn't couple their noise.
    fn run_rng(&mut self) -> Rng {
        self.run_counter += 1;
        self.root_rng.split(0x5EED_0000 + self.run_counter)
    }

    /// Solve the CFL load/redundancy policy: `cfg.delta = None` runs the
    /// full Eq. 16 optimization; `Some(δ)` pins c = δ·m (Fig. 2/5 sweeps).
    pub fn policy(&self) -> Result<LoadPolicy> {
        let m = self.fleet.total_points();
        match self.cfg.delta {
            None => {
                let c_up = (self.cfg.c_up_fraction * m as f64).round() as usize;
                optimize(&self.fleet, c_up, self.cfg.epsilon)
            }
            Some(delta) => {
                let c = (delta * m as f64).round() as usize;
                anyhow::ensure!(c > 0, "delta={delta} gives zero parity rows; use train_uncoded");
                optimize_fixed_c(&self.fleet, c, self.cfg.epsilon)
            }
        }
    }

    /// Closed-form least-squares NMSE — the Fig. 2 lower bound.
    pub fn ls_bound(&self) -> Result<f64> {
        let ls = solve_ls(&self.dataset.x, &self.dataset.y)?;
        Ok(ls.nmse(&self.dataset.beta_star))
    }

    // ---------------------------------------------------------------------
    // CFL setup phase (§III-A): draw codes, encode, upload, composite.
    // ---------------------------------------------------------------------

    /// Returns (composite parity, device states, setup seconds, parity bits).
    fn setup_cfl(
        &mut self,
        policy: &LoadPolicy,
        rng: &mut Rng,
    ) -> Result<(CompositeParity, Vec<DeviceState>, f64, f64)> {
        let d = self.cfg.model_dim;
        let c = policy.parity_rows;
        let mut composite = CompositeParity::zeros(c, d);
        let mut states = Vec::with_capacity(self.shards.len());
        let mut setup_secs = 0.0f64;
        let mut parity_bits = 0.0f64;
        // one parity row = d features + 1 label, with header overhead
        let row_bits = (d as f64 + 1.0) * 32.0 * (1.0 + self.cfg.header_overhead);

        for (i, shard) in self.shards.iter().enumerate() {
            let load = policy.device_loads[i];
            let code = DeviceCode::draw(
                shard.rows(),
                c,
                load,
                policy.miss_probs[i],
                self.cfg.generator,
                rng,
            );
            let (xt, yt) = self.backend.encode(&code.generator, &code.weights, &shard.x, &shard.y)?;
            composite.accumulate(&xt, &yt);

            // parity upload: c rows over this device's link, all devices in
            // parallel → setup time is the slowest upload (Fig. 2 offsets)
            let upload = self.fleet.sample_parity_upload_secs(i, c, row_bits, rng);
            setup_secs = setup_secs.max(upload);
            parity_bits += c as f64 * row_bits;

            // freeze the systematic submatrix (private permutation order)
            let mut x_sys = Mat::zeros(load, d);
            let mut y_sys = Mat::zeros(load, 1);
            for (r, &src) in code.systematic_rows().iter().enumerate() {
                x_sys.row_mut(r).copy_from_slice(shard.x.row(src));
                y_sys[(r, 0)] = shard.y[(src, 0)];
            }
            let handle =
                if load > 0 { self.backend.register_shard(&x_sys, &y_sys)? } else { None };
            states.push(DeviceState { x_sys, y_sys, load, handle });
        }
        Ok((composite, states, setup_secs, parity_bits))
    }

    // ---------------------------------------------------------------------
    // Training runs
    // ---------------------------------------------------------------------

    /// Train with Coded Federated Learning (§III). Simulated time starts
    /// at the parity-upload completion and advances t* per epoch.
    pub fn train_cfl(&mut self) -> Result<RunResult> {
        let policy = self.policy()?;
        self.train_cfl_with_policy(&policy)
    }

    /// CFL with an explicit policy (benches sweep δ through here).
    pub fn train_cfl_with_policy(&mut self, policy: &LoadPolicy) -> Result<RunResult> {
        let mut rng = self.run_rng();
        let (composite, states, setup_secs, parity_bits) = self.setup_cfl(policy, &mut rng)?;
        let d = self.cfg.model_dim;
        let m = self.fleet.total_points();
        let c = policy.parity_rows;
        let t_star = policy.epoch_deadline;

        let mut model = GlobalModel::zeros(d, self.cfg.learning_rate, m);
        let mut trace = ConvergenceTrace::new(format!("cfl δ={:.3}", policy.delta));
        let mut epoch_times = Vec::new();
        let mut gather_mc_times = Vec::new();
        let mut converged = None;
        let mut now = setup_secs;
        trace.push(now, 0, model.nmse(&self.dataset.beta_star));
        // §Perf: keep the composite parity device-resident (PJRT fast path)
        let parity_handle = self.backend.register_parity(&composite.xt, &composite.yt, c)?;

        /// DES event payload: who finished computing.
        #[derive(Clone, Copy, PartialEq)]
        enum Actor {
            Device(usize),
            Master,
        }

        // client selection (§V extension): sample k of n devices per epoch
        let n = self.fleet.n_devices();
        let k = ((self.cfg.client_fraction * n as f64).round() as usize).clamp(1, n);

        for epoch in 0..self.cfg.max_epochs {
            // --- timing: schedule every completion, gather until t* ------
            let selected: Option<Vec<bool>> = if k < n {
                let mut mask = vec![false; n];
                for i in rng.sample_indices(n, k) {
                    mask[i] = true;
                }
                Some(mask)
            } else {
                None
            };
            let mut sim = Simulator::new();
            for (i, (dev, st)) in self.fleet.devices.iter().zip(&states).enumerate() {
                if st.load == 0 || selected.as_ref().is_some_and(|m| !m[i]) {
                    continue;
                }
                let t = dev.sample_total_delay(st.load, &mut rng);
                sim.schedule_at(t, Actor::Device(i));
            }
            let t_master = self.fleet.master.sample_total_delay(c, &mut rng);
            sim.schedule_at(t_master, Actor::Master);

            // Fig. 3 bottom: when would the devices alone have covered
            // m − c points? (diagnostic; computed from the same samples)
            {
                let mut returned = 0usize;
                let mut t_mc = f64::INFINITY;
                let mut pending: Vec<(f64, usize)> = sim
                    .snapshot()
                    .into_iter()
                    .filter_map(|(t, a)| match a {
                        Actor::Device(i) => Some((t, states[i].load)),
                        Actor::Master => None,
                    })
                    .collect();
                pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for (t, pts) in pending {
                    returned += pts;
                    if returned >= m.saturating_sub(c) {
                        t_mc = t;
                        break;
                    }
                }
                gather_mc_times.push(t_mc);
            }

            let arrived = sim.run_until(t_star);

            // --- numerics: Eq. 18 + 19 -----------------------------------
            let mut parity_grad: Option<Mat> = None;
            let mut device_grads: Vec<Mat> = Vec::new();
            for ev in &arrived {
                match ev.payload {
                    Actor::Master => {
                        parity_grad = Some(match parity_handle {
                            Some(h) => self.backend.parity_grad_registered(h, &model.beta)?,
                            None => self.backend.parity_grad(
                                &composite.xt,
                                &model.beta,
                                &composite.yt,
                                c,
                            )?,
                        });
                    }
                    Actor::Device(i) => {
                        let st = &states[i];
                        let mut g = match st.handle {
                            Some(h) => self.backend.partial_grad_registered(h, &model.beta)?,
                            None => {
                                self.backend.partial_grad(&st.x_sys, &model.beta, &st.y_sys)?
                            }
                        };
                        if k < n {
                            // inverse-probability weighting keeps the
                            // combined estimate unbiased under selection
                            g.scale(n as f32 / k as f32);
                        }
                        device_grads.push(g);
                    }
                }
            }
            let grad_refs: Vec<&Mat> = device_grads.iter().collect();
            let grad = assemble_coded_gradient(d, parity_grad.as_ref(), &grad_refs);
            model.apply_gradient(&grad);

            now += t_star;
            epoch_times.push(t_star);
            let nmse = model.nmse(&self.dataset.beta_star);
            trace.push(now, epoch + 1, nmse);
            if converged.is_none() && nmse <= self.cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        Ok(RunResult {
            label: trace.label.clone(),
            trace,
            epoch_times,
            setup_secs,
            parity_upload_bits: parity_bits,
            per_epoch_bits: self.round_trip_bits(&policy.device_loads),
            converged,
            delta: policy.delta,
            epoch_deadline: t_star,
            gather_mc_times,
        })
    }

    /// Train uncoded FL: full loads, the master waits for all m partial
    /// gradients each epoch (Fig. 3 top's heavy-tailed gather).
    pub fn train_uncoded(&mut self) -> Result<RunResult> {
        let mut rng = self.run_rng();
        let d = self.cfg.model_dim;
        let m = self.fleet.total_points();

        let mut model = GlobalModel::zeros(d, self.cfg.learning_rate, m);
        let mut trace = ConvergenceTrace::new("uncoded");
        let mut epoch_times = Vec::new();
        let mut converged = None;
        let mut now = 0.0f64;
        trace.push(now, 0, model.nmse(&self.dataset.beta_star));

        // §Perf: pre-register the full dataset in row chunks so the exact
        // full gradient is a handful of β-only PJRT calls per epoch
        // (native backend: returns None, slow path below)
        let chunk = 512;
        let mut chunk_handles: Vec<(u64, usize)> = Vec::new(); // (handle, start)
        let mut all_registered = true;
        {
            let mut start = 0;
            while start < self.dataset.rows() {
                let end = (start + chunk).min(self.dataset.rows());
                match self.backend.register_shard(
                    &self.dataset.x.slice_rows(start, end),
                    &self.dataset.y.slice_rows(start, end),
                )? {
                    Some(h) => chunk_handles.push((h, start)),
                    None => {
                        all_registered = false;
                        break;
                    }
                }
                start = end;
            }
        }

        for epoch in 0..self.cfg.max_epochs {
            // epoch duration = slowest device (wait-for-all)
            let mut epoch_len = 0.0f64;
            for dev in &self.fleet.devices {
                epoch_len = epoch_len.max(dev.sample_total_delay(dev.points, &mut rng));
            }
            // exact full gradient over the global data (Σᵢ inner sums)
            let grad = if all_registered {
                let mut acc = Mat::zeros(d, 1);
                for &(h, _) in &chunk_handles {
                    acc.add_assign(&self.backend.partial_grad_registered(h, &model.beta)?);
                }
                acc
            } else {
                self.backend.partial_grad(&self.dataset.x, &model.beta, &self.dataset.y)?
            };
            model.apply_gradient(&grad);

            now += epoch_len;
            epoch_times.push(epoch_len);
            let nmse = model.nmse(&self.dataset.beta_star);
            trace.push(now, epoch + 1, nmse);
            if converged.is_none() && nmse <= self.cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        let full_loads: Vec<usize> = self.fleet.devices.iter().map(|p| p.points).collect();
        Ok(RunResult {
            label: "uncoded".into(),
            trace,
            epoch_times,
            setup_secs: 0.0,
            parity_upload_bits: 0.0,
            per_epoch_bits: self.round_trip_bits(&full_loads),
            converged,
            delta: 0.0,
            epoch_deadline: f64::INFINITY,
            gather_mc_times: Vec::new(),
        })
    }

    /// Round-trip traffic per epoch: every participating device downloads
    /// the model and uploads a gradient (2 packets).
    fn round_trip_bits(&self, loads: &[usize]) -> f64 {
        loads.iter().filter(|&&l| l > 0).count() as f64 * 2.0 * self.fleet.packet_bits
    }
}
