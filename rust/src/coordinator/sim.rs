//! DES-driven training coordinator (the paper's evaluation harness).

use super::core::{Coordinator, RunResult, Session};
use crate::config::{ExperimentConfig, Participation};
use crate::des::Simulator;
use crate::fl::{assemble_coded_gradient_tree, GlobalModel, GradBackend, NativeBackend};
use crate::lb::LoadPolicy;
use crate::linalg::Mat;
use crate::obs::{Phase, PhaseBook, Stopwatch};
use crate::simnet::Fleet;
use anyhow::{Context, Result};

/// DES-driven coordinator. Owns the shared [`Session`] (fleet, data,
/// shards, randomness streams) plus the gradient backend; per-epoch
/// device delays are sampled from §II-A's models and fed through the DES
/// queue, so every run is deterministic per seed.
pub struct SimCoordinator {
    session: Session,
    backend: Box<dyn GradBackend>,
}

impl SimCoordinator {
    /// Build the problem instance from a config. Loads PJRT artifacts when
    /// `cfg.artifacts_dir` is set, otherwise uses the native backend.
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let backend: Box<dyn GradBackend> = match &cfg.artifacts_dir {
            Some(dir) => Box::new(
                crate::runtime::PjrtBackend::load(dir)
                    .with_context(|| format!("loading artifacts from {dir}"))?,
            ),
            None => Box::new(NativeBackend),
        };
        Self::with_backend(cfg, backend)
    }

    /// Build with an explicit backend (tests inject oracles/mocks here).
    pub fn with_backend(cfg: &ExperimentConfig, backend: Box<dyn GradBackend>) -> Result<Self> {
        Ok(Self { session: Session::new(cfg)?, backend })
    }

    /// The shared problem instance (config, fleet, dataset, shards).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// The experiment configuration the session was built from.
    pub fn cfg(&self) -> &ExperimentConfig {
        &self.session.cfg
    }

    /// The simulated fleet (device profiles + master).
    pub fn fleet(&self) -> &Fleet {
        &self.session.fleet
    }

    /// The backend actually in use ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Solve the CFL load/redundancy policy (see [`Session::policy`]).
    pub fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    /// Closed-form least-squares NMSE — the Fig. 2 lower bound.
    pub fn ls_bound(&self) -> Result<f64> {
        self.session.ls_bound()
    }

    // ---------------------------------------------------------------------
    // Training runs
    // ---------------------------------------------------------------------

    /// Train with Coded Federated Learning (§III). Simulated time starts
    /// at the parity-upload completion and advances t* per epoch.
    pub fn train_cfl(&mut self) -> Result<RunResult> {
        let policy = self.session.policy()?;
        self.train_cfl_with_policy(&policy)
    }

    /// CFL with an explicit policy (ablations sweep weights through here).
    pub fn train_cfl_with_policy(&mut self, policy: &LoadPolicy) -> Result<RunResult> {
        let run_sw = Stopwatch::start();
        let mut phases = PhaseBook::with_capacity(self.session.cfg.max_epochs);
        let mut rng = self.session.run_rng();
        let setup =
            self.session.build_setup(policy, self.backend.as_mut(), &mut rng)?;
        phases.record(Phase::ParityEncode, run_sw.elapsed_s());
        let states = &setup.devices;
        let composite = &setup.composite;
        let d = self.session.cfg.model_dim;
        let m = self.session.fleet.total_points();
        let c = policy.parity_rows;
        let t_star = policy.epoch_deadline;

        let label = format!("cfl δ={:.3}", policy.delta);
        let mut model = GlobalModel::zeros(d, self.session.cfg.learning_rate, m);
        let mut trace_log = self.session.start_trace_log(
            label.clone(),
            setup.setup_secs,
            model.nmse(self.session.beta_star()),
        );
        let mut epoch_times = Vec::new();
        let mut gather_mc_times = Vec::new();
        // membership trace: the sim fleet never churns, but client
        // selection (§V) and sampled participation vary the per-epoch
        // gather set — record it so sim and live traces carry the same
        // members column
        let mut epoch_members = vec![states.iter().filter(|s| s.load > 0).count()];
        let mut converged = None;
        let mut on_time = 0u64;
        let mut late = 0u64;
        let mut now = setup.setup_secs;
        // §Perf: keep the composite parity device-resident (PJRT fast path)
        let parity_handle = self.backend.register_parity(&composite.xt, &composite.yt, c)?;
        let rows_streamed = crate::obs::registry().counter("data.rows_streamed");

        /// DES event payload: who finished computing.
        #[derive(Clone, Copy, PartialEq)]
        enum Actor {
            Device(usize),
            Master,
        }

        // per-epoch participation: the legacy §V client_fraction mask and
        // the scale-mode `participation` axis both resolve to k of n
        // devices per epoch (config validation forbids combining them)
        let n = self.session.fleet.n_devices();
        let k = self.session.cfg.sampled_per_epoch();
        // `participation != all` walks only the O(k) sampled set per epoch;
        // the legacy mask path scans the whole fleet and is kept verbatim
        // so client_fraction runs stay byte-identical
        let sparse = self.session.cfg.participation != Participation::All;

        for epoch in 0..self.session.cfg.max_epochs {
            let mut ep_span = crate::obs_span!(Debug, "epoch");
            let mut ep_sw = Stopwatch::start();
            // --- timing: schedule every completion, gather until t* ------
            let mut sim = Simulator::new();
            let mut scheduled_devices = 0u64;
            if sparse && k < n {
                // O(k) per epoch: draw the sampled set, touch only it
                for i in rng.sample_indices_sparse(n, k) {
                    if states[i].load == 0 {
                        continue;
                    }
                    let t = self.session.fleet.devices[i]
                        .sample_total_delay(states[i].load, &mut rng);
                    sim.schedule_at(t, Actor::Device(i));
                    scheduled_devices += 1;
                }
            } else {
                let selected: Option<Vec<bool>> = if k < n {
                    let mut mask = vec![false; n];
                    for i in rng.sample_indices(n, k) {
                        mask[i] = true;
                    }
                    Some(mask)
                } else {
                    None
                };
                for (i, (dev, st)) in
                    self.session.fleet.devices.iter().zip(states).enumerate()
                {
                    if st.load == 0 || selected.as_ref().is_some_and(|m| !m[i]) {
                        continue;
                    }
                    let t = dev.sample_total_delay(st.load, &mut rng);
                    sim.schedule_at(t, Actor::Device(i));
                    scheduled_devices += 1;
                }
            }
            let t_master = self.session.fleet.master.sample_total_delay(c, &mut rng);
            sim.schedule_at(t_master, Actor::Master);

            // Fig. 3 bottom: when would the devices alone have covered
            // m − c points? (diagnostic; computed from the same samples)
            {
                let pending: Vec<(f64, usize)> = sim
                    .snapshot()
                    .into_iter()
                    .filter_map(|(t, a)| match a {
                        Actor::Device(i) => Some((t, states[i].load)),
                        Actor::Master => None,
                    })
                    .collect();
                gather_mc_times.push(time_to_cover(pending, m.saturating_sub(c)));
            }

            let arrived = sim.run_until(t_star);
            let gather_s = ep_sw.lap_s();

            // --- numerics: Eq. 18 + 19 -----------------------------------
            let mut parity_grad: Option<Mat> = None;
            let mut device_grads: Vec<Mat> = Vec::new();
            for ev in &arrived {
                match ev.payload {
                    Actor::Master => {
                        parity_grad = Some(match parity_handle {
                            Some(h) => self.backend.parity_grad_registered(h, &model.beta)?,
                            None => self.backend.parity_grad(
                                &composite.xt,
                                &model.beta,
                                &composite.yt,
                                c,
                            )?,
                        });
                    }
                    Actor::Device(i) => {
                        let st = &states[i];
                        let mut g = match st.handle {
                            Some(h) => self.backend.partial_grad_registered(h, &model.beta)?,
                            None => match self.session.lean() {
                                // lean fleet: stream exactly the ℓᵢ-row
                                // systematic prefix, then drop it
                                Some(lean) => {
                                    let view = lean.shard_view(i, st.load);
                                    rows_streamed.add(st.load as u64);
                                    self.backend.partial_grad(&view.x, &model.beta, &view.y)?
                                }
                                None => self.backend.partial_grad(
                                    &st.x_sys,
                                    &model.beta,
                                    &st.y_sys,
                                )?,
                            },
                        };
                        if k < n {
                            // inverse-probability weighting keeps the
                            // combined estimate unbiased under selection
                            g.scale(n as f32 / k as f32);
                        }
                        device_grads.push(g);
                    }
                }
            }
            let grad_s = ep_sw.lap_s();
            on_time += device_grads.len() as u64;
            late += scheduled_devices - device_grads.len() as u64;
            epoch_members.push(scheduled_devices as usize);
            let grad_refs: Vec<&Mat> = device_grads.iter().collect();
            let grad = assemble_coded_gradient_tree(
                d,
                parity_grad.as_ref(),
                &grad_refs,
                self.session.cfg.agg_fanin,
            );
            model.apply_gradient(&grad);

            now += t_star;
            epoch_times.push(t_star);
            let nmse = model.nmse(self.session.beta_star());
            trace_log.push(now, epoch + 1, nmse);

            let agg_s = ep_sw.lap_s();
            phases.record(Phase::Gather, gather_s);
            phases.record(Phase::LocalGrad, grad_s);
            phases.record(Phase::Aggregate, agg_s);
            if ep_span.active() {
                ep_span.field("epoch", epoch + 1);
                ep_span.field("nmse", nmse);
                ep_span.field("members", scheduled_devices);
                ep_span.field("gather_ms", gather_s * 1e3);
                ep_span.field("local_grad_ms", grad_s * 1e3);
                ep_span.field("aggregate_ms", agg_s * 1e3);
            }

            if converged.is_none() && nmse <= self.session.cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        crate::obs_event!(
            Debug,
            "run_done",
            label = label.as_str(),
            epochs = epoch_times.len(),
            wall_s = run_sw.elapsed_s(),
        );
        Ok(RunResult {
            label,
            trace: trace_log.finish(),
            epoch_times,
            setup_secs: setup.setup_secs,
            parity_upload_bits: setup.parity_upload_bits,
            per_epoch_bits: self.session.round_trip_bits(&policy.device_loads),
            converged,
            delta: policy.delta,
            epoch_deadline: t_star,
            gather_mc_times,
            wall_secs: run_sw.elapsed_s(),
            on_time_gradients: on_time,
            late_gradients: late,
            epoch_members,
            disconnects: 0,
            rejoins: 0,
            phases: phases.summaries(),
        })
    }

    /// Train uncoded FL: full loads, the master waits for all m partial
    /// gradients each epoch (Fig. 3 top's heavy-tailed gather).
    ///
    /// Requires `data_mode = materialized`: the exact full-data gradient
    /// needs every row resident each epoch, which is precisely what lean
    /// mode exists to avoid (scale sweeps run `--skip-uncoded`).
    pub fn train_uncoded(&mut self) -> Result<RunResult> {
        let run_sw = Stopwatch::start();
        let mut phases = PhaseBook::with_capacity(self.session.cfg.max_epochs);
        let mut rng = self.session.run_rng();
        let d = self.session.cfg.model_dim;
        let m = self.session.fleet.total_points();
        anyhow::ensure!(
            self.session.lean().is_none(),
            "train_uncoded needs the full dataset resident; \
             data_mode = lean supports train_cfl only (use --skip-uncoded)"
        );

        let mut model = GlobalModel::zeros(d, self.session.cfg.learning_rate, m);
        let mut trace = self.session.start_trace(
            "uncoded".into(),
            0.0,
            model.nmse(self.session.beta_star()),
        );
        let mut epoch_times = Vec::new();
        let mut converged = None;
        let mut on_time = 0u64;
        let mut now = 0.0f64;

        // §Perf: pre-register the full dataset in row chunks so the exact
        // full gradient is a handful of β-only PJRT calls per epoch
        // (native backend: returns None, slow path below)
        let dataset = self.session.dataset()?;
        let chunk = 512;
        let mut chunk_handles: Vec<(u64, usize)> = Vec::new(); // (handle, start)
        let mut all_registered = true;
        {
            let mut start = 0;
            while start < dataset.rows() {
                let end = (start + chunk).min(dataset.rows());
                match self.backend.register_shard(
                    &dataset.x.slice_rows(start, end),
                    &dataset.y.slice_rows(start, end),
                )? {
                    Some(h) => chunk_handles.push((h, start)),
                    None => {
                        all_registered = false;
                        break;
                    }
                }
                start = end;
            }
        }

        for epoch in 0..self.session.cfg.max_epochs {
            let mut ep_span = crate::obs_span!(Debug, "epoch");
            let mut ep_sw = Stopwatch::start();
            // epoch duration = slowest device (wait-for-all)
            let mut epoch_len = 0.0f64;
            for dev in &self.session.fleet.devices {
                epoch_len = epoch_len.max(dev.sample_total_delay(dev.points, &mut rng));
            }
            let gather_s = ep_sw.lap_s();
            // exact full gradient over the global data (Σᵢ inner sums)
            let grad = if all_registered {
                let mut acc = Mat::zeros(d, 1);
                for &(h, _) in &chunk_handles {
                    acc.add_assign(&self.backend.partial_grad_registered(h, &model.beta)?);
                }
                acc
            } else {
                self.backend.partial_grad(&dataset.x, &model.beta, &dataset.y)?
            };
            let grad_s = ep_sw.lap_s();
            model.apply_gradient(&grad);
            on_time += self.session.fleet.n_devices() as u64;

            now += epoch_len;
            epoch_times.push(epoch_len);
            let nmse = model.nmse(&dataset.beta_star);
            trace.push(now, epoch + 1, nmse);

            let agg_s = ep_sw.lap_s();
            phases.record(Phase::Gather, gather_s);
            phases.record(Phase::LocalGrad, grad_s);
            phases.record(Phase::Aggregate, agg_s);
            if ep_span.active() {
                ep_span.field("epoch", epoch + 1);
                ep_span.field("nmse", nmse);
                ep_span.field("local_grad_ms", grad_s * 1e3);
                ep_span.field("aggregate_ms", agg_s * 1e3);
            }

            if converged.is_none() && nmse <= self.session.cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        let full_loads: Vec<usize> =
            self.session.fleet.devices.iter().map(|p| p.points).collect();
        let epoch_members = vec![self.session.fleet.n_devices(); epoch_times.len() + 1];
        crate::obs_event!(
            Debug,
            "run_done",
            label = trace.label.as_str(),
            epochs = epoch_times.len(),
            wall_s = run_sw.elapsed_s(),
        );
        Ok(RunResult {
            label: "uncoded".into(),
            trace,
            epoch_times,
            setup_secs: 0.0,
            parity_upload_bits: 0.0,
            per_epoch_bits: self.session.round_trip_bits(&full_loads),
            converged,
            delta: 0.0,
            epoch_deadline: f64::INFINITY,
            gather_mc_times: Vec::new(),
            wall_secs: run_sw.elapsed_s(),
            on_time_gradients: on_time,
            late_gradients: 0,
            epoch_members,
            disconnects: 0,
            rejoins: 0,
            phases: phases.summaries(),
        })
    }
}

/// Fig. 3 bottom diagnostic: earliest completion time at which the
/// pending `(finish_time, points)` contributions alone cover `need`
/// points (+∞ when they never do).
///
/// Sorting uses [`f64::total_cmp`]: a NaN finish time (a degenerate
/// delay-model draw) sorts to the end as "slowest" instead of making the
/// comparator panic mid-run.
pub(crate) fn time_to_cover(mut pending: Vec<(f64, usize)>, need: usize) -> f64 {
    pending.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut returned = 0usize;
    for (t, pts) in pending {
        returned += pts;
        if returned >= need {
            return t;
        }
    }
    f64::INFINITY
}

impl Coordinator for SimCoordinator {
    fn kind(&self) -> &'static str {
        "sim"
    }

    fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    fn train_cfl(&mut self) -> Result<RunResult> {
        SimCoordinator::train_cfl(self)
    }

    fn train_uncoded(&mut self) -> Result<RunResult> {
        SimCoordinator::train_uncoded(self)
    }
}
