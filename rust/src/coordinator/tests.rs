use super::*;
use crate::config::ExperimentConfig;
use crate::fl::NativeBackend;

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.seed = 77;
    cfg
}

#[test]
fn cfl_converges_on_small_problem() {
    let mut sim = SimCoordinator::new(&small_cfg()).unwrap();
    let run = sim.train_cfl().unwrap();
    assert!(
        run.converged.is_some(),
        "CFL did not reach NMSE {} (final {:?})",
        small_cfg().target_nmse,
        run.trace.final_nmse()
    );
    assert!(run.setup_secs > 0.0, "parity upload must take time");
    assert!(run.parity_upload_bits > 0.0);
    assert!(run.delta > 0.0);
    assert!(run.epoch_deadline.is_finite());
    // trace times strictly increase by t* per epoch after setup
    let pts = &run.trace.points;
    assert!((pts[1].time_s - pts[0].time_s - run.epoch_deadline).abs() < 1e-9);
}

#[test]
fn uncoded_converges_and_has_no_setup() {
    let mut sim = SimCoordinator::new(&small_cfg()).unwrap();
    let run = sim.train_uncoded().unwrap();
    assert!(run.converged.is_some(), "uncoded did not converge");
    assert_eq!(run.setup_secs, 0.0);
    assert_eq!(run.parity_upload_bits, 0.0);
    assert_eq!(run.delta, 0.0);
    assert!(run.epoch_deadline.is_infinite());
    // epoch times vary (max of sampled delays) and are all positive
    assert!(run.epoch_times.iter().all(|&t| t > 0.0));
    let first = run.epoch_times[0];
    assert!(run.epoch_times.iter().any(|&t| (t - first).abs() > 1e-12));
}

#[test]
fn run_results_carry_phase_summaries() {
    let mut sim = SimCoordinator::new(&small_cfg()).unwrap();
    let coded = sim.train_cfl().unwrap();
    let names: Vec<&str> = coded.phases.iter().map(|p| p.phase).collect();
    for phase in ["parity_encode", "local_grad", "gather", "aggregate"] {
        assert!(names.contains(&phase), "missing {phase} in {names:?}");
    }
    let grad = coded.phases.iter().find(|p| p.phase == "local_grad").unwrap();
    assert_eq!(grad.count, coded.epoch_times.len() as u64, "one sample per epoch");
    assert!(grad.p95_s >= grad.p50_s, "quantiles out of order: {grad:?}");
    assert!(grad.total_s >= grad.p95_s, "total below p95: {grad:?}");

    let uncoded = sim.train_uncoded().unwrap();
    let names: Vec<&str> = uncoded.phases.iter().map(|p| p.phase).collect();
    assert!(!names.contains(&"parity_encode"), "uncoded has no parity step: {names:?}");
    assert!(names.contains(&"local_grad"), "{names:?}");
}

#[test]
fn runs_are_seed_reproducible() {
    let mut a = SimCoordinator::new(&small_cfg()).unwrap();
    let mut b = SimCoordinator::new(&small_cfg()).unwrap();
    let ra = a.train_cfl().unwrap();
    let rb = b.train_cfl().unwrap();
    assert_eq!(ra.trace.points.len(), rb.trace.points.len());
    for (pa, pb) in ra.trace.points.iter().zip(&rb.trace.points) {
        assert_eq!(pa.time_s, pb.time_s);
        assert_eq!(pa.nmse, pb.nmse);
    }
}

#[test]
fn cfl_and_uncoded_reach_similar_floors() {
    // both are unbiased estimators of the same GD dynamics; their final
    // NMSE (epoch-limited) should land in the same decade
    let mut cfg = small_cfg();
    cfg.max_epochs = 2500;
    cfg.target_nmse = 0.0; // run to the epoch cap
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let coded = sim.train_cfl().unwrap();
    let uncoded = sim.train_uncoded().unwrap();
    let (nc, nu) = (coded.trace.final_nmse().unwrap(), uncoded.trace.final_nmse().unwrap());
    assert!(nc < 1e-2, "coded floor too high: {nc:.2e}");
    assert!(nu < 1e-2, "uncoded floor too high: {nu:.2e}");
    assert!((nc.log10() - nu.log10()).abs() < 1.5, "floors diverge: {nc:.2e} vs {nu:.2e}");
}

#[test]
fn fixed_delta_is_respected() {
    let mut cfg = small_cfg();
    cfg.delta = Some(0.15);
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let run = sim.train_cfl().unwrap();
    assert!((run.delta - 0.15).abs() < 0.01, "delta {} != 0.15", run.delta);
}

#[test]
fn gather_mc_times_recorded_per_epoch() {
    let mut cfg = small_cfg();
    cfg.max_epochs = 50;
    cfg.target_nmse = 0.0;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let run = sim.train_cfl().unwrap();
    assert_eq!(run.gather_mc_times.len(), run.epoch_times.len());
    // finite gathers must be positive
    assert!(run.gather_mc_times.iter().all(|&t| t > 0.0));
}

#[test]
fn ls_bound_is_below_targets() {
    let sim = SimCoordinator::new(&small_cfg()).unwrap();
    let ls = sim.ls_bound().unwrap();
    assert!(ls > 0.0 && ls < small_cfg().target_nmse, "LS bound {ls:.3e} not a floor");
}

#[test]
fn with_backend_injection_works() {
    let sim = SimCoordinator::with_backend(&small_cfg(), Box::new(NativeBackend)).unwrap();
    assert_eq!(sim.backend_name(), "native");
}

#[test]
fn invalid_config_rejected() {
    let mut cfg = small_cfg();
    cfg.nu_comp = 1.5;
    assert!(SimCoordinator::new(&cfg).is_err());
}

fn live_cfg() -> ExperimentConfig {
    let mut cfg = small_cfg();
    cfg.n_devices = 4;
    cfg.points_per_device = 40;
    cfg.model_dim = 16;
    cfg.max_epochs = 40;
    cfg.target_nmse = 0.0; // no early stop: run every epoch
    cfg
}

#[test]
fn live_coordinator_runs_and_learns() {
    let mut live = LiveCoordinator::new(&live_cfg(), 1e-4).unwrap();
    let report = live.train_cfl().unwrap();
    assert_eq!(report.epoch_times.len(), 40);
    let final_nmse = report.trace.final_nmse().unwrap();
    assert!(final_nmse < 0.9, "live run did not learn: {final_nmse}");
    assert!(report.on_time_gradients > 0, "no gradients arrived on time");
    assert!(report.wall_secs < 60.0);
    // the unified result vocabulary carries the CFL setup accounting
    assert!(report.setup_secs > 0.0 && report.parity_upload_bits > 0.0);
    assert!(report.delta > 0.0 && report.epoch_deadline.is_finite());
}

#[test]
fn live_uncoded_waits_for_every_gradient() {
    let mut cfg = live_cfg();
    cfg.max_epochs = 20;
    let mut live = LiveCoordinator::new(&cfg, 1e-4).unwrap();
    let run = live.train_uncoded().unwrap();
    assert_eq!(run.epoch_times.len(), 20);
    // wait-for-all: every device reports every epoch
    assert_eq!(run.on_time_gradients, (cfg.n_devices * 20) as u64);
    assert_eq!(run.delta, 0.0);
    assert_eq!(run.setup_secs, 0.0);
    assert!(run.epoch_deadline.is_infinite());
    assert!(run.trace.final_nmse().unwrap() < 1.0);
}

#[test]
fn session_setup_is_deterministic() {
    // the shared setup layer: same seed + policy ⇒ byte-identical parity,
    // shard state, and load assignment, no matter who consumes it
    let cfg = small_cfg();
    let build = || {
        let mut session = Session::new(&cfg).unwrap();
        let policy = session.policy().unwrap();
        let mut rng = session.run_rng();
        let setup = session.build_setup(&policy, &mut NativeBackend, &mut rng).unwrap();
        (policy, setup)
    };
    let (p1, s1) = build();
    let (p2, s2) = build();
    assert_eq!(p1.device_loads, p2.device_loads);
    assert_eq!(s1.composite.xt, s2.composite.xt, "composite parity X̃ must match");
    assert_eq!(s1.composite.yt, s2.composite.yt, "composite parity ỹ must match");
    assert_eq!(s1.setup_secs, s2.setup_secs);
    assert_eq!(s1.parity_upload_bits, s2.parity_upload_bits);
    assert_eq!(s1.devices.len(), s2.devices.len());
    for (a, b) in s1.devices.iter().zip(&s2.devices) {
        assert_eq!(a.load, b.load);
        assert_eq!(a.x_sys, b.x_sys);
        assert_eq!(a.y_sys, b.y_sys);
    }
}

#[test]
fn sim_and_live_share_the_session_state() {
    // both coordinators build from Session::new, so fleet, dataset and
    // sharding are identical for the same seed — the state the two setup
    // phases used to construct independently
    let cfg = small_cfg();
    let sim = SimCoordinator::new(&cfg).unwrap();
    let live = LiveCoordinator::new(&cfg, 1e-3).unwrap();
    assert_eq!(sim.session().fleet.devices, live.session().fleet.devices);
    let (sd, ld) = (sim.session().dataset().unwrap(), live.session().dataset().unwrap());
    assert_eq!(sd.x, ld.x);
    assert_eq!(sd.y, ld.y);
    let (ss, ls) = (sim.session().shards().unwrap(), live.session().shards().unwrap());
    assert_eq!(ss.len(), ls.len());
    for (a, b) in ss.iter().zip(ls) {
        assert_eq!(a.x, b.x);
        assert_eq!(a.offset, b.offset);
    }
}

#[test]
fn coordinator_kind_builds_both_backends() {
    let mut cfg = live_cfg();
    cfg.max_epochs = 10;
    let live = CoordinatorKind::Live {
        time_scale: 1e-4,
        transport: crate::transport::TransportKind::Channel,
        placement: None,
    };
    for kind in [CoordinatorKind::Sim, live] {
        let mut coord = kind.build(&cfg).unwrap();
        assert_eq!(coord.kind(), kind.tag());
        let policy = coord.policy().unwrap();
        assert!(policy.parity_rows > 0);
        let run = coord.train_cfl().unwrap();
        assert_eq!(run.epoch_times.len(), 10, "{} ran short", kind.tag());
        assert!(run.trace.points.len() == 11);
    }
}

// ---------------------------------------------------------------------
// transport-generic behavior (TCP legs skip silently where the sandbox
// denies loopback bind)

fn loopback() -> Option<std::net::TcpListener> {
    match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("skipping TCP coordinator test: loopback bind denied ({e})");
            None
        }
    }
}

#[test]
fn tcp_and_channel_transports_reach_the_same_trajectory() {
    use crate::transport::{run_device, TcpTransport};
    use std::time::Duration;

    let Some(listener) = loopback() else { return };
    let cfg = live_cfg(); // target 0: both runs last exactly max_epochs
    // pin a generous grace so no gradient straggles on either wire —
    // then both transports gather the same per-epoch reply sets and the
    // trajectories may differ only by float summation order
    let grace = Some(Duration::from_millis(250));

    let mut chan = LiveCoordinator::new(&cfg, 1e-6).unwrap();
    chan.grace = grace;
    let a = chan.train_cfl().unwrap();

    let addr = listener.local_addr().unwrap().to_string();
    let mut devices = Vec::new();
    for id in 0..cfg.n_devices {
        let addr = addr.clone();
        devices.push(std::thread::spawn(move || {
            run_device(&addr, id, Duration::from_secs(5))
        }));
    }
    let tcp = TcpTransport::serve(listener, cfg.n_devices, Duration::from_secs(5)).unwrap();
    let mut live = LiveCoordinator::with_transport(&cfg, 1e-6, Box::new(tcp)).unwrap();
    live.grace = grace;
    let b = live.train_cfl().unwrap();
    drop(live); // Shutdown: device processes (threads here) exit
    for h in devices {
        h.join().unwrap().unwrap();
    }

    assert_eq!(a.trace.points.len(), b.trace.points.len(), "trajectory lengths diverge");
    for (pa, pb) in a.trace.points.iter().zip(&b.trace.points) {
        // the simulated-time axis is deadline-gated: exactly equal
        assert_eq!(pa.time_s, pb.time_s);
        assert_eq!(pa.epoch, pb.epoch);
        let tol = 1e-3 * pa.nmse.abs().max(1e-12);
        assert!(
            (pa.nmse - pb.nmse).abs() <= tol,
            "epoch {}: chan NMSE {:.6e} vs tcp NMSE {:.6e}",
            pa.epoch,
            pa.nmse,
            pb.nmse
        );
    }
    assert_eq!(a.on_time_gradients, b.on_time_gradients, "reply sets diverged");
}

#[test]
fn mid_run_disconnect_degrades_instead_of_hanging() {
    use crate::fl::GradBackend;
    use crate::transport::frame::{
        decode_to_device, encode_from_device, read_frame, write_frame, PROTOCOL_VERSION,
    };
    use crate::transport::{run_device, FromDevice, TcpTransport, ToDevice};
    use std::time::{Duration, Instant};

    let Some(listener) = loopback() else { return };
    let mut cfg = live_cfg();
    cfg.max_epochs = 8;
    let addr = listener.local_addr().unwrap().to_string();

    // three well-behaved devices …
    let mut devices = Vec::new();
    for id in 0..cfg.n_devices - 1 {
        let addr = addr.clone();
        devices.push(std::thread::spawn(move || {
            run_device(&addr, id, Duration::from_secs(5))
        }));
    }
    // … and one that answers two epochs, then drops its socket mid-run
    let dropper_id = cfg.n_devices - 1;
    let dropper = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let hello =
            FromDevice::Hello { device_id: dropper_id, protocol: PROTOCOL_VERSION };
        write_frame(&mut s, &encode_from_device(&hello)).unwrap();
        let mut state: Option<(crate::linalg::Mat, crate::linalg::Mat, u64)> = None;
        let mut replies = 0u32;
        while let Some(payload) = read_frame(&mut s).unwrap() {
            match decode_to_device(&payload).unwrap() {
                ToDevice::Setup(init) => state = Some((init.x_sys, init.y_sys, init.run)),
                ToDevice::Ping { nonce } => {
                    write_frame(&mut s, &encode_from_device(&FromDevice::Pong { nonce }))
                        .unwrap();
                }
                ToDevice::Model { epoch, beta } => {
                    if replies >= 2 {
                        return; // disconnect: socket closes mid-gather
                    }
                    replies += 1;
                    let (x, y, run) = state.as_ref().unwrap();
                    let grad = NativeBackend.partial_grad(x, &beta, y).unwrap();
                    let msg = FromDevice::Grad { run: *run, epoch, grad, delay: 1e-6 };
                    write_frame(&mut s, &encode_from_device(&msg)).unwrap();
                }
                ToDevice::Stop => state = None,
                ToDevice::Shutdown => return,
            }
        }
    });

    let tcp = TcpTransport::serve(listener, cfg.n_devices, Duration::from_secs(5)).unwrap();
    let mut live = LiveCoordinator::with_transport(&cfg, 1e-6, Box::new(tcp)).unwrap();
    live.grace = Some(Duration::from_millis(100));
    let started = Instant::now();
    // the uncoded gather is wait-for-all: without disconnect degradation
    // it would stall WAIT_ALL_TIMEOUT (30 s) on every epoch after the drop
    let run = live.train_uncoded().unwrap();
    assert_eq!(run.epoch_times.len(), cfg.max_epochs);
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "gather hung on the disconnected device"
    );
    // the dead device's broadcast gradient went late exactly once; the
    // survivors kept reporting every epoch
    assert!(run.late_gradients >= 1, "the dropped gradient must count late");
    assert!(
        run.on_time_gradients >= ((cfg.n_devices - 1) * cfg.max_epochs) as u64,
        "survivors stopped being gathered after the disconnect"
    );
    drop(live);
    dropper.join().unwrap();
    for h in devices {
        h.join().unwrap().unwrap();
    }
}

// ---------------------------------------------------------------------
// reconnect & rejoin: a disconnected device must be restorable to the
// coded gather set, not demoted to parity-only forever

#[test]
fn channel_kill_and_rejoin_restores_the_coded_gather_set() {
    use crate::transport::ChannelTransport;
    use std::time::Duration;

    let mut cfg = live_cfg();
    cfg.max_epochs = 200;
    // homogeneous fleet: every device is guaranteed a positive coded
    // load, so the killed slot is certainly in the gather set
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let chan = ChannelTransport::new(cfg.n_devices);
    let ctl = chan.controller();
    // time scale 0.2 paces every epoch with real milliseconds of slept
    // delay (the slowest link's round trip alone is ≥ 1 ms), so the
    // wall-clock churn below reliably lands mid-run
    let mut live = LiveCoordinator::with_transport(&cfg, 0.2, Box::new(chan)).unwrap();
    live.grace = Some(Duration::from_millis(250));

    // churn from another thread while the coordinator trains: kill a
    // device early in the run, restart it shortly after
    let churn = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(60));
        ctl.kill(2);
        std::thread::sleep(Duration::from_millis(100));
        ctl.respawn(2);
    });
    let run = live.train_cfl().unwrap();
    churn.join().unwrap();

    assert!(run.disconnects >= 1, "the kill was never observed");
    assert!(run.rejoins >= 1, "the respawn was never admitted");
    assert_eq!(
        *run.epoch_members.last().unwrap(),
        cfg.n_devices,
        "the rejoined device never returned to the coded gather set"
    );
    assert!(
        run.epoch_members.iter().any(|&m| m < cfg.n_devices),
        "churn never dipped the gather set — the kill landed too late"
    );
    assert_eq!(run.epoch_members.len(), run.trace.points.len());
    assert!(run.trace.final_nmse().unwrap() < 0.9, "run did not learn through churn");
}

#[test]
fn tcp_kill_and_rejoin_matches_channel_recovery() {
    use crate::fl::GradBackend;
    use crate::transport::frame::{
        decode_to_device, encode_from_device, read_frame, write_frame, PROTOCOL_VERSION,
    };
    use crate::transport::{run_device_retry, ChannelTransport, FromDevice, TcpTransport, ToDevice};
    use std::time::Duration;

    let Some(listener) = loopback() else { return };
    let mut cfg = live_cfg();
    cfg.max_epochs = 160;
    // homogeneous fleet: the mortal device is guaranteed a positive
    // coded load on both legs
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let grace = Some(Duration::from_millis(250));
    // time scale 0.2: epochs are paced by real slept delay (≥ ~1 ms
    // each), so wall-clock churn lands mid-run on both legs
    let time_scale = 0.2;

    // --- channel leg: scripted churn via the controller ----------------
    let chan = ChannelTransport::new(cfg.n_devices);
    let ctl = chan.controller();
    let mut live = LiveCoordinator::with_transport(&cfg, time_scale, Box::new(chan)).unwrap();
    live.grace = grace;
    let churn = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        ctl.kill(cfg.n_devices - 1);
        std::thread::sleep(Duration::from_millis(100));
        ctl.respawn(cfg.n_devices - 1);
    });
    let chan_run = live.train_cfl().unwrap();
    churn.join().unwrap();
    drop(live);

    // --- tcp leg: a mortal device that dies after 2 gradients, then a
    // fresh incarnation rejoining with the retry loop ------------------
    let addr = listener.local_addr().unwrap().to_string();
    let mut devices = Vec::new();
    for id in 0..cfg.n_devices - 1 {
        let addr = addr.clone();
        devices.push(std::thread::spawn(move || {
            crate::transport::run_device(&addr, id, Duration::from_secs(5))
        }));
    }
    let mortal_id = cfg.n_devices - 1;
    let mortal_addr = addr.clone();
    let mortal = std::thread::spawn(move || {
        // incarnation 1: hand-rolled device that answers pings and the
        // first two models, then drops its socket mid-run
        {
            let mut s = std::net::TcpStream::connect(&mortal_addr).unwrap();
            let hello =
                FromDevice::Hello { device_id: mortal_id, protocol: PROTOCOL_VERSION };
            write_frame(&mut s, &encode_from_device(&hello)).unwrap();
            let mut state: Option<(crate::linalg::Mat, crate::linalg::Mat, u64)> = None;
            let mut replies = 0u32;
            'session: while let Some(payload) = read_frame(&mut s).unwrap() {
                match decode_to_device(&payload).unwrap() {
                    ToDevice::Setup(init) => {
                        state = Some((init.x_sys, init.y_sys, init.run));
                    }
                    ToDevice::Ping { nonce } => {
                        write_frame(&mut s, &encode_from_device(&FromDevice::Pong { nonce }))
                            .unwrap();
                    }
                    ToDevice::Model { epoch, beta } => {
                        if replies >= 2 {
                            break 'session; // die mid-run
                        }
                        replies += 1;
                        let (x, y, run) = state.as_ref().unwrap();
                        let grad = NativeBackend.partial_grad(x, &beta, y).unwrap();
                        let msg = FromDevice::Grad { run: *run, epoch, grad, delay: 1e-6 };
                        write_frame(&mut s, &encode_from_device(&msg)).unwrap();
                    }
                    ToDevice::Stop => state = None,
                    ToDevice::Shutdown => return,
                }
            }
        }
        // incarnation 2: the real retry loop re-claims the slot and
        // serves until the coordinator shuts the session down
        run_device_retry(&mortal_addr, mortal_id, Duration::from_secs(10), true).unwrap();
    });

    let tcp = TcpTransport::serve(listener, cfg.n_devices, Duration::from_secs(5)).unwrap();
    let mut live = LiveCoordinator::with_transport(&cfg, time_scale, Box::new(tcp)).unwrap();
    live.grace = grace;
    let tcp_run = live.train_cfl().unwrap();
    drop(live); // Shutdown: devices exit
    mortal.join().unwrap();
    for h in devices {
        h.join().unwrap().unwrap();
    }

    // both transports recover the same way: the dead device is observed,
    // re-admitted, and finishes the run inside the coded gather set
    for (tag, run) in [("chan", &chan_run), ("tcp", &tcp_run)] {
        assert!(run.disconnects >= 1, "{tag}: the death was never observed");
        assert!(run.rejoins >= 1, "{tag}: the rejoin was never admitted");
        assert_eq!(
            *run.epoch_members.last().unwrap(),
            cfg.n_devices,
            "{tag}: full coded coverage was not restored"
        );
    }
    // and the NMSE trajectories land on the same GD fixed point: same
    // epoch count (target 0 disables early stop) and final NMSE within a
    // decade — churn shifts individual epochs, not the destination
    assert_eq!(chan_run.trace.points.len(), tcp_run.trace.points.len());
    let (a, b) =
        (chan_run.trace.final_nmse().unwrap(), tcp_run.trace.final_nmse().unwrap());
    assert!(
        (a.log10() - b.log10()).abs() < 1.5,
        "transports diverged after rejoin: chan {a:.3e} vs tcp {b:.3e}"
    );
}

#[test]
fn rejoin_after_run_boundary_restores_full_participation() {
    use crate::transport::ChannelTransport;
    use std::time::Duration;

    let mut cfg = live_cfg();
    cfg.max_epochs = 6;
    let chan = ChannelTransport::new(cfg.n_devices);
    let ctl = chan.controller();
    let mut live = LiveCoordinator::with_transport(&cfg, 1e-6, Box::new(chan)).unwrap();
    live.grace = Some(Duration::from_millis(250));

    // the kill lands during run 1's calibration: the device sits run 1
    // out entirely (uncoded runs on both sides — every device carries a
    // full shard, so participation counts are exact)
    ctl.kill(1);
    let run1 = live.train_uncoded().unwrap();
    assert!(run1.disconnects >= 1);
    assert_eq!(*run1.epoch_members.last().unwrap(), cfg.n_devices - 1);

    // restart it between runs: the queued rejoin is admitted during run
    // 2's calibration and the device is re-armed at the first epoch
    // boundary — run 2 trains with the full fleet from epoch 0
    ctl.respawn(1);
    let run2 = live.train_uncoded().unwrap();
    assert_eq!(run2.rejoins, 1, "the boundary rejoin was not admitted");
    assert_eq!(
        run2.on_time_gradients,
        (cfg.n_devices * cfg.max_epochs) as u64,
        "the rejoined device missed epochs of run 2"
    );
    assert_eq!(*run2.epoch_members.last().unwrap(), cfg.n_devices);
}

#[test]
fn silent_calibration_corpse_costs_one_cap_and_is_excluded() {
    use crate::transport::frame::{
        encode_from_device, read_frame, write_frame, PROTOCOL_VERSION,
    };
    use crate::transport::{run_device, FromDevice, TcpTransport};
    use std::time::{Duration, Instant};

    let Some(listener) = loopback() else { return };
    let mut cfg = live_cfg();
    cfg.max_epochs = 5;
    // homogeneous fleet: the mute device is guaranteed a coded load, so
    // calibration certainly probes it
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let addr = listener.local_addr().unwrap().to_string();

    let mut devices = Vec::new();
    for id in 0..cfg.n_devices - 1 {
        let addr = addr.clone();
        devices.push(std::thread::spawn(move || {
            run_device(&addr, id, Duration::from_secs(5))
        }));
    }
    // one device joins, then goes mute: it reads everything and answers
    // nothing — the socket stays open, so no Gone ever arrives and only
    // calibration silence can unmask it
    let mute_id = cfg.n_devices - 1;
    let mute_addr = addr.clone();
    let mute = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(&mute_addr).unwrap();
        let hello = FromDevice::Hello { device_id: mute_id, protocol: PROTOCOL_VERSION };
        write_frame(&mut s, &encode_from_device(&hello)).unwrap();
        while let Ok(Some(_)) = read_frame(&mut s) {}
    });

    let tcp = TcpTransport::serve(listener, cfg.n_devices, Duration::from_secs(5)).unwrap();
    let mut live = LiveCoordinator::with_transport(&cfg, 1e-6, Box::new(tcp)).unwrap();
    // grace deliberately NOT pinned: the handshake is the liveness probe
    let started = Instant::now();
    let run = live.train_cfl().unwrap();
    let elapsed = started.elapsed();

    // pre-fix, the mute endpoint was pinged CALIBRATION_ROUNDS times and
    // charged 3 × 500 ms of dead waiting; now it is abandoned after one
    // silent round
    assert!(
        elapsed < Duration::from_millis(1300),
        "mute endpoint charged more than one calibration cap: {elapsed:?}"
    );
    assert_eq!(run.disconnects, 1, "calibration silence must count as a disconnect");
    assert_eq!(
        *run.epoch_members.last().unwrap(),
        cfg.n_devices - 1,
        "the mute endpoint must be excluded from the gather set"
    );
    assert_eq!(run.late_gradients, 0, "a never-broadcast device cannot go late");
    assert!(run.on_time_gradients >= ((cfg.n_devices - 1) * cfg.max_epochs) as u64);

    drop(live); // Shutdown closes the mute socket: the thread unblocks
    mute.join().unwrap();
    for h in devices {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn time_to_cover_is_nan_safe() {
    // a NaN finish time (degenerate delay draw) must sort, not panic —
    // and must never be mistaken for an early completion
    let t = super::sim::time_to_cover(
        vec![(f64::NAN, 10), (2.0, 10), (1.0, 10)],
        20,
    );
    assert_eq!(t, 2.0, "NaN must sort last, not first");
    let t = super::sim::time_to_cover(vec![(f64::NAN, 30)], 20);
    assert!(t.is_nan(), "an all-NaN cover keeps the NaN visible");
    assert_eq!(super::sim::time_to_cover(vec![(1.0, 5)], 20), f64::INFINITY);
}

/// Failure injection: a backend that errors after N calls.
struct FailingBackend {
    inner: NativeBackend,
    calls_left: std::cell::Cell<u32>,
}

impl crate::fl::GradBackend for FailingBackend {
    fn partial_grad(
        &mut self,
        x: &crate::linalg::Mat,
        beta: &crate::linalg::Mat,
        y: &crate::linalg::Mat,
    ) -> anyhow::Result<crate::linalg::Mat> {
        let left = self.calls_left.get();
        anyhow::ensure!(left > 0, "injected backend failure");
        self.calls_left.set(left - 1);
        self.inner.partial_grad(x, beta, y)
    }
    fn parity_grad(
        &mut self,
        xt: &crate::linalg::Mat,
        beta: &crate::linalg::Mat,
        yt: &crate::linalg::Mat,
        c: usize,
    ) -> anyhow::Result<crate::linalg::Mat> {
        self.inner.parity_grad(xt, beta, yt, c)
    }
    fn encode(
        &mut self,
        g: &crate::linalg::Mat,
        w: &[f32],
        x: &crate::linalg::Mat,
        y: &crate::linalg::Mat,
    ) -> anyhow::Result<(crate::linalg::Mat, crate::linalg::Mat)> {
        self.inner.encode(g, w, x, y)
    }
    fn name(&self) -> &'static str {
        "failing"
    }
}

#[test]
fn backend_failure_propagates_cleanly() {
    let cfg = small_cfg();
    let backend = FailingBackend { inner: NativeBackend, calls_left: std::cell::Cell::new(30) };
    let mut sim = SimCoordinator::with_backend(&cfg, Box::new(backend)).unwrap();
    let err = sim.train_cfl().unwrap_err().to_string();
    assert!(err.contains("injected backend failure"), "lost error context: {err}");
}

// ---------------------------------------------------------------------
// million-device scale knobs: sampled participation, lean data, bounded
// traces, hierarchical aggregation

#[test]
fn participation_count_n_is_byte_identical_to_all() {
    // sampling every device is the no-sampling fast path: `count:<n>`
    // must reproduce the legacy `all` run bit for bit (same RNG
    // consumption, same float summation order)
    let base = small_cfg();
    let mut sampled = base.clone();
    sampled.participation = crate::config::Participation::Count(base.n_devices);
    let ra = SimCoordinator::new(&base).unwrap().train_cfl().unwrap();
    let rb = SimCoordinator::new(&sampled).unwrap().train_cfl().unwrap();
    assert_eq!(ra.setup_secs, rb.setup_secs);
    assert_eq!(ra.delta, rb.delta);
    assert_eq!(ra.parity_upload_bits, rb.parity_upload_bits);
    assert_eq!(ra.epoch_times, rb.epoch_times);
    assert_eq!(ra.trace.points.len(), rb.trace.points.len());
    for (pa, pb) in ra.trace.points.iter().zip(&rb.trace.points) {
        assert_eq!(pa.time_s, pb.time_s);
        assert_eq!(pa.nmse, pb.nmse);
    }
}

#[test]
fn sampled_participation_is_deterministic_and_changes_the_run() {
    let mut cfg = small_cfg();
    cfg.participation = crate::config::Participation::Count(3);
    cfg.max_epochs = 200;
    cfg.target_nmse = 0.0;
    let ra = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    let rb = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    assert_eq!(ra.epoch_times, rb.epoch_times, "sampling must be seed-deterministic");
    for (pa, pb) in ra.trace.points.iter().zip(&rb.trace.points) {
        assert_eq!(pa.nmse, pb.nmse);
    }
    // and it really is a different run than full participation
    let mut full_cfg = small_cfg();
    full_cfg.max_epochs = 200;
    full_cfg.target_nmse = 0.0;
    let full = SimCoordinator::new(&full_cfg).unwrap().train_cfl().unwrap();
    assert_ne!(ra.epoch_times, full.epoch_times, "count:3 of 8 must subsample epochs");
    // the n/k upscale keeps the estimator unbiased: a sampled run still
    // descends instead of stalling at NMSE 1
    assert!(ra.trace.final_nmse().unwrap() < 0.9, "sampled run did not learn");
}

#[test]
fn lean_mode_is_deterministic_and_learns() {
    let mut cfg = small_cfg();
    cfg.data_mode = crate::config::DataMode::Lean;
    let ra = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    let rb = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    assert_eq!(ra.epoch_times, rb.epoch_times, "lean streams must be seed-stable");
    for (pa, pb) in ra.trace.points.iter().zip(&rb.trace.points) {
        assert_eq!(pa.time_s, pb.time_s);
        assert_eq!(pa.nmse, pb.nmse);
    }
    assert!(
        ra.converged.is_some(),
        "lean CFL did not reach the target (final {:?})",
        ra.trace.final_nmse()
    );
}

#[test]
fn lean_mode_refuses_the_resident_dataset_paths() {
    let mut cfg = small_cfg();
    cfg.data_mode = crate::config::DataMode::Lean;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let err = sim.session().dataset().unwrap_err().to_string();
    assert!(err.contains("lean"), "unclear lean error: {err}");
    let err = sim.train_uncoded().unwrap_err().to_string();
    assert!(err.contains("skip-uncoded"), "missing remediation hint: {err}");
}

#[test]
fn trace_points_bounds_the_trace_and_keeps_the_ends() {
    let mut cfg = small_cfg();
    cfg.max_epochs = 500;
    cfg.target_nmse = 0.0;
    cfg.trace_points = 8;
    let run = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    let pts = &run.trace.points;
    assert!(pts.len() <= 2 * 8 + 1, "trace not bounded: {} points", pts.len());
    assert!(pts.len() >= 8, "decimated too aggressively: {} points", pts.len());
    assert_eq!(pts.first().unwrap().epoch, 0, "the setup point must survive");
    assert_eq!(pts.last().unwrap().epoch, 500, "the final epoch must survive");
    // the decimated trace samples the same trajectory the exact run walks
    let mut exact_cfg = cfg.clone();
    exact_cfg.trace_points = 0;
    let exact = SimCoordinator::new(&exact_cfg).unwrap().train_cfl().unwrap();
    assert_eq!(exact.trace.points.len(), 501);
    for p in pts {
        let full = exact.trace.points.iter().find(|q| q.epoch == p.epoch).unwrap();
        assert_eq!(p.nmse, full.nmse, "epoch {} diverged under decimation", p.epoch);
    }
}

#[test]
fn agg_fanin_tree_stays_on_the_flat_trajectory() {
    let mut cfg = small_cfg();
    cfg.max_epochs = 200;
    cfg.target_nmse = 0.0;
    let flat = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    cfg.agg_fanin = 4;
    let tree = SimCoordinator::new(&cfg).unwrap().train_cfl().unwrap();
    // same RNG consumption: the timing axis is bit-identical; only the
    // float association order of the gradient sum differs
    assert_eq!(flat.epoch_times, tree.epoch_times);
    let (a, b) = (flat.trace.final_nmse().unwrap(), tree.trace.final_nmse().unwrap());
    assert!(
        (a.log10() - b.log10()).abs() < 0.5,
        "fanin 4 diverged from flat: {a:.3e} vs {b:.3e}"
    );
}
