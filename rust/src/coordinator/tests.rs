use super::*;
use crate::config::ExperimentConfig;
use crate::fl::NativeBackend;

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.seed = 77;
    cfg
}

#[test]
fn cfl_converges_on_small_problem() {
    let mut sim = SimCoordinator::new(&small_cfg()).unwrap();
    let run = sim.train_cfl().unwrap();
    assert!(
        run.converged.is_some(),
        "CFL did not reach NMSE {} (final {:?})",
        small_cfg().target_nmse,
        run.trace.final_nmse()
    );
    assert!(run.setup_secs > 0.0, "parity upload must take time");
    assert!(run.parity_upload_bits > 0.0);
    assert!(run.delta > 0.0);
    assert!(run.epoch_deadline.is_finite());
    // trace times strictly increase by t* per epoch after setup
    let pts = &run.trace.points;
    assert!((pts[1].time_s - pts[0].time_s - run.epoch_deadline).abs() < 1e-9);
}

#[test]
fn uncoded_converges_and_has_no_setup() {
    let mut sim = SimCoordinator::new(&small_cfg()).unwrap();
    let run = sim.train_uncoded().unwrap();
    assert!(run.converged.is_some(), "uncoded did not converge");
    assert_eq!(run.setup_secs, 0.0);
    assert_eq!(run.parity_upload_bits, 0.0);
    assert_eq!(run.delta, 0.0);
    assert!(run.epoch_deadline.is_infinite());
    // epoch times vary (max of sampled delays) and are all positive
    assert!(run.epoch_times.iter().all(|&t| t > 0.0));
    let first = run.epoch_times[0];
    assert!(run.epoch_times.iter().any(|&t| (t - first).abs() > 1e-12));
}

#[test]
fn runs_are_seed_reproducible() {
    let mut a = SimCoordinator::new(&small_cfg()).unwrap();
    let mut b = SimCoordinator::new(&small_cfg()).unwrap();
    let ra = a.train_cfl().unwrap();
    let rb = b.train_cfl().unwrap();
    assert_eq!(ra.trace.points.len(), rb.trace.points.len());
    for (pa, pb) in ra.trace.points.iter().zip(&rb.trace.points) {
        assert_eq!(pa.time_s, pb.time_s);
        assert_eq!(pa.nmse, pb.nmse);
    }
}

#[test]
fn cfl_and_uncoded_reach_similar_floors() {
    // both are unbiased estimators of the same GD dynamics; their final
    // NMSE (epoch-limited) should land in the same decade
    let mut cfg = small_cfg();
    cfg.max_epochs = 2500;
    cfg.target_nmse = 0.0; // run to the epoch cap
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let coded = sim.train_cfl().unwrap();
    let uncoded = sim.train_uncoded().unwrap();
    let (nc, nu) = (coded.trace.final_nmse().unwrap(), uncoded.trace.final_nmse().unwrap());
    assert!(nc < 1e-2, "coded floor too high: {nc:.2e}");
    assert!(nu < 1e-2, "uncoded floor too high: {nu:.2e}");
    assert!((nc.log10() - nu.log10()).abs() < 1.5, "floors diverge: {nc:.2e} vs {nu:.2e}");
}

#[test]
fn fixed_delta_is_respected() {
    let mut cfg = small_cfg();
    cfg.delta = Some(0.15);
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let run = sim.train_cfl().unwrap();
    assert!((run.delta - 0.15).abs() < 0.01, "delta {} != 0.15", run.delta);
}

#[test]
fn gather_mc_times_recorded_per_epoch() {
    let mut cfg = small_cfg();
    cfg.max_epochs = 50;
    cfg.target_nmse = 0.0;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let run = sim.train_cfl().unwrap();
    assert_eq!(run.gather_mc_times.len(), run.epoch_times.len());
    // finite gathers must be positive
    assert!(run.gather_mc_times.iter().all(|&t| t > 0.0));
}

#[test]
fn ls_bound_is_below_targets() {
    let sim = SimCoordinator::new(&small_cfg()).unwrap();
    let ls = sim.ls_bound().unwrap();
    assert!(ls > 0.0 && ls < small_cfg().target_nmse, "LS bound {ls:.3e} not a floor");
}

#[test]
fn with_backend_injection_works() {
    let sim = SimCoordinator::with_backend(&small_cfg(), Box::new(NativeBackend)).unwrap();
    assert_eq!(sim.backend_name(), "native");
}

#[test]
fn invalid_config_rejected() {
    let mut cfg = small_cfg();
    cfg.nu_comp = 1.5;
    assert!(SimCoordinator::new(&cfg).is_err());
}

#[test]
fn live_coordinator_runs_and_learns() {
    let mut cfg = small_cfg();
    cfg.n_devices = 4;
    cfg.points_per_device = 40;
    cfg.model_dim = 16;
    let live = LiveCoordinator::new(&cfg, 1e-4);
    let report = live.run(40).unwrap();
    assert_eq!(report.epochs, 40);
    assert!(report.final_nmse < 0.9, "live run did not learn: {}", report.final_nmse);
    assert!(report.on_time_gradients > 0, "no gradients arrived on time");
    assert!(report.wall_secs < 60.0);
}

/// Failure injection: a backend that errors after N calls.
struct FailingBackend {
    inner: NativeBackend,
    calls_left: std::cell::Cell<u32>,
}

impl crate::fl::GradBackend for FailingBackend {
    fn partial_grad(
        &mut self,
        x: &crate::linalg::Mat,
        beta: &crate::linalg::Mat,
        y: &crate::linalg::Mat,
    ) -> anyhow::Result<crate::linalg::Mat> {
        let left = self.calls_left.get();
        anyhow::ensure!(left > 0, "injected backend failure");
        self.calls_left.set(left - 1);
        self.inner.partial_grad(x, beta, y)
    }
    fn parity_grad(
        &mut self,
        xt: &crate::linalg::Mat,
        beta: &crate::linalg::Mat,
        yt: &crate::linalg::Mat,
        c: usize,
    ) -> anyhow::Result<crate::linalg::Mat> {
        self.inner.parity_grad(xt, beta, yt, c)
    }
    fn encode(
        &mut self,
        g: &crate::linalg::Mat,
        w: &[f32],
        x: &crate::linalg::Mat,
        y: &crate::linalg::Mat,
    ) -> anyhow::Result<(crate::linalg::Mat, crate::linalg::Mat)> {
        self.inner.encode(g, w, x, y)
    }
    fn name(&self) -> &'static str {
        "failing"
    }
}

#[test]
fn backend_failure_propagates_cleanly() {
    let cfg = small_cfg();
    let backend = FailingBackend { inner: NativeBackend, calls_left: std::cell::Cell::new(30) };
    let mut sim = SimCoordinator::with_backend(&cfg, Box::new(backend)).unwrap();
    let err = sim.train_cfl().unwrap_err().to_string();
    assert!(err.contains("injected backend failure"), "lost error context: {err}");
}
