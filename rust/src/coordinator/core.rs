//! Shared coordinator core: the setup phase and result vocabulary that
//! every training backend consumes.
//!
//! The paper's CFL scheme is *one* protocol — draw private codes, encode
//! and upload parity once (§III-A), then run a deadline-gathered epoch
//! loop with the master's redundant parity gradient standing in for
//! stragglers (Eqs. 18–19). The repo offers two executions of that
//! protocol ([`SimCoordinator`] on simulated time, [`LiveCoordinator`] on
//! real threads), and everything execution-independent lives here:
//!
//! * [`Session`] — the frozen problem instance: config, fleet, the
//!   training data (a materialized dataset + shards, or — in
//!   `data_mode = lean` — per-shard generator descriptors that
//!   rematerialize rows on demand), and the root randomness stream.
//!   Both coordinators build their setup phase from it, so parity/shard
//!   state is identical by construction for a given seed.
//! * [`CflSetup`] / [`DeviceSetup`] — the output of the §III-A setup
//!   phase: the master's composite parity set, each device's frozen
//!   systematic submatrix, and the setup-time accounting.
//! * [`RunResult`] — the unified outcome of one training run, shared by
//!   both backends so sweep reports render them in one CSV.
//! * [`Coordinator`] / [`CoordinatorKind`] — the backend abstraction the
//!   [`crate::sweep`] runner drives: `cfl sweep --live` is just the same
//!   grid executed through [`CoordinatorKind::Live`].
//!
//! ```
//! use cfl::config::ExperimentConfig;
//! use cfl::coordinator::{Coordinator, CoordinatorKind};
//!
//! let mut cfg = ExperimentConfig::small();
//! cfg.max_epochs = 5;
//! cfg.target_nmse = 0.0; // run all 5 epochs
//! let mut sim = CoordinatorKind::Sim.build(&cfg).unwrap();
//! let run = sim.train_cfl().unwrap();
//! assert_eq!(run.epoch_times.len(), 5);
//! ```
//!
//! [`SimCoordinator`]: crate::coordinator::SimCoordinator
//! [`LiveCoordinator`]: crate::coordinator::LiveCoordinator

use super::{LiveCoordinator, SimCoordinator};
use crate::coding::{CompositeParity, DeviceCode};
use crate::config::{DataMode, ExperimentConfig};
use crate::data::{shard_sizes, split, Dataset, LeanDataset, Shard};
use crate::fl::GradBackend;
use crate::lb::{optimize, optimize_fixed_c, LoadPolicy};
use crate::linalg::{solve_ls, Mat};
use crate::metrics::{BoundedTraceLog, ConvergenceTrace};
use crate::rng::Rng;
use crate::simnet::Fleet;
use crate::transport::{TcpTransport, TransportKind};
use anyhow::Result;

/// Outcome of one training run (one curve of Fig. 2, one cell of
/// Fig. 4/5) — the result vocabulary shared by every backend.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    /// NMSE vs simulated time (time includes `setup_secs` for CFL — the
    /// Fig. 2 initial offsets). The live backend uses the same axis with
    /// the same accounting — coded epochs advance by the policy deadline
    /// t*, uncoded epochs by the slowest device's modeled delay — so both
    /// backends plot on one chart; host overheads show up only in
    /// `wall_secs`.
    ///
    /// With `trace_points = 0` (the default) every epoch is a point.
    /// With `trace_points = N > 0` the sim backend records through a
    /// [`BoundedTraceLog`]: at most `2N + 1` evenly-strided points are
    /// kept, always including the first and last epoch — million-epoch
    /// runs keep a bounded, plot-faithful curve instead of an O(epochs)
    /// vector. `converged` and the epoch counters are exact either way
    /// (they are tracked outside the trace).
    pub trace: ConvergenceTrace,
    /// Per-epoch gather durations (Fig. 3 histograms), simulated seconds.
    pub epoch_times: Vec<f64>,
    /// One-time parity-transfer delay before epoch 0 (0 for uncoded).
    pub setup_secs: f64,
    /// Bits uploaded as parity during setup (0 for uncoded).
    pub parity_upload_bits: f64,
    /// Round-trip model/gradient bits per epoch, summed over devices.
    pub per_epoch_bits: f64,
    /// (epoch, simulated time) at which `target_nmse` was first reached.
    pub converged: Option<(usize, f64)>,
    /// δ actually used (0 for uncoded).
    pub delta: f64,
    /// t* actually used (∞ for uncoded).
    pub epoch_deadline: f64,
    /// For CFL: per-epoch times until the devices alone had returned
    /// m − c points (Fig. 3 bottom); +∞ when an epoch never got there.
    /// Only the DES backend computes this diagnostic (empty otherwise).
    pub gather_mc_times: Vec<f64>,
    /// Real seconds the run took on the host (the DES backend's virtual
    /// clock is `trace`; this is wall time in both backends).
    pub wall_secs: f64,
    /// Device gradients that arrived within their epoch's deadline.
    pub on_time_gradients: u64,
    /// Device gradients scheduled/sent but missed by the gather.
    pub late_gradients: u64,
    /// Per-epoch gather-set size, aligned with `trace.points` (entry 0 is
    /// the fleet participating at setup; entry k > 0 is how many devices
    /// epoch k's broadcast actually reached). Under churn this dips when
    /// a device disconnects and recovers when it rejoins — the membership
    /// column of the exported trace.
    pub epoch_members: Vec<usize>,
    /// Mid-session device disconnects observed (live backend; 0 for sim).
    pub disconnects: u64,
    /// Devices re-admitted after a disconnect (live backend; 0 for sim).
    pub rejoins: u64,
    /// Per-phase host wall-clock digests (count/total/p50/p95 for parity
    /// encode, local gradient, gather, aggregation, calibration) — the
    /// profile behind the bench JSON's `phases` object and the
    /// `cfl bench-check` wall-clock gate. Empty only for hand-built
    /// results.
    pub phases: Vec<crate::obs::PhaseSummary>,
}

impl RunResult {
    /// Convergence time to a target NMSE (Figs. 4/5 metric).
    pub fn time_to(&self, target: f64) -> Option<f64> {
        self.trace.time_to_nmse(target)
    }

    /// The per-epoch convergence trace (same simulated-seconds axis for
    /// both backends — the live/sim trace-export parity contract).
    pub fn trace(&self) -> &ConvergenceTrace {
        &self.trace
    }

    /// Write the per-epoch `time_s,epoch,nmse,members` trace as CSV — the
    /// per-scenario export behind `cfl sweep --traces-dir` and the
    /// `cfl train` trace files, identical for sim and live runs. The
    /// `members` column is the epoch's gather-set size, so churn (a
    /// device dropping to parity-only coverage, then rejoining) is
    /// visible directly in the trace.
    pub fn write_trace_csv(&self, path: &str) -> Result<()> {
        self.write_trace_csv_decimated(path, 1)
    }

    /// [`RunResult::write_trace_csv`] keeping only every `every`-th row
    /// plus the final one (row 0 always survives, so the first and last
    /// points of the curve are always present; `every == 1` keeps all).
    /// This is `cfl sweep --trace-decimate N`: million-scenario grids
    /// keep their convergence *shape* on disk without drowning in rows.
    pub fn write_trace_csv_decimated(&self, path: &str, every: usize) -> Result<()> {
        anyhow::ensure!(every >= 1, "trace decimation stride must be ≥ 1, got {every}");
        let n = self.trace.points.len();
        let keep = |i: usize| i % every == 0 || i + 1 == n;
        if self.epoch_members.len() == n {
            let mut w = crate::metrics::CsvWriter::create(
                path,
                &["time_s", "epoch", "nmse", "members"],
            )?;
            for (i, (p, &m)) in self.trace.points.iter().zip(&self.epoch_members).enumerate() {
                if keep(i) {
                    w.write_row(&[p.time_s, p.epoch as f64, p.nmse, m as f64])?;
                }
            }
            w.flush()
        } else {
            // membership unknown (hand-built results): classic 3 columns
            let mut w =
                crate::metrics::CsvWriter::create(path, &["time_s", "epoch", "nmse"])?;
            for (i, p) in self.trace.points.iter().enumerate() {
                if keep(i) {
                    w.write_row(&[p.time_s, p.epoch as f64, p.nmse])?;
                }
            }
            w.flush()
        }
    }
}

/// Per-device state frozen at setup time (§III-A).
pub struct DeviceSetup {
    /// Systematic submatrix (the rows processed each epoch), ℓᵢ*×d —
    /// rows in the device's private permutation order.
    pub x_sys: Mat,
    pub y_sys: Mat,
    /// Assigned systematic load ℓᵢ*(t*).
    pub load: usize,
    /// Backend fast-path handle (PJRT: device-resident buffers) — §Perf.
    pub handle: Option<u64>,
}

/// Everything the §III-A setup phase produces: what the master holds
/// (composite parity), what each device holds (systematic shard), and
/// what the one-time parity upload cost.
pub struct CflSetup {
    /// The master's composite parity set (Eq. 10 sum over devices).
    pub composite: CompositeParity,
    /// Per-device frozen systematic state, index-aligned with the fleet.
    pub devices: Vec<DeviceSetup>,
    /// Simulated seconds until the slowest parity upload completed
    /// (uploads run in parallel — the Fig. 2 initial offsets).
    pub setup_secs: f64,
    /// Total bits uploaded as parity across all devices.
    pub parity_upload_bits: f64,
}

/// The session's training data in one of two residency modes.
enum SessionData {
    /// The classic layout: the full m×d dataset plus per-device shard
    /// slices, all resident (what every pre-scale release produced —
    /// byte-identical for a given seed).
    Materialized { dataset: Dataset, shards: Vec<Shard> },
    /// `data_mode = lean`: per-shard generator descriptors; rows are
    /// rematerialized on demand and dropped after use (million-device
    /// fleets). Same distribution, different RNG stream — lean bytes are
    /// *not* comparable to materialized bytes.
    Lean(LeanDataset),
}

/// The frozen problem instance both coordinators consume: one seed ⇒ one
/// fleet, one dataset, one sharding, and one stream of per-run RNGs.
///
/// Construction performs the setup steps [`SimCoordinator`] and
/// [`LiveCoordinator`] used to duplicate: validate the config, build the
/// §IV heterogeneity fleet, generate (or, in lean mode, *describe*) the
/// regression problem, and split it into per-device shards.
/// [`Session::build_setup`] then runs the §III-A coding phase against any
/// [`GradBackend`].
///
/// [`SimCoordinator`]: crate::coordinator::SimCoordinator
/// [`LiveCoordinator`]: crate::coordinator::LiveCoordinator
pub struct Session {
    pub cfg: ExperimentConfig,
    pub fleet: Fleet,
    data: SessionData,
    root_rng: Rng,
    run_counter: u64,
}

impl Session {
    /// Build the problem instance from a config: fleet ladders, dataset,
    /// shard split — all drawn from `cfg.seed` in a fixed order.
    ///
    /// `data_mode = materialized` consumes exactly the draws previous
    /// releases consumed, so existing results stay byte-identical;
    /// `data_mode = lean` keeps only descriptors (no m×d matrix is ever
    /// resident).
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let mut root_rng = Rng::new(cfg.seed);
        let mut fleet = Fleet::from_config(cfg, &mut root_rng);
        let data = match cfg.data_mode {
            DataMode::Materialized => {
                let dataset = Dataset::generate(
                    cfg.total_points(),
                    cfg.model_dim,
                    cfg.snr_db,
                    &mut root_rng,
                );
                let sizes =
                    shard_sizes(cfg.sharding, cfg.total_points(), cfg.n_devices, &mut root_rng);
                fleet.set_points(&sizes);
                let shards = split(&dataset, &sizes);
                SessionData::Materialized { dataset, shards }
            }
            DataMode::Lean => {
                let sizes =
                    shard_sizes(cfg.sharding, cfg.total_points(), cfg.n_devices, &mut root_rng);
                fleet.set_points(&sizes);
                SessionData::Lean(LeanDataset::new(
                    cfg.model_dim,
                    cfg.snr_db,
                    sizes,
                    &mut root_rng,
                ))
            }
        };
        crate::obs::registry().gauge("fleet.devices").set(fleet.n_devices() as f64);
        Ok(Self { cfg: cfg.clone(), fleet, data, root_rng, run_counter: 0 })
    }

    /// The fully materialized dataset — available only in
    /// `data_mode = materialized` (lean sessions never hold it).
    pub fn dataset(&self) -> Result<&Dataset> {
        match &self.data {
            SessionData::Materialized { dataset, .. } => Ok(dataset),
            SessionData::Lean(_) => anyhow::bail!(
                "the full dataset is not resident in data_mode = lean \
                 (use data_mode = materialized)"
            ),
        }
    }

    /// The resident per-device shards — available only in
    /// `data_mode = materialized`.
    pub fn shards(&self) -> Result<&[Shard]> {
        match &self.data {
            SessionData::Materialized { shards, .. } => Ok(shards),
            SessionData::Lean(_) => anyhow::bail!(
                "shards are not resident in data_mode = lean \
                 (use Session::lean to stream shard views)"
            ),
        }
    }

    /// The lean descriptor set, when `data_mode = lean`.
    pub fn lean(&self) -> Option<&LeanDataset> {
        match &self.data {
            SessionData::Lean(lean) => Some(lean),
            SessionData::Materialized { .. } => None,
        }
    }

    /// Ground-truth model β* — the NMSE reference, resident in both modes.
    pub fn beta_star(&self) -> &Mat {
        match &self.data {
            SessionData::Materialized { dataset, .. } => &dataset.beta_star,
            SessionData::Lean(lean) => lean.beta_star(),
        }
    }

    /// Rows held by device `i`'s shard (both modes).
    pub fn shard_rows(&self, i: usize) -> usize {
        match &self.data {
            SessionData::Materialized { shards, .. } => shards[i].rows(),
            SessionData::Lean(lean) => lean.shard_rows(i),
        }
    }

    /// Fresh RNG stream per run so `train_cfl(); train_uncoded()` order
    /// doesn't couple their noise.
    pub fn run_rng(&mut self) -> Rng {
        self.run_counter += 1;
        self.root_rng.split(0x5EED_0000 + self.run_counter)
    }

    /// Solve the CFL load/redundancy policy: `cfg.delta = None` runs the
    /// full Eq. 16 optimization; `Some(δ)` pins c = δ·m (Fig. 2/5 sweeps).
    pub fn policy(&self) -> Result<LoadPolicy> {
        let m = self.fleet.total_points();
        match self.cfg.delta {
            None => {
                let c_up = (self.cfg.c_up_fraction * m as f64).round() as usize;
                optimize(&self.fleet, c_up, self.cfg.epsilon)
            }
            Some(delta) => {
                let c = (delta * m as f64).round() as usize;
                anyhow::ensure!(c > 0, "delta={delta} gives zero parity rows; use train_uncoded");
                optimize_fixed_c(&self.fleet, c, self.cfg.epsilon)
            }
        }
    }

    /// Closed-form least-squares NMSE — the Fig. 2 lower bound. Requires
    /// the materialized dataset (a lean session would have to regenerate
    /// all m rows to form the normal equations, defeating its purpose).
    pub fn ls_bound(&self) -> Result<f64> {
        let dataset = self.dataset().map_err(|_| {
            anyhow::anyhow!(
                "ls_bound needs the full dataset resident; \
                 data_mode = lean does not support it"
            )
        })?;
        let ls = solve_ls(&dataset.x, &dataset.y)?;
        Ok(ls.nmse(&dataset.beta_star))
    }

    /// Bits of one parity row: d features + 1 label, with header overhead.
    pub fn parity_row_bits(&self) -> f64 {
        (self.cfg.model_dim as f64 + 1.0) * 32.0 * (1.0 + self.cfg.header_overhead)
    }

    /// Round-trip traffic per epoch: every participating device downloads
    /// the model and uploads a gradient (2 packets).
    pub fn round_trip_bits(&self, loads: &[usize]) -> f64 {
        loads.iter().filter(|&&l| l > 0).count() as f64 * 2.0 * self.fleet.packet_bits
    }

    /// CFL setup phase (§III-A): draw each device's private code, encode
    /// and accumulate parity into the master's composite set, account the
    /// upload time, and freeze the systematic submatrices.
    ///
    /// Per-device RNG draw order (code, then upload sample) is fixed, so
    /// a given `(seed, policy)` yields byte-identical setup state no
    /// matter which coordinator consumes it.
    ///
    /// In lean mode each shard is rematerialized just long enough to
    /// encode its parity, then dropped; `x_sys`/`y_sys` stay empty
    /// (devices regenerate their ℓᵢ-row prefix per epoch instead), so
    /// peak residency during setup is one shard, not the fleet.
    pub fn build_setup(
        &self,
        policy: &LoadPolicy,
        backend: &mut dyn GradBackend,
        rng: &mut Rng,
    ) -> Result<CflSetup> {
        let d = self.cfg.model_dim;
        let c = policy.parity_rows;
        let n = self.fleet.n_devices();
        let mut composite = CompositeParity::zeros(c, d);
        let mut devices = Vec::with_capacity(n);
        let mut setup_secs = 0.0f64;
        let mut parity_bits = 0.0f64;
        let row_bits = self.parity_row_bits();
        let rows_counter = crate::obs::registry().counter("data.rows_materialized");

        for i in 0..n {
            let load = policy.device_loads[i];
            let points = self.shard_rows(i);
            let (code, owned_shard);
            let (shard_x, shard_y): (&Mat, &Mat) = match &self.data {
                SessionData::Materialized { shards, .. } => {
                    code = DeviceCode::draw(
                        points,
                        c,
                        load,
                        policy.miss_probs[i],
                        self.cfg.generator,
                        rng,
                    );
                    (&shards[i].x, &shards[i].y)
                }
                SessionData::Lean(lean) => {
                    code = DeviceCode::draw_prefix(
                        points,
                        c,
                        load,
                        policy.miss_probs[i],
                        self.cfg.generator,
                        rng,
                    );
                    owned_shard = lean.shard(i);
                    rows_counter.add(points as u64);
                    (&owned_shard.x, &owned_shard.y)
                }
            };
            let (xt, yt) = backend.encode(&code.generator, &code.weights, shard_x, shard_y)?;
            composite.accumulate(&xt, &yt);

            // parity upload: c rows over this device's link, all devices in
            // parallel → setup time is the slowest upload (Fig. 2 offsets)
            let upload = self.fleet.sample_parity_upload_secs(i, c, row_bits, rng);
            setup_secs = setup_secs.max(upload);
            parity_bits += c as f64 * row_bits;

            let setup = match &self.data {
                SessionData::Materialized { .. } => {
                    // freeze the systematic submatrix (private permutation
                    // order)
                    let mut x_sys = Mat::zeros(load, d);
                    let mut y_sys = Mat::zeros(load, 1);
                    for (r, &src) in code.systematic_rows().iter().enumerate() {
                        x_sys.row_mut(r).copy_from_slice(shard_x.row(src));
                        y_sys[(r, 0)] = shard_y[(src, 0)];
                    }
                    let handle =
                        if load > 0 { backend.register_shard(&x_sys, &y_sys)? } else { None };
                    DeviceSetup { x_sys, y_sys, load, handle }
                }
                SessionData::Lean(_) => {
                    // the systematic set is the shard's ℓᵢ-row prefix
                    // (identity permutation); it is streamed per epoch,
                    // never frozen
                    DeviceSetup {
                        x_sys: Mat::zeros(0, d),
                        y_sys: Mat::zeros(0, 1),
                        load,
                        handle: None,
                    }
                }
            };
            devices.push(setup);
        }
        Ok(CflSetup { composite, devices, setup_secs, parity_upload_bits: parity_bits })
    }

    /// Start a labelled trace at the post-setup instant with the model's
    /// initial NMSE — epoch 0 of every backend's curve.
    pub fn start_trace(&self, label: String, setup_secs: f64, nmse0: f64) -> ConvergenceTrace {
        let mut trace = ConvergenceTrace::new(label);
        trace.push(setup_secs, 0, nmse0);
        trace
    }

    /// [`Session::start_trace`] as a bounded recorder honouring
    /// `cfg.trace_points` (the sim backend's path; `trace_points = 0`
    /// keeps every epoch and finishes byte-identical to the plain trace).
    pub fn start_trace_log(&self, label: String, setup_secs: f64, nmse0: f64) -> BoundedTraceLog {
        let mut log = BoundedTraceLog::new(label, self.cfg.trace_points);
        log.push(setup_secs, 0, nmse0);
        log
    }
}

/// Backend-agnostic training driver: the contract the sweep runner (and
/// any other multi-scenario caller) programs against. Implemented by
/// [`SimCoordinator`] (DES virtual time — deterministic, the figures'
/// path) and [`LiveCoordinator`] (threads + wall clock).
///
/// [`SimCoordinator`]: crate::coordinator::SimCoordinator
/// [`LiveCoordinator`]: crate::coordinator::LiveCoordinator
pub trait Coordinator {
    /// Short backend tag ("sim" / "live"), rendered in sweep reports.
    fn kind(&self) -> &'static str;

    /// The Eq. 13–16 policy this coordinator's CFL runs will use.
    fn policy(&self) -> Result<LoadPolicy>;

    /// Train CFL (§III) under the session's config.
    fn train_cfl(&mut self) -> Result<RunResult>;

    /// Train the uncoded-FL baseline (wait-for-all gather, no parity).
    fn train_uncoded(&mut self) -> Result<RunResult>;
}

/// Which [`Coordinator`] backend to instantiate per scenario — the
/// sweep-facing factory behind `cfl sweep --live`.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum CoordinatorKind {
    /// Discrete-event-simulated time (deterministic per seed; parallel
    /// sweeps are byte-identical to serial ones).
    #[default]
    Sim,
    /// Live cluster: simulated delays slept out at `time_scale`
    /// wall-seconds per simulated second, over a real device transport.
    /// Wall-clock scheduling makes outcomes *not* bit-reproducible
    /// across runs.
    Live {
        /// Simulated-seconds → wall-seconds factor (e.g. 1e-3 runs a 5 s
        /// simulated deadline as 5 ms of real sleep).
        time_scale: f64,
        /// How the fleet is reached: in-process channel threads
        /// (default), or TCP loopback subprocesses spawned per scenario
        /// (`cfl sweep --live --transport tcp`).
        transport: TransportKind,
        /// Cross-host slot manifest (`--placement <file>`, TCP only):
        /// bind the manifest's address, host its `local` slots in one
        /// child process, await its remote slots. `None` keeps the
        /// self-contained loopback fleet.
        placement: Option<crate::transport::Placement>,
    },
}

impl CoordinatorKind {
    /// The tag [`Coordinator::kind`] of the built backend will report.
    pub fn tag(&self) -> &'static str {
        match self {
            CoordinatorKind::Sim => "sim",
            CoordinatorKind::Live { .. } => "live",
        }
    }

    /// Build a coordinator of this kind over a fresh [`Session`] for
    /// `cfg`.
    pub fn build(&self, cfg: &ExperimentConfig) -> Result<Box<dyn Coordinator>> {
        Ok(match self {
            CoordinatorKind::Sim => Box::new(SimCoordinator::new(cfg)?),
            CoordinatorKind::Live { time_scale, transport: TransportKind::Channel, placement } => {
                anyhow::ensure!(
                    placement.is_none(),
                    "--placement requires --transport tcp (a channel fleet has no hosts to place)"
                );
                Box::new(LiveCoordinator::new(cfg, *time_scale)?)
            }
            CoordinatorKind::Live { time_scale, transport: TransportKind::Tcp, placement } => {
                // one fleet per scenario: placement-described when a
                // manifest is given, else a self-contained loopback fleet
                // (bind an ephemeral port, spawn `cfl device` children)
                let bin = crate::transport::local_device_bin()?;
                let tcp = match placement {
                    Some(p) => TcpTransport::spawn_placed(&bin, cfg.n_devices, p)?,
                    None => TcpTransport::spawn_local(&bin, cfg.n_devices)?,
                };
                Box::new(LiveCoordinator::with_transport(cfg, *time_scale, Box::new(tcp))?)
            }
        })
    }
}
