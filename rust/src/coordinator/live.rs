//! Live (threaded) coordinator: real concurrency, wall-clock deadlines.
//!
//! One `std::thread` per device; each epoch the master broadcasts the
//! model over channels, device workers compute their partial gradient
//! (native kernels — each worker owns its systematic shard), sleep out
//! their *simulated* residual delay scaled by `time_scale`, and send the
//! gradient back. The master gathers until the scaled deadline, computes
//! the parity gradient meanwhile, and updates the model.
//!
//! This is the deployment-shaped path: it demonstrates that the epoch
//! logic (deadline gather + Eq. 18/19 assembly) is driven by real message
//! arrival, not by simulator bookkeeping. The DES coordinator remains the
//! source of the paper's figures (its virtual clock is exact), but both
//! backends now build the §III-A setup phase from the same
//! [`Session`] and report the same [`RunResult`] vocabulary, so
//! `cfl sweep --live` renders live grids with the sim reports unchanged.

use super::core::{Coordinator, RunResult, Session};
use crate::coding::CompositeParity;
use crate::config::ExperimentConfig;
use crate::fl::{assemble_coded_gradient, GlobalModel, GradBackend, NativeBackend};
use crate::lb::LoadPolicy;
use crate::linalg::Mat;
use anyhow::Result;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Ceiling on any single scaled sleep/deadline, keeping demos snappy even
/// when a heavy-tailed delay draw meets a large `time_scale`.
const MAX_SCALED_SECS: f64 = 0.25;

/// Wall-clock cap on an uncoded wait-for-all gather (only reached if a
/// device worker dies mid-run).
const WAIT_ALL_TIMEOUT: Duration = Duration::from_secs(30);

enum ToDevice {
    /// (epoch, β) — compute and reply.
    Model(usize, Mat),
    Stop,
}

struct FromDevice {
    epoch: usize,
    grad: Mat,
    /// The §II-A delay this reply simulated (uncapped), simulated seconds.
    delay: f64,
}

/// Threaded master/worker training loop over a shared [`Session`].
pub struct LiveCoordinator {
    session: Session,
    /// Simulated-seconds → wall-seconds factor (e.g. 1e-3 runs a 5 s
    /// simulated deadline as 5 ms of real sleep).
    pub time_scale: f64,
    /// Fixed wall-clock grace added to every epoch deadline to absorb the
    /// *host's* overheads (thread wakeup, channel hop, the real gradient
    /// GEMM) which exist on top of the simulated delays being slept out.
    pub grace: Duration,
}

impl LiveCoordinator {
    /// Build the coordinator over a fresh [`Session`] for `cfg`.
    pub fn new(cfg: &ExperimentConfig, time_scale: f64) -> Result<Self> {
        anyhow::ensure!(
            time_scale.is_finite() && time_scale > 0.0,
            "time_scale must be a positive finite factor"
        );
        // fail loudly rather than run a client-selection config as full
        // participation — the §V extension is implemented by the DES
        // backend only
        anyhow::ensure!(
            cfg.client_fraction >= 1.0,
            "the live coordinator does not implement client selection \
             (client_fraction = {}); use the sim backend",
            cfg.client_fraction
        );
        Ok(Self { session: Session::new(cfg)?, time_scale, grace: Duration::from_millis(8) })
    }

    /// The shared problem instance (config, fleet, dataset, shards).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Solve the CFL load/redundancy policy (see [`Session::policy`]).
    pub fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    /// Run live CFL for up to `cfg.max_epochs` epochs (early-stops at
    /// `cfg.target_nmse`, like the DES backend).
    pub fn train_cfl(&mut self) -> Result<RunResult> {
        let policy = self.session.policy()?;
        self.run_with(&policy, true)
    }

    /// Run the live uncoded baseline: full shards, no parity, the master
    /// waits for every device's gradient each epoch.
    pub fn train_uncoded(&mut self) -> Result<RunResult> {
        let policy = LoadPolicy::uncoded(&self.session.fleet);
        self.run_with(&policy, false)
    }

    /// The shared master/worker loop. `coded` selects the §III-A setup +
    /// deadline gather; uncoded runs full shards with a wait-for-all
    /// gather (and no setup offset).
    fn run_with(&mut self, policy: &LoadPolicy, coded: bool) -> Result<RunResult> {
        // wall_secs spans setup + training in both backends
        let started = Instant::now();
        let mut rng = self.session.run_rng();
        let mut backend = NativeBackend;

        // --- setup phase: shared Session construction ---------------------
        // (device index, x_sys, y_sys, load) — zero-load devices are fully
        // punctured and get no worker, mirroring the DES backend's skip
        type WorkerState = (usize, Mat, Mat, usize);
        let (worker_states, composite, setup_secs, parity_bits): (
            Vec<WorkerState>,
            Option<CompositeParity>,
            f64,
            f64,
        ) = if coded {
            let setup = self.session.build_setup(policy, &mut backend, &mut rng)?;
            let devices: Vec<WorkerState> = setup
                .devices
                .into_iter()
                .enumerate()
                .filter(|(_, s)| s.load > 0)
                .map(|(i, s)| (i, s.x_sys, s.y_sys, s.load))
                .collect();
            (devices, Some(setup.composite), setup.setup_secs, setup.parity_upload_bits)
        } else {
            let devices: Vec<WorkerState> = self
                .session
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| (i, s.x.clone(), s.y.clone(), s.rows()))
                .collect();
            (devices, None, 0.0, 0.0)
        };

        let cfg = &self.session.cfg;
        let d = cfg.model_dim;
        let m = self.session.fleet.total_points();
        let c = policy.parity_rows;
        let scale = self.time_scale;

        // --- spawn device workers ----------------------------------------
        let (to_master, from_devices) = mpsc::channel::<FromDevice>();
        let mut to_devices = Vec::new();
        let mut handles = Vec::new();
        for (i, x_sys, y_sys, load) in worker_states {
            let (tx, rx) = mpsc::channel::<ToDevice>();
            to_devices.push(tx);
            let master_tx = to_master.clone();
            let profile = self.session.fleet.devices[i];
            // split() keys on the device index alone, so skipping punctured
            // devices doesn't shift anyone else's stream
            let mut dev_rng = rng.split(0xD0_0000 + i as u64);
            handles.push(thread::spawn(move || {
                let mut be = NativeBackend;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ToDevice::Stop => break,
                        ToDevice::Model(epoch, beta) => {
                            let grad = be
                                .partial_grad(&x_sys, &beta, &y_sys)
                                .expect("device gradient");
                            // sleep out the simulated delay (compute+link)
                            let delay = profile.sample_total_delay(load, &mut dev_rng);
                            thread::sleep(Duration::from_secs_f64(
                                (delay * scale).min(MAX_SCALED_SECS),
                            ));
                            // master may have dropped the channel at stop
                            let _ = master_tx.send(FromDevice { epoch, grad, delay });
                        }
                    }
                }
            }));
        }
        drop(to_master);

        // --- epoch loop ----------------------------------------------------
        let n_workers = to_devices.len();
        let mut model = GlobalModel::zeros(d, cfg.learning_rate, m);
        let label = if coded {
            format!("live cfl δ={:.3}", policy.delta)
        } else {
            "live uncoded".to_string()
        };
        let mut trace = self.session.start_trace(
            label.clone(),
            setup_secs,
            model.nmse(&self.session.dataset.beta_star),
        );
        let deadline_wall = if coded {
            Duration::from_secs_f64((policy.epoch_deadline * scale).min(MAX_SCALED_SECS))
                + self.grace
        } else {
            WAIT_ALL_TIMEOUT
        };
        let mut epoch_times = Vec::new();
        let mut converged = None;
        let mut late = 0u64;
        let mut on_time = 0u64;
        let mut now = setup_secs;

        for epoch in 0..cfg.max_epochs {
            let epoch_start = Instant::now();
            for tx in &to_devices {
                // a worker that panicked would sever its channel; surface that
                tx.send(ToDevice::Model(epoch, model.beta.clone()))
                    .map_err(|_| anyhow::anyhow!("device worker died"))?;
            }
            // master computes the parity gradient while devices work
            let parity = match &composite {
                Some(cp) => Some(backend.parity_grad(&cp.xt, &model.beta, &cp.yt, c)?),
                None => None,
            };

            // anchor the gather window *after* the parity GEMM: the grace
            // budget covers channel/wakeup overheads, not the master's own
            // compute, which at paper scale can exceed the whole window
            let epoch_deadline = Instant::now() + deadline_wall;
            let mut grads: Vec<Mat> = Vec::new();
            let mut slowest_delay = 0.0f64;
            loop {
                // uncoded: stop as soon as everyone reported (wait-for-all)
                if !coded && grads.len() == n_workers {
                    break;
                }
                let t = Instant::now();
                if t >= epoch_deadline {
                    break;
                }
                match from_devices.recv_timeout(epoch_deadline - t) {
                    Ok(msg) if msg.epoch == epoch => {
                        grads.push(msg.grad);
                        slowest_delay = slowest_delay.max(msg.delay);
                        on_time += 1;
                    }
                    // straggler from a previous epoch — already counted
                    // late when its own epoch closed; just discard it
                    Ok(_) => {}
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            // same semantics as the DES backend: every broadcast gradient
            // that missed this epoch's gather is late, whether or not its
            // message ever surfaces
            late += (n_workers - grads.len()) as u64;
            let refs: Vec<&Mat> = grads.iter().collect();
            let grad = assemble_coded_gradient(d, parity.as_ref(), &refs);
            model.apply_gradient(&grad);

            // simulated-second axis, matching the DES backend's accounting:
            // a coded epoch lasts exactly t* (deadline-gated), an uncoded
            // epoch lasts as long as its slowest device's *modeled* delay —
            // host overheads (grace, the sleep cap, thread wakeups) stay
            // out of the trace and are visible in wall_secs instead
            let epoch_secs = if coded {
                policy.epoch_deadline
            } else if slowest_delay > 0.0 {
                slowest_delay
            } else {
                epoch_start.elapsed().as_secs_f64() / scale
            };
            now += epoch_secs;
            epoch_times.push(epoch_secs);
            let nmse = model.nmse(&self.session.dataset.beta_star);
            trace.push(now, epoch + 1, nmse);
            if converged.is_none() && nmse <= cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        for tx in &to_devices {
            let _ = tx.send(ToDevice::Stop);
        }
        // drain so workers blocked on send can exit, then join (these
        // stragglers were already counted late when their epochs closed)
        while from_devices.try_recv().is_ok() {}
        for h in handles {
            let _ = h.join();
        }

        Ok(RunResult {
            label,
            trace,
            epoch_times,
            setup_secs,
            parity_upload_bits: parity_bits,
            per_epoch_bits: self.session.round_trip_bits(&policy.device_loads),
            converged,
            delta: policy.delta,
            epoch_deadline: policy.epoch_deadline,
            gather_mc_times: Vec::new(),
            wall_secs: started.elapsed().as_secs_f64(),
            on_time_gradients: on_time,
            late_gradients: late,
        })
    }
}

impl Coordinator for LiveCoordinator {
    fn kind(&self) -> &'static str {
        "live"
    }

    fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    fn train_cfl(&mut self) -> Result<RunResult> {
        LiveCoordinator::train_cfl(self)
    }

    fn train_uncoded(&mut self) -> Result<RunResult> {
        LiveCoordinator::train_uncoded(self)
    }
}
