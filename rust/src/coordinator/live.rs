//! Live (threaded) coordinator: real concurrency, wall-clock deadlines.
//!
//! One `std::thread` per device; each epoch the master broadcasts the
//! model over channels, device workers compute their partial gradient
//! (native kernels — each worker owns its systematic shard), sleep out
//! their *simulated* residual delay scaled by `time_scale`, and send the
//! gradient back. The master gathers until the scaled deadline, computes
//! the parity gradient meanwhile, and updates the model.
//!
//! This is the deployment-shaped path: it demonstrates that the epoch
//! logic (deadline gather + Eq. 18/19 assembly) is driven by real message
//! arrival, not by simulator bookkeeping. The DES coordinator remains the
//! source of the paper's figures (its virtual clock is exact).

use crate::coding::{CompositeParity, DeviceCode};
use crate::config::ExperimentConfig;
use crate::data::{shard_sizes, split, Dataset};
use crate::fl::{assemble_coded_gradient, GlobalModel, GradBackend, NativeBackend};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::simnet::Fleet;
use anyhow::Result;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Outcome of a live run.
#[derive(Clone, Debug)]
pub struct LiveReport {
    pub epochs: usize,
    pub final_nmse: f64,
    /// Wall-clock seconds spent in the epoch loop.
    pub wall_secs: f64,
    /// Gradients that arrived after their epoch's deadline (discarded).
    pub late_gradients: u64,
    /// Gradients gathered in time.
    pub on_time_gradients: u64,
}

enum ToDevice {
    /// (epoch, β) — compute and reply.
    Model(usize, Mat),
    Stop,
}

struct FromDevice {
    epoch: usize,
    device: usize,
    grad: Mat,
}

/// Threaded master/worker training loop.
pub struct LiveCoordinator {
    cfg: ExperimentConfig,
    /// Simulated-seconds → wall-seconds factor (e.g. 1e-3 runs a 5 s
    /// simulated deadline as 5 ms of real sleep).
    pub time_scale: f64,
    /// Fixed wall-clock grace added to every epoch deadline to absorb the
    /// *host's* overheads (thread wakeup, channel hop, the real gradient
    /// GEMM) which exist on top of the simulated delays being slept out.
    pub grace: Duration,
}

impl LiveCoordinator {
    pub fn new(cfg: &ExperimentConfig, time_scale: f64) -> Self {
        Self { cfg: cfg.clone(), time_scale, grace: Duration::from_millis(8) }
    }

    /// Run `epochs` epochs of live CFL; returns the report.
    pub fn run(&self, epochs: usize) -> Result<LiveReport> {
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed);
        let mut fleet = Fleet::from_config(cfg, &mut rng);
        let dataset = Dataset::generate(cfg.total_points(), cfg.model_dim, cfg.snr_db, &mut rng);
        let sizes = shard_sizes(cfg.sharding, cfg.total_points(), cfg.n_devices, &mut rng);
        fleet.set_points(&sizes);
        let shards = split(&dataset, &sizes);

        let policy = match cfg.delta {
            None => crate::lb::optimize(
                &fleet,
                (cfg.c_up_fraction * fleet.total_points() as f64) as usize,
                cfg.epsilon,
            )?,
            Some(delta) => crate::lb::optimize_fixed_c(
                &fleet,
                (delta * fleet.total_points() as f64).round() as usize,
                cfg.epsilon,
            )?,
        };
        let c = policy.parity_rows;
        let d = cfg.model_dim;

        // --- setup phase: codes + composite parity (master side) ---------
        let mut backend = NativeBackend;
        let mut composite = CompositeParity::zeros(c, d);
        let mut worker_shards = Vec::new();
        for (i, shard) in shards.iter().enumerate() {
            let code = DeviceCode::draw(
                shard.rows(),
                c,
                policy.device_loads[i],
                policy.miss_probs[i],
                cfg.generator,
                &mut rng,
            );
            let (xt, yt) = backend.encode(&code.generator, &code.weights, &shard.x, &shard.y)?;
            composite.accumulate(&xt, &yt);
            let mut x_sys = Mat::zeros(code.systematic_count, d);
            let mut y_sys = Mat::zeros(code.systematic_count, 1);
            for (r, &src) in code.systematic_rows().iter().enumerate() {
                x_sys.row_mut(r).copy_from_slice(shard.x.row(src));
                y_sys[(r, 0)] = shard.y[(src, 0)];
            }
            worker_shards.push((x_sys, y_sys));
        }

        // --- spawn device workers ----------------------------------------
        let (to_master, from_devices) = mpsc::channel::<FromDevice>();
        let mut to_devices = Vec::new();
        let mut handles = Vec::new();
        for (i, (x_sys, y_sys)) in worker_shards.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<ToDevice>();
            to_devices.push(tx);
            let master_tx = to_master.clone();
            let profile = fleet.devices[i];
            let load = policy.device_loads[i];
            let scale = self.time_scale;
            let mut dev_rng = rng.split(0xD0_0000 + i as u64);
            handles.push(thread::spawn(move || {
                let mut be = NativeBackend;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ToDevice::Stop => break,
                        ToDevice::Model(epoch, beta) => {
                            let grad = be
                                .partial_grad(&x_sys, &beta, &y_sys)
                                .expect("device gradient");
                            // sleep out the simulated delay (compute+link)
                            let delay = profile.sample_total_delay(load, &mut dev_rng);
                            thread::sleep(Duration::from_secs_f64(
                                (delay * scale).min(0.25), // hard cap: keep demos snappy
                            ));
                            // master may have dropped the channel at stop
                            let _ = master_tx.send(FromDevice { epoch, device: i, grad });
                        }
                    }
                }
            }));
        }
        drop(to_master);

        // --- epoch loop ----------------------------------------------------
        let mut model = GlobalModel::zeros(d, cfg.learning_rate, fleet.total_points());
        let deadline_wall = Duration::from_secs_f64((policy.epoch_deadline * self.time_scale).min(0.25))
            + self.grace;
        let started = Instant::now();
        let mut late = 0u64;
        let mut on_time = 0u64;

        for epoch in 0..epochs {
            for tx in &to_devices {
                // a worker that panicked would sever its channel; surface that
                tx.send(ToDevice::Model(epoch, model.beta.clone()))
                    .map_err(|_| anyhow::anyhow!("device worker died"))?;
            }
            // master computes the parity gradient while devices work
            let parity = backend.parity_grad(&composite.xt, &model.beta, &composite.yt, c)?;

            let epoch_deadline = Instant::now() + deadline_wall;
            let mut grads: Vec<Mat> = Vec::new();
            loop {
                let now = Instant::now();
                if now >= epoch_deadline {
                    break;
                }
                match from_devices.recv_timeout(epoch_deadline - now) {
                    Ok(msg) if msg.epoch == epoch => {
                        grads.push(msg.grad);
                        on_time += 1;
                        let _ = msg.device;
                    }
                    Ok(_) => late += 1, // straggler from a previous epoch
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            let refs: Vec<&Mat> = grads.iter().collect();
            let grad = assemble_coded_gradient(d, Some(&parity), &refs);
            model.apply_gradient(&grad);
        }

        for tx in &to_devices {
            let _ = tx.send(ToDevice::Stop);
        }
        // drain so workers blocked on send can exit, then join
        while from_devices.try_recv().is_ok() {
            late += 1;
        }
        for h in handles {
            let _ = h.join();
        }

        Ok(LiveReport {
            epochs,
            final_nmse: model.nmse(&dataset.beta_star),
            wall_secs: started.elapsed().as_secs_f64(),
            late_gradients: late,
            on_time_gradients: on_time,
        })
    }
}
