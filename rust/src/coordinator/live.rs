//! Live coordinator: real concurrency, wall-clock deadlines, pluggable
//! transports.
//!
//! Each epoch the master broadcasts the model to its device fleet over a
//! [`Transport`], devices compute their partial gradient (native
//! kernels — each endpoint owns its systematic shard), sleep out their
//! *simulated* residual delay scaled by `time_scale`, and reply. The
//! master gathers until the scaled deadline, computes the parity gradient
//! meanwhile, and updates the model.
//!
//! Two wires implement the same protocol: [`ChannelTransport`] (one
//! thread per device, in-process `mpsc` — the default) and
//! [`crate::transport::TcpTransport`] (one socket per device, so the
//! fleet can be real OS processes started with `cfl device`). The device
//! side is the same state machine either way
//! ([`crate::transport::run_device_loop`]).
//!
//! Wall-clock deadlines stay honest via a ping/echo **calibration
//! handshake** at the start of every run: the measured worst round-trip
//! (thread wakeup + channel hop, or socket + scheduler, depending on the
//! transport) sets the grace budget added to every epoch deadline, so a
//! loaded CI host widens its gather window instead of dropping every
//! gradient as a false straggler. Set [`LiveCoordinator::grace`] to pin
//! it manually.
//!
//! A device that disconnects mid-run (socket EOF, worker death) is the
//! paper's erasure case: the master degrades it to parity-only coverage —
//! its gradients are simply never gathered — rather than waiting on it
//! each epoch. The uncoded baseline's wait-for-all gather likewise
//! shrinks to the surviving fleet instead of hanging.
//!
//! Crucially, that demotion is **not permanent**: when the transport
//! re-admits a fresh incarnation of the device ([`Event::Rejoined`] — a
//! restarted `cfl device --retry` process, a respawned channel worker),
//! the master re-sends `Setup` with the device's frozen shard at the
//! next epoch boundary and restores it to the coded gather set (or the
//! uncoded wait-for-all set), shrinking the parity's effective coverage
//! back to the true stragglers. Without this, every long-running fleet
//! would decay toward the centralized parity-only regime the paper's
//! *federated* operating point is defined against. Per-epoch membership
//! is recorded in [`RunResult::epoch_members`], so exported traces show
//! the churn.
//!
//! This is the deployment-shaped path: it demonstrates that the epoch
//! logic (deadline gather + Eq. 18/19 assembly) is driven by real message
//! arrival, not simulator bookkeeping. The DES coordinator remains the
//! source of the paper's figures (its virtual clock is exact), but both
//! backends build the §III-A setup phase from the same [`Session`] and
//! report the same [`RunResult`], so `cfl sweep --live` renders live
//! grids with the sim reports unchanged.

use super::core::{Coordinator, RunResult, Session};
use crate::coding::CompositeParity;
use crate::config::{DataMode, ExperimentConfig, Participation};
use crate::fl::{assemble_coded_gradient, GlobalModel, GradBackend, NativeBackend};
use crate::lb::LoadPolicy;
use crate::linalg::Mat;
use crate::obs::{Phase, PhaseBook};
use crate::rng::mix_seed;
use crate::transport::{ChannelTransport, DeviceInit, Event, FromDevice, ToDevice, Transport};
use anyhow::Result;
use std::time::{Duration, Instant};

/// Ceiling on any single scaled sleep/deadline, keeping demos snappy even
/// when a heavy-tailed delay draw meets a large `time_scale`.
const MAX_SCALED_SECS: f64 = 0.25;

/// Wall-clock cap on an uncoded wait-for-all gather (only reached if a
/// device endpoint dies without its transport noticing).
const WAIT_ALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Ping/echo round trips per device in the calibration handshake.
const CALIBRATION_ROUNDS: usize = 3;

/// Cap on fleet-wide calibration silence: the handshake gives up on
/// every still-unanswered probe once this long passes without *any*
/// pong landing (the clock resets on each one).
const CALIBRATION_TIMEOUT: Duration = Duration::from_millis(500);

/// Calibrated grace = worst observed RTT × this headroom factor …
const GRACE_HEADROOM: u32 = 8;

/// … clamped into this wall-clock band.
const GRACE_FLOOR: Duration = Duration::from_millis(2);
const GRACE_CEIL: Duration = Duration::from_millis(250);

/// Master/worker training loop over a shared [`Session`] and a pluggable
/// [`Transport`].
pub struct LiveCoordinator {
    session: Session,
    /// Simulated-seconds → wall-seconds factor (e.g. 1e-3 runs a 5 s
    /// simulated deadline as 5 ms of real sleep).
    pub time_scale: f64,
    /// Wall-clock grace added to every epoch deadline to absorb the
    /// *host's* overheads (thread wakeup, channel/socket hop, the real
    /// gradient GEMM) which exist on top of the simulated delays being
    /// slept out. `None` (the default) uses the per-run ping/echo
    /// handshake's measurement; `Some` pins the budget (the handshake
    /// still runs — it doubles as the liveness probe that excludes
    /// silently-dead endpoints from the run).
    pub grace: Option<Duration>,
    transport: Box<dyn Transport>,
    /// Run counter: tags every `Setup`/`Grad` so stragglers from a
    /// finished run can never pollute the next one.
    runs: u64,
}

impl LiveCoordinator {
    /// Build the coordinator over a fresh [`Session`] for `cfg`, with the
    /// default in-process [`ChannelTransport`] (one thread per device).
    pub fn new(cfg: &ExperimentConfig, time_scale: f64) -> Result<Self> {
        let transport = Box::new(ChannelTransport::new(cfg.n_devices));
        Self::with_transport(cfg, time_scale, transport)
    }

    /// Build the coordinator over an already-established transport (e.g.
    /// a [`crate::transport::TcpTransport`] whose devices have connected).
    /// The transport must expose exactly one endpoint per fleet device.
    pub fn with_transport(
        cfg: &ExperimentConfig,
        time_scale: f64,
        transport: Box<dyn Transport>,
    ) -> Result<Self> {
        anyhow::ensure!(
            time_scale.is_finite() && time_scale > 0.0,
            "time_scale must be a positive finite factor"
        );
        // fail loudly rather than run a client-selection config as full
        // participation — the §V extension is implemented by the DES
        // backend only
        anyhow::ensure!(
            cfg.client_fraction >= 1.0,
            "the live coordinator does not implement client selection \
             (client_fraction = {}); use the sim backend or the \
             `participation` axis",
            cfg.client_fraction
        );
        anyhow::ensure!(
            cfg.data_mode == DataMode::Materialized,
            "the live coordinator requires data_mode = materialized \
             (lean fleets are sim-only)"
        );
        anyhow::ensure!(
            transport.n_endpoints() == cfg.n_devices,
            "transport has {} endpoint(s) for a {}-device fleet",
            transport.n_endpoints(),
            cfg.n_devices
        );
        Ok(Self { session: Session::new(cfg)?, time_scale, grace: None, transport, runs: 0 })
    }

    /// The shared problem instance (config, fleet, dataset, shards).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Solve the CFL load/redundancy policy (see [`Session::policy`]).
    pub fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    /// Run live CFL for up to `cfg.max_epochs` epochs (early-stops at
    /// `cfg.target_nmse`, like the DES backend).
    pub fn train_cfl(&mut self) -> Result<RunResult> {
        let policy = self.session.policy()?;
        self.run_with(&policy, true)
    }

    /// Run the live uncoded baseline: full shards, no parity, the master
    /// waits for every (surviving) device's gradient each epoch.
    pub fn train_uncoded(&mut self) -> Result<RunResult> {
        let policy = LoadPolicy::uncoded(&self.session.fleet);
        self.run_with(&policy, false)
    }

    /// The shared master/fleet loop. `coded` selects the §III-A setup +
    /// deadline gather; uncoded runs full shards with a wait-for-all
    /// gather (and no setup offset).
    fn run_with(&mut self, policy: &LoadPolicy, coded: bool) -> Result<RunResult> {
        // wall_secs spans setup + training in both backends
        let started = Instant::now();
        let mut phases = PhaseBook::with_capacity(self.session.cfg.max_epochs);
        let mut rng = self.session.run_rng();
        let mut backend = NativeBackend;
        self.runs += 1;
        let run_id = self.runs;
        let scale = self.time_scale;

        // --- setup phase: shared Session construction ---------------------
        // zero-load devices are fully punctured and sit the run out,
        // mirroring the DES backend's skip
        type Frozen = (usize, Mat, Mat, usize);
        let (frozen, composite, setup_secs, parity_bits): (
            Vec<Frozen>,
            Option<CompositeParity>,
            f64,
            f64,
        ) = if coded {
            let t_setup = Instant::now();
            let setup = self.session.build_setup(policy, &mut backend, &mut rng)?;
            phases.record(Phase::ParityEncode, t_setup.elapsed().as_secs_f64());
            let devices: Vec<Frozen> = setup
                .devices
                .into_iter()
                .enumerate()
                .filter(|(_, s)| s.load > 0)
                .map(|(i, s)| (i, s.x_sys, s.y_sys, s.load))
                .collect();
            (devices, Some(setup.composite), setup.setup_secs, setup.parity_upload_bits)
        } else {
            let devices: Vec<Frozen> = self
                .session
                .shards()?
                .iter()
                .enumerate()
                .map(|(i, s)| (i, s.x.clone(), s.y.clone(), s.rows()))
                .collect();
            (devices, None, 0.0, 0.0)
        };

        let cfg = &self.session.cfg;
        let d = cfg.model_dim;
        let m = self.session.fleet.total_points();
        let c = policy.parity_rows;

        // --- arm the fleet ------------------------------------------------
        // delay-stream seeds key on the device index alone (drawn after
        // the setup phase so the §III-A rng draws stay aligned with the
        // sim backend), so skipping punctured devices doesn't shift
        // anyone else's stream
        let seed_base = rng.next_u64();
        let inits: Vec<DeviceInit> = frozen
            .into_iter()
            .map(|(i, x_sys, y_sys, load)| DeviceInit {
                run: run_id,
                device_index: i,
                load,
                delay_seed: mix_seed(seed_base, i as u64),
                time_scale: scale,
                max_scaled_secs: MAX_SCALED_SECS,
                profile: self.session.fleet.devices[i],
                x_sys,
                y_sys,
            })
            .collect();
        let active: Vec<usize> = inits.iter().map(|init| init.device_index).collect();
        anyhow::ensure!(!active.is_empty(), "no device carries a positive load");
        let n_endpoints = self.transport.n_endpoints();
        // keep each participating device's frozen state around so a
        // rejoined incarnation can be re-armed mid-run (Setup is re-sent
        // at the next epoch boundary). This is a deliberate one-per-run
        // deep copy of the shard state — at paper scale ~one dataset's
        // worth of f32s held for the run's duration; the §III-A setup is
        // rng-coupled, so rebuilding it lazily on rejoin would mean
        // replaying the whole coding phase instead. Revisit (Arc'd
        // matrices) if fleet memory ever becomes the constraint.
        let mut rejoin_inits: Vec<Option<DeviceInit>> = vec![None; n_endpoints];
        for init in &inits {
            rejoin_inits[init.device_index] = Some(init.clone());
        }
        // slots whose fresh incarnation was admitted but not yet re-armed
        let mut needs_setup = vec![false; n_endpoints];
        let mut disconnects = 0u64;
        let mut rejoins = 0u64;
        // an endpoint is alive only if this run's Setup actually reached
        // it — a slot dead at begin_run starts the run awaiting rejoin
        // (its fresh incarnation, admitted later, must not be broadcast
        // to before its Setup lands)
        let delivered = self.transport.begin_run(inits)?;
        let mut alive = vec![false; n_endpoints];
        for (&slot, ok) in active.iter().zip(delivered) {
            alive[slot] = ok;
        }

        // --- deadline calibration -----------------------------------------
        let t_calibrate = Instant::now();
        let measured = calibrate_grace(
            self.transport.as_mut(),
            &active,
            &mut alive,
            &mut needs_setup,
            &mut disconnects,
            &mut rejoins,
        );
        phases.record(Phase::Calibrate, t_calibrate.elapsed().as_secs_f64());
        let grace = self.grace.unwrap_or(measured);
        crate::obs_event!(
            Debug,
            "calibrated",
            rtt_grace_ms = measured.as_secs_f64() * 1e3,
            grace_ms = grace.as_secs_f64() * 1e3,
            live_endpoints = alive.iter().filter(|a| **a).count(),
        );

        // --- epoch loop ---------------------------------------------------
        let mut model = GlobalModel::zeros(d, cfg.learning_rate, m);
        let label = if coded {
            format!("live cfl δ={:.3}", policy.delta)
        } else {
            "live uncoded".to_string()
        };
        let mut trace = self.session.start_trace(
            label.clone(),
            setup_secs,
            model.nmse(self.session.beta_star()),
        );
        let deadline_wall = if coded {
            Duration::from_secs_f64((policy.epoch_deadline * scale).min(MAX_SCALED_SECS)) + grace
        } else {
            WAIT_ALL_TIMEOUT
        };
        let mut epoch_times = Vec::new();
        let mut epoch_members = vec![active.len()];
        let mut converged = None;
        let mut late = 0u64;
        let mut on_time = 0u64;
        let mut now = setup_secs;

        // sampled participation (the scale axis): each coded epoch
        // broadcasts to k of n devices only. Uncoded FL is wait-for-all
        // by definition, so sampling applies to the coded path alone.
        let n_fleet = cfg.n_devices;
        let k_sample = cfg.sampled_per_epoch();
        let sampling = coded && cfg.participation != Participation::All && k_sample < n_fleet;

        for epoch in 0..cfg.max_epochs {
            let mut ep_span = crate::obs_span!(Debug, "epoch");
            let epoch_start = Instant::now();
            // epoch boundary: drain queued lifecycle events without
            // blocking. This is what keeps an all-dead fleet revivable —
            // the gather loop below only runs while replies are pending,
            // so with zero live devices a queued rejoin would otherwise
            // starve forever and the run would decay parity-only to its
            // end. Stray replies here are stragglers of a closed gather
            // (already counted late) or stale pongs: dropped.
            loop {
                match self.transport.recv_timeout(Duration::ZERO) {
                    Event::Gone(slot) => {
                        if alive[slot] {
                            alive[slot] = false;
                            disconnects += 1;
                        }
                        needs_setup[slot] = false;
                    }
                    Event::Rejoined(slot) => {
                        if alive[slot] {
                            // suppressed death notice: see the gather arm
                            alive[slot] = false;
                            disconnects += 1;
                        }
                        if !needs_setup[slot] {
                            needs_setup[slot] = true;
                            rejoins += 1;
                        }
                    }
                    Event::Msg(_, _) => {}
                    Event::Timeout | Event::Closed => break,
                }
            }
            // … then re-arm any freshly rejoined incarnation — it holds
            // no run state, so it gets the frozen Setup (same run tag,
            // same shard, same delay stream) before this epoch's Model,
            // restoring it to the gather set
            for slot in 0..n_endpoints {
                if !needs_setup[slot] {
                    continue;
                }
                needs_setup[slot] = false;
                let Some(init) = rejoin_inits[slot].as_ref() else {
                    continue; // a zero-load / non-participating slot
                };
                let re = ToDevice::Setup(Box::new(init.clone()));
                if self.transport.send(slot, &re)? {
                    alive[slot] = true;
                }
            }
            // broadcast to the surviving fleet (one message, serialized
            // once by the transport); a failed delivery is this epoch's
            // discovery that an endpoint died
            let mut sent_to = vec![false; n_endpoints];
            let mut pending = 0usize;
            let msg = ToDevice::Model { epoch, beta: model.beta.clone() };
            let targets: Vec<usize> = if sampling {
                let mut mask = vec![false; n_fleet];
                for i in rng.sample_indices_sparse(n_fleet, k_sample) {
                    mask[i] = true;
                }
                active.iter().copied().filter(|&s| alive[s] && mask[s]).collect()
            } else {
                active.iter().copied().filter(|&s| alive[s]).collect()
            };
            let delivered = self.transport.broadcast(&targets, &msg)?;
            for (&slot, ok) in targets.iter().zip(delivered) {
                if ok {
                    sent_to[slot] = true;
                    pending += 1;
                } else {
                    // a failed delivery is an observed death too (the
                    // Gone that follows, if any, is guarded by `alive`)
                    alive[slot] = false;
                    disconnects += 1;
                }
            }
            anyhow::ensure!(
                coded || pending > 0,
                "every device endpoint is gone; uncoded FL cannot proceed"
            );
            // master computes the parity gradient while devices work
            let t_parity = Instant::now();
            let parity = match &composite {
                Some(cp) => Some(backend.parity_grad(&cp.xt, &model.beta, &cp.yt, c)?),
                None => None,
            };
            let t_gather_start = Instant::now();
            phases.record(Phase::LocalGrad, t_gather_start.duration_since(t_parity).as_secs_f64());

            // anchor the gather window *after* the parity GEMM: the grace
            // budget covers transport/wakeup overheads, not the master's
            // own compute, which at paper scale can exceed the window
            let epoch_deadline = Instant::now() + deadline_wall;
            let sent = pending;
            let mut replied = vec![false; n_endpoints];
            let mut grads: Vec<Mat> = Vec::new();
            let mut slowest_delay = 0.0f64;
            while pending > 0 {
                let t = Instant::now();
                if t >= epoch_deadline {
                    break;
                }
                match self.transport.recv_timeout(epoch_deadline - t) {
                    Event::Msg(slot, FromDevice::Grad { run, epoch: e, grad, delay }) => {
                        // stragglers from a previous epoch/run were already
                        // counted late when their gather closed; discard
                        if run == run_id && e == epoch && sent_to[slot] && !replied[slot] {
                            replied[slot] = true;
                            pending -= 1;
                            grads.push(grad);
                            slowest_delay = slowest_delay.max(delay);
                            on_time += 1;
                        }
                    }
                    // stray Hello/Pong: nothing to do mid-epoch
                    Event::Msg(_, _) => {}
                    Event::Gone(slot) => {
                        // mid-epoch disconnect: degrade this device to
                        // parity-only coverage instead of waiting on it
                        // (until a fresh incarnation rejoins)
                        if alive[slot] {
                            alive[slot] = false;
                            disconnects += 1;
                            if sent_to[slot] && !replied[slot] {
                                pending -= 1;
                            }
                        }
                        needs_setup[slot] = false; // died again pre-Setup
                    }
                    Event::Rejoined(slot) => {
                        // a rejoin for a slot still thought alive means
                        // the old incarnation's death notice was
                        // suppressed by the generation filter (kill and
                        // rejoin back-to-back): account the implicit
                        // disconnect first, or the gather would wait out
                        // the deadline for a reply that can never come —
                        // and the blank replacement would be broadcast to
                        // before its Setup, dying of a protocol violation
                        if alive[slot] {
                            alive[slot] = false;
                            disconnects += 1;
                            if sent_to[slot] && !replied[slot] {
                                pending -= 1;
                            }
                        }
                        // re-arm the fresh incarnation at the next epoch
                        // boundary (it missed this epoch's broadcast)
                        if !needs_setup[slot] {
                            needs_setup[slot] = true;
                            rejoins += 1;
                        }
                    }
                    Event::Timeout => break,
                    Event::Closed => {
                        for &slot in &active {
                            alive[slot] = false;
                        }
                        break;
                    }
                }
            }
            let t_aggregate = Instant::now();
            phases.record(Phase::Gather, t_aggregate.duration_since(t_gather_start).as_secs_f64());
            // same semantics as the DES backend: every broadcast gradient
            // that missed this epoch's gather is late, whether it was slow,
            // lost, or its endpoint died mid-flight
            late += (sent - grads.len()) as u64;
            epoch_members.push(sent);
            if sampling {
                // inverse-probability weighting, matching the sim backend
                for g in &mut grads {
                    g.scale(n_fleet as f32 / k_sample as f32);
                }
            }
            let refs: Vec<&Mat> = grads.iter().collect();
            let grad = assemble_coded_gradient(d, parity.as_ref(), &refs);
            model.apply_gradient(&grad);

            // simulated-second axis, matching the DES backend's accounting:
            // a coded epoch lasts exactly t* (deadline-gated), an uncoded
            // epoch lasts as long as its slowest device's *modeled* delay —
            // host overheads (grace, the sleep cap, transport hops) stay
            // out of the trace and are visible in wall_secs instead
            let epoch_secs = if coded {
                policy.epoch_deadline
            } else if slowest_delay > 0.0 {
                slowest_delay
            } else {
                epoch_start.elapsed().as_secs_f64() / scale
            };
            now += epoch_secs;
            epoch_times.push(epoch_secs);
            let nmse = model.nmse(self.session.beta_star());
            trace.push(now, epoch + 1, nmse);
            phases.record(Phase::Aggregate, t_aggregate.elapsed().as_secs_f64());
            if ep_span.active() {
                ep_span.field("epoch", epoch + 1);
                ep_span.field("nmse", nmse);
                ep_span.field("members", sent);
                ep_span.field("gathered", grads.len());
                ep_span.field(
                    "local_grad_ms",
                    t_gather_start.duration_since(t_parity).as_secs_f64() * 1e3,
                );
                ep_span.field(
                    "gather_ms",
                    t_aggregate.duration_since(t_gather_start).as_secs_f64() * 1e3,
                );
            }
            if converged.is_none() && nmse <= cfg.target_nmse {
                converged = Some((epoch + 1, now));
                break;
            }
        }

        self.transport.end_run();
        crate::obs_event!(
            Debug,
            "run_done",
            label = label.as_str(),
            epochs = epoch_times.len(),
            wall_s = started.elapsed().as_secs_f64(),
            disconnects = disconnects,
            rejoins = rejoins,
        );

        Ok(RunResult {
            label,
            trace,
            epoch_times,
            setup_secs,
            parity_upload_bits: parity_bits,
            per_epoch_bits: self.session.round_trip_bits(&policy.device_loads),
            converged,
            delta: policy.delta,
            epoch_deadline: policy.epoch_deadline,
            gather_mc_times: Vec::new(),
            wall_secs: started.elapsed().as_secs_f64(),
            on_time_gradients: on_time,
            late_gradients: late,
            epoch_members,
            disconnects,
            rejoins,
            phases: phases.summaries(),
        })
    }
}

/// The calibration handshake: a few ping/echo round trips per active
/// device; the worst observed RTT — which prices the *transport's* full
/// hop (thread wakeup + channel, or socket + scheduler) under the host's
/// current load — times a headroom factor becomes the grace budget,
/// clamped to a sane band.
///
/// The handshake is *pipelined*: every endpoint's probe sequence runs
/// concurrently (each is `CALIBRATION_ROUNDS` strictly sequential
/// ping→pong exchanges, re-armed as its pong lands), so fleet
/// calibration costs the slowest endpoint's round trips, not the sum of
/// everyone's — the shape a readiness-driven transport makes natural.
///
/// The handshake doubles as the run's liveness probe, and dying devices
/// must cost the run at most ~one wait cap *total*: the silence clock is
/// shared, resetting on every pong, so the cap prices consecutive
/// fleet-wide silence — when it expires, everything still probing is a
/// mute corpse (a silently-partitioned socket whose writes still land in
/// the kernel buffer), marked dead and severed in one sweep so restarted
/// devices can re-claim the slots. An endpoint that dies mid-probe (a
/// `Gone` arrives, or its re-arm send fails) is excluded immediately.
/// Lifecycle events that land mid-handshake are honored: a `Gone` for
/// any slot kills it, a `Rejoined` marks the slot for re-arming at the
/// first epoch boundary (rejoined incarnations are not pinged — the
/// surviving fleet's worst RTT already prices the host).
fn calibrate_grace(
    transport: &mut dyn Transport,
    active: &[usize],
    alive: &mut [bool],
    needs_setup: &mut [bool],
    disconnects: &mut u64,
    rejoins: &mut u64,
) -> Duration {
    /// One endpoint's in-flight probe.
    struct Probe {
        /// Exchanges still to run after the in-flight one.
        rounds_left: usize,
        /// Nonce of the in-flight ping.
        nonce: u64,
        sent_at: Instant,
    }
    let mut max_rtt = Duration::ZERO;
    let mut mark_gone = |s: usize, alive: &mut [bool], needs_setup: &mut [bool]| {
        if let Some(flag) = alive.get_mut(s) {
            if *flag {
                *flag = false;
                *disconnects += 1;
            }
        }
        if let Some(flag) = needs_setup.get_mut(s) {
            *flag = false;
        }
    };
    // launch: one ping per live active endpoint, all at once. Nonces are
    // partitioned per slot (slot j uses j·ROUNDS‥(j+1)·ROUNDS), so a
    // straggling pong can never satisfy another slot's probe.
    let mut probes: Vec<Option<Probe>> = (0..alive.len()).map(|_| None).collect();
    let mut outstanding = 0usize;
    for (j, &slot) in active.iter().enumerate() {
        if !alive.get(slot).copied().unwrap_or(false) {
            continue;
        }
        let nonce = (j * CALIBRATION_ROUNDS) as u64;
        let sent_at = Instant::now();
        if matches!(transport.send(slot, &ToDevice::Ping { nonce }), Ok(true)) {
            if let Some(p) = probes.get_mut(slot) {
                *p = Some(Probe { rounds_left: CALIBRATION_ROUNDS - 1, nonce, sent_at });
                outstanding += 1;
            }
        } else {
            mark_gone(slot, alive, needs_setup);
        }
    }
    let mut quiet_since = Instant::now();
    while outstanding > 0 {
        let deadline = quiet_since + CALIBRATION_TIMEOUT;
        let now = Instant::now();
        if now >= deadline {
            // nobody has spoken for a whole cap: every endpoint still
            // probing is a mute corpse — mark it dead and sever the
            // half-open link so a restarted device can re-claim the slot
            // instead of being refused as a duplicate of the corpse
            for (slot, probe) in probes.iter_mut().enumerate() {
                if probe.take().is_some() {
                    mark_gone(slot, alive, needs_setup);
                    transport.disconnect(slot);
                }
            }
            break;
        }
        match transport.recv_timeout(deadline - now) {
            Event::Msg(s, FromDevice::Pong { nonce: n }) => {
                // judge the pong against s's in-flight probe first, then
                // apply the verdict (None = stale, ignore; Some(None) =
                // probe finished; Some(Some(nonce)) = re-arm and ping)
                let verdict = match probes.get_mut(s).and_then(|p| p.as_mut()) {
                    Some(probe) if probe.nonce == n => {
                        max_rtt = max_rtt.max(probe.sent_at.elapsed());
                        quiet_since = Instant::now();
                        if probe.rounds_left == 0 {
                            Some(None)
                        } else {
                            probe.rounds_left -= 1;
                            probe.nonce += 1;
                            probe.sent_at = Instant::now();
                            Some(Some(probe.nonce))
                        }
                    }
                    // a stale pong (an earlier run's straggler, or a
                    // probe this slot no longer runs)
                    _ => None,
                };
                match verdict {
                    None => {}
                    Some(None) => {
                        if let Some(p) = probes.get_mut(s) {
                            *p = None;
                        }
                        outstanding -= 1;
                    }
                    Some(Some(nonce)) => {
                        if !matches!(transport.send(s, &ToDevice::Ping { nonce }), Ok(true)) {
                            if let Some(p) = probes.get_mut(s) {
                                *p = None;
                            }
                            outstanding -= 1;
                            mark_gone(s, alive, needs_setup);
                        }
                    }
                }
            }
            // stale replies from an earlier run: discard
            Event::Msg(_, _) => {}
            Event::Gone(s) => {
                mark_gone(s, alive, needs_setup);
                if probes.get_mut(s).and_then(Option::take).is_some() {
                    outstanding -= 1;
                }
            }
            Event::Rejoined(s) => {
                // a suppressed death notice (kill + rejoin back-to-back)
                // surfaces as a rejoin for a slot still thought alive:
                // account the implicit disconnect, then mark the fresh
                // incarnation for re-arming at the first epoch boundary.
                // The incarnation this slot's probe went to is gone and
                // can never pong — retire the probe, or the quiet-clock
                // sweep would sever the freshly admitted replacement and
                // cancel its re-arm.
                mark_gone(s, alive, needs_setup);
                if let Some(flag) = needs_setup.get_mut(s) {
                    *flag = true;
                    *rejoins += 1;
                }
                if probes.get_mut(s).and_then(Option::take).is_some() {
                    outstanding -= 1;
                }
            }
            // Timeout: the loop head re-checks the shared quiet deadline
            Event::Timeout => {}
            Event::Closed => {
                for (slot, probe) in probes.iter_mut().enumerate() {
                    if probe.take().is_some() {
                        mark_gone(slot, alive, needs_setup);
                    }
                }
                break;
            }
        }
    }
    (max_rtt * GRACE_HEADROOM).clamp(GRACE_FLOOR, GRACE_CEIL)
}

impl Coordinator for LiveCoordinator {
    fn kind(&self) -> &'static str {
        "live"
    }

    fn policy(&self) -> Result<LoadPolicy> {
        self.session.policy()
    }

    fn train_cfl(&mut self) -> Result<RunResult> {
        LiveCoordinator::train_cfl(self)
    }

    fn train_uncoded(&mut self) -> Result<RunResult> {
        LiveCoordinator::train_uncoded(self)
    }
}
