//! Metamorphic invariants: transformations of an experiment that must
//! not change its outcome, checked through [`crate::testing::prop`].
//!
//! Each invariant is a named property with committed regression seeds in
//! `testing/corpus.txt` (replayed before fresh generation), wrapped so a
//! property panic becomes a conformance failure whose detail carries the
//! shrunk counterexample and the `CFL_PROP_SEED` reproduction line.
//!
//! * **sim rerun determinism** — two fresh [`SimCoordinator`]s over the
//!   same config produce bit-identical traces, epoch times, and policy.
//! * **train order independence** — a sweep's per-scenario records are a
//!   pure function of each scenario's config: running the grid reversed
//!   and on a different worker count changes nothing.
//! * **zip equals cross diagonal** — a zipped axis group expands to
//!   exactly the diagonal of the cartesian expansion of the same axes.
//! * **device relabeling symmetry** — reversing the fleet's device order
//!   permutes the load optimizer's output and nothing else.
//! * **participation sampling** — per-epoch sampled sets are a pure
//!   function of the seed (bit-identical reruns), and the no-sampling
//!   spellings (`all`, `count:<n>`, `frac:1`) are byte-identical to each
//!   other — i.e. sampling-off reproduces the pre-sampling simulator
//!   exactly.
//!
//! [`SimCoordinator`]: crate::coordinator::SimCoordinator

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::coordinator::SimCoordinator;
use crate::lb::{optimal_load, optimize_fixed_c};
use crate::rng::Rng;
use crate::simnet::Fleet;
use crate::sweep::{
    config_fingerprint, run_scenarios, scenario_json_record, ScenarioGrid, ScenarioOutcome,
    SweepOptions,
};
use crate::testing::prop::{self, assert_close, assert_that, Gen, PropResult};

use super::{CheckDef, Outcome, DEFAULT_SEED};

/// Run a named property, converting a `prop::check` panic (which carries
/// the shrunk counterexample and reproduction seed) into a failure.
fn run_prop(
    name: &'static str,
    cases: usize,
    seed: u64,
    body: fn(&mut Gen) -> PropResult,
) -> Outcome {
    let cfg = prop::Config { cases, seed, max_shrink: 200 };
    match catch_unwind(AssertUnwindSafe(|| prop::check(name, cfg, body))) {
        Ok(()) => Outcome::pass(format!("{cases} cases + corpus seeds")),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "property panicked without a message".to_string());
            Outcome::fail(msg)
        }
    }
}

fn prop_sim_rerun(g: &mut Gen) -> PropResult {
    let cfg = g.fleet_config();
    let mut a = SimCoordinator::new(&cfg).map_err(|e| format!("sim a: {e:#}"))?;
    let ra = a.train_cfl().map_err(|e| format!("train a: {e:#}"))?;
    let mut b = SimCoordinator::new(&cfg).map_err(|e| format!("sim b: {e:#}"))?;
    let rb = b.train_cfl().map_err(|e| format!("train b: {e:#}"))?;
    assert_that(
        ra.setup_secs == rb.setup_secs,
        format!("setup_secs: {} vs {}", ra.setup_secs, rb.setup_secs),
    )?;
    assert_that(ra.delta == rb.delta, format!("delta: {} vs {}", ra.delta, rb.delta))?;
    assert_that(
        ra.epoch_deadline == rb.epoch_deadline,
        format!("epoch_deadline: {} vs {}", ra.epoch_deadline, rb.epoch_deadline),
    )?;
    assert_that(ra.epoch_times == rb.epoch_times, "epoch_times differ between reruns")?;
    assert_that(
        ra.trace.points.len() == rb.trace.points.len(),
        format!("trace length: {} vs {}", ra.trace.points.len(), rb.trace.points.len()),
    )?;
    for (i, (p, q)) in ra.trace.points.iter().zip(&rb.trace.points).enumerate() {
        assert_that(
            p.time_s == q.time_s && p.epoch == q.epoch && p.nmse == q.nmse,
            format!(
                "trace point {i}: ({}, {}, {}) vs ({}, {}, {})",
                p.time_s, p.epoch, p.nmse, q.time_s, q.epoch, q.nmse
            ),
        )?;
    }
    Ok(())
}

fn prop_train_order(g: &mut Gen) -> PropResult {
    let es = |e: anyhow::Error| format!("{e:#}");
    let cfg = g.fleet_config();
    // distinct-by-construction axis values: offsets larger than the draw
    // range keep scenario ids unique
    let base = g.f64_in(0.0, 0.1);
    let grid = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &[base, base + 0.3])
        .map_err(es)?
        .axis_f64("nu_link", &[base + 0.15, base + 0.45])
        .map_err(es)?;
    let fwd_scenarios = grid.expand().map_err(es)?;
    let rev_scenarios = {
        let mut v = grid.expand().map_err(es)?;
        v.reverse();
        v
    };
    let serial = SweepOptions { workers: 1, uncoded_baseline: true, ..Default::default() };
    let pooled = SweepOptions { workers: 2, uncoded_baseline: true, ..Default::default() };
    let fwd = run_scenarios(fwd_scenarios, &serial).map_err(es)?;
    let rev = run_scenarios(rev_scenarios, &pooled).map_err(es)?;
    let records = |outs: &[ScenarioOutcome]| -> Vec<(String, String)> {
        let mut v: Vec<(String, String)> =
            outs.iter().map(|o| (o.scenario.id.clone(), scenario_json_record(o))).collect();
        v.sort();
        v
    };
    let (f, r) = (records(&fwd), records(&rev));
    assert_that(
        f == r,
        "per-scenario records depend on execution order or worker count",
    )
}

fn prop_zip_cross(g: &mut Gen) -> PropResult {
    let es = |e: anyhow::Error| format!("{e:#}");
    let cfg = g.fleet_config();
    let k = g.size_in(2, 4);
    // distinct values per axis (offset spacing exceeds the draw range)
    let base_a = g.f64_in(0.0, 0.1);
    let base_b = g.f64_in(0.0, 0.1);
    let a: Vec<f64> = (0..k).map(|j| base_a + 0.12 * j as f64).collect();
    let b: Vec<f64> = (0..k).map(|j| base_b + 0.12 * j as f64).collect();
    let zipped = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &a)
        .map_err(es)?
        .axis_f64("nu_link", &b)
        .map_err(es)?
        .zip_axes(["nu_comp", "nu_link"])
        .map_err(es)?
        .expand()
        .map_err(es)?;
    let crossed = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &a)
        .map_err(es)?
        .axis_f64("nu_link", &b)
        .map_err(es)?
        .expand()
        .map_err(es)?;
    assert_that(zipped.len() == k, format!("zipped count {} != {k}", zipped.len()))?;
    assert_that(crossed.len() == k * k, format!("crossed count {} != {}", crossed.len(), k * k))?;
    for i in 0..k {
        // axis 0 is the slowest dimension of the row-major expansion, so
        // the diagonal of the k×k cross sits at index i·k + i
        let z = &zipped[i];
        let c = &crossed[i * k + i];
        assert_that(
            z.assignment == c.assignment,
            format!("assignment at diagonal {i}: {:?} vs {:?}", z.assignment, c.assignment),
        )?;
        assert_that(
            config_fingerprint(&z.cfg) == config_fingerprint(&c.cfg),
            format!("config fingerprint differs at diagonal {i}"),
        )?;
    }
    Ok(())
}

fn prop_relabel(g: &mut Gen) -> PropResult {
    let cfg = g.fleet_config();
    let mut rng = Rng::new(cfg.seed ^ 0xF1EE7);
    let fleet = Fleet::from_config(&cfg, &mut rng);
    let m = fleet.total_points();
    let c = (((m as f64) * 0.15).round() as usize).max(1);
    let fwd = optimize_fixed_c(&fleet, c, cfg.epsilon).map_err(|e| format!("optimize fwd: {e:#}"))?;
    let mut rev_fleet = fleet.clone();
    rev_fleet.devices.reverse();
    let rev =
        optimize_fixed_c(&rev_fleet, c, cfg.epsilon).map_err(|e| format!("optimize rev: {e:#}"))?;
    let n = fleet.devices.len();
    // t* comes from the same bisection path; only the aggregate's float
    // summation order changed, so the deadline and the (order-summed)
    // expected return get a tolerance while per-device outputs are exact
    assert_close(fwd.epoch_deadline, rev.epoch_deadline, 1e-9, "epoch_deadline under relabeling")?;
    assert_close(fwd.expected_return, rev.expected_return, 1e-9, "expected_return under relabeling")?;
    assert_that(fwd.delta == rev.delta, format!("delta: {} vs {}", fwd.delta, rev.delta))?;
    assert_that(
        fwd.parity_rows == rev.parity_rows,
        format!("parity_rows: {} vs {}", fwd.parity_rows, rev.parity_rows),
    )?;
    for i in 0..n {
        let j = n - 1 - i;
        assert_that(
            fwd.device_loads[i] == rev.device_loads[j],
            format!(
                "device {i}: load {} != relabeled load {}",
                fwd.device_loads[i], rev.device_loads[j]
            ),
        )?;
        assert_close(fwd.miss_probs[i], rev.miss_probs[j], 1e-9, "miss prob under relabeling")?;
    }
    // and the loads are the pure per-device optimum at the common t*
    for (i, dev) in fleet.devices.iter().enumerate() {
        let (l, _) = optimal_load(dev, fwd.epoch_deadline, dev.points);
        assert_that(
            l == fwd.device_loads[i],
            format!("device {i}: optimal_load {l} != policy load {}", fwd.device_loads[i]),
        )?;
    }
    Ok(())
}

fn prop_participation(g: &mut Gen) -> PropResult {
    use crate::config::Participation;
    use crate::coordinator::RunResult;

    let same_run = |what: &str, a: &RunResult, b: &RunResult| -> PropResult {
        assert_that(
            a.setup_secs == b.setup_secs && a.delta == b.delta,
            format!("{what}: setup/δ differ"),
        )?;
        assert_that(a.epoch_times == b.epoch_times, format!("{what}: epoch_times differ"))?;
        assert_that(
            a.trace.points.len() == b.trace.points.len(),
            format!("{what}: trace length {} vs {}", a.trace.points.len(), b.trace.points.len()),
        )?;
        for (i, (p, q)) in a.trace.points.iter().zip(&b.trace.points).enumerate() {
            assert_that(
                p.time_s == q.time_s && p.epoch == q.epoch && p.nmse == q.nmse,
                format!("{what}: trace point {i} differs"),
            )?;
        }
        Ok(())
    };
    let train = |cfg: &crate::config::ExperimentConfig| -> Result<RunResult, String> {
        SimCoordinator::new(cfg)
            .map_err(|e| format!("sim: {e:#}"))?
            .train_cfl()
            .map_err(|e| format!("train: {e:#}"))
    };

    let mut cfg = g.fleet_config();
    let n = cfg.n_devices;
    // k may equal n: the boundary where sampling degenerates to the
    // no-sampling fast path
    let k = g.size_in(1, n);
    cfg.participation = Participation::Count(k);
    // the sampled sets are drawn from the run RNG: same seed ⇒ the same
    // devices are sampled every epoch ⇒ bit-identical trajectories
    same_run("sampled rerun", &train(&cfg)?, &train(&cfg)?)?;

    // spelling equivalence: `all`, `count:<n>` and `frac:1` all mean
    // no sampling, and must reproduce the legacy simulator byte for byte
    let mut all = cfg.clone();
    all.participation = Participation::All;
    let mut count_n = cfg.clone();
    count_n.participation = Participation::Count(n);
    let mut frac_one = cfg.clone();
    frac_one.participation = Participation::Fraction(1.0);
    let ra = train(&all)?;
    same_run("count:n vs all", &train(&count_n)?, &ra)?;
    same_run("frac:1 vs all", &train(&frac_one)?, &ra)?;
    Ok(())
}

pub(crate) fn checks(full: bool) -> Vec<CheckDef> {
    let scale = if full { 4 } else { 1 };
    let def = |name: &'static str, id: &'static str, cases: usize, body: fn(&mut Gen) -> PropResult| {
        CheckDef {
            kind: "invariant",
            id: id.to_string(),
            seed: DEFAULT_SEED,
            run: Box::new(move |seed| run_prop(name, cases, seed, body)),
        }
    };
    vec![
        def("sim rerun determinism", "invariant__sim-rerun-determinism", 6 * scale, prop_sim_rerun),
        def(
            "train order independence",
            "invariant__train-order-independence",
            3 * scale,
            prop_train_order,
        ),
        def("zip equals cross diagonal", "invariant__zip-cross-diagonal", 16 * scale, prop_zip_cross),
        def(
            "device relabeling symmetry",
            "invariant__device-relabeling",
            24 * scale,
            prop_relabel,
        ),
        def(
            "participation sampling",
            "invariant__participation-sampling",
            4 * scale,
            prop_participation,
        ),
    ]
}
