//! The fixture corpus: scenario configs every backend must agree on.
//!
//! Each fixture is a complete [`ExperimentConfig`] (the check seed is
//! substituted at run time, so `--seed` replays a failure exactly). The
//! quick tier runs the small fixtures through sim vs live(channel) plus
//! one live(channel) vs live(tcp) wire leg; `--full` adds the medium
//! fixture and a wire leg per fixture.
//!
//! Live legs run at microsecond time scale with a pinned grace window, so
//! every simulated delay sleeps out in nanoseconds and the gather loop
//! exits the moment the last reply lands — a full fixture is milliseconds
//! of wall clock, not simulated-seconds of it.

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::config::{ExperimentConfig, Participation, ShardingKind};
use crate::coordinator::{LiveCoordinator, SimCoordinator};
use crate::transport::{run_device, TcpTransport};

use super::{diff, CheckDef, Outcome, DEFAULT_SEED};

/// Wall-seconds per simulated second for conformance live legs.
const TIME_SCALE: f64 = 1e-6;
/// Pinned per-epoch grace: large against host jitter at this scale, so
/// the live gather collects every reply deterministically.
const GRACE: Duration = Duration::from_millis(250);
/// Device connect / fleet accept timeout for the TCP legs.
const TCP_TIMEOUT: Duration = Duration::from_secs(5);

/// One conformance fixture.
pub struct Fixture {
    pub id: &'static str,
    /// Runs only under `cfl conformance --full`.
    pub full_only: bool,
    pub cfg: ExperimentConfig,
}

/// The committed fixture corpus. Axes covered: fleet size (4/6/8),
/// redundancy (optimized δ vs pinned δ=0.25), MEC heterogeneity
/// (ν ∈ {0, 0.2, 0.3}), data sharding (equal vs power-law), stop rule
/// (fixed epoch budget vs target-NMSE early stop), model size (16/24),
/// per-epoch participation (all vs sampled count:3).
pub fn fixtures() -> Vec<Fixture> {
    let small = |nu: f64| {
        let mut cfg = ExperimentConfig::small();
        cfg.n_devices = 4;
        cfg.points_per_device = 40;
        cfg.model_dim = 16;
        cfg.max_epochs = 60;
        cfg.target_nmse = 0.0;
        cfg.nu_comp = nu;
        cfg.nu_link = nu;
        cfg
    };

    let base_homog = small(0.0);
    let hetero_mid = small(0.3);
    let mut fleet6_delta25 = small(0.2);
    fleet6_delta25.n_devices = 6;
    fleet6_delta25.delta = Some(0.25);
    let mut early_stop = small(0.2);
    early_stop.target_nmse = 0.85;
    early_stop.max_epochs = 300;
    let mut powerlaw_shards = small(0.2);
    powerlaw_shards.sharding = ShardingKind::PowerLaw(1.2);
    let mut medium_fleet8 = small(0.2);
    medium_fleet8.n_devices = 8;
    medium_fleet8.model_dim = 24;
    medium_fleet8.max_epochs = 80;
    // per-epoch sampled participation (count:3 of 6): both backends must
    // sample the same sets from the run RNG and apply the same n/k
    // gradient upscale, so the coded runs stay comparable under the
    // usual sim-vs-live tolerances (appended last: fixture seeds are
    // index-derived and earlier fixtures must keep theirs)
    let mut sampled_part = small(0.2);
    sampled_part.n_devices = 6;
    sampled_part.participation = Participation::Count(3);
    sampled_part.max_epochs = 80;

    vec![
        Fixture { id: "base_homog", full_only: false, cfg: base_homog },
        Fixture { id: "hetero_mid", full_only: false, cfg: hetero_mid },
        Fixture { id: "fleet6_delta25", full_only: false, cfg: fleet6_delta25 },
        Fixture { id: "early_stop", full_only: false, cfg: early_stop },
        Fixture { id: "powerlaw_shards", full_only: false, cfg: powerlaw_shards },
        Fixture { id: "medium_fleet8", full_only: true, cfg: medium_fleet8 },
        Fixture { id: "sampled_part", full_only: false, cfg: sampled_part },
    ]
}

/// Sim vs live(channel), coded and uncoded, through the declared
/// tolerances.
fn run_fixture(mut cfg: ExperimentConfig, seed: u64) -> Result<Outcome> {
    cfg.seed = seed;
    let mut sim = SimCoordinator::new(&cfg)?;
    let sim_cfl = sim.train_cfl()?;
    let sim_unc = sim.train_uncoded()?;
    let mut live = LiveCoordinator::new(&cfg, TIME_SCALE)?;
    live.grace = Some(GRACE);
    let live_cfl = live.train_cfl()?;
    let live_unc = live.train_uncoded()?;
    Ok(diff::sim_vs_live(&sim_cfl, &live_cfl, &sim_unc, &live_unc, cfg.target_nmse, &diff::Tol::default()))
}

fn loopback() -> Option<TcpListener> {
    match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => Some(l),
        Err(e) => {
            crate::obs_event!(Warn, "conformance_bind_denied", error = e.to_string());
            None
        }
    }
}

/// live(channel) vs live(tcp), coded, same config and seed.
fn run_wire(mut cfg: ExperimentConfig, seed: u64) -> Result<Outcome> {
    cfg.seed = seed;
    let mut chan = LiveCoordinator::new(&cfg, TIME_SCALE)?;
    chan.grace = Some(GRACE);
    let chan_cfl = chan.train_cfl()?;
    drop(chan);

    let Some(listener) = loopback() else {
        return Ok(Outcome::skip("loopback TCP bind denied in this sandbox"));
    };
    let addr = listener.local_addr()?.to_string();
    let n = cfg.n_devices;
    let devices: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.clone();
            thread::spawn(move || run_device(&addr, id, TCP_TIMEOUT))
        })
        .collect();
    let transport = TcpTransport::serve(listener, n, TCP_TIMEOUT)?;
    let mut tcp = LiveCoordinator::with_transport(&cfg, TIME_SCALE, Box::new(transport))?;
    tcp.grace = Some(GRACE);
    let tcp_cfl = tcp.train_cfl()?;
    // dropping the coordinator broadcasts Shutdown, releasing the devices
    drop(tcp);
    for d in devices {
        match d.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Ok(Outcome::fail(format!("device thread error: {e:#}"))),
            Err(_) => return Ok(Outcome::fail("device thread panicked")),
        }
    }
    Ok(diff::wire(&chan_cfl, &tcp_cfl, &diff::Tol::default()))
}

pub(crate) fn checks(full: bool) -> Vec<CheckDef> {
    let mut out = Vec::new();
    for (i, fx) in fixtures().into_iter().enumerate() {
        if fx.full_only && !full {
            continue;
        }
        let seed = DEFAULT_SEED + i as u64;
        let cfg = fx.cfg.clone();
        out.push(CheckDef {
            kind: "fixture",
            id: format!("fixture__{}", fx.id),
            seed,
            run: Box::new(move |s| match run_fixture(cfg.clone(), s) {
                Ok(o) => o,
                Err(e) => Outcome::fail(format!("fixture run error: {e:#}")),
            }),
        });
        // the wire leg is expensive (real sockets, device threads), so
        // the quick tier exercises it once; --full covers every fixture
        if full || fx.id == "base_homog" {
            let cfg = fx.cfg.clone();
            out.push(CheckDef {
                kind: "fixture",
                id: format!("fixture__{}__wire", fx.id),
                seed,
                run: Box::new(move |s| match run_wire(cfg.clone(), s) {
                    Ok(o) => o,
                    Err(e) => Outcome::fail(format!("wire run error: {e:#}")),
                }),
            });
        }
    }
    out
}
