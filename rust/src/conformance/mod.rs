//! Cross-backend conformance suite: one fixture corpus, three execution
//! paths, declared tolerances.
//!
//! The repo has three ways to run the same experiment — the DES backend
//! ([`SimCoordinator`]), the live threaded cluster over in-process
//! channels, and the live cluster over real TCP framing — plus two
//! training modes (coded CFL and the uncoded baseline). Refactors keep
//! touching all of them at once, and "the unit tests pass" says nothing
//! about whether the *backends still agree with each other*. This module
//! is that check, runnable as `cfl conformance` and, for the quick tier,
//! as ordinary `cargo test` cases:
//!
//! * [`corpus`] — the fixture corpus: small/medium scenario configs
//!   spanning fleet size, redundancy δ, MEC heterogeneity ν, data
//!   sharding, and target-NMSE early stop. Every fixture trains coded and
//!   uncoded through sim and live(channel), and (one fixture per quick
//!   run, all of them under `--full`) live(channel) vs live(tcp).
//! * [`diff`] — the tolerance policy: which quantities must agree
//!   bit-for-bit across backends (policy outputs: δ, t*, setup cost,
//!   parity bits), which agree to float-accumulation tolerance (coded
//!   virtual time axes), and which only loosely (final NMSE, within
//!   decades — the backends drop different stragglers by design).
//! * [`invariants`] — metamorphic properties through [`testing::prop`]:
//!   rerun determinism, scenario-order/parallelism independence, zipped
//!   grids matching the cartesian diagonal, device-relabeling symmetry of
//!   the load optimizer.
//! * [`faults`] — a [`ChannelCtl`] fault-injection matrix killing and
//!   respawning a device at each lifecycle phase (calibration, mid-epoch,
//!   run boundary, back-to-back kill/respawn racing the rejoin Setup),
//!   asserting convergence plus exact `disconnects`/`rejoins`/
//!   `epoch_members` accounting.
//! * [`report`] — rendering plus CSV/JSONL artifact streaming.
//!
//! Every check runs under an explicit seed and a failure prints a
//! one-command replay line (`cfl conformance --only '<id>' --seed <s>`).
//!
//! [`SimCoordinator`]: crate::coordinator::SimCoordinator
//! [`ChannelCtl`]: crate::transport::ChannelCtl
//! [`testing::prop`]: crate::testing::prop

pub mod corpus;
pub mod diff;
pub mod faults;
pub mod invariants;
pub mod report;

#[cfg(test)]
mod tests;

use anyhow::Result;

pub use report::render;

/// Base seed for every check (overridable per run with `--seed`).
pub const DEFAULT_SEED: u64 = 0xC0DE;

/// Verdict of a single conformance check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Pass,
    Fail,
    /// The check could not run in this environment (e.g. the sandbox
    /// denies loopback TCP). Skips never fail a run, but they are
    /// reported so CI coverage gaps stay visible.
    Skip,
}

impl Status {
    pub fn as_str(self) -> &'static str {
        match self {
            Status::Pass => "pass",
            Status::Fail => "FAIL",
            Status::Skip => "skip",
        }
    }
}

/// One executed check, with enough context to reproduce it.
#[derive(Clone, Debug)]
pub struct Check {
    /// Check family: `"fixture"`, `"invariant"`, or `"fault"`.
    pub kind: &'static str,
    /// Stable identifier, e.g. `fixture__base_homog__wire`.
    pub id: String,
    pub status: Status,
    /// The seed the check actually ran under.
    pub seed: u64,
    /// Pass summary or failure diagnostics.
    pub detail: String,
    /// Single-command reproduction line.
    pub replay: String,
    /// Host wall-clock the check took.
    pub wall_s: f64,
}

/// Suite options (the `cfl conformance` flag surface).
#[derive(Clone, Debug, Default)]
pub struct Options {
    /// Run the full tier: medium fixtures, a TCP leg per fixture, the
    /// whole fault matrix, and more property cases.
    pub full: bool,
    /// Run only checks whose id contains this substring.
    pub only: Option<String>,
    /// Override every check's seed (for replaying a reported failure).
    pub seed: Option<u64>,
    /// Stream `conformance.csv` / `conformance.jsonl` into this directory.
    /// (Per-check progress renders from the `conformance_check` Info
    /// events — raise the stderr log level to see them.)
    pub out_dir: Option<String>,
}

/// Result of a suite run.
#[derive(Clone, Debug, Default)]
pub struct ConformanceReport {
    pub checks: Vec<Check>,
}

impl ConformanceReport {
    /// True when no check failed (skips do not fail a run).
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.status != Status::Fail)
    }

    /// `(passed, failed, skipped)` counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut n = (0, 0, 0);
        for c in &self.checks {
            match c.status {
                Status::Pass => n.0 += 1,
                Status::Fail => n.1 += 1,
                Status::Skip => n.2 += 1,
            }
        }
        n
    }

    pub fn failures(&self) -> impl Iterator<Item = &Check> {
        self.checks.iter().filter(|c| c.status == Status::Fail)
    }
}

/// What a check's body reports back; the runner adds identity and replay.
pub struct Outcome {
    pub status: Status,
    pub detail: String,
}

impl Outcome {
    pub fn pass(detail: impl Into<String>) -> Self {
        Self { status: Status::Pass, detail: detail.into() }
    }

    pub fn fail(detail: impl Into<String>) -> Self {
        Self { status: Status::Fail, detail: detail.into() }
    }

    pub fn skip(detail: impl Into<String>) -> Self {
        Self { status: Status::Skip, detail: detail.into() }
    }
}

/// A registered check: identity plus a seeded body.
pub(crate) struct CheckDef {
    pub kind: &'static str,
    pub id: String,
    pub seed: u64,
    pub run: Box<dyn Fn(u64) -> Outcome>,
}

/// The one-command reproduction line reported for failures.
pub fn replay_command(id: &str, seed: u64, full: bool) -> String {
    let tier = if full { " --full" } else { "" };
    format!("cfl conformance --only '{id}' --seed {seed}{tier}")
}

/// Run the suite. Checks execute serially (live fixtures and the fault
/// matrix own the host's wall clock; running them concurrently would
/// distort the very deadlines under test). Artifacts stream per check, so
/// a crashed run still leaves a usable partial report.
pub fn run(opts: &Options) -> Result<ConformanceReport> {
    let mut defs = Vec::new();
    defs.extend(corpus::checks(opts.full));
    defs.extend(invariants::checks(opts.full));
    defs.extend(faults::checks(opts.full));
    if let Some(pat) = &opts.only {
        defs.retain(|d| d.id.contains(pat.as_str()));
        anyhow::ensure!(!defs.is_empty(), "--only '{pat}' matches no conformance check");
    }

    let mut sink = report::ArtifactSink::create(opts.out_dir.as_deref())?;
    let mut checks = Vec::with_capacity(defs.len());
    for def in defs {
        let seed = opts.seed.unwrap_or(def.seed);
        let replay = replay_command(&def.id, seed, opts.full);
        let t0 = std::time::Instant::now();
        let outcome = (def.run)(seed);
        let check = Check {
            kind: def.kind,
            id: def.id,
            status: outcome.status,
            seed,
            detail: outcome.detail,
            replay,
            wall_s: t0.elapsed().as_secs_f64(),
        };
        crate::obs_event!(
            Info,
            "conformance_check",
            check = check.id.as_str(),
            status = check.status.as_str(),
            wall_s = check.wall_s,
        );
        sink.push(&check)?;
        checks.push(check);
    }
    sink.flush()?;
    Ok(ConformanceReport { checks })
}
