use super::*;

fn failures_summary(report: &ConformanceReport) -> String {
    report
        .failures()
        .map(|c| format!("{}: {} (replay: {})", c.id, c.detail, c.replay))
        .collect::<Vec<_>>()
        .join("\n")
}

/// The quick fixture corpus is the `cargo test` face of `cfl
/// conformance`: sim vs live(channel) on every small fixture plus one
/// channel-vs-tcp wire leg.
#[test]
fn quick_fixtures_agree_across_backends() {
    let opts = Options { only: Some("fixture__".into()), ..Options::default() };
    let report = run(&opts).unwrap();
    assert!(report.checks.len() >= 6, "expected the full quick fixture corpus, got {}", report.checks.len());
    assert!(report.passed(), "fixture conformance failed:\n{}", failures_summary(&report));
}

#[test]
fn metamorphic_invariants_hold() {
    let opts = Options { only: Some("invariant__".into()), ..Options::default() };
    let report = run(&opts).unwrap();
    assert_eq!(report.checks.len(), 4);
    assert!(report.passed(), "invariant conformance failed:\n{}", failures_summary(&report));
}

#[test]
fn fault_matrix_quick_cells_account_lifecycle() {
    let opts = Options { only: Some("fault__".into()), ..Options::default() };
    let report = run(&opts).unwrap();
    assert_eq!(report.checks.len(), 2, "quick tier runs the mid-epoch and boundary cells");
    assert!(report.passed(), "fault conformance failed:\n{}", failures_summary(&report));
}

#[test]
fn full_tier_registers_the_whole_matrix() {
    // registration only — the full tier's execution belongs to CI's
    // non-blocking job, not to `cargo test`
    let quick: Vec<String> = corpus::checks(false)
        .iter()
        .chain(&invariants::checks(false))
        .chain(&faults::checks(false))
        .map(|d| d.id.clone())
        .collect();
    let full: Vec<String> = corpus::checks(true)
        .iter()
        .chain(&invariants::checks(true))
        .chain(&faults::checks(true))
        .map(|d| d.id.clone())
        .collect();
    for id in &quick {
        assert!(full.contains(id), "quick check {id} missing from the full tier");
    }
    for id in ["fixture__medium_fleet8", "fixture__early_stop__wire", "fault__calibration", "fault__respawn_race"] {
        assert!(full.iter().any(|f| f == id), "full tier missing {id}");
        assert!(!quick.iter().any(|q| q == id), "{id} should be full-tier only");
    }
}

#[test]
fn replay_line_reproduces_a_check() {
    assert_eq!(
        replay_command("fixture__base_homog", 0xC0DE, false),
        "cfl conformance --only 'fixture__base_homog' --seed 49374"
    );
    assert_eq!(
        replay_command("fault__respawn_race", 7, true),
        "cfl conformance --only 'fault__respawn_race' --seed 7 --full"
    );
}

#[test]
fn unknown_only_filter_is_an_error() {
    let opts = Options { only: Some("no_such_check".into()), ..Options::default() };
    let err = run(&opts).unwrap_err().to_string();
    assert!(err.contains("no_such_check"), "unhelpful error: {err}");
}

#[test]
fn artifacts_stream_one_line_per_check() {
    let dir = std::env::temp_dir().join("cfl_conformance_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = Options {
        only: Some("invariant__zip-cross-diagonal".into()),
        out_dir: Some(dir.to_string_lossy().into_owned()),
        ..Options::default()
    };
    let report = run(&opts).unwrap();
    assert_eq!(report.checks.len(), 1);
    assert!(report.passed(), "{}", failures_summary(&report));

    let csv = std::fs::read_to_string(dir.join("conformance.csv")).unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 2, "header + one check row:\n{csv}");
    assert!(lines[0].starts_with("kind,check,status,"));
    assert!(lines[1].contains("invariant__zip-cross-diagonal"));

    let jsonl = std::fs::read_to_string(dir.join("conformance.jsonl")).unwrap();
    let records: Vec<&str> = jsonl.lines().collect();
    assert_eq!(records.len(), 1);
    assert!(records[0].contains("\"check\": \"invariant__zip-cross-diagonal\""));
    assert!(records[0].contains("\"status\": \"pass\""));
    assert!(records[0].contains("\"replay\": \"cfl conformance --only "));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn failing_checks_render_with_replay_and_fail_the_report() {
    let check = Check {
        kind: "fixture",
        id: "fixture__broken".into(),
        status: Status::Fail,
        seed: 0xBAD,
        detail: "delta: 0.1 vs 0.2 (rel tol 1e-12)\nsecond line".into(),
        replay: replay_command("fixture__broken", 0xBAD, false),
        wall_s: 0.5,
    };
    let report = ConformanceReport { checks: vec![check] };
    assert!(!report.passed());
    assert_eq!(report.counts(), (0, 1, 0));
    let rendered = render(&report);
    assert!(rendered.contains("FAIL"), "{rendered}");
    assert!(rendered.contains("fixture__broken"));
    // multi-line details flatten into the table cell
    assert!(rendered.contains("(rel tol 1e-12) | second line"), "{rendered}");
    assert_eq!(
        report.checks[0].replay,
        "cfl conformance --only 'fixture__broken' --seed 2989"
    );
}
