//! Tolerance policy: what "the backends agree" means, quantity by
//! quantity.
//!
//! The three execution paths are *designed* to diverge in places — the
//! DES backend drops simulated stragglers at the t* deadline while the
//! time-scaled live cluster (microsecond scale + grace window) gathers
//! every reply, so per-epoch NMSE is not comparable point-for-point
//! between sim and live. What must agree, and how tightly, is declared
//! here rather than scattered through assertions:
//!
//! | quantity                          | sim vs live   | chan vs tcp |
//! |-----------------------------------|---------------|-------------|
//! | δ, t*, setup cost, parity bits    | ≤ 1e-12 rel   | (same runs) |
//! | trace length (target = 0)         | equal         | equal       |
//! | coded virtual time axis           | ≤ 1e-9 rel    | exact       |
//! | per-epoch NMSE                    | not compared  | ≤ 1e-3 rel  |
//! | final NMSE                        | ≤ 1.5 decades | ≤ 1e-3 rel  |
//! | on-time gradient count            | not compared  | equal       |
//! | convergence + gain (target > 0)   | ratio ≤ 3×    | (same runs) |
//!
//! Both backends additionally must actually *learn* (final NMSE below
//! [`Tol::learn_threshold`]) so a pair of equally-broken runs cannot
//! agree their way to a pass.

use crate::coordinator::RunResult;

use super::Outcome;

/// Declared agreement tolerances (see the module table).
#[derive(Clone, Copy, Debug)]
pub struct Tol {
    /// Policy quantities both backends derive from the identical
    /// [`Session`](crate::coordinator::Session): δ, t*, setup seconds,
    /// parity upload bits. Bit-equal in practice; the tolerance absorbs
    /// nothing but gives failures a number to report against.
    pub policy_rel: f64,
    /// Coded virtual time axes (sums of the same per-epoch deadline,
    /// accumulated independently per backend).
    pub time_rel: f64,
    /// Per-epoch NMSE between the two live transports, which execute the
    /// same gather semantics over the same delay streams.
    pub nmse_rel: f64,
    /// Final NMSE between sim and live, in log10 decades — the backends
    /// aggregate different straggler sets, so floors differ but must land
    /// in the same regime.
    pub final_decades: f64,
    /// Every compared run must get at least this far below NMSE 1.0.
    pub learn_threshold: f64,
    /// Early-stop fixtures: sim and live coding gains must agree within
    /// this multiplicative ratio.
    pub gain_ratio: f64,
}

impl Default for Tol {
    fn default() -> Self {
        Self {
            policy_rel: 1e-12,
            time_rel: 1e-9,
            nmse_rel: 1e-3,
            final_decades: 1.5,
            learn_threshold: 0.95,
            gain_ratio: 3.0,
        }
    }
}

fn rel_close(a: f64, b: f64, rel: f64) -> bool {
    if a == b {
        return true;
    }
    if !a.is_finite() || !b.is_finite() {
        return false;
    }
    (a - b).abs() <= rel * a.abs().max(b.abs())
}

fn check_rel(errs: &mut Vec<String>, what: &str, a: f64, b: f64, rel: f64) {
    if !rel_close(a, b, rel) {
        errs.push(format!("{what}: {a} vs {b} (rel tol {rel:e})"));
    }
}

fn final_nmse(r: &RunResult) -> f64 {
    r.trace.points.last().map(|p| p.nmse).unwrap_or(f64::INFINITY)
}

/// Compare one leg's (coded or uncoded) final NMSE across backends: both
/// must have learned, and agree within `tol.final_decades`.
fn check_final(errs: &mut Vec<String>, leg: &str, sim: &RunResult, live: &RunResult, tol: &Tol) {
    let (s, l) = (final_nmse(sim), final_nmse(live));
    if !(s < tol.learn_threshold) {
        errs.push(format!("{leg} sim did not learn: final NMSE {s}"));
    }
    if !(l < tol.learn_threshold) {
        errs.push(format!("{leg} live did not learn: final NMSE {l}"));
    }
    let decades = (s.max(1e-300).log10() - l.max(1e-300).log10()).abs();
    if decades > tol.final_decades {
        errs.push(format!(
            "{leg} final NMSE disagrees by {decades:.2} decades: sim {s} vs live {l}"
        ));
    }
}

fn verdict(errs: Vec<String>, ok: String) -> Outcome {
    if errs.is_empty() {
        Outcome::pass(ok)
    } else {
        Outcome::fail(errs.join("; "))
    }
}

/// Sim-backend vs live(channel) agreement for one fixture (coded and
/// uncoded runs of each).
pub fn sim_vs_live(
    sim_cfl: &RunResult,
    live_cfl: &RunResult,
    sim_unc: &RunResult,
    live_unc: &RunResult,
    target_nmse: f64,
    tol: &Tol,
) -> Outcome {
    let mut errs = Vec::new();
    // policy quantities: pure functions of the shared Session
    check_rel(&mut errs, "delta", sim_cfl.delta, live_cfl.delta, tol.policy_rel);
    check_rel(&mut errs, "epoch_deadline", sim_cfl.epoch_deadline, live_cfl.epoch_deadline, tol.policy_rel);
    check_rel(&mut errs, "setup_secs", sim_cfl.setup_secs, live_cfl.setup_secs, tol.policy_rel);
    check_rel(&mut errs, "parity_upload_bits", sim_cfl.parity_upload_bits, live_cfl.parity_upload_bits, tol.policy_rel);

    if target_nmse <= 0.0 {
        // fixed-epoch fixtures: every run goes to the epoch cap, so the
        // trace shapes are comparable even though the NMSE paths are not
        let (ns, nl) = (sim_cfl.trace.points.len(), live_cfl.trace.points.len());
        if ns != nl {
            errs.push(format!("coded trace length: sim {ns} vs live {nl}"));
        } else {
            for (i, (s, l)) in
                sim_cfl.trace.points.iter().zip(&live_cfl.trace.points).enumerate()
            {
                if s.epoch != l.epoch {
                    errs.push(format!("coded epoch index [{i}]: sim {} vs live {}", s.epoch, l.epoch));
                    break;
                }
                if !rel_close(s.time_s, l.time_s, tol.time_rel) {
                    errs.push(format!(
                        "coded time axis [{i}]: sim {} vs live {}",
                        s.time_s, l.time_s
                    ));
                    break;
                }
            }
        }
        let (us, ul) = (sim_unc.trace.points.len(), live_unc.trace.points.len());
        if us != ul {
            errs.push(format!("uncoded trace length: sim {us} vs live {ul}"));
        }
        check_final(&mut errs, "coded", sim_cfl, live_cfl, tol);
        check_final(&mut errs, "uncoded", sim_unc, live_unc, tol);
    } else {
        // early-stop fixtures: all four runs must reach the target, and
        // the backends' coding gains must land in the same regime
        for (name, r) in [
            ("sim coded", sim_cfl),
            ("sim uncoded", sim_unc),
            ("live coded", live_cfl),
            ("live uncoded", live_unc),
        ] {
            if r.converged.is_none() {
                errs.push(format!("{name} never reached target NMSE {target_nmse}"));
            }
        }
        if errs.is_empty() {
            let gain = |cfl: &RunResult, unc: &RunResult| -> Option<f64> {
                let (tc, tu) = (cfl.time_to(target_nmse)?, unc.time_to(target_nmse)?);
                (tc > 0.0).then(|| tu / tc)
            };
            match (gain(sim_cfl, sim_unc), gain(live_cfl, live_unc)) {
                (Some(gs), Some(gl)) if gs > 0.0 && gl > 0.0 => {
                    let ratio = (gs / gl).max(gl / gs);
                    if ratio > tol.gain_ratio {
                        errs.push(format!(
                            "coding gain disagrees {ratio:.2}×: sim {gs:.3} vs live {gl:.3}"
                        ));
                    }
                }
                (gs, gl) => errs.push(format!("gain undefined: sim {gs:?} vs live {gl:?}")),
            }
        }
    }
    verdict(
        errs,
        format!(
            "sim and live agree (final NMSE {:.3e} vs {:.3e})",
            final_nmse(sim_cfl),
            final_nmse(live_cfl)
        ),
    )
}

/// live(channel) vs live(tcp) agreement for one coded run: identical
/// gather semantics over identical delay streams, so the wire may not
/// change the trajectory beyond float noise.
pub fn wire(chan: &RunResult, tcp: &RunResult, tol: &Tol) -> Outcome {
    let mut errs = Vec::new();
    let (nc, nt) = (chan.trace.points.len(), tcp.trace.points.len());
    if nc != nt {
        errs.push(format!("trace length: chan {nc} vs tcp {nt}"));
    } else {
        for (i, (c, t)) in chan.trace.points.iter().zip(&tcp.trace.points).enumerate() {
            if c.epoch != t.epoch || c.time_s != t.time_s {
                errs.push(format!(
                    "virtual time axis [{i}]: chan ({}, {}) vs tcp ({}, {})",
                    c.epoch, c.time_s, t.epoch, t.time_s
                ));
                break;
            }
            if !rel_close(c.nmse, t.nmse, tol.nmse_rel) {
                errs.push(format!("NMSE [{i}]: chan {} vs tcp {}", c.nmse, t.nmse));
                break;
            }
        }
    }
    if chan.on_time_gradients != tcp.on_time_gradients {
        errs.push(format!(
            "on-time gradients: chan {} vs tcp {}",
            chan.on_time_gradients, tcp.on_time_gradients
        ));
    }
    verdict(
        errs,
        format!("chan and tcp traces agree over {nc} points"),
    )
}
