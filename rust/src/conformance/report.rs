//! Conformance reporting: the per-check summary table and the streamed
//! CSV/JSONL artifacts (`conformance.csv` / `conformance.jsonl`).
//!
//! Artifacts flush per check, so an interrupted or crashed suite run
//! still leaves every completed verdict on disk. The JSONL lines carry
//! the full multi-line detail (JSON-escaped); the CSV and the table
//! flatten it to one line.

use std::fs::File;
use std::io::{BufWriter, Write};

use anyhow::{Context, Result};

use crate::metrics::{CsvWriter, Table};
use crate::sweep::json;

use super::{Check, ConformanceReport};

/// Table-cell width for the detail column.
const DETAIL_WIDTH: usize = 72;

fn one_line(s: &str) -> String {
    s.replace('\n', " | ").replace('\r', "")
}

fn clipped(s: &str, width: usize) -> String {
    let flat = one_line(s);
    if flat.chars().count() <= width {
        return flat;
    }
    let head: String = flat.chars().take(width.saturating_sub(1)).collect();
    format!("{head}…")
}

/// Render the per-check verdict table.
pub fn render(report: &ConformanceReport) -> String {
    let mut table = Table::new(&["kind", "check", "status", "seed", "wall_s", "detail"]);
    for c in &report.checks {
        table.row(&[
            c.kind.to_string(),
            c.id.clone(),
            c.status.as_str().to_string(),
            format!("{:#x}", c.seed),
            format!("{:.2}", c.wall_s),
            clipped(&c.detail, DETAIL_WIDTH),
        ]);
    }
    table.render()
}

fn json_line(c: &Check) -> String {
    format!(
        "{{\"kind\": \"{}\", \"check\": \"{}\", \"status\": \"{}\", \"seed\": {}, \
         \"wall_s\": {}, \"detail\": \"{}\", \"replay\": \"{}\"}}",
        json::escape(c.kind),
        json::escape(&c.id),
        c.status.as_str(),
        c.seed,
        json::num(c.wall_s),
        json::escape(&c.detail),
        json::escape(&c.replay),
    )
}

/// Per-check artifact streamer; a no-op when no output directory is set.
pub struct ArtifactSink {
    csv: Option<CsvWriter>,
    jsonl: Option<BufWriter<File>>,
}

impl ArtifactSink {
    pub fn create(out_dir: Option<&str>) -> Result<Self> {
        let Some(dir) = out_dir else {
            return Ok(Self { csv: None, jsonl: None });
        };
        let csv = CsvWriter::create(
            format!("{dir}/conformance.csv"),
            &["kind", "check", "status", "seed", "wall_s", "detail", "replay"],
        )?;
        let jsonl_path = format!("{dir}/conformance.jsonl");
        let file =
            File::create(&jsonl_path).with_context(|| format!("create {jsonl_path}"))?;
        Ok(Self { csv: Some(csv), jsonl: Some(BufWriter::new(file)) })
    }

    pub fn push(&mut self, c: &Check) -> Result<()> {
        if let Some(csv) = &mut self.csv {
            csv.write_row_str(&[
                c.kind,
                &c.id,
                c.status.as_str(),
                &c.seed.to_string(),
                &format!("{:.3}", c.wall_s),
                &one_line(&c.detail),
                &c.replay,
            ])?;
            csv.flush()?;
        }
        if let Some(out) = &mut self.jsonl {
            writeln!(out, "{}", json_line(c))?;
            out.flush()?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(csv) = &mut self.csv {
            csv.flush()?;
        }
        if let Some(out) = &mut self.jsonl {
            out.flush()?;
        }
        Ok(())
    }
}
