//! Fault-injection matrix: kill/respawn a device at each lifecycle
//! phase and assert both convergence and exact lifecycle accounting.
//!
//! All cells run the live coordinator over a [`ChannelTransport`] with a
//! [`ChannelCtl`] handle injecting the faults. The matrix covers the
//! phases a disconnect can land in:
//!
//! | cell                    | kill lands                   | tier  |
//! |-------------------------|------------------------------|-------|
//! | `fault__calibration`    | before the run begins        | full  |
//! | `fault__mid_epoch`      | inside an epoch's gather     | quick |
//! | `fault__epoch_boundary` | between two runs             | quick |
//! | `fault__respawn_race`   | kill→respawn→kill back-to-back (exercises the generation filter's suppressed-death accounting) | full |
//!
//! Every cell asserts: the run still learns, `disconnects`/`rejoins`
//! count the injected faults, `epoch_members` tracks the dip and the
//! recovery, and the members series stays aligned with the trace.
//!
//! [`ChannelTransport`]: crate::transport::ChannelTransport
//! [`ChannelCtl`]: crate::transport::ChannelCtl

use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{LiveCoordinator, RunResult};
use crate::transport::{ChannelCtl, ChannelTransport};

use super::{CheckDef, Outcome, DEFAULT_SEED};

/// Homogeneous fleet so any slot's death measurably shrinks the gather
/// set, and target 0 so runs go the full epoch budget.
fn fault_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.n_devices = 4;
    cfg.points_per_device = 40;
    cfg.model_dim = 16;
    cfg.target_nmse = 0.0;
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    cfg.seed = seed;
    cfg
}

fn chan_live(cfg: &ExperimentConfig, scale: f64) -> Result<(LiveCoordinator, ChannelCtl)> {
    let chan = ChannelTransport::new(cfg.n_devices);
    let ctl = chan.controller();
    let mut live = LiveCoordinator::with_transport(cfg, scale, Box::new(chan))?;
    live.grace = Some(Duration::from_millis(250));
    Ok((live, ctl))
}

/// The shared post-run assertions (`expect_dip`: whether the fault must
/// have visibly shrunk at least one epoch's broadcast set).
fn accounting(errs: &mut Vec<String>, r: &RunResult, n: usize, expect_dip: bool) {
    if r.disconnects < 1 {
        errs.push(format!("disconnects {} < 1: the kill went unobserved", r.disconnects));
    }
    if r.rejoins < 1 {
        errs.push(format!("rejoins {} < 1: the respawn went unobserved", r.rejoins));
    }
    match r.epoch_members.last() {
        Some(&last) if last == n => {}
        other => errs.push(format!("final members {other:?} != fleet size {n}: no recovery")),
    }
    if expect_dip && !r.epoch_members.iter().any(|&m| m < n) {
        errs.push("members never dipped below fleet size: the kill missed the run".to_string());
    }
    if r.epoch_members.len() != r.trace.points.len() {
        errs.push(format!(
            "members series length {} != trace length {}",
            r.epoch_members.len(),
            r.trace.points.len()
        ));
    }
    let fin = r.trace.points.last().map(|p| p.nmse).unwrap_or(f64::INFINITY);
    if !(fin < 0.95) {
        errs.push(format!("did not learn through the fault: final NMSE {fin}"));
    }
}

fn verdict(errs: Vec<String>, r: &RunResult) -> Outcome {
    if errs.is_empty() {
        Outcome::pass(format!(
            "converged through the fault (disconnects {}, rejoins {}, final NMSE {:.3e})",
            r.disconnects,
            r.rejoins,
            r.trace.points.last().map(|p| p.nmse).unwrap_or(f64::NAN)
        ))
    } else {
        Outcome::fail(errs.join("; "))
    }
}

/// Kill queued before the run starts: the death surfaces during setup
/// delivery / calibration; the respawn lands mid-run and is re-admitted
/// at an epoch boundary. (The dip is not asserted — a rejoin processed
/// during calibration restores the fleet before the first broadcast.)
fn fault_calibration(seed: u64) -> Result<Outcome> {
    let mut cfg = fault_cfg(seed);
    cfg.max_epochs = 200;
    let (mut live, ctl) = chan_live(&cfg, 0.2)?;
    ctl.kill(2);
    let churn = thread::spawn(move || {
        thread::sleep(Duration::from_millis(50));
        ctl.respawn(2);
    });
    let r = live.train_cfl()?;
    churn.join().ok();
    let mut errs = Vec::new();
    accounting(&mut errs, &r, cfg.n_devices, false);
    Ok(verdict(errs, &r))
}

/// Kill inside an epoch's gather window, respawn 100 ms later.
fn fault_mid_epoch(seed: u64) -> Result<Outcome> {
    let mut cfg = fault_cfg(seed);
    cfg.max_epochs = 200;
    let (mut live, ctl) = chan_live(&cfg, 0.2)?;
    let churn = thread::spawn(move || {
        thread::sleep(Duration::from_millis(60));
        ctl.kill(2);
        thread::sleep(Duration::from_millis(100));
        ctl.respawn(2);
    });
    let r = live.train_cfl()?;
    churn.join().ok();
    let mut errs = Vec::new();
    accounting(&mut errs, &r, cfg.n_devices, true);
    Ok(verdict(errs, &r))
}

/// Kill between two runs of the same coordinator: run 1 ends short one
/// member, the respawn is admitted by run 2's setup delivery, and run 2
/// gathers the full fleet every epoch.
fn fault_epoch_boundary(seed: u64) -> Result<Outcome> {
    let mut cfg = fault_cfg(seed);
    cfg.max_epochs = 6;
    let (mut live, ctl) = chan_live(&cfg, 1e-6)?;
    let n = cfg.n_devices;
    ctl.kill(1);
    let r1 = live.train_uncoded()?;
    ctl.respawn(1);
    let r2 = live.train_uncoded()?;
    let mut errs = Vec::new();
    if r1.disconnects < 1 {
        errs.push(format!("run 1 disconnects {} < 1", r1.disconnects));
    }
    match r1.epoch_members.last() {
        Some(&last) if last == n - 1 => {}
        other => errs.push(format!("run 1 final members {other:?} != {}", n - 1)),
    }
    if r2.rejoins != 1 {
        errs.push(format!("run 2 rejoins {} != 1", r2.rejoins));
    }
    if r2.on_time_gradients != (n * cfg.max_epochs) as u64 {
        errs.push(format!(
            "run 2 on-time gradients {} != {}: the rejoined device missed epochs",
            r2.on_time_gradients,
            n * cfg.max_epochs
        ));
    }
    match r2.epoch_members.last() {
        Some(&last) if last == n => {}
        other => errs.push(format!("run 2 final members {other:?} != fleet size {n}")),
    }
    let fin = r2.trace.points.last().map(|p| p.nmse).unwrap_or(f64::INFINITY);
    if errs.is_empty() {
        Ok(Outcome::pass(format!(
            "boundary kill/rejoin accounted exactly (run 2 final NMSE {fin:.3e})"
        )))
    } else {
        Ok(Outcome::fail(errs.join("; ")))
    }
}

/// Kill, respawn 5 ms later, kill again, then respawn for good: the
/// second kill can race the rejoin Setup, and the generation filter may
/// suppress the old incarnation's death notice — the coordinator must
/// account the implicit disconnect and still recover.
fn fault_respawn_race(seed: u64) -> Result<Outcome> {
    let mut cfg = fault_cfg(seed);
    cfg.max_epochs = 200;
    let (mut live, ctl) = chan_live(&cfg, 0.2)?;
    let churn = thread::spawn(move || {
        thread::sleep(Duration::from_millis(60));
        ctl.kill(1);
        thread::sleep(Duration::from_millis(5));
        ctl.respawn(1);
        thread::sleep(Duration::from_millis(5));
        ctl.kill(1);
        thread::sleep(Duration::from_millis(100));
        ctl.respawn(1);
    });
    let r = live.train_cfl()?;
    churn.join().ok();
    let mut errs = Vec::new();
    accounting(&mut errs, &r, cfg.n_devices, true);
    Ok(verdict(errs, &r))
}

pub(crate) fn checks(full: bool) -> Vec<CheckDef> {
    let def = |id: &'static str, full_only: bool, f: fn(u64) -> Result<Outcome>| {
        (!full_only || full).then(|| CheckDef {
            kind: "fault",
            id: id.to_string(),
            seed: DEFAULT_SEED,
            run: Box::new(move |seed| match f(seed) {
                Ok(o) => o,
                Err(e) => Outcome::fail(format!("fault cell error: {e:#}")),
            }),
        })
    };
    [
        def("fault__mid_epoch", false, fault_mid_epoch),
        def("fault__epoch_boundary", false, fault_epoch_boundary),
        def("fault__calibration", true, fault_calibration),
        def("fault__respawn_race", true, fault_respawn_race),
    ]
    .into_iter()
    .flatten()
    .collect()
}
