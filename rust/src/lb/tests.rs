use super::*;
use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::simnet::Fleet;
use crate::testing::prop::{self, assert_that};

fn paper_fleet(seed: u64) -> Fleet {
    let cfg = ExperimentConfig::paper();
    Fleet::from_config(&cfg, &mut Rng::new(seed))
}

#[test]
fn optimal_load_matches_brute_force() {
    let fleet = paper_fleet(1);
    for dev in fleet.devices.iter().step_by(6) {
        let t = 0.8 * dev.mean_total_delay(dev.points);
        let (l, r) = optimal_load(dev, t, dev.points);
        // brute force without the early-exit shortcut
        let mut best = (0usize, 0.0f64);
        for cand in 1..=dev.points {
            let ret = dev.expected_return(cand, t);
            if ret > best.1 {
                best = (cand, ret);
            }
        }
        assert_eq!(l, best.0);
        assert!((r - best.1).abs() < 1e-12);
    }
}

#[test]
fn optimal_load_zero_when_deadline_unreachable() {
    let fleet = paper_fleet(2);
    let dev = &fleet.devices[0];
    // deadline below the minimum possible round trip: nothing can return
    let (l, r) = optimal_load(dev, 1e-9, dev.points);
    assert_eq!(l, 0);
    assert_eq!(r, 0.0);
}

#[test]
fn optimize_reaches_m_within_tolerance() {
    let fleet = paper_fleet(3);
    let m = fleet.total_points() as f64;
    let c_up = (0.3 * m) as usize;
    let policy = optimize(&fleet, c_up, 1.0).unwrap();
    assert!(
        policy.expected_return >= m && policy.expected_return <= m + 25.0,
        "E[R] = {} not ≈ m = {m}",
        policy.expected_return
    );
    assert!(policy.epoch_deadline.is_finite() && policy.epoch_deadline > 0.0);
    assert!(policy.parity_rows > 0, "heterogeneous fleet should want parity");
    assert!(policy.parity_rows <= c_up);
    assert!((policy.delta - policy.parity_rows as f64 / m).abs() < 1e-12);
}

#[test]
fn optimize_loads_respect_local_data() {
    let fleet = paper_fleet(4);
    let policy = optimize(&fleet, 2000, 1.0).unwrap();
    for (load, dev) in policy.device_loads.iter().zip(&fleet.devices) {
        assert!(*load <= dev.points, "load {load} exceeds shard {}", dev.points);
    }
    assert_eq!(policy.miss_probs.len(), fleet.n_devices());
    for p in &policy.miss_probs {
        assert!((0.0..=1.0).contains(p));
    }
}

#[test]
fn optimize_fixed_c_hits_requested_delta() {
    let fleet = paper_fleet(5);
    let m = fleet.total_points();
    let c = (0.13 * m as f64) as usize;
    let policy = optimize_fixed_c(&fleet, c, 1.0).unwrap();
    assert_eq!(policy.parity_rows, c);
    assert!((policy.delta - 0.13).abs() < 0.001);
    assert!(policy.expected_return >= m as f64);
}

#[test]
fn fixed_c_zero_errors_out() {
    // δ = 0 cannot reach E[R] = m at finite t — the optimizer must say so
    // (the caller should use LoadPolicy::uncoded instead).
    let fleet = paper_fleet(6);
    assert!(optimize_fixed_c(&fleet, 0, 1.0).is_err());
}

#[test]
fn uncoded_policy_is_full_load_no_deadline() {
    let fleet = paper_fleet(7);
    let p = LoadPolicy::uncoded(&fleet);
    assert_eq!(p.parity_rows, 0);
    assert_eq!(p.delta, 0.0);
    assert!(p.epoch_deadline.is_infinite());
    assert_eq!(p.device_loads, vec![300; 24]);
}

#[test]
fn deadline_decreases_with_more_redundancy_allowed() {
    // more parity capacity ⇒ the master substitutes for more stragglers ⇒
    // the deadline needed to gather an expected m returns shrinks
    let fleet = paper_fleet(8);
    let m = fleet.total_points() as f64;
    let t_small = optimize_fixed_c(&fleet, (0.05 * m) as usize, 1.0).unwrap().epoch_deadline;
    let t_large = optimize_fixed_c(&fleet, (0.25 * m) as usize, 1.0).unwrap().epoch_deadline;
    assert!(
        t_large < t_small,
        "t*(δ=0.25) = {t_large} should be < t*(δ=0.05) = {t_small}"
    );
}

#[test]
fn homogeneous_fleet_needs_little_parity() {
    // Fig. 4 at (0,0): coding gain ≈ 1 — the optimizer should want little
    // redundancy relative to the heterogeneous case
    let mut cfg = ExperimentConfig::paper();
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let homo = Fleet::from_config(&cfg, &mut Rng::new(9));
    let hetero = paper_fleet(9);
    let c_up = (0.3 * homo.total_points() as f64) as usize;
    let p_homo = optimize(&homo, c_up, 1.0).unwrap();
    let p_hetero = optimize(&hetero, c_up, 1.0).unwrap();
    assert!(
        p_homo.delta <= p_hetero.delta + 1e-9,
        "homogeneous δ = {} should not exceed heterogeneous δ = {}",
        p_homo.delta,
        p_hetero.delta
    );
}

#[test]
fn tiered_fleet_policy_matches_per_device_scan() {
    // profile-class memoization must be invisible: on a fleet with many
    // duplicate profiles, every device's load is exactly the answer the
    // direct per-device scan gives at t*
    let mut cfg = ExperimentConfig::paper();
    cfg.n_devices = 48;
    cfg.ladder_tiers = 8;
    let fleet = Fleet::from_config(&cfg, &mut Rng::new(11));
    let m = fleet.total_points() as f64;
    let policy = optimize(&fleet, (0.3 * m) as usize, 1.0).unwrap();
    for (dev, &l) in fleet.devices.iter().zip(&policy.device_loads) {
        let (want, _) = optimal_load(dev, policy.epoch_deadline, dev.points);
        assert_eq!(l, want);
    }
    // identical profiles ⇒ identical loads
    for (i, a) in fleet.devices.iter().enumerate() {
        for (j, b) in fleet.devices.iter().enumerate().skip(i + 1) {
            if a == b {
                assert_eq!(policy.device_loads[i], policy.device_loads[j]);
            }
        }
    }
}

#[test]
fn prop_optimizer_invariants() {
    prop::check("optimizer invariants", prop::cfg_cases(12), |g| {
        let mut cfg = ExperimentConfig::paper();
        cfg.n_devices = g.size_in(2, 12);
        cfg.points_per_device = g.size_in(20, 120);
        cfg.nu_comp = g.f64_in(0.0, 0.5);
        cfg.nu_link = g.f64_in(0.0, 0.5);
        let mut rng = g.rng();
        let fleet = Fleet::from_config(&cfg, &mut rng);
        let m = fleet.total_points() as f64;
        let c_up = (0.4 * m).ceil() as usize;
        let policy = optimize(&fleet, c_up, 1.0)
            .map_err(|e| format!("optimize failed: {e}"))?;
        assert_that(policy.expected_return >= m - 1e-6, "aggregate must reach m")?;
        assert_that(policy.parity_rows <= c_up, "c within cap")?;
        assert_that(
            policy.device_loads.iter().zip(&fleet.devices).all(|(&l, d)| l <= d.points),
            "loads within shards",
        )?;
        assert_that(policy.epoch_deadline > 0.0, "positive deadline")?;
        // miss probabilities consistent with the returned loads/deadline
        for (i, (&l, dev)) in policy.device_loads.iter().zip(&fleet.devices).enumerate() {
            let want = dev.prob_miss(l, policy.epoch_deadline);
            let got = policy.miss_probs[i];
            assert_that((want - got).abs() < 1e-9, format!("miss prob mismatch dev {i}"))?;
        }
        Ok(())
    });
}
