//! Load balancing & coding-redundancy optimization (§III-B, Eqs. 13–16).
//!
//! The two-step optimization adapted from Reisizadeh et al. [6]:
//!
//! 1. For a candidate epoch deadline `t`, each device's optimal systematic
//!    load is `ℓᵢ*(t) = argmax_{0≤ℓ̃≤ℓᵢ} E[R(t; ℓ̃)]` (Eq. 14) where
//!    `E[R] = ℓ̃ · P{T(ℓ̃) ≤ t}` — concave-shaped with an interior max
//!    (Fig. 1). The master's parity load is maximized the same way up to
//!    the cap `c^up` (Eq. 15).
//! 2. The epoch deadline is the smallest `t` whose expected aggregate
//!    return reaches the total data count: `m ≤ E[R(t; ℓ*(t))] ≤ m + ε`
//!    (Eq. 16). Since every `E[Rᵢ(t; ℓᵢ*(t))]` is nondecreasing in `t`,
//!    the aggregate is monotone and bisection converges.
//!
//! The coding redundancy is then `c = ℓ*_{n+1}(t*)` and `δ = c/m`.
//! [`optimize_fixed_c`] solves the Fig. 2/5 variant where δ (hence c) is
//! pinned and only `t*` and the device loads are optimized.

mod optimizer;

pub use optimizer::{optimal_load, optimize, optimize_fixed_c, LoadPolicy};

#[cfg(test)]
mod tests;
