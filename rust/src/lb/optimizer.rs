//! The Eq. 13–16 optimizer.

use crate::simnet::{DeviceProfile, Fleet};
use anyhow::Result;

/// Output of the load/redundancy optimization: everything the coordinator
/// needs to configure an epoch.
#[derive(Clone, Debug)]
pub struct LoadPolicy {
    /// ℓᵢ*(t*) — systematic points each device processes per epoch.
    pub device_loads: Vec<usize>,
    /// c = ℓ*_{n+1}(t*) — parity rows each device uploads once and the
    /// master processes per epoch.
    pub parity_rows: usize,
    /// t* — the master's per-epoch deadline (seconds).
    pub epoch_deadline: f64,
    /// Redundancy metric δ = c/m (§IV).
    pub delta: f64,
    /// E[R(t*; ℓ*)] — expected aggregate return at the chosen point.
    pub expected_return: f64,
    /// Per-device P{Tᵢ ≥ t*} at the assigned loads (Eq. 17 weights²,
    /// cached here because both the weight matrices and the analysis
    /// benches need them).
    pub miss_probs: Vec<f64>,
}

impl LoadPolicy {
    /// The uncoded-FL policy (δ = 0): every device processes its full
    /// shard, no deadline (the master waits for all m partial gradients).
    pub fn uncoded(fleet: &Fleet) -> Self {
        Self {
            device_loads: fleet.devices.iter().map(|p| p.points).collect(),
            parity_rows: 0,
            epoch_deadline: f64::INFINITY,
            delta: 0.0,
            expected_return: fleet.total_points() as f64,
            miss_probs: vec![0.0; fleet.n_devices()],
        }
    }
}

/// Eq. 14/15: maximize `ℓ̃ · P{T(ℓ̃) ≤ t}` over `ℓ̃ ∈ [0, cap]`.
///
/// Exhaustive scan: the expected-return curve is unimodal in practice
/// (Fig. 1) but cheap enough (cap ≤ a few thousand, CDF is closed-form)
/// that assuming unimodality buys nothing and risks missing the true max
/// on the stepped boundary where `kmax` changes.
pub fn optimal_load(profile: &DeviceProfile, t: f64, cap: usize) -> (usize, f64) {
    let mut best = (0usize, 0.0f64);
    for l in 1..=cap {
        let r = profile.expected_return(l, t);
        if r > best.1 {
            best = (l, r);
        }
        // early exit: once the deterministic compute time alone exceeds t,
        // every larger load returns 0
        if (l as f64) * profile.compute.secs_per_point > t {
            break;
        }
    }
    best
}

/// Devices deduplicated into *profile classes* — exact-bit equality on
/// every delay-model parameter plus the shard size. `optimal_load` is a
/// pure function of (profile, t, cap), so devices in the same class get
/// the same answer and the inner scan only needs to run once per class
/// per bisection step. On a tiered million-device fleet
/// (`ladder_tiers = 24` ⇒ ≤ 24² link×compute combinations) this turns
/// each bisection evaluation from O(n · points) CDF work into
/// O(classes · points) + an O(n) table walk.
struct ProfileClasses<'a> {
    /// `class_of[i]` — class id of device i.
    class_of: Vec<usize>,
    /// One representative profile per class, in first-seen order.
    profiles: Vec<&'a DeviceProfile>,
}

impl<'a> ProfileClasses<'a> {
    fn build(fleet: &'a Fleet) -> Self {
        let mut map = std::collections::HashMap::new();
        let mut class_of = Vec::with_capacity(fleet.n_devices());
        let mut profiles: Vec<&DeviceProfile> = Vec::new();
        for dev in &fleet.devices {
            let key = (
                dev.compute.secs_per_point.to_bits(),
                dev.compute.mem_rate.to_bits(),
                dev.link.secs_per_packet.to_bits(),
                dev.link.erasure_prob.to_bits(),
                dev.points,
            );
            let next_id = profiles.len();
            let id = *map.entry(key).or_insert_with(|| {
                profiles.push(dev);
                next_id
            });
            class_of.push(id);
        }
        Self { class_of, profiles }
    }
}

/// Expected aggregate return at deadline `t` with per-step optimal loads
/// (the objective of Eq. 16). Returns (aggregate, device loads, master
/// load). `fixed_c` pins the master's parity load instead of optimizing.
///
/// The per-device loop walks devices in their original order and adds the
/// same `optimal_load` value the direct scan would produce, so the float
/// summation — and therefore every byte of the resulting policy — is
/// identical to the pre-memoization implementation.
fn aggregate_at(
    fleet: &Fleet,
    classes: &ProfileClasses,
    t: f64,
    c_up: usize,
    fixed_c: Option<usize>,
) -> (f64, Vec<usize>, usize) {
    let per_class: Vec<(usize, f64)> =
        classes.profiles.iter().map(|p| optimal_load(p, t, p.points)).collect();
    let mut total = 0.0;
    let mut loads = Vec::with_capacity(fleet.n_devices());
    for &cls in &classes.class_of {
        let (l, r) = per_class[cls];
        loads.push(l);
        total += r;
    }
    let master_load = match fixed_c {
        Some(c) => c,
        None => optimal_load(&fleet.master, t, c_up).0,
    };
    total += fleet.master.expected_return(master_load, t);
    (total, loads, master_load)
}

/// Eq. 16: the full two-step optimization.
///
/// * `c_up` — the master-side parity cap c^up (Eq. 15).
/// * `epsilon` — tolerance on the expected aggregate return, in points.
///
/// Bisection on `t`: the aggregate is nondecreasing in `t` and reaches
/// `m + c_up ≥ m` as `t → ∞`, so a bracket always exists.
pub fn optimize(fleet: &Fleet, c_up: usize, epsilon: f64) -> Result<LoadPolicy> {
    optimize_inner(fleet, c_up, epsilon, None)
}

/// Fig. 2/5 variant: δ (hence c) is pinned; optimize loads and t* only.
pub fn optimize_fixed_c(fleet: &Fleet, c: usize, epsilon: f64) -> Result<LoadPolicy> {
    // δ = 0 can only reach E[R] = m in the t → ∞ limit (every device at
    // full load with certain return) — that is uncoded FL, a different
    // policy (`LoadPolicy::uncoded`), not a degenerate bisection answer.
    anyhow::ensure!(c > 0, "c = 0 is uncoded FL; use LoadPolicy::uncoded");
    optimize_inner(fleet, c, epsilon, Some(c))
}

fn optimize_inner(
    fleet: &Fleet,
    c_up: usize,
    epsilon: f64,
    fixed_c: Option<usize>,
) -> Result<LoadPolicy> {
    let m = fleet.total_points() as f64;
    anyhow::ensure!(m > 0.0, "fleet holds no data");
    anyhow::ensure!(epsilon >= 0.0, "epsilon must be nonnegative");
    let classes = ProfileClasses::build(fleet);

    // bracket: grow t until the aggregate reaches m
    let mut lo = 0.0f64;
    let mut hi = classes
        .profiles
        .iter()
        .map(|p| p.mean_total_delay(p.points))
        .fold(0.0f64, f64::max)
        .max(1e-6);
    let mut hi_agg = aggregate_at(fleet, &classes, hi, c_up, fixed_c).0;
    let mut guard = 0;
    while hi_agg < m {
        hi *= 2.0;
        hi_agg = aggregate_at(fleet, &classes, hi, c_up, fixed_c).0;
        guard += 1;
        anyhow::ensure!(
            guard <= 60,
            "cannot reach aggregate return m={m}: the fleet cannot return all \
             data in finite time (got {hi_agg} at t={hi})"
        );
    }

    // bisect to the smallest t with aggregate ≥ m (within ε or time-res)
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let agg = aggregate_at(fleet, &classes, mid, c_up, fixed_c).0;
        if agg >= m {
            hi = mid;
            hi_agg = agg;
            if agg <= m + epsilon {
                break; // inside the Eq. 16 tolerance band
            }
        } else {
            lo = mid;
        }
        if hi - lo < 1e-9 * hi.max(1.0) {
            break;
        }
    }

    let t_star = hi;
    let (expected_return, device_loads, master_load) =
        aggregate_at(fleet, &classes, t_star, c_up, fixed_c);
    debug_assert!((expected_return - hi_agg).abs() < 1e-6);
    let miss_probs = fleet
        .devices
        .iter()
        .zip(&device_loads)
        .map(|(p, &l)| p.prob_miss(l, t_star))
        .collect();
    Ok(LoadPolicy {
        device_loads,
        parity_rows: master_load,
        epoch_deadline: t_star,
        delta: master_load as f64 / m,
        expected_return,
        miss_probs,
    })
}
