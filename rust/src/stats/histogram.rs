//! Fixed-bin histogram with text rendering (Fig. 3 is a pair of these).

/// Uniform-bin histogram over [lo, hi); samples outside the range land in
/// saturating edge bins so tails are never silently dropped (the uncoded-FL
/// tail beyond the plot edge is exactly what Fig. 3 is about).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let w = (self.hi - self.lo) / n as f64;
            let idx = (((x - self.lo) / w) as usize).min(n - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// (bin center, count) pairs — the plot series.
    pub fn series(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c))
            .collect()
    }

    /// Fraction of samples at or above `x` (empirical tail, Fig. 3's story).
    pub fn tail_fraction(&self, x: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let mut above = self.overflow;
        for (i, &c) in self.bins.iter().enumerate() {
            if self.lo + i as f64 * w >= x {
                above += c;
            }
        }
        above as f64 / self.count as f64
    }

    /// Render as ASCII rows: `[lo, hi)  count  bar` (for bench output).
    pub fn render(&self, max_width: usize) -> String {
        let peak = self.bins.iter().copied().max().unwrap_or(0).max(1);
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat(((c as f64 / peak as f64) * max_width as f64).round() as usize);
            out.push_str(&format!(
                "[{:8.2},{:8.2})  {:6}  {}\n",
                self.lo + i as f64 * w,
                self.lo + (i + 1) as f64 * w,
                c,
                bar
            ));
        }
        if self.overflow > 0 {
            out.push_str(&format!("[{:8.2},     inf)  {:6}  (overflow)\n", self.hi, self.overflow));
        }
        out
    }
}
