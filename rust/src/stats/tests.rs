use super::*;

#[test]
fn summary_known_values() {
    let mut s = Summary::new();
    s.extend(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
    assert_eq!(s.count(), 8);
    assert!((s.mean() - 5.0).abs() < 1e-12);
    assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
    assert_eq!(s.min(), 2.0);
    assert_eq!(s.max(), 9.0);
}

#[test]
fn summary_single_sample() {
    let mut s = Summary::new();
    s.push(3.0);
    assert_eq!(s.mean(), 3.0);
    assert_eq!(s.var(), 0.0);
    assert_eq!(s.std(), 0.0);
}

#[test]
fn summary_stability_large_offset() {
    // Welford must survive a huge common offset
    let mut s = Summary::new();
    for i in 0..1000 {
        s.push(1e12 + (i % 10) as f64);
    }
    assert!((s.mean() - (1e12 + 4.5)).abs() < 1e-3);
    assert!((s.var() - 8.2582582582).abs() < 1e-3, "var={}", s.var());
}

#[test]
fn quantile_interpolates() {
    let xs = [1.0, 2.0, 3.0, 4.0];
    assert_eq!(quantile(&xs, 0.0), 1.0);
    assert_eq!(quantile(&xs, 1.0), 4.0);
    assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
}

#[test]
fn quantile_unsorted_input() {
    let xs = [9.0, 1.0, 5.0];
    assert_eq!(quantile(&xs, 0.5), 5.0);
}

#[test]
#[should_panic(expected = "empty")]
fn quantile_empty_panics() {
    quantile(&[], 0.5);
}

#[test]
fn quantile_is_nan_safe() {
    // a NaN sample (a diagnostic stream carrying 0/0) used to panic the
    // partial_cmp comparator; total_cmp orders it past +inf instead, so
    // the finite quantiles stay meaningful
    let xs = [3.0, f64::NAN, 1.0, 2.0];
    assert_eq!(quantile(&xs, 0.0), 1.0);
    assert!((quantile(&xs, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    assert!(quantile(&xs, 1.0).is_nan(), "the NaN stays visible at the top");
}

#[test]
fn mean_ci95_shrinks_with_n() {
    let a: Vec<f64> = (0..100).map(|i| (i % 7) as f64).collect();
    let b: Vec<f64> = (0..10000).map(|i| (i % 7) as f64).collect();
    let (_, hw_a) = mean_ci95(&a);
    let (_, hw_b) = mean_ci95(&b);
    assert!(hw_b < hw_a / 5.0);
}

#[test]
fn histogram_bin_assignment() {
    let mut h = Histogram::new(0.0, 10.0, 10);
    h.extend(&[0.0, 0.5, 1.0, 9.99, 5.5]);
    assert_eq!(h.bins()[0], 2);
    assert_eq!(h.bins()[1], 1);
    assert_eq!(h.bins()[9], 1);
    assert_eq!(h.bins()[5], 1);
    assert_eq!(h.count(), 5);
}

#[test]
fn histogram_overflow_underflow() {
    let mut h = Histogram::new(0.0, 1.0, 4);
    h.extend(&[-0.1, 0.5, 1.0, 2.0]);
    assert_eq!(h.underflow(), 1);
    assert_eq!(h.overflow(), 2);
    assert_eq!(h.count(), 4);
}

#[test]
fn histogram_tail_fraction() {
    let mut h = Histogram::new(0.0, 10.0, 10);
    for i in 0..10 {
        h.push(i as f64 + 0.5);
    }
    h.push(150.0); // far-tail sample
    assert!((h.tail_fraction(5.0) - 6.0 / 11.0).abs() < 1e-12);
    assert!((h.tail_fraction(10.0) - 1.0 / 11.0).abs() < 1e-12);
}

#[test]
fn histogram_series_centers() {
    let mut h = Histogram::new(0.0, 4.0, 4);
    h.push(1.5);
    let s = h.series();
    assert_eq!(s.len(), 4);
    assert!((s[0].0 - 0.5).abs() < 1e-12);
    assert_eq!(s[1], (1.5, 1));
}

#[test]
fn histogram_render_contains_overflow_row() {
    let mut h = Histogram::new(0.0, 1.0, 2);
    h.extend(&[0.1, 5.0]);
    let text = h.render(10);
    assert!(text.contains("overflow"));
}
