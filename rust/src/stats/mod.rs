//! Descriptive statistics substrate: running moments, histograms,
//! quantiles, empirical CDFs — everything Figs. 1/3/4/5 report.

mod histogram;
mod summary;

pub use histogram::Histogram;
pub use summary::{mean_ci95, quantile, Summary};

#[cfg(test)]
mod tests;
