//! Running summary statistics (Welford) and quantiles.

/// Single-pass running moments + extrema (Welford's algorithm — numerically
/// stable for the long delay streams the simulator produces).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Empirical quantile (linear interpolation between order statistics).
/// `q` in [0, 1]. Sorts a copy — fine for reporting paths. NaN inputs
/// (e.g. a diagnostic stream containing 0/0) are totally ordered to the
/// extremes by [`f64::total_cmp`] instead of panicking the comparator.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Mean with a 95% normal-approximation confidence half-width.
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    let mut s = Summary::new();
    s.extend(xs);
    let hw = if s.count() > 1 { 1.96 * s.std() / (s.count() as f64).sqrt() } else { 0.0 };
    (s.mean(), hw)
}
