//! Convergence traces: (virtual time, epoch, NMSE) series — the raw
//! material of Figs. 2, 4, 5.

use super::CsvWriter;
use anyhow::Result;

/// One point on a convergence curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Virtual wall-clock (simulated seconds since training start,
    /// including any parity-transfer setup delay).
    pub time_s: f64,
    pub epoch: usize,
    pub nmse: f64,
}

/// A labelled convergence curve.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl ConvergenceTrace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, time_s: f64, epoch: usize, nmse: f64) {
        self.points.push(TracePoint { time_s, epoch, nmse });
    }

    /// First simulated time at which the curve reaches `target` NMSE
    /// (the Fig. 4/5 "convergence time"). `None` if never reached.
    pub fn time_to_nmse(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.nmse <= target).map(|p| p.time_s)
    }

    /// Final NMSE value.
    pub fn final_nmse(&self) -> Option<f64> {
        self.points.last().map(|p| p.nmse)
    }

    /// NMSE at (or right after) a given virtual time — for aligned
    /// cross-curve comparisons.
    pub fn nmse_at_time(&self, t: f64) -> Option<f64> {
        self.points.iter().find(|p| p.time_s >= t).map(|p| p.nmse)
    }

    /// Thin the trace to at most `n` points (plot-friendly decimation;
    /// always keeps the first and last point).
    pub fn decimate(&self, n: usize) -> Self {
        assert!(n >= 2);
        if self.points.len() <= n {
            return self.clone();
        }
        let stride = (self.points.len() - 1) as f64 / (n - 1) as f64;
        let mut pts = Vec::with_capacity(n);
        for i in 0..n {
            pts.push(self.points[(i as f64 * stride).round() as usize]);
        }
        Self { label: self.label.clone(), points: pts }
    }

    /// Write `time_s,epoch,nmse` rows to CSV.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(path, &["time_s", "epoch", "nmse"])?;
        for p in &self.points {
            w.write_row(&[p.time_s, p.epoch as f64, p.nmse])?;
        }
        w.flush()
    }
}
