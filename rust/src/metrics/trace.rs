//! Convergence traces: (virtual time, epoch, NMSE) series — the raw
//! material of Figs. 2, 4, 5.

use super::CsvWriter;
use anyhow::Result;

/// One point on a convergence curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Virtual wall-clock (simulated seconds since training start,
    /// including any parity-transfer setup delay).
    pub time_s: f64,
    pub epoch: usize,
    pub nmse: f64,
}

/// A labelled convergence curve.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTrace {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl ConvergenceTrace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, time_s: f64, epoch: usize, nmse: f64) {
        self.points.push(TracePoint { time_s, epoch, nmse });
    }

    /// First simulated time at which the curve reaches `target` NMSE
    /// (the Fig. 4/5 "convergence time"). `None` if never reached.
    pub fn time_to_nmse(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.nmse <= target).map(|p| p.time_s)
    }

    /// Final NMSE value.
    pub fn final_nmse(&self) -> Option<f64> {
        self.points.last().map(|p| p.nmse)
    }

    /// NMSE at (or right after) a given virtual time — for aligned
    /// cross-curve comparisons.
    pub fn nmse_at_time(&self, t: f64) -> Option<f64> {
        self.points.iter().find(|p| p.time_s >= t).map(|p| p.nmse)
    }

    /// Thin the trace to at most `n` points (plot-friendly decimation;
    /// always keeps the first and last point).
    pub fn decimate(&self, n: usize) -> Self {
        assert!(n >= 2);
        if self.points.len() <= n {
            return self.clone();
        }
        let stride = (self.points.len() - 1) as f64 / (n - 1) as f64;
        let mut pts = Vec::with_capacity(n);
        for i in 0..n {
            pts.push(self.points[(i as f64 * stride).round() as usize]);
        }
        Self { label: self.label.clone(), points: pts }
    }

    /// Write `time_s,epoch,nmse` rows to CSV.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(path, &["time_s", "epoch", "nmse"])?;
        for p in &self.points {
            w.write_row(&[p.time_s, p.epoch as f64, p.nmse])?;
        }
        w.flush()
    }
}

/// Online, bounded-memory trace recorder: stride-doubling decimation.
///
/// A million-device run over thousands of epochs cannot afford to keep
/// every `(time, epoch, nmse)` point, but decimating *after* the run
/// (as [`ConvergenceTrace::decimate`] does) still pays the full storage
/// bill. `BoundedTraceLog` decimates *as it records*: points are kept at
/// a power-of-two epoch stride, and whenever the buffer would exceed
/// `2·cap` the stride doubles and every other kept point is dropped —
/// so at most `2·cap + 1` points are resident at any moment, the kept
/// epochs are evenly spaced, and the first point is always retained.
/// The most recent push is tracked separately so the final epoch is
/// always present in [`BoundedTraceLog::finish`]'s output.
///
/// `cap = 0` disables decimation entirely: every push is kept, and the
/// finished trace is byte-identical to pushing straight into a
/// [`ConvergenceTrace`] — the sim backend's default, preserving existing
/// results exactly.
#[derive(Clone, Debug)]
pub struct BoundedTraceLog {
    label: String,
    cap: usize,
    stride: usize,
    /// (push index, point) for kept points, ascending.
    kept: Vec<(usize, TracePoint)>,
    /// Last pushed point, if not already in `kept`.
    tail: Option<(usize, TracePoint)>,
    pushes: usize,
}

impl BoundedTraceLog {
    /// `cap = 0` keeps everything; `cap ≥ 2` bounds residency to
    /// `2·cap + 1` points.
    pub fn new(label: impl Into<String>, cap: usize) -> Self {
        assert!(cap == 0 || cap >= 2, "cap must be 0 (unbounded) or >= 2");
        Self {
            label: label.into(),
            cap,
            stride: 1,
            kept: Vec::new(),
            tail: None,
            pushes: 0,
        }
    }

    pub fn push(&mut self, time_s: f64, epoch: usize, nmse: f64) {
        let p = TracePoint { time_s, epoch, nmse };
        let idx = self.pushes;
        self.pushes += 1;
        if self.cap == 0 {
            self.kept.push((idx, p));
            return;
        }
        if idx % self.stride == 0 {
            self.kept.push((idx, p));
            self.tail = None;
            if self.kept.len() > 2 * self.cap {
                self.stride *= 2;
                self.kept.retain(|(i, _)| i % self.stride == 0);
            }
        } else {
            self.tail = Some((idx, p));
        }
    }

    /// Points currently resident (kept + pending tail).
    pub fn len(&self) -> usize {
        self.kept.len() + usize::from(self.tail.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Last recorded point (always the most recent push).
    pub fn last(&self) -> Option<&TracePoint> {
        match &self.tail {
            Some((_, p)) => Some(p),
            None => self.kept.last().map(|(_, p)| p),
        }
    }

    /// Total pushes seen (≥ the resident count once decimation kicks in).
    pub fn pushes(&self) -> usize {
        self.pushes
    }

    /// Freeze into a [`ConvergenceTrace`]: kept points in push order, plus
    /// the final push if the stride skipped it.
    pub fn finish(self) -> ConvergenceTrace {
        let mut points: Vec<TracePoint> = self.kept.into_iter().map(|(_, p)| p).collect();
        if let Some((_, p)) = self.tail {
            points.push(p);
        }
        ConvergenceTrace { label: self.label, points }
    }
}
