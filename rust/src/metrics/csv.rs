//! Tiny CSV writer (quoting rules for the subset we emit: numbers and
//! simple labels; anything containing a comma/quote/newline is quoted).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Buffered CSV writer.
pub struct CsvWriter {
    out: Box<dyn Write>,
    cols: usize,
}

impl CsvWriter {
    /// Create a file (parent directories are created as needed) and write
    /// the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir -p {dir:?}"))?;
        }
        let file = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        let mut w = Self { out: Box::new(std::io::BufWriter::new(file)), cols: header.len() };
        w.write_row_str(header)?;
        Ok(w)
    }

    /// In-memory writer (tests).
    pub fn in_memory(header: &[&str]) -> (Self, std::rc::Rc<std::cell::RefCell<Vec<u8>>>) {
        let buf = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        struct Shared(std::rc::Rc<std::cell::RefCell<Vec<u8>>>);
        impl Write for Shared {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.borrow_mut().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w = Self { out: Box::new(Shared(buf.clone())), cols: header.len() };
        w.write_row_str(header).expect("in-memory write");
        (w, buf)
    }

    /// CSV field escaping (crate-visible so the sweep resume code can
    /// render an expected header line for comparison without a writer).
    pub(crate) fn escape(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Write a row of string fields.
    pub fn write_row_str(&mut self, fields: &[&str]) -> Result<()> {
        anyhow::ensure!(fields.len() == self.cols, "row has {} fields, header {}", fields.len(), self.cols);
        let line =
            fields.iter().map(|f| Self::escape(f)).collect::<Vec<_>>().join(",");
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Append an already-rendered CSV line verbatim (no re-escaping).
    /// Crate-internal: only the sweep resume merge, which replays rows
    /// recovered from a prior partial CSV byte-for-byte, may bypass the
    /// field-count/escaping guarantees of the public writers.
    pub(crate) fn write_raw_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Write a row of f64 values (full precision).
    pub fn write_row(&mut self, fields: &[f64]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row_str(&refs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}
