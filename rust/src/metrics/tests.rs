use super::*;

#[test]
fn csv_escaping_and_rows() {
    let (mut w, buf) = CsvWriter::in_memory(&["a", "b,with comma", "c"]);
    w.write_row_str(&["1", "he said \"hi\"", "plain"]).unwrap();
    w.write_row(&[1.5, 2.0, -3.25]).unwrap();
    w.flush().unwrap();
    let text = String::from_utf8(buf.borrow().clone()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "a,\"b,with comma\",c");
    assert_eq!(lines[1], "1,\"he said \"\"hi\"\"\",plain");
    assert_eq!(lines[2], "1.5,2,-3.25");
}

#[test]
fn csv_rejects_wrong_width() {
    let (mut w, _) = CsvWriter::in_memory(&["a", "b"]);
    assert!(w.write_row_str(&["only one"]).is_err());
}

#[test]
fn csv_create_writes_file() {
    let dir = std::env::temp_dir().join("cfl_csv_test");
    let path = dir.join("sub/out.csv");
    let mut w = CsvWriter::create(&path, &["x"]).unwrap();
    w.write_row(&[42.0]).unwrap();
    w.flush().unwrap();
    drop(w);
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text, "x\n42\n");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn table_renders_aligned_and_markdown() {
    let mut t = Table::new(&["name", "value"]);
    t.row(&["alpha".into(), "1".into()]);
    t.row_f(&[2.5, 3.25], 2);
    let text = t.render();
    assert!(text.contains("name"));
    assert!(text.lines().count() == 4);
    let md = t.render_markdown();
    assert!(md.starts_with("| name | value |"));
    assert!(md.contains("|---|---|"));
    assert!(md.contains("| alpha | 1 |"));
}

#[test]
#[should_panic(expected = "row width")]
fn table_rejects_wrong_width() {
    Table::new(&["a"]).row(&["x".into(), "y".into()]);
}

#[test]
fn trace_time_to_nmse() {
    let mut tr = ConvergenceTrace::new("test");
    tr.push(0.0, 0, 1.0);
    tr.push(10.0, 1, 0.5);
    tr.push(20.0, 2, 0.01);
    assert_eq!(tr.time_to_nmse(0.5), Some(10.0));
    assert_eq!(tr.time_to_nmse(0.02), Some(20.0));
    assert_eq!(tr.time_to_nmse(1e-9), None);
    assert_eq!(tr.final_nmse(), Some(0.01));
    assert_eq!(tr.nmse_at_time(15.0), Some(0.01));
}

#[test]
fn trace_decimate_keeps_ends() {
    let mut tr = ConvergenceTrace::new("d");
    for i in 0..100 {
        tr.push(i as f64, i, 1.0 / (i + 1) as f64);
    }
    let thin = tr.decimate(10);
    assert_eq!(thin.points.len(), 10);
    assert_eq!(thin.points[0], tr.points[0]);
    assert_eq!(thin.points[9], tr.points[99]);
    // short traces pass through
    assert_eq!(tr.decimate(1000).points.len(), 100);
}

#[test]
fn bounded_log_cap_zero_is_exact() {
    let mut log = BoundedTraceLog::new("exact", 0);
    let mut direct = ConvergenceTrace::new("exact");
    for i in 0..1000 {
        let (t, n) = (i as f64 * 0.5, 1.0 / (i + 1) as f64);
        log.push(t, i, n);
        direct.push(t, i, n);
    }
    assert_eq!(log.finish().points, direct.points);
}

#[test]
fn bounded_log_bounds_residency_and_keeps_ends() {
    let cap = 16;
    let mut log = BoundedTraceLog::new("b", cap);
    for i in 0..10_000 {
        log.push(i as f64, i, 1.0 / (i + 1) as f64);
        assert!(log.len() <= 2 * cap + 1, "resident {} at push {i}", log.len());
        // the latest push is always observable
        assert_eq!(log.last().unwrap().epoch, i);
    }
    assert_eq!(log.pushes(), 10_000);
    let tr = log.finish();
    assert!(tr.points.len() <= 2 * cap + 1);
    assert_eq!(tr.points[0].epoch, 0, "first point retained");
    assert_eq!(tr.points.last().unwrap().epoch, 9_999, "last point retained");
    // kept epochs strictly increasing (push order preserved)
    assert!(tr.points.windows(2).all(|w| w[0].epoch < w[1].epoch));
    // interior points are evenly spaced at the final power-of-two stride
    let strides: Vec<usize> =
        tr.points.windows(2).map(|w| w[1].epoch - w[0].epoch).collect();
    let s = strides[0];
    assert!(s.is_power_of_two());
    assert!(strides[..strides.len() - 1].iter().all(|&x| x == s));
}

#[test]
fn bounded_log_short_run_keeps_everything() {
    let mut log = BoundedTraceLog::new("s", 64);
    for i in 0..50 {
        log.push(i as f64, i, 0.5);
    }
    assert_eq!(log.finish().points.len(), 50);
}

#[test]
fn trace_csv_roundtrip() {
    let dir = std::env::temp_dir().join("cfl_trace_test");
    let path = dir.join("trace.csv");
    let mut tr = ConvergenceTrace::new("t");
    tr.push(1.0, 1, 0.5);
    tr.write_csv(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text, "time_s,epoch,nmse\n1,1,0.5\n");
    std::fs::remove_dir_all(&dir).ok();
}
