//! Aligned text / markdown table rendering for bench output.

/// Simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row of formatted f64s with the given precision.
    pub fn row_f(&mut self, cells: &[f64], prec: usize) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|v| format!("{v:.prec$}")).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>width$}", width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut s = fmt_row(&self.header);
        s.push('\n');
        s.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row));
            s.push('\n');
        }
        s
    }

    /// Render as a GitHub-markdown table (EXPERIMENTS.md format).
    pub fn render_markdown(&self) -> String {
        let mut s = format!("| {} |\n", self.header.join(" | "));
        s.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}
