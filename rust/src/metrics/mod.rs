//! Experiment output: convergence traces, CSV files, markdown tables.
//!
//! Every bench/figure harness writes (a) a human-readable table on stdout
//! and (b) a CSV under `results/` so curves can be re-plotted; the
//! markdown emitters feed EXPERIMENTS.md directly.

mod csv;
mod table;
mod trace;

pub use csv::CsvWriter;
pub use table::Table;
pub use trace::{BoundedTraceLog, ConvergenceTrace, TracePoint};

#[cfg(test)]
mod tests;
