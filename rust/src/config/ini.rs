//! Line-oriented `key = value` config parser with `[section]` support.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed INI document: `section → key → value`. Keys outside any section
/// live in the `""` section. Later duplicates override earlier ones.
#[derive(Clone, Debug, Default)]
pub struct Ini {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Ini {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut sections: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section header", lineno + 1))?;
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            sections
                .entry(current.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { sections })
    }

    /// Load and parse a file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::parse(&text)
    }

    /// Raw string lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("[{section}] {key} = '{s}': {e}")),
        }
    }

    /// Comma-separated list lookup: `key = a, b, c` → `["a", "b", "c"]`.
    /// Empty items are dropped (`a,,b` → `["a", "b"]`); `None` when the
    /// key is absent. Used by the `[sweep]` axis syntax.
    pub fn get_list(&self, section: &str, key: &str) -> Option<Vec<String>> {
        self.get(section, key).map(|s| {
            s.split(',')
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
                .collect()
        })
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// All keys of a section.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }
}
